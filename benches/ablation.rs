//! Ablation bench (DESIGN.md §3.3): what do SGP's ingredients buy?
//!
//!  * full SGP (curvature scaling + blocked sets + safeguard + trust)
//!  * GP (no curvature scaling — the paper's own ablation, Fig. 5b)
//!  * SGP with the descent safeguard off (accept any finite step)
//!  * async SGP (one random block per update — Theorem 2 schedule)
//!
//! Reports iterations-to-1% and final cost on the Connected-ER instance.
//!
//! Run: `cargo bench --bench ablation`

use cecflow::algo::{Gp, Sgp};
use cecflow::coordinator::report::write_csv;
use cecflow::coordinator::{optimize, RunConfig, ScenarioSpec};
use cecflow::model::{compute_flows, Strategy};
use cecflow::sim::run_async;
use cecflow::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let sc = ScenarioSpec::by_name("connected-er").unwrap().build(2026);
    let net = &sc.net;
    let phi0 = Strategy::local_compute_init(net);
    let cfg = RunConfig {
        max_iters: 120,
        tol: 1e-7,
        patience: 5,
    };

    let mut t = Table::new(&["variant", "final T", "iters", "iters-to-1%", "notes"]);
    let mut rows = Vec::new();

    // full SGP
    let mut sgp = Sgp::new();
    let full = optimize(net, &mut sgp, &phi0, &cfg)?;
    t.row(vec![
        "sgp (full)".into(),
        fnum(full.final_cost()),
        full.costs.len().to_string(),
        full.iters_to_1pct.to_string(),
        format!("{} safeguard retries", sgp.retries),
    ]);
    rows.push(vec!["sgp".into(), format!("{}", full.final_cost()), full.iters_to_1pct.to_string()]);

    // GP
    let mut gp = Gp::new(1.0);
    let gp_run = optimize(net, &mut gp, &phi0, &cfg)?;
    t.row(vec![
        "gp (no scaling)".into(),
        fnum(gp_run.final_cost()),
        gp_run.costs.len().to_string(),
        gp_run.iters_to_1pct.to_string(),
        "paper baseline".into(),
    ]);
    rows.push(vec!["gp".into(), format!("{}", gp_run.final_cost()), gp_run.iters_to_1pct.to_string()]);

    // SGP without safeguard
    let mut wild = Sgp::new();
    wild.safeguard = false;
    let wild_run = optimize(net, &mut wild, &phi0, &cfg);
    match wild_run {
        Ok(run) => {
            let mono = run
                .costs
                .windows(2)
                .all(|w| w[1] <= w[0] * (1.0 + 1e-9));
            t.row(vec![
                "sgp (no safeguard)".into(),
                fnum(run.final_cost()),
                run.costs.len().to_string(),
                run.iters_to_1pct.to_string(),
                if mono { "still monotone".into() } else { "NON-MONOTONE".to_string() },
            ]);
            rows.push(vec!["sgp-nosafeguard".into(), format!("{}", run.final_cost()), run.iters_to_1pct.to_string()]);
        }
        Err(err) => {
            t.row(vec![
                "sgp (no safeguard)".into(),
                "diverged".into(),
                "-".into(),
                "-".into(),
                format!("{err}"),
            ]);
            rows.push(vec!["sgp-nosafeguard".into(), "inf".into(), "-".into()]);
        }
    }

    // async SGP (random single-block schedule); measure sweep-equivalents.
    // blocks = nodes x tasks x planes; give each block ~20 expected visits.
    let blocks = net.n() * net.s() * 2;
    let updates = 20 * blocks;
    let trace = run_async(net, &phi0, updates, 7)?;
    let t_async = *trace.costs.last().unwrap();
    let thresh = t_async * 1.01;
    let first = trace
        .costs
        .iter()
        .position(|&c| c <= thresh)
        .map(|p| p + 1)
        .unwrap_or(updates);
    t.row(vec![
        "sgp (async, Thm 2)".into(),
        fnum(t_async),
        format!("{} block-updates", trace.costs.len()),
        format!("{} (~{} sweeps)", first, first / net.n().max(1)),
        "one random block per update".into(),
    ]);
    rows.push(vec!["sgp-async".into(), format!("{t_async}"), first.to_string()]);

    t.print();
    write_csv("ablation.csv", &["variant", "final_cost", "iters_to_1pct"], &rows)?;

    // sanity: all variants that converge land on the same optimum ±1%
    let reference = full.final_cost();
    let t_gp = gp_run.final_cost();
    assert!(
        (t_gp - reference).abs() < 0.01 * reference,
        "GP and SGP fixed points diverge"
    );
    assert!(
        (t_async - reference).abs() < 0.02 * reference,
        "async and sync fixed points diverge: {t_async} vs {reference}"
    );
    let _ = compute_flows(net, &trace.phi)?;
    println!("ablation: all convergent variants agree on the optimum (±1%)");
    Ok(())
}
