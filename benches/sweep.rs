//! Sweep smoke driver: a small `scenario × seed × algorithm × backend`
//! grid on worker threads, printing the aggregated report and writing
//! CSV/JSON under `results/`. CI runs this with `CECFLOW_BENCH_FAST=1`
//! (one scenario, two seeds) as the parallel-sweep smoke test.
//!
//! Shape checks (paper claims, not absolute values):
//!   * SGP's mean final cost is at or below every baseline's in every
//!     scenario group;
//!   * per-cell results are identical when the same grid is re-run on a
//!     different worker count (the determinism contract, also pinned by
//!     `rust/tests/sweep_determinism.rs`);
//!   * per-cell results are identical when the same grid is re-run split
//!     across two child *processes* (`run_sweep_sharded`, the contract of
//!     `rust/tests/sweep_shard.rs`).
//!
//! Run: `cargo bench --bench sweep`   (CECFLOW_BENCH_FAST=1 shrinks the grid)

use std::time::Instant;

use cecflow::coordinator::report::{write_csv, write_json};
use cecflow::coordinator::{
    run_sweep, run_sweep_sharded, Algorithm, CellBackend, PatternSchedule, RunConfig,
    ShardOptions, SweepSpec,
};
use cecflow::util::table::fnum;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("CECFLOW_BENCH_FAST").is_ok();
    let spec = SweepSpec {
        scenarios: if fast {
            vec!["abilene".into()]
        } else {
            vec!["abilene".into(), "connected-er".into(), "balanced-tree".into()]
        },
        seeds: if fast { vec![1, 2] } else { vec![1, 2, 3, 4] },
        algorithms: vec![Algorithm::Sgp, Algorithm::Gp, Algorithm::Lpr],
        // SGP additionally priced through the native dense backend
        // (step_dense + evaluate_batch) so sweeps exercise both planes
        backends: vec![CellBackend::Sparse, CellBackend::Native],
        // static only: the schedule axis has its own driver, benches/dynamic.rs
        schedules: vec![PatternSchedule::static_()],
        rate_scale: 1.0,
        run: RunConfig::quick(),
        sim: None,
        cache: None,
    };
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(4);

    eprintln!(
        "[sweep] {} cells on {workers} workers ...",
        spec.cells().len()
    );
    let start = Instant::now();
    let report = run_sweep(&spec, workers)?;
    let wall = start.elapsed().as_secs_f64();
    println!("{}", report.render());
    println!("sweep wall time: {wall:.2}s on {workers} workers");

    // ---- machine-readable outputs ----
    write_json("sweep.json", &report.to_json())?;
    let rows: Vec<Vec<String>> = report
        .cells
        .iter()
        .map(|c| {
            vec![
                c.cell.scenario.clone(),
                c.cell.seed.to_string(),
                c.cell.algorithm.name().to_string(),
                c.cell.backend.name().to_string(),
                fnum(c.final_cost),
                c.iterations.to_string(),
                c.iters_to_1pct.to_string(),
                format!("{:.3}", c.wall_seconds),
            ]
        })
        .collect();
    write_csv(
        "sweep.csv",
        &[
            "scenario",
            "seed",
            "algorithm",
            "backend",
            "final_cost",
            "iterations",
            "iters_to_1pct",
            "wall_seconds",
        ],
        &rows,
    )?;

    // ---- shape assertions ----
    let mut ok = true;
    let groups = report.groups();
    // Fig. 4 headline on the sparse plane: SGP at or below every baseline.
    for g in &groups {
        if g.algorithm != "sgp" || g.backend != "sparse" {
            continue;
        }
        for other in groups
            .iter()
            .filter(|o| o.scenario == g.scenario && o.backend == "sparse")
        {
            if g.mean_cost > other.mean_cost * 1.001 {
                println!(
                    "SHAPE VIOLATION: {}: sgp mean {} > {} mean {}",
                    g.scenario,
                    fnum(g.mean_cost),
                    other.algorithm,
                    fnum(other.mean_cost)
                );
                ok = false;
            }
        }
    }
    // The dense-routed SGP (Jacobi joint steps) lands in the same
    // neighborhood as the sparse Gauss–Seidel run (xla_parity tolerance).
    for g in &groups {
        if g.algorithm != "sgp" || g.backend != "native" {
            continue;
        }
        if let Some(sparse) = groups
            .iter()
            .find(|o| o.scenario == g.scenario && o.algorithm == "sgp" && o.backend == "sparse")
        {
            if g.mean_cost > sparse.mean_cost * 1.05 {
                println!(
                    "SHAPE VIOLATION: {}: sgp@native mean {} drifted above sgp@sparse mean {}",
                    g.scenario,
                    fnum(g.mean_cost),
                    fnum(sparse.mean_cost)
                );
                ok = false;
            }
        }
    }
    // determinism spot-check across worker counts (serial rerun)
    let rerun = run_sweep(&spec, 1)?;
    if rerun.fingerprint() != report.fingerprint() {
        println!("SHAPE VIOLATION: sweep results differ between 1 and {workers} workers");
        ok = false;
    }
    // determinism spot-check across *process shards*: the same grid split
    // over two cecflow child processes must reassemble bit-identically
    let sharded = run_sweep_sharded(
        &spec,
        std::path::Path::new(env!("CARGO_BIN_EXE_cecflow")),
        &ShardOptions {
            shards: 2,
            workers,
            ..Default::default()
        },
    )?;
    if sharded.fingerprint() != report.fingerprint() {
        println!("SHAPE VIOLATION: sweep results differ between in-process and 2-shard runs");
        ok = false;
    }
    println!("sweep shape: {}", if ok { "OK" } else { "VIOLATIONS (see above)" });
    if !ok {
        std::process::exit(1);
    }
    Ok(())
}
