//! Sweep smoke driver: a small `scenario × seed × algorithm` grid on
//! worker threads, printing the aggregated report and writing
//! CSV/JSON under `results/`. CI runs this with `CECFLOW_BENCH_FAST=1`
//! (one scenario, two seeds) as the parallel-sweep smoke test.
//!
//! Shape checks (paper claims, not absolute values):
//!   * SGP's mean final cost is at or below every baseline's in every
//!     scenario group;
//!   * per-cell results are identical when the same grid is re-run on a
//!     different worker count (the determinism contract, also pinned by
//!     `rust/tests/sweep_determinism.rs`).
//!
//! Run: `cargo bench --bench sweep`   (CECFLOW_BENCH_FAST=1 shrinks the grid)

use std::time::Instant;

use cecflow::coordinator::report::{write_csv, write_json};
use cecflow::coordinator::{run_sweep, Algorithm, RunConfig, SweepSpec};
use cecflow::util::table::fnum;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("CECFLOW_BENCH_FAST").is_ok();
    let spec = SweepSpec {
        scenarios: if fast {
            vec!["abilene".into()]
        } else {
            vec!["abilene".into(), "connected-er".into(), "balanced-tree".into()]
        },
        seeds: if fast { vec![1, 2] } else { vec![1, 2, 3, 4] },
        algorithms: vec![Algorithm::Sgp, Algorithm::Gp, Algorithm::Lpr],
        rate_scale: 1.0,
        run: RunConfig::quick(),
    };
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(4);

    eprintln!(
        "[sweep] {} cells on {workers} workers ...",
        spec.cells().len()
    );
    let start = Instant::now();
    let report = run_sweep(&spec, workers)?;
    let wall = start.elapsed().as_secs_f64();
    println!("{}", report.render());
    println!("sweep wall time: {wall:.2}s on {workers} workers");

    // ---- machine-readable outputs ----
    write_json("sweep.json", &report.to_json())?;
    let rows: Vec<Vec<String>> = report
        .cells
        .iter()
        .map(|c| {
            vec![
                c.cell.scenario.clone(),
                c.cell.seed.to_string(),
                c.cell.algorithm.name().to_string(),
                fnum(c.final_cost),
                c.iterations.to_string(),
                c.iters_to_1pct.to_string(),
                format!("{:.3}", c.wall_seconds),
            ]
        })
        .collect();
    write_csv(
        "sweep.csv",
        &[
            "scenario",
            "seed",
            "algorithm",
            "final_cost",
            "iterations",
            "iters_to_1pct",
            "wall_seconds",
        ],
        &rows,
    )?;

    // ---- shape assertions ----
    let mut ok = true;
    let groups = report.groups();
    for g in &groups {
        if g.algorithm != "sgp" {
            continue;
        }
        for other in groups.iter().filter(|o| o.scenario == g.scenario) {
            if g.mean_cost > other.mean_cost * 1.001 {
                println!(
                    "SHAPE VIOLATION: {}: sgp mean {} > {} mean {}",
                    g.scenario,
                    fnum(g.mean_cost),
                    other.algorithm,
                    fnum(other.mean_cost)
                );
                ok = false;
            }
        }
    }
    // determinism spot-check across worker counts (serial rerun)
    let rerun = run_sweep(&spec, 1)?;
    if rerun.fingerprint() != report.fingerprint() {
        println!("SHAPE VIOLATION: sweep results differ between 1 and {workers} workers");
        ok = false;
    }
    println!("sweep shape: {}", if ok { "OK" } else { "VIOLATIONS (see above)" });
    if !ok {
        std::process::exit(1);
    }
    Ok(())
}
