//! Table II regenerator: instantiate every simulated network scenario,
//! report its realized size against the paper's numbers, and measure the
//! per-iteration cost of the SGP optimizer plus the distributed broadcast
//! footprint (messages / completion time, §IV Complexity).
//!
//! Run: `cargo bench --bench table2`   (CECFLOW_BENCH_FAST=1 skips SW)

use std::time::Instant;

use cecflow::algo::{Optimizer, Sgp};
use cecflow::coordinator::report::write_csv;
use cecflow::coordinator::ScenarioSpec;
use cecflow::model::{compute_flows, Strategy};
use cecflow::sim::run_broadcast;
use cecflow::util::table::Table;
use cecflow::util::timer::fmt_duration;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("CECFLOW_BENCH_FAST").is_ok();
    // paper's Table II (|V|, links, |S|, |R|)
    let paper: &[(&str, usize, usize, usize, usize)] = &[
        ("connected-er", 20, 40, 15, 5),
        ("balanced-tree", 15, 14, 20, 5),
        ("fog", 19, 30, 30, 5),
        ("abilene", 11, 14, 10, 3),
        ("lhc", 16, 31, 30, 5),
        ("geant", 22, 33, 40, 7),
        ("sw", 100, 320, 120, 10),
    ];

    let mut t = Table::new(&[
        "scenario", "|V|", "links", "|S|", "paper(V/E/S)", "iter time", "bcast msgs",
        "bcast time",
    ]);
    let mut rows = Vec::new();

    for &(name, pv, pe, ps, _pr) in paper {
        if fast && name == "sw" {
            continue;
        }
        let spec = ScenarioSpec::by_name(name).unwrap();
        let sc = spec.build(2026);
        let net = &sc.net;

        // one warm iteration + timed iterations
        let mut phi = Strategy::local_compute_init(net);
        let mut sgp = Sgp::new();
        sgp.step(net, &mut phi)?;
        let reps = if name == "sw" { 2 } else { 5 };
        let t0 = Instant::now();
        for _ in 0..reps {
            sgp.step(net, &mut phi)?;
        }
        let iter_time = t0.elapsed().as_secs_f64() / reps as f64;

        // broadcast footprint on the current state
        let flows = compute_flows(net, &phi)?;
        let bc = run_broadcast(net, &phi, &flows, 1.0);

        t.row(vec![
            name.to_string(),
            net.n().to_string(),
            (net.e() / 2).to_string(),
            net.s().to_string(),
            format!("{pv}/{pe}/{ps}"),
            fmt_duration(iter_time),
            bc.messages.to_string(),
            format!("{:.0} t_c", bc.completion_time),
        ]);
        rows.push(vec![
            name.to_string(),
            net.n().to_string(),
            (net.e() / 2).to_string(),
            net.s().to_string(),
            format!("{iter_time}"),
            bc.messages.to_string(),
            format!("{}", bc.completion_time),
        ]);

        // size checks vs the paper (fog's link count documented as 33)
        assert_eq!(net.n(), pv, "{name}: |V|");
        assert_eq!(net.s(), ps, "{name}: |S|");
        let links = net.e() / 2;
        assert!(
            links == pe || name == "fog",
            "{name}: links {links} vs paper {pe}"
        );
        // §IV: message bound 2|S||E| per iteration
        assert!(bc.messages <= 2 * (net.s() * net.e()) as u64);
    }
    t.print();
    write_csv(
        "table2.csv",
        &["scenario", "V", "links", "S", "iter_seconds", "bcast_msgs", "bcast_time"],
        &rows,
    )?;
    println!("table2: sizes match the paper (fog: 33 links vs paper 30 — see DESIGN.md §3.6)");
    Ok(())
}
