//! PR 6 perf driver: the layered request-level simulator.
//!
//! Two planes, matching the engine's layering:
//!
//!  * `sim::core` raw calendar-queue throughput (events/sec under a
//!    hold-1000 schedule/pop churn with pseudo-random forward delays);
//!  * end-to-end `sim::tasks` throughput (requests/sec) releasing 10^5
//!    and 10^6 Poisson requests through the converged SGP strategy on
//!    abilene, with the tail quantiles sanity-checked (p50 ≤ p99 ≤
//!    p99.9) and the peak in-flight count reported — the 10^6 tier is
//!    the bounded-memory witness (slab + sketch, no per-request heap
//!    growth).
//!
//! Emits the machine-readable perf-trajectory record (ROADMAP item 3) as
//! `BENCH_6.json` in the working directory (`CECFLOW_BENCH_OUT`
//! overrides the path). `CECFLOW_BENCH_FAST=1` shrinks both planes for
//! the CI smoke run.
//!
//! Run: `cargo bench --bench sim`

use std::time::Instant;

use cecflow::coordinator::{build_scenario_network, run_algorithm, Algorithm, RunConfig};
use cecflow::sim::core::EventQueue;
use cecflow::sim::{simulate, ArrivalSpec, SimConfig, SimEpoch, SimPlan};
use cecflow::util::json::Json;

fn record(name: &str, per_sec: f64, count: u64, seconds: f64) -> Json {
    let mut o = Json::obj();
    o.set("name", Json::Str(name.to_string()))
        .set("per_sec", Json::Num(per_sec))
        .set("count", Json::Num(count as f64))
        .set("seconds", Json::Num(seconds));
    o
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("CECFLOW_BENCH_FAST").is_ok();
    let mut records: Vec<Json> = Vec::new();

    // ---- plane 1: raw calendar-queue churn ----------------------------
    // Hold ~1000 events in flight and cycle schedule/pop with a cheap
    // xorshift delay draw, so the measurement is queue overhead, not rng.
    let total_events: u64 = if fast { 200_000 } else { 2_000_000 };
    let mut q = EventQueue::new();
    for i in 0..1_000u64 {
        q.schedule(i as f64 * 1e-3, i);
    }
    let mut state = 0x9e37_79b9_7f4a_7c15u64;
    let start = Instant::now();
    let mut processed = 0u64;
    while processed < total_events {
        let ev = q.pop().expect("held events cannot drain");
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        let delay = (state >> 11) as f64 / (1u64 << 53) as f64;
        q.schedule(delay, ev.payload);
        processed += 1;
    }
    let secs = start.elapsed().as_secs_f64();
    let eps = processed as f64 / secs;
    println!("calendar queue: {processed} events in {secs:.3}s = {eps:.0} events/s");
    records.push(record("calendar_queue_events_per_sec", eps, processed, secs));

    // ---- plane 2: end-to-end request-level simulation -----------------
    let net = build_scenario_network("abilene", 1, 1.0)?;
    let out = run_algorithm(&net, Algorithm::Sgp, &RunConfig::quick())?;
    let plan = SimPlan {
        epochs: vec![SimEpoch {
            net,
            phi: out.phi.expect("sgp yields a strategy"),
        }],
    };
    let tiers: &[u64] = if fast {
        &[20_000]
    } else {
        &[100_000, 1_000_000]
    };
    for &requests in tiers {
        let cfg = SimConfig {
            requests,
            warmup: 0.05,
            seed: 1,
            ..SimConfig::default()
        };
        let start = Instant::now();
        let t = simulate(&plan, &ArrivalSpec::default(), &cfg)?;
        let secs = start.elapsed().as_secs_f64();
        let (p50, p99, p999) = t.tail();
        assert!(
            p50 <= p99 && p99 <= p999,
            "quantiles disordered: {p50} {p99} {p999}"
        );
        assert_eq!(t.completed + t.stranded, requests, "requests lost");
        let rps = requests as f64 / secs;
        println!(
            "simulate {requests} requests: {secs:.3}s = {rps:.0} req/s \
             (p50 {p50:.4} p99 {p99:.4} p99.9 {p999:.4}, {} events, peak {} in flight)",
            t.events, t.max_in_flight
        );
        records.push(record(
            &format!("simulate_abilene_{requests}_requests_per_sec"),
            rps,
            requests,
            secs,
        ));
    }

    // ---- trajectory record --------------------------------------------
    let path = std::env::var("CECFLOW_BENCH_OUT").unwrap_or_else(|_| "BENCH_6.json".to_string());
    if let Some(parent) = std::path::Path::new(&path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut doc = Json::obj();
    doc.set("pr", Json::Num(6.0))
        .set("bench", Json::Str("sim".to_string()))
        .set("fast_mode", Json::Bool(fast))
        .set("records", Json::Arr(records));
    std::fs::write(&path, doc.pretty())?;
    println!("wrote {path}");
    Ok(())
}
