//! Fig. 4 regenerator: steady-state total cost of SGP vs SPOO / LCOR / LPR
//! on every Table II scenario (plus the SW-linear and SW-queue variants),
//! normalized per scenario to the worst algorithm — the paper's bar chart
//! in text form.
//!
//! Shape checks (paper claims, not absolute values):
//!   * SGP produces the lowest cost in every scenario;
//!   * the SGP-vs-LPR margin is large on congestible (queue) networks —
//!     the paper reports "as much as 50%";
//!   * LCOR is weakest where routing cannot help (Balanced-tree).
//!
//! Run: `cargo bench --bench fig4`   (CECFLOW_BENCH_FAST=1 skips SW)

use cecflow::coordinator::report::{
    figure_json, render_normalized_bars, write_csv, write_json, Series,
};
use cecflow::coordinator::{run_algorithm, Algorithm, RunConfig, ScenarioSpec};
use cecflow::util::table::fnum;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("CECFLOW_BENCH_FAST").is_ok();
    let seed = 2026;
    let algos = [
        Algorithm::Sgp,
        Algorithm::Spoo,
        Algorithm::Lcor,
        Algorithm::Lpr,
    ];

    let mut specs: Vec<ScenarioSpec> = ScenarioSpec::table2()
        .into_iter()
        .filter(|s| !(fast && s.name == "sw"))
        .collect();
    // Fig. 4 shows SW twice: linear and queue cost families.
    if let Some(sw) = ScenarioSpec::by_name("sw") {
        if !fast {
            specs.pop(); // replace plain "sw" with the two labelled variants
            specs.push(sw.clone().sw_linear());
            let mut swq = sw;
            swq.name = "sw-queue";
            specs.push(swq);
        }
    }

    let cfg = RunConfig {
        max_iters: 60,
        tol: 1e-6,
        patience: 4,
    };

    let mut scenario_names = Vec::new();
    let mut costs: Vec<Vec<f64>> = Vec::new();
    let mut rows = Vec::new();

    for spec in &specs {
        let sc = spec.build(seed);
        eprintln!("[fig4] {} (|V|={} |S|={}) ...", spec.name, sc.net.n(), sc.net.s());
        let mut per_algo = Vec::new();
        for &algo in &algos {
            let out = run_algorithm(&sc.net, algo, &cfg)?;
            rows.push(vec![
                spec.name.to_string(),
                out.algorithm.clone(),
                fnum(out.final_cost),
                out.iterations.to_string(),
                format!("{:.2}", out.wall_seconds),
            ]);
            per_algo.push(out.final_cost);
        }
        scenario_names.push(spec.name.to_string());
        costs.push(per_algo);
    }

    let algo_names: Vec<String> = algos.iter().map(|a| a.name().to_string()).collect();
    println!(
        "{}",
        render_normalized_bars(&scenario_names, &algo_names, &costs)
    );

    // ---- machine-readable outputs ----
    write_csv(
        "fig4.csv",
        &["scenario", "algorithm", "total_cost", "iterations", "seconds"],
        &rows,
    )?;
    let series: Vec<Series> = algos
        .iter()
        .enumerate()
        .map(|(ai, a)| Series {
            label: a.name().to_string(),
            x: (0..costs.len()).map(|i| i as f64).collect(),
            y: costs.iter().map(|c| c[ai]).collect(),
        })
        .collect();
    write_json("fig4.json", &figure_json("fig4-normalized-cost", &series))?;
    cecflow::coordinator::report::write_bars_svg(
        "fig4.svg",
        "Fig. 4 — normalized total cost (lower is better)",
        &scenario_names,
        &algo_names,
        &costs,
    )?;

    // ---- shape assertions (paper claims) ----
    let mut ok = true;
    for (si, name) in scenario_names.iter().enumerate() {
        let sgp = costs[si][0];
        for (ai, aname) in algo_names.iter().enumerate().skip(1) {
            if sgp > costs[si][ai] * 1.001 {
                println!("SHAPE VIOLATION: {name}: sgp {sgp} > {aname} {}", costs[si][ai]);
                ok = false;
            }
        }
    }
    // congested-network margin vs LPR: >= 30% somewhere (paper: up to 50%)
    let best_margin = scenario_names
        .iter()
        .enumerate()
        .map(|(si, _)| {
            let sgp = costs[si][0];
            let lpr = costs[si][3];
            if lpr.is_finite() {
                1.0 - sgp / lpr
            } else {
                1.0 // LPR saturated: unbounded margin
            }
        })
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "max SGP improvement over LPR across scenarios: {:.0}%  (paper: up to ~50%)",
        100.0 * best_margin
    );
    if best_margin < 0.3 {
        println!("SHAPE VIOLATION: expected >= 30% improvement over LPR somewhere");
        ok = false;
    }
    println!("fig4 shape: {}", if ok { "OK" } else { "VIOLATIONS (see above)" });
    Ok(())
}
