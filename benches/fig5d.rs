//! Fig. 5d regenerator: the average data / result travel distances
//! `L_data`, `L_result` of the SGP optimum as the result-size ratio `a_m`
//! sweeps from small to large, on the Connected-ER instance.
//!
//! Shape checks: `L_data` is (weakly) increasing and `L_result` (weakly)
//! decreasing in `a_m` — the paper's "balance" phenomenon: tasks with big
//! results are computed nearer the destination.
//!
//! Run: `cargo bench --bench fig5d`

use cecflow::algo::{Optimizer, Sgp};
use cecflow::coordinator::metrics::travel_distance;
use cecflow::coordinator::report::{
    figure_json, render_series_table, write_csv, write_json, Series,
};
use cecflow::coordinator::ScenarioSpec;
use cecflow::model::{compute_flows, CostFn, Strategy};
use cecflow::util::stats::spearman;

fn main() -> anyhow::Result<()> {
    let sweep = [0.1, 0.25, 0.5, 1.0, 2.0, 4.0];
    let spec = ScenarioSpec::by_name("connected-er").unwrap();

    let mut l_data = Vec::new();
    let mut l_result = Vec::new();
    let mut rows = Vec::new();

    for &am in &sweep {
        // one instance, all types forced to the sweep value (isolates the
        // a_m effect exactly as the paper's sweep does)
        let mut sc = spec.build(2026);
        for a in sc.net.result_ratio.iter_mut() {
            *a = am;
        }
        // feasibility head-room after the override (large a_m multiplies
        // all result flows)
        for _ in 0..40 {
            let phi0 = Strategy::local_compute_init(&sc.net);
            if compute_flows(&sc.net, &phi0)?.total_cost.is_finite() {
                break;
            }
            for c in sc.net.link_cost.iter_mut() {
                if let CostFn::Queue { cap } = c {
                    *cap *= 1.3;
                }
            }
        }

        let mut phi = Strategy::local_compute_init(&sc.net);
        let mut sgp = Sgp::new();
        for _ in 0..60 {
            sgp.step(&sc.net, &mut phi)?;
        }
        let flows = compute_flows(&sc.net, &phi)?;
        let td = travel_distance(&sc.net, &flows);
        eprintln!("[fig5d] a_m={am}: L_data={:.3} L_result={:.3}", td.l_data, td.l_result);
        l_data.push(td.l_data);
        l_result.push(td.l_result);
        rows.push(vec![
            format!("{am}"),
            format!("{}", td.l_data),
            format!("{}", td.l_result),
        ]);
    }

    let series = vec![
        Series {
            label: "L_data".into(),
            x: sweep.to_vec(),
            y: l_data.clone(),
        },
        Series {
            label: "L_result".into(),
            x: sweep.to_vec(),
            y: l_result.clone(),
        },
    ];
    println!("{}", render_series_table("a_m", &series));
    write_csv("fig5d.csv", &["a_m", "l_data", "l_result"], &rows)?;
    write_json("fig5d.json", &figure_json("fig5d-travel-distance", &series))?;
    cecflow::coordinator::report::write_series_svg(
        "fig5d.svg",
        "Fig. 5d — travel distances vs result-size ratio a_m",
        "a_m",
        "hops",
        &series,
    )?;

    // ---- shape checks: monotone trends ----
    let up = spearman(&sweep, &l_data);
    let down = spearman(&sweep, &l_result);
    println!("L_data trend (spearman): {up:.2} (expect > 0.6)");
    println!("L_result trend (spearman): {down:.2} (expect < -0.6)");
    let ok = up > 0.6 && down < -0.6;
    println!("fig5d shape: {}", if ok { "OK" } else { "VIOLATIONS" });
    Ok(())
}
