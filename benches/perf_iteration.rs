//! Performance microbenches for the hot paths (EXPERIMENTS.md §Perf):
//!
//!  * flow computation (`model::flows`)
//!  * marginal recursion (`model::marginals`)
//!  * blocked-set construction
//!  * per-node QP projection
//!  * one full SGP Gauss–Seidel iteration
//!  * XLA dense evaluation (small class) vs native, when artifacts exist
//!
//! Run: `cargo bench --bench perf_iteration`

use std::time::Duration;

use cecflow::algo::blocked::blocked_sets;
use cecflow::algo::simplex_qp::scaled_simplex_qp;
use cecflow::algo::{Optimizer, Sgp};
use cecflow::coordinator::report::write_csv;
use cecflow::coordinator::ScenarioSpec;
use cecflow::model::{compute_flows, compute_marginals, Strategy};
use cecflow::runtime::{DenseBackend, NativeBackend};
use cecflow::util::timer::{bench_fn, BenchReport};

fn main() -> anyhow::Result<()> {
    let budget = Duration::from_millis(400);
    let mut report = BenchReport::new("cecflow hot paths");
    let mut rows: Vec<Vec<String>> = Vec::new();
    let record = |rows: &mut Vec<Vec<String>>, m: &cecflow::util::timer::Measurement| {
        rows.push(vec![m.name.clone(), format!("{}", m.per_iter.mean)]);
    };

    for name in ["abilene", "geant", "sw"] {
        let sc = ScenarioSpec::by_name(name).unwrap().build(2026);
        let net = &sc.net;
        // pre-optimize a few sweeps so flows are multi-path (realistic)
        let mut phi = Strategy::local_compute_init(net);
        let mut sgp = Sgp::new();
        let warm = if name == "sw" { 2 } else { 5 };
        for _ in 0..warm {
            sgp.step(net, &mut phi)?;
        }

        let m = bench_fn(&format!("{name}: compute_flows"), budget, || {
            let _ = compute_flows(net, &phi).unwrap();
        });
        report.add_measurement(&m);
        record(&mut rows, &m);

        let flows = compute_flows(net, &phi)?;
        let m = bench_fn(&format!("{name}: compute_marginals"), budget, || {
            let _ = compute_marginals(net, &phi, &flows).unwrap();
        });
        report.add_measurement(&m);
        record(&mut rows, &m);

        let marg = compute_marginals(net, &phi, &flows)?;
        let m = bench_fn(&format!("{name}: blocked_sets (all tasks)"), budget, || {
            for s in 0..net.s() {
                let _ = blocked_sets(net, &phi, &marg, s);
            }
        });
        report.add_measurement(&m);
        record(&mut rows, &m);

        let mut phi_iter = phi.clone();
        let m = bench_fn(&format!("{name}: sgp full iteration"), budget, || {
            let mut s = Sgp::new();
            let _ = s.step(net, &mut phi_iter).unwrap();
        });
        report.add_measurement(&m);
        record(&mut rows, &m);
    }

    // QP microbench
    let phi_v = [0.4, 0.3, 0.2, 0.1, 0.0, 0.0];
    let delta = [1.0, 0.5, 2.0, 0.1, 3.0, 0.7];
    let scale = [0.5, 1.0, 0.2, 2.0, 1.0, 0.8];
    let blocked = [false, false, false, false, true, false];
    let m = bench_fn("qp: 6-slot projection", budget, || {
        let _ = scaled_simplex_qp(&phi_v, &delta, &scale, &blocked);
    });
    report.add_measurement(&m);
    record(&mut rows, &m);

    // Dense-backend evaluation through the trait object (the abstraction
    // the accelerated loop pays for), vs the direct native calls.
    {
        let sc = ScenarioSpec::by_name("abilene").unwrap().build(2026);
        let net = &sc.net;
        let phi = Strategy::local_compute_init(net);
        let backend: &dyn DenseBackend = &NativeBackend;
        let m = bench_fn("abilene: NativeBackend dense evaluate", budget, || {
            let _ = backend.evaluate(net, &phi).unwrap();
        });
        report.add_measurement(&m);
        record(&mut rows, &m);
        let m = bench_fn("abilene: native flows+marginals", budget, || {
            let f = compute_flows(net, &phi).unwrap();
            let _ = compute_marginals(net, &phi, &f).unwrap();
        });
        report.add_measurement(&m);
        record(&mut rows, &m);
    }

    // XLA dense evaluation (small class), only in `--features pjrt` builds.
    #[cfg(feature = "pjrt")]
    {
        use cecflow::runtime::{default_artifacts_dir, DenseEvaluator, Engine};
        match Engine::load_filtered(&default_artifacts_dir(), |c| c.name == "small") {
            Ok(engine) => {
                let sc = ScenarioSpec::by_name("abilene").unwrap().build(2026);
                let net = &sc.net;
                let phi = Strategy::local_compute_init(net);
                let eval = DenseEvaluator::new(&engine);
                let m = bench_fn("abilene: XLA dense_eval (N=32,S=48 padded)", budget, || {
                    let _ = eval.evaluate(net, &phi).unwrap();
                });
                report.add_measurement(&m);
                record(&mut rows, &m);
            }
            Err(err) => {
                report.add_row("xla", format!("skipped ({err:#})"));
            }
        }
    }
    #[cfg(not(feature = "pjrt"))]
    report.add_row(
        "xla",
        "skipped (built without the `pjrt` cargo feature)".to_string(),
    );

    report.print();
    write_csv("perf_iteration.csv", &["path", "seconds_per_iter"], &rows)?;
    Ok(())
}
