//! Dynamic task-pattern smoke driver: the adaptivity claim measured over
//! the scenario library, plus the schedule axis of the sweep grid.
//! CI runs this with `CECFLOW_BENCH_FAST=1` (two scenarios, one
//! schedule) as the dynamics smoke test.
//!
//! Shape checks (paper claims, not absolute values):
//!   * warm-started re-optimization takes at most the cold-started
//!     iteration count on every epoch after the first, on every
//!     scenario × schedule pair in the grid;
//!   * warm transient regret never exceeds cold;
//!   * a sweep over the schedule axis is fingerprint-identical across
//!     worker counts (dynamic cells honor the determinism contract).
//!
//! Run: `cargo bench --bench dynamic`   (CECFLOW_BENCH_FAST=1 shrinks the grid)

use std::time::Instant;

use cecflow::coordinator::report::write_csv;
use cecflow::coordinator::{
    run_sweep, AdaptiveRunner, Algorithm, CellBackend, PatternSchedule, RunConfig, SweepSpec,
};
use cecflow::util::table::fnum;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("CECFLOW_BENCH_FAST").is_ok();
    let scenarios: Vec<&str> = if fast {
        vec!["abilene", "grid-torus"]
    } else {
        vec!["abilene", "connected-er", "grid-torus", "scale-free", "fat-tree"]
    };
    let schedules: Vec<&str> = if fast {
        vec!["step:3:1.5"]
    } else {
        vec!["step:3:1.5", "bursty:4:2", "diurnal:4:2", "churn:3:0.25", "rescale:3:1.25"]
    };
    let cfg = RunConfig::quick();

    let mut ok = true;
    let mut rows: Vec<Vec<String>> = Vec::new();
    let start = Instant::now();
    for scenario in &scenarios {
        for label in &schedules {
            let schedule = PatternSchedule::parse(label)?;
            let warm = AdaptiveRunner::warm(cfg).run_scenario(scenario, 1, 1.0, schedule)?;
            let cold = AdaptiveRunner::cold(cfg).run_scenario(scenario, 1, 1.0, schedule)?;
            for (w, c) in warm.epochs.iter().zip(&cold.epochs) {
                rows.push(vec![
                    scenario.to_string(),
                    label.to_string(),
                    w.epoch.to_string(),
                    fnum(w.final_cost),
                    w.iterations.to_string(),
                    c.iterations.to_string(),
                    fnum(w.transient_regret),
                    fnum(c.transient_regret),
                ]);
                if w.epoch == 0 {
                    continue;
                }
                // Churn moves destinations, so the carried point can sit
                // in a different basin than the all-local start — the
                // warm-≤-cold bound is a theorem only for rate scalings;
                // for churn it is reported, not enforced.
                let advisory = label.starts_with("churn");
                if w.iterations > c.iterations {
                    println!(
                        "{}: {scenario} under {label} epoch {}: warm took {} iterations \
                         vs cold {}",
                        if advisory { "note" } else { "SHAPE VIOLATION" },
                        w.epoch,
                        w.iterations,
                        c.iterations
                    );
                    ok = ok && advisory;
                }
                if w.transient_regret > c.transient_regret + 1e-9 {
                    println!(
                        "{}: {scenario} under {label} epoch {}: warm regret {} vs cold {}",
                        if advisory { "note" } else { "SHAPE VIOLATION" },
                        w.epoch,
                        fnum(w.transient_regret),
                        fnum(c.transient_regret)
                    );
                    ok = ok && advisory;
                }
            }
            println!(
                "{scenario:>13} {label:<14} re-convergence: warm {:>3} vs cold {:>3} iters",
                warm.reconvergence_iterations(),
                cold.reconvergence_iterations()
            );
        }
    }
    write_csv(
        "dynamic.csv",
        &[
            "scenario",
            "schedule",
            "epoch",
            "final_cost",
            "warm_iters",
            "cold_iters",
            "warm_regret",
            "cold_regret",
        ],
        &rows,
    )?;

    // the schedule axis of the sweep grid stays deterministic
    let spec = SweepSpec {
        scenarios: scenarios.iter().map(|s| s.to_string()).collect(),
        seeds: vec![1],
        algorithms: vec![Algorithm::Sgp],
        backends: vec![CellBackend::Sparse],
        schedules: std::iter::once(PatternSchedule::static_())
            .chain(schedules.iter().map(|l| PatternSchedule::parse(l).unwrap()))
            .collect(),
        rate_scale: 1.0,
        run: cfg,
        sim: None,
        cache: None,
    };
    let serial = run_sweep(&spec, 1)?;
    let parallel = run_sweep(&spec, 4)?;
    if serial.fingerprint() != parallel.fingerprint() {
        println!("SHAPE VIOLATION: dynamic sweep cells differ between 1 and 4 workers");
        ok = false;
    }

    println!(
        "dynamic bench wall time: {:.2}s — shape: {}",
        start.elapsed().as_secs_f64(),
        if ok { "OK" } else { "VIOLATIONS (see above)" }
    );
    if !ok {
        std::process::exit(1);
    }
    Ok(())
}
