//! PR 8 perf driver: the content-addressed strategy store.
//!
//! Three planes:
//!
//!  * raw sparse SGP optimizer throughput (iterations/sec on abilene) —
//!    the work a cache hit avoids, for scale;
//!  * cold sweep throughput (cells/sec) populating a fresh `FsStore`;
//!  * cache-hit sweep throughput (cells/sec) re-running the same grid
//!    against the populated store, with the hit rate and the
//!    fingerprint-identity to the cold run asserted — the speedup ratio
//!    is the headline number of the store layer.
//!
//! Emits the machine-readable perf-trajectory record as `BENCH_8.json`
//! in the working directory (`CECFLOW_BENCH_OUT` overrides the path).
//! `CECFLOW_BENCH_FAST=1` shrinks the grid for the CI smoke run.
//!
//! Run: `cargo bench --bench cache`

use std::time::Instant;

use cecflow::algo::Sgp;
use cecflow::coordinator::{
    build_scenario_network, optimize, run_sweep, Algorithm, CellBackend, PatternSchedule,
    RunConfig, SweepSpec,
};
use cecflow::model::strategy::Strategy;
use cecflow::util::json::Json;

fn record(name: &str, per_sec: f64, count: u64, seconds: f64) -> Json {
    let mut o = Json::obj();
    o.set("name", Json::Str(name.to_string()))
        .set("per_sec", Json::Num(per_sec))
        .set("count", Json::Num(count as f64))
        .set("seconds", Json::Num(seconds));
    o
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("CECFLOW_BENCH_FAST").is_ok();
    let mut records: Vec<Json> = Vec::new();

    // ---- plane 1: raw sparse SGP iteration throughput -----------------
    // Repeated full solves under a generous iteration budget; the metric
    // is optimizer iterations/sec — the unit of work a store hit avoids.
    let net = build_scenario_network("abilene", 1, 1.0)?;
    let phi0 = Strategy::local_compute_init(&net);
    let max_iters = if fast { 40 } else { 200 };
    let cfg = RunConfig {
        max_iters,
        tol: 0.0,
        // a patience window longer than the budget can never fill: every
        // solve runs the full budget, so the metric is steps, not
        // convergence luck
        patience: max_iters,
    };
    let solves = if fast { 3 } else { 10 };
    let mut iters = 0u64;
    let start = Instant::now();
    for _ in 0..solves {
        let res = optimize(&net, &mut Sgp::new(), &phi0, &cfg)?;
        iters += res.costs.len() as u64;
    }
    let secs = start.elapsed().as_secs_f64();
    let ips = iters as f64 / secs;
    println!("sparse sgp: {iters} iterations in {secs:.3}s = {ips:.0} iters/s");
    records.push(record("sparse_sgp_iterations_per_sec", ips, iters, secs));

    // ---- planes 2+3: cold vs cache-hit sweep --------------------------
    let dir = std::env::temp_dir().join(format!("cecflow-bench-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = SweepSpec {
        scenarios: vec!["abilene".into(), "connected-er".into()],
        seeds: if fast { vec![1, 2] } else { (1..=6).collect() },
        algorithms: vec![Algorithm::Sgp, Algorithm::Gp],
        backends: vec![CellBackend::Sparse],
        schedules: vec![PatternSchedule::static_()],
        rate_scale: 1.0,
        run: RunConfig::quick(),
        sim: None,
        cache: Some(dir.display().to_string()),
    };
    let cells = spec.cells().len() as u64;

    let start = Instant::now();
    let cold = run_sweep(&spec, 2)?;
    let cold_secs = start.elapsed().as_secs_f64();
    let cold_cps = cells as f64 / cold_secs;
    println!("cold sweep: {cells} cells in {cold_secs:.3}s = {cold_cps:.1} cells/s");
    records.push(record("sweep_cells_cold_per_sec", cold_cps, cells, cold_secs));

    let start = Instant::now();
    let warm = run_sweep(&spec, 2)?;
    let warm_secs = start.elapsed().as_secs_f64();
    let warm_cps = cells as f64 / warm_secs;
    let hits = warm
        .cells
        .iter()
        .filter(|c| c.cache.is_some_and(|k| k.hit))
        .count();
    let saved: usize = warm
        .cells
        .iter()
        .filter_map(|c| c.cache.map(|k| k.iters_saved))
        .sum();
    // saturated cells (∞ cost) are deliberately never stored; every
    // finite cell must come back as a verified hit
    let finite = warm
        .cells
        .iter()
        .filter(|c| c.final_cost.is_finite())
        .count();
    assert_eq!(hits, finite, "warmed sweep must hit on every finite cell");
    assert!(saved > 0, "hits must save iterations");
    assert_eq!(
        warm.fingerprint(),
        cold.fingerprint(),
        "cache-hit sweep drifted from the cold run"
    );
    println!(
        "cache-hit sweep: {cells} cells in {warm_secs:.3}s = {warm_cps:.1} cells/s \
         ({hits} hits, {saved} iterations saved, {:.1}x cold)",
        warm_cps / cold_cps
    );
    records.push(record("sweep_cells_cache_hit_per_sec", warm_cps, cells, warm_secs));
    let _ = std::fs::remove_dir_all(&dir);

    // ---- trajectory record --------------------------------------------
    let path = std::env::var("CECFLOW_BENCH_OUT").unwrap_or_else(|_| "BENCH_8.json".to_string());
    if let Some(parent) = std::path::Path::new(&path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut doc = Json::obj();
    doc.set("pr", Json::Num(8.0))
        .set("bench", Json::Str("cache".to_string()))
        .set("fast_mode", Json::Bool(fast))
        .set("records", Json::Arr(records));
    std::fs::write(&path, doc.pretty())?;
    println!("wrote {path}");
    Ok(())
}
