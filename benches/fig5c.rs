//! Fig. 5c regenerator: steady-state total cost vs exogenous-input scale
//! factor on the Connected-ER instance, for SGP and all baselines.
//!
//! Shape checks: every algorithm's cost grows with load, and the
//! SGP-advantage ratio (baseline/SGP) grows as the network congests —
//! "the performance advantage of SGP has a quick growth as the network
//! getting more congested, especially against LPR".
//!
//! Run: `cargo bench --bench fig5c`

use cecflow::coordinator::report::{
    figure_json, render_series_table, write_csv, write_json, Series,
};
use cecflow::coordinator::{run_algorithm, Algorithm, RunConfig, ScenarioSpec};
use cecflow::util::stats::spearman;

fn main() -> anyhow::Result<()> {
    let scales = [0.6, 0.8, 1.0, 1.1, 1.2];
    let algos = [
        Algorithm::Sgp,
        Algorithm::Spoo,
        Algorithm::Lcor,
        Algorithm::Lpr,
    ];
    let spec = ScenarioSpec::by_name("connected-er").unwrap();
    let cfg = RunConfig {
        max_iters: 60,
        tol: 1e-6,
        patience: 4,
    };

    // LPR can saturate (infinite true cost) at high loads; cap for the
    // table/ratios and report the saturation explicitly.
    let mut series: Vec<Series> = algos
        .iter()
        .map(|a| Series {
            label: a.name().to_string(),
            x: scales.to_vec(),
            y: Vec::new(),
        })
        .collect();

    let mut rows = Vec::new();
    for &scale in &scales {
        let mut sc = spec.build(2026);
        sc.net.scale_rates(scale);
        eprintln!("[fig5c] scale {scale} ...");
        for (ai, &algo) in algos.iter().enumerate() {
            let out = run_algorithm(&sc.net, algo, &cfg)?;
            series[ai].y.push(out.final_cost);
            rows.push(vec![
                format!("{scale}"),
                out.algorithm.clone(),
                format!("{}", out.final_cost),
            ]);
        }
    }

    println!("{}", render_series_table("scale", &series));
    write_csv("fig5c.csv", &["scale", "algorithm", "total_cost"], &rows)?;
    write_json("fig5c.json", &figure_json("fig5c-cost-vs-load", &series))?;
    cecflow::coordinator::report::write_series_svg(
        "fig5c.svg",
        "Fig. 5c — steady-state cost vs input-rate scale",
        "rate scale",
        "total cost T",
        &series,
    )?;

    // ---- shape checks ----
    let mut ok = true;
    // monotone growth per algorithm (treat inf as "very large")
    for s in &series {
        let capped: Vec<f64> = s.y.iter().map(|&v| if v.is_finite() { v } else { 1e12 }).collect();
        if spearman(&s.x, &capped) < 0.99 {
            println!("SHAPE VIOLATION: {} cost not increasing with load: {:?}", s.label, s.y);
            ok = false;
        }
    }
    // advantage ratio grows with congestion for every baseline
    for bi in 1..algos.len() {
        let ratios: Vec<f64> = (0..scales.len())
            .map(|k| {
                let b = series[bi].y[k];
                let s = series[0].y[k];
                if b.is_finite() {
                    b / s
                } else {
                    1e6 // saturated baseline: advantage unbounded
                }
            })
            .collect();
        let trend = spearman(&series[0].x, &ratios);
        println!(
            "{}/sgp ratio over load: {:?} (spearman {:.2})",
            series[bi].label,
            ratios.iter().map(|r| format!("{r:.2}")).collect::<Vec<_>>(),
            trend
        );
        if ratios.last().unwrap() < ratios.first().unwrap() {
            println!(
                "SHAPE VIOLATION: {} advantage shrinks with congestion",
                series[bi].label
            );
            ok = false;
        }
    }
    println!("fig5c shape: {}", if ok { "OK" } else { "VIOLATIONS" });
    Ok(())
}
