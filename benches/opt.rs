//! PR 10 perf driver: the allocation-free optimizer hot path.
//!
//! Four planes, each a record in the perf-trajectory file:
//!
//!  * `sparse_step_iters_per_sec` — full Gauss–Seidel SGP sweeps through
//!    one persistent [`OptWorkspace`] (the steady-state hot path: zero
//!    heap allocation per iteration after warm-up). A side-by-side run of
//!    the legacy allocating wrapper (`sparse_step_legacy_iters_per_sec`)
//!    uses the same seed and iteration budget, and the two cost
//!    trajectories are asserted bitwise identical — the speedup ratio is
//!    the headline number of the workspace layer, and the assert is the
//!    determinism contract it rides on.
//!  * `dense_step_iters_per_sec` — batched dense-ladder SGP through the
//!    pure-rust [`NativeBackend`], workspace-pooled candidates.
//!  * `marginals_per_sec` — raw [`compute_marginals_into`] throughput on
//!    a warm [`MarginalScratch`] (the broadcast recursion every iteration
//!    pays at least once).
//!  * `dynamic_epochs_per_sec` — warm-started re-optimization epochs
//!    through a bursty [`PatternSchedule`], one workspace reused across
//!    the whole trace.
//!
//! Emits the machine-readable record as `BENCH_10.json` in the working
//! directory (`CECFLOW_BENCH_OUT` overrides the path).
//! `CECFLOW_BENCH_FAST=1` shrinks every budget for the CI smoke run.
//!
//! Run: `cargo bench --bench opt`

use std::time::Instant;

use cecflow::algo::{OptWorkspace, Optimizer, Sgp};
use cecflow::coordinator::{
    build_scenario_network, optimize_accelerated, AdaptiveRunner, PatternSchedule, RunConfig,
    ScheduleKind,
};
use cecflow::model::flows::compute_flows;
use cecflow::model::marginals::{compute_marginals_into, MarginalScratch};
use cecflow::model::strategy::Strategy;
use cecflow::runtime::NativeBackend;
use cecflow::util::json::Json;

fn record(name: &str, per_sec: f64, count: u64, seconds: f64) -> Json {
    let mut o = Json::obj();
    o.set("name", Json::Str(name.to_string()))
        .set("per_sec", Json::Num(per_sec))
        .set("count", Json::Num(count as f64))
        .set("seconds", Json::Num(seconds));
    o
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("CECFLOW_BENCH_FAST").is_ok();
    let mut records: Vec<Json> = Vec::new();

    let net = build_scenario_network("abilene", 1, 1.0)?;
    let phi0 = Strategy::local_compute_init(&net);
    let iters = if fast { 60 } else { 400 };

    // ---- plane 1: sparse sweeps, legacy wrapper vs persistent arena ---
    // Manual stepping (no convergence stop) so both paths run the exact
    // same number of sweeps from the exact same start point.
    let mut phi_legacy = phi0.clone();
    let mut sgp = Sgp::new();
    let mut legacy_costs = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters {
        // the allocating wrapper: a throwaway workspace per call
        let st = sgp.step(&net, &mut phi_legacy)?;
        legacy_costs.push(st.total_cost);
    }
    let legacy_secs = start.elapsed().as_secs_f64();
    let legacy_ips = iters as f64 / legacy_secs;
    println!(
        "sparse legacy: {iters} iterations in {legacy_secs:.3}s = {legacy_ips:.0} iters/s"
    );
    records.push(record(
        "sparse_step_legacy_iters_per_sec",
        legacy_ips,
        iters as u64,
        legacy_secs,
    ));

    let mut phi_ws = phi0.clone();
    let mut sgp = Sgp::new();
    let mut ws = OptWorkspace::new();
    let mut ws_costs = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters {
        let st = sgp.step_ws(&net, &mut phi_ws, &mut ws)?;
        ws_costs.push(st.total_cost);
    }
    let ws_secs = start.elapsed().as_secs_f64();
    let ws_ips = iters as f64 / ws_secs;
    println!(
        "sparse workspace: {iters} iterations in {ws_secs:.3}s = {ws_ips:.0} iters/s \
         ({:.2}x legacy)",
        ws_ips / legacy_ips
    );
    records.push(record(
        "sparse_step_iters_per_sec",
        ws_ips,
        iters as u64,
        ws_secs,
    ));

    // the determinism contract: same FP op order, bitwise-equal costs
    assert_eq!(legacy_costs.len(), ws_costs.len());
    for (k, (a, b)) in legacy_costs.iter().zip(&ws_costs).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "workspace trajectory diverged from legacy at iteration {k}: {a} vs {b}"
        );
    }

    // ---- plane 2: dense batched ladder on the native backend ----------
    let dense_iters = if fast { 40 } else { 200 };
    let cfg = RunConfig {
        max_iters: dense_iters,
        tol: 0.0,
        patience: dense_iters,
    };
    let solves = if fast { 2 } else { 5 };
    let mut dense_total = 0u64;
    let start = Instant::now();
    for _ in 0..solves {
        let res = optimize_accelerated(&net, &mut Sgp::new(), &phi0, &cfg, &NativeBackend)?;
        dense_total += res.costs.len() as u64;
    }
    let dense_secs = start.elapsed().as_secs_f64();
    let dense_ips = dense_total as f64 / dense_secs;
    println!(
        "dense: {dense_total} iterations in {dense_secs:.3}s = {dense_ips:.0} iters/s"
    );
    records.push(record(
        "dense_step_iters_per_sec",
        dense_ips,
        dense_total,
        dense_secs,
    ));

    // ---- plane 3: raw marginal-broadcast throughput -------------------
    // One converged-ish strategy, flows held fixed, the recursion rerun
    // on a warm scratch: this is the floor every sweep pays per task.
    let flows = compute_flows(&net, &phi_ws)?;
    let mut scratch = MarginalScratch::new();
    compute_marginals_into(&net, &phi_ws, &flows, &mut scratch)?; // warm-up
    let marg_reps: u64 = if fast { 200 } else { 5_000 };
    let start = Instant::now();
    for _ in 0..marg_reps {
        compute_marginals_into(&net, &phi_ws, &flows, &mut scratch)?;
    }
    let marg_secs = start.elapsed().as_secs_f64();
    let marg_ps = marg_reps as f64 / marg_secs;
    println!("marginals: {marg_reps} passes in {marg_secs:.3}s = {marg_ps:.0} passes/s");
    records.push(record("marginals_per_sec", marg_ps, marg_reps, marg_secs));

    // ---- plane 4: dynamic re-optimization epochs ----------------------
    let epochs = if fast { 4 } else { 12 };
    let schedule = PatternSchedule::new(ScheduleKind::Bursty, epochs, 1.5)?;
    let runner = AdaptiveRunner::warm(RunConfig::quick());
    let start = Instant::now();
    let trace = runner.run_scenario("abilene", 1, 1.0, schedule)?;
    let dyn_secs = start.elapsed().as_secs_f64();
    let n_epochs = trace.epochs.len() as u64;
    let eps = n_epochs as f64 / dyn_secs;
    println!("dynamic: {n_epochs} epochs in {dyn_secs:.3}s = {eps:.1} epochs/s");
    records.push(record("dynamic_epochs_per_sec", eps, n_epochs, dyn_secs));

    // ---- trajectory record --------------------------------------------
    let path = std::env::var("CECFLOW_BENCH_OUT").unwrap_or_else(|_| "BENCH_10.json".to_string());
    if let Some(parent) = std::path::Path::new(&path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut doc = Json::obj();
    doc.set("pr", Json::Num(10.0))
        .set("bench", Json::Str("opt".to_string()))
        .set("fast_mode", Json::Bool(fast))
        .set("records", Json::Arr(records));
    std::fs::write(&path, doc.pretty())?;
    println!("wrote {path}");
    Ok(())
}
