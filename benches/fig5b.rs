//! Fig. 5b regenerator: convergence of GP vs SGP on the Connected-ER
//! server instance (Fig. 5a), with server S1 failing at iteration 100.
//!
//! Shape checks: SGP needs markedly fewer iterations than GP both from the
//! cold start and to re-converge after the failure.
//!
//! Run: `cargo bench --bench fig5b`

use cecflow::algo::{Gp, Sgp};
use cecflow::coordinator::connected_er_servers;
use cecflow::coordinator::report::{figure_json, write_csv, write_json, Series};
use cecflow::model::Strategy;
use cecflow::sim::run_with_failure;
use cecflow::util::table::{fnum, Table};

fn iters_within(costs: &[f64], upto: usize, frac: f64) -> usize {
    let steady = costs[upto - 1];
    costs[..upto]
        .iter()
        .position(|&c| c <= steady * (1.0 + frac))
        .map(|p| p + 1)
        .unwrap_or(upto)
}

fn main() -> anyhow::Result<()> {
    let fail_at = 100;
    let total = 200;
    let sc = connected_er_servers(42);
    let s1 = sc.servers[0];
    let fallback = sc.servers[1];
    println!(
        "Fig. 5a instance: Connected-ER |V|=20, servers {:?}; S1={} fails at iter {}",
        sc.servers, s1, fail_at
    );

    let phi0 = Strategy::local_compute_init(&sc.net);
    let sgp = run_with_failure(&sc.net, Sgp::new, &phi0, fail_at, total, s1, fallback, 0.001)?;
    let gp = run_with_failure(
        &sc.net,
        || Gp::new(1.0),
        &phi0,
        fail_at,
        total,
        s1,
        fallback,
        0.001,
    )?;

    let mut t = Table::new(&["metric", "SGP", "GP"]);
    let sgp_cold = iters_within(&sgp.costs, fail_at, 0.001);
    let gp_cold = iters_within(&gp.costs, fail_at, 0.001);
    t.row(vec![
        "cold-start iters (0.1%)".into(),
        sgp_cold.to_string(),
        gp_cold.to_string(),
    ]);
    t.row(vec![
        "post-failure iters (0.1%)".into(),
        sgp.reconverge_iters.to_string(),
        gp.reconverge_iters.to_string(),
    ]);
    t.row(vec![
        "steady-state T (healthy)".into(),
        fnum(sgp.costs[fail_at - 1]),
        fnum(gp.costs[fail_at - 1]),
    ]);
    t.row(vec![
        "steady-state T (degraded)".into(),
        fnum(sgp.final_cost),
        fnum(gp.final_cost),
    ]);
    t.print();

    // trajectory dump
    let rows: Vec<Vec<String>> = (0..total)
        .map(|k| {
            vec![
                k.to_string(),
                format!("{}", sgp.costs[k]),
                format!("{}", gp.costs[k]),
            ]
        })
        .collect();
    write_csv("fig5b.csv", &["iteration", "sgp", "gp"], &rows)?;
    let series = vec![
        Series {
            label: "sgp".into(),
            x: (0..total).map(|k| k as f64).collect(),
            y: sgp.costs.clone(),
        },
        Series {
            label: "gp".into(),
            x: (0..total).map(|k| k as f64).collect(),
            y: gp.costs.clone(),
        },
    ];
    write_json("fig5b.json", &figure_json("fig5b-convergence", &series))?;
    cecflow::coordinator::report::write_series_svg(
        "fig5b.svg",
        "Fig. 5b — convergence with S1 failure at iteration 100",
        "iteration",
        "total cost T",
        &series,
    )?;

    // shape checks
    let mut ok = true;
    if sgp_cold * 2 > gp_cold {
        println!("SHAPE VIOLATION: SGP cold-start not >=2x faster ({sgp_cold} vs {gp_cold})");
        ok = false;
    }
    if sgp.reconverge_iters > gp.reconverge_iters {
        println!(
            "SHAPE VIOLATION: SGP post-failure slower ({} vs {})",
            sgp.reconverge_iters, gp.reconverge_iters
        );
        ok = false;
    }
    // both reach the same optima (within 0.5%)
    for (a, b, tag) in [
        (sgp.costs[fail_at - 1], gp.costs[fail_at - 1], "healthy"),
        (sgp.final_cost, gp.final_cost, "degraded"),
    ] {
        if (a - b).abs() > 0.005 * a.abs() {
            println!("SHAPE VIOLATION: {tag} steady states diverge: {a} vs {b}");
            ok = false;
        }
    }
    println!("fig5b shape: {}", if ok { "OK" } else { "VIOLATIONS" });
    Ok(())
}
