# Convenience targets. Tier-1 verification is `cargo build --release &&
# cargo test -q` and needs none of the python tooling below.

ARTIFACTS_DIR ?= artifacts

.PHONY: all build test artifacts bench-smoke clean-artifacts

all: build

build:
	cargo build --release

test:
	cargo test -q

# AOT-lower the JAX/Pallas dense_eval program to HLO-text artifacts +
# manifest.json consumed by the `pjrt` runtime feature. Requires jax.
artifacts:
	cd python && python -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

# Fast bench smoke used by CI to catch driver rot (skips the SW scenario).
bench-smoke:
	CECFLOW_BENCH_FAST=1 cargo bench --bench fig4

clean-artifacts:
	rm -rf $(ARTIFACTS_DIR)
