# Convenience targets. Tier-1 verification is `cargo build --release &&
# cargo test -q` and needs none of the python tooling below.

ARTIFACTS_DIR ?= artifacts

.PHONY: all build test artifacts bench-smoke opt-bench clean-artifacts pgo clean-pgo

all: build

build:
	cargo build --release

test:
	cargo test -q

# AOT-lower the JAX/Pallas dense_eval program to HLO-text artifacts +
# manifest.json consumed by the `pjrt` runtime feature. Requires jax.
artifacts:
	cd python && python -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

# Fast bench smoke used by CI to catch driver rot (skips the SW scenario).
bench-smoke:
	CECFLOW_BENCH_FAST=1 cargo bench --bench fig4

clean-artifacts:
	rm -rf $(ARTIFACTS_DIR)

# PR 10 optimizer bench: emits BENCH_10.json (CECFLOW_BENCH_FAST=1 for
# the CI smoke variant).
opt-bench:
	cargo bench --bench opt

# ---- profile-guided optimization --------------------------------------
#
# Three passes (see perf.md for measured results):
#   1. build the CLI under [profile.release-pgo] with -Cprofile-generate,
#   2. run a representative workload — a small multi-scenario sweep plus
#      a dynamic trace, covering the sparse SGP hot path, the GP
#      baseline, and the epoch re-optimization loop,
#   3. merge the .profraw shards and rebuild with -Cprofile-use.
# The final binary lands in target/release-pgo/cecflow. Requires
# llvm-profdata matching the rustc LLVM version (shipped as
# `cargo profdata` via llvm-tools, or the system llvm-profdata).
PGO_DIR ?= target/pgo-profiles
LLVM_PROFDATA ?= llvm-profdata

pgo:
	rm -rf $(PGO_DIR)
	RUSTFLAGS="-Cprofile-generate=$(PGO_DIR)" \
		cargo build --profile release-pgo --bin cecflow
	./target/release-pgo/cecflow sweep \
		--scenarios abilene,connected-er --seeds 1..4 --algos sgp,gp
	./target/release-pgo/cecflow dynamic \
		--scenario abilene --seed 1 --schedule bursty:6:1.5
	$(LLVM_PROFDATA) merge -o $(PGO_DIR)/merged.profdata $(PGO_DIR)
	RUSTFLAGS="-Cprofile-use=$(PGO_DIR)/merged.profdata" \
		cargo build --profile release-pgo --bin cecflow

clean-pgo:
	rm -rf $(PGO_DIR) target/release-pgo
