"""Structural sanity of the TPU resource estimates (DESIGN.md §7)."""

from compile.estimate import (
    IterationEstimate,
    PropStepEstimate,
    VMEM_BYTES,
    render_table,
)


def test_vmem_within_budget_for_all_classes():
    for n, s in [(32, 48), (128, 128)]:
        p = PropStepEstimate(n=n, s=s, block_n=min(128, n))
        assert p.vmem_bytes < 0.25 * VMEM_BYTES, (n, p.vmem_bytes)


def test_large_class_matches_design_doc():
    p = PropStepEstimate(n=128, s=128, block_n=128)
    # 64 KiB phi tile dominates
    assert abs(p.vmem_bytes - (4 * (128 * 128 + 128 + 256))) < 1
    assert 0.003 < p.vmem_fraction < 0.005
    # mat-vec: 0.5 flop/byte
    assert abs(p.arithmetic_intensity - 0.5) < 1e-9
    # [1,128]x[128,128] dot: 1/128 of the array per pass
    assert abs(p.mxu_utilization - 1 / 128) < 1e-9


def test_grid_covers_all_outputs():
    p = PropStepEstimate(n=128, s=48, block_n=128)
    gs, gb = p.grid
    assert gs == 48 and gb == 1
    p2 = PropStepEstimate(n=128, s=48, block_n=64)
    assert p2.grid == (48, 2)


def test_iteration_flops_scaling():
    small = IterationEstimate(n=32, s=48, block_n=32)
    large = IterationEstimate(n=128, s=128, block_n=128)
    # flops scale as S * N^3 (4 recursions x N waves x S·N² per wave)
    ratio = large.total_flops / small.total_flops
    expect = (128 * 128**3) / (48 * 32**3)
    assert abs(ratio - expect) / expect < 1e-9
    assert large.roofline_seconds > small.roofline_seconds


def test_render_table_mentions_classes():
    text = render_table()
    assert "small" in text and "large" in text
    assert "KiB" in text
