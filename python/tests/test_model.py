"""L2 dense evaluator vs a straightforward NumPy oracle on random loop-free
strategies over random small graphs."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import dense_eval

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


def numpy_oracle(pd, pl_, pr, r, a, w, lp, lk, lm, cp, ck):
    """Direct NumPy evaluation of §II/§III on dense tensors."""
    s, n = r.shape
    inv = np.linalg.inv  # loop-free => (I - Phi^T) invertible with spectral radius < 1

    t_minus = np.zeros((s, n))
    t_plus = np.zeros((s, n))
    dt_plus = np.zeros((s, n))
    dt_r = np.zeros((s, n))

    for si in range(s):
        t_minus[si] = r[si] @ inv(np.eye(n) - pd[si])
    g = t_minus * pl_
    for si in range(s):
        t_plus[si] = (a[si] * g[si]) @ inv(np.eye(n) - pr[si])

    F = np.einsum("si,sij->ij", t_minus, pd) + np.einsum("si,sij->ij", t_plus, pr)
    G = np.sum(w * g, axis=0)

    def cost(f, param, kind, mask):
        lin_d, lin_dp = param * f, param
        gap = np.maximum(param - f, 1e-30)
        que_d, que_dp = f / gap, param / gap**2
        d = np.where(kind > 0.5, que_d, lin_d) * (mask > 0.5)
        dp = np.where(kind > 0.5, que_dp, lin_dp) * (mask > 0.5)
        return d, dp

    D, Dp = cost(F, lp, lk, lm)
    C, Cp = cost(G, cp, ck, np.ones_like(G))
    T = D.sum() + C.sum()

    for si in range(s):
        bias = np.einsum("ij,ij->i", pr[si], Dp)
        dt_plus[si] = bias @ inv(np.eye(n) - pr[si].T)
    for si in range(s):
        bias = pl_[si] * (w[si] * Cp + a[si] * dt_plus[si]) + np.einsum(
            "ij,ij->i", pd[si], Dp
        )
        dt_r[si] = bias @ inv(np.eye(n) - pd[si].T)

    return T, F, G, Dp, Cp, dt_plus, dt_r, t_minus, t_plus


@st.composite
def random_instance(draw):
    """Random loop-free strategy over a random DAG-ordered graph."""
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    n = draw(st.sampled_from([8, 16]))
    s = draw(st.integers(min_value=1, max_value=3))

    # random permutation gives a topological order; route only "forward"
    order = rng.permutation(n)
    rank = np.empty(n, int)
    rank[order] = np.arange(n)

    lm = np.zeros((n, n), np.float32)
    for i in range(n):
        for j in range(n):
            if i != j and rng.uniform() < 0.4:
                lm[i, j] = 1.0
    lp = rng.uniform(5, 15, (n, n)).astype(np.float32) * lm
    lk = (rng.uniform(0, 1, (n, n)) > 0.5).astype(np.float32)

    pd = np.zeros((s, n, n), np.float32)
    pr = np.zeros((s, n, n), np.float32)
    pl_ = np.zeros((s, n), np.float32)
    r = np.zeros((s, n), np.float32)
    dests = rng.integers(0, n, s)
    for si in range(s):
        for i in range(n):
            fwd = [j for j in range(n) if lm[i, j] > 0 and rank[j] > rank[i]]
            # data plane: split between local compute and forward edges
            weights = rng.uniform(0.1, 1.0, len(fwd) + 1)
            weights /= weights.sum()
            pl_[si, i] = weights[0]
            for k, j in enumerate(fwd):
                pd[si, i, j] = weights[k + 1]
            # result plane: forward-only split (dest row stays zero)
            if i != dests[si] and fwd:
                wts = rng.uniform(0.1, 1.0, len(fwd))
                wts /= wts.sum()
                for k, j in enumerate(fwd):
                    pr[si, i, j] = wts[k]
            elif i != dests[si]:
                pl_[si, i] = 1.0  # no forward edges: everything local
                pd[si, i, :] = 0.0
        r[si] = rng.uniform(0, 1, n).astype(np.float32) * (rng.uniform(0, 1, n) < 0.4)
    a = rng.uniform(0.2, 2.0, s).astype(np.float32)
    w = rng.uniform(0.5, 2.0, (s, n)).astype(np.float32)
    cp = rng.uniform(20, 40, n).astype(np.float32)
    ck = (rng.uniform(0, 1, n) > 0.5).astype(np.float32)
    return pd, pl_, pr, r, a, w, lp, lk, lm, cp, ck


@given(random_instance())
def test_dense_eval_matches_numpy_oracle(inst):
    pd, pl_, pr, r, a, w, lp, lk, lm, cp, ck = inst
    n = r.shape[1]
    got = dense_eval(
        *(jnp.array(x) for x in inst), iters=n, block_n=min(128, n)
    )
    want = numpy_oracle(*(np.asarray(x, np.float64) for x in inst))
    names = [
        "T", "F", "G", "Dp", "Cp", "dt_plus", "dt_r", "t_minus", "t_plus",
    ]
    for name, gv, wv in zip(names, got, want):
        np.testing.assert_allclose(
            np.asarray(gv), wv, rtol=2e-4, atol=2e-4, err_msg=name
        )


def test_shapes_and_dtypes():
    n, s = 8, 2
    zeros2 = jnp.zeros((s, n), jnp.float32)
    zeros3 = jnp.zeros((s, n, n), jnp.float32)
    eye_mask = jnp.ones((n, n), jnp.float32)
    out = dense_eval(
        zeros3, jnp.ones((s, n), jnp.float32), zeros3, zeros2,
        jnp.ones((s,), jnp.float32), jnp.ones((s, n), jnp.float32),
        jnp.ones((n, n), jnp.float32), jnp.zeros((n, n), jnp.float32), eye_mask,
        jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32),
        iters=n, block_n=8,
    )
    t, f, g = out[0], out[1], out[2]
    assert t.shape == ()
    assert f.shape == (n, n)
    assert g.shape == (n,)
    assert all(o.dtype == jnp.float32 for o in out[1:])


def test_zero_input_zero_cost():
    n, s = 8, 1
    out = dense_eval(
        jnp.zeros((s, n, n), jnp.float32), jnp.ones((s, n), jnp.float32),
        jnp.zeros((s, n, n), jnp.float32), jnp.zeros((s, n), jnp.float32),
        jnp.ones((s,), jnp.float32), jnp.ones((s, n), jnp.float32),
        jnp.ones((n, n), jnp.float32), jnp.ones((n, n), jnp.float32),
        jnp.ones((n, n), jnp.float32), jnp.ones((n,), jnp.float32),
        jnp.ones((n,), jnp.float32), iters=n, block_n=8,
    )
    assert float(out[0]) == 0.0
