"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; fixed edge cases pin the
saturation/masking semantics. This is the CORE correctness signal for the
compute plane — the AOT artifacts contain exactly these kernels.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import SAT_BIG, link_cost, prop_step
from compile.kernels.ref import link_cost_ref, prop_step_ref, propagate_ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


# ---------------------------------------------------------------- link_cost
@st.composite
def cost_arrays(draw):
    blocks = draw(st.integers(min_value=1, max_value=4))
    n = 128 * blocks
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    f = rng.uniform(0.0, 8.0, n).astype(np.float32)
    param = rng.uniform(0.5, 12.0, n).astype(np.float32)
    kind = (rng.uniform(0, 1, n) > 0.5).astype(np.float32)
    mask = (rng.uniform(0, 1, n) > 0.25).astype(np.float32)
    return f, param, kind, mask


@given(cost_arrays())
def test_link_cost_matches_ref(arrays):
    f, param, kind, mask = arrays
    d, dp = link_cost(jnp.array(f), jnp.array(param), jnp.array(kind), jnp.array(mask))
    d_ref, dp_ref = link_cost_ref(f, param, kind, mask)
    np.testing.assert_allclose(d, d_ref, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(dp, dp_ref, rtol=1e-6, atol=1e-6)


def test_link_cost_linear_family():
    f = jnp.full((128,), 3.0, jnp.float32)
    param = jnp.full((128,), 2.0, jnp.float32)
    kind = jnp.zeros((128,), jnp.float32)
    mask = jnp.ones((128,), jnp.float32)
    d, dp = link_cost(f, param, kind, mask)
    np.testing.assert_allclose(d, 6.0, rtol=1e-6)
    np.testing.assert_allclose(dp, 2.0, rtol=1e-6)


def test_link_cost_queue_family():
    f = jnp.full((128,), 5.0, jnp.float32)
    cap = jnp.full((128,), 10.0, jnp.float32)
    kind = jnp.ones((128,), jnp.float32)
    mask = jnp.ones((128,), jnp.float32)
    d, dp = link_cost(f, cap, kind, mask)
    np.testing.assert_allclose(d, 1.0, rtol=1e-6)       # 5/(10-5)
    np.testing.assert_allclose(dp, 0.4, rtol=1e-6)      # 10/25


def test_link_cost_saturation_clamps():
    f = jnp.array([10.0, 11.0] + [0.0] * 126, jnp.float32)
    cap = jnp.full((128,), 10.0, jnp.float32)
    kind = jnp.ones((128,), jnp.float32)
    mask = jnp.ones((128,), jnp.float32)
    d, dp = link_cost(f, cap, kind, mask)
    assert float(d[0]) >= SAT_BIG and float(d[1]) >= SAT_BIG
    assert float(dp[0]) >= SAT_BIG
    assert np.isfinite(np.asarray(d)).all()  # clamped, not inf/NaN


def test_link_cost_mask_zeroes_padding():
    f = jnp.full((128,), 3.0, jnp.float32)
    param = jnp.full((128,), 1.0, jnp.float32)
    kind = jnp.zeros((128,), jnp.float32)
    mask = jnp.zeros((128,), jnp.float32)
    d, dp = link_cost(f, param, kind, mask)
    assert float(jnp.abs(d).sum()) == 0.0
    assert float(jnp.abs(dp).sum()) == 0.0


def test_link_cost_rejects_bad_block():
    with pytest.raises(ValueError):
        link_cost(
            jnp.zeros(100, jnp.float32),
            jnp.ones(100, jnp.float32),
            jnp.zeros(100, jnp.float32),
            jnp.ones(100, jnp.float32),
            block=128,
        )


# ---------------------------------------------------------------- prop_step
@st.composite
def prop_arrays(draw):
    s = draw(st.integers(min_value=1, max_value=5))
    n_pow = draw(st.sampled_from([8, 16, 32, 64]))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    t = rng.uniform(0, 2, (s, n_pow)).astype(np.float32)
    phi = rng.uniform(0, 1, (s, n_pow, n_pow)).astype(np.float32)
    r = rng.uniform(0, 1, (s, n_pow)).astype(np.float32)
    return t, phi, r


@given(prop_arrays())
def test_prop_step_matches_ref(arrays):
    t, phi, r = arrays
    out = prop_step(jnp.array(t), jnp.array(phi), jnp.array(r), block_n=min(128, t.shape[1]))
    ref = prop_step_ref(t, phi, r)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_step_block_invariance(seed):
    # different BlockSpec tilings must give identical results
    rng = np.random.default_rng(seed)
    s, n = 2, 32
    t = rng.uniform(0, 1, (s, n)).astype(np.float32)
    phi = rng.uniform(0, 1, (s, n, n)).astype(np.float32)
    r = rng.uniform(0, 1, (s, n)).astype(np.float32)
    full = prop_step(jnp.array(t), jnp.array(phi), jnp.array(r), block_n=32)
    tiled = prop_step(jnp.array(t), jnp.array(phi), jnp.array(r), block_n=8)
    np.testing.assert_allclose(full, tiled, rtol=1e-6, atol=1e-6)


def test_propagation_fixed_point_on_dag():
    # chain 0 -> 1 -> 2 -> 3; after N waves, t must be the exact fixed point
    s, n = 1, 8
    phi = np.zeros((s, n, n), np.float32)
    for i in range(3):
        phi[0, i, i + 1] = 1.0
    r = np.zeros((s, n), np.float32)
    r[0, 0] = 2.0
    t = propagate_ref(jnp.array(phi), jnp.array(r), n)
    # every chain node accumulates the source rate
    np.testing.assert_allclose(np.asarray(t)[0, :4], [2.0, 2.0, 2.0, 2.0], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(t)[0, 4:], 0.0)
    # kernel-based propagation agrees
    tk = jnp.zeros((s, n), jnp.float32)
    for _ in range(n):
        tk = prop_step(tk, jnp.array(phi), jnp.array(r), block_n=8)
    np.testing.assert_allclose(tk, t, rtol=1e-6)
