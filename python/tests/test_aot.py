"""AOT artifact emission: HLO text lowering + manifest schema."""

import json

import pytest

from compile import aot
from compile.model import INPUT_NAMES, OUTPUT_NAMES


def test_lower_small_class_produces_hlo_text():
    text = aot.lower_class(8, 2)
    assert "HloModule" in text
    assert "ENTRY" in text
    # must be pure text, parseable line-by-line
    assert all(len(line) < 100_000 for line in text.splitlines())


def test_manifest_written(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path)]
    # lower only a tiny class to keep the test fast
    orig = aot.SIZE_CLASSES
    aot.SIZE_CLASSES = [("tiny", 8, 2)]
    try:
        aot.main()
    finally:
        aot.SIZE_CLASSES = orig
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert manifest["inputs"] == INPUT_NAMES
    assert manifest["outputs"] == OUTPUT_NAMES
    assert manifest["classes"][0]["n"] == 8
    assert (tmp_path / manifest["classes"][0]["file"]).exists()


@pytest.mark.parametrize("n,s", [(8, 2), (16, 4)])
def test_lowered_text_mentions_while_loop(n, s):
    # the propagation fori_loop must survive lowering as an HLO while
    text = aot.lower_class(n, s)
    assert "while" in text.lower()
