"""L2: the dense per-iteration evaluation core, in JAX, calling the L1
Pallas kernels.

``dense_eval`` computes — for a whole network, all tasks at once, over
dense padded tensors — everything one optimizer iteration needs from the
flow model (§II) and the marginal recursions (§III):

  forward:   t- (eq. 1/3), g (eq. 4), t+ (eq. 2/6), F, G
  costs:     D(F), D'(F), C(G), C'(G), T (eq. 8)
  backward:  dT/dt+ (eq. 12), dT/dr (eq. 11)

The loop-free fixed points are solved exactly with ``iters`` propagation
waves of the ``prop_step`` kernel (iters >= N-1 suffices; see
kernels/prop_step.py). The backward recursions are the transposed
propagation with bias terms built from D'/C' — the same kernel applied to
the transposed routing tensors.

This function is lowered ONCE per size class by ``aot.py`` into HLO text;
the rust runtime (rust/src/runtime/) loads and executes it on the PJRT CPU
client on its hot path. Python never runs at request time.

Tensor layout (all float32):
  phi_data   [S, N, N]  data routing fractions (row i -> col j)
  phi_local  [S, N]     local-computation fractions (slot 0 of the paper)
  phi_result [S, N, N]  result routing fractions
  r          [S, N]     exogenous input rates
  a          [S]        result-size ratio a_m per task
  w          [S, N]     computation weight w_{i, m_s} per task x node
  link_param [N, N]     cost parameter per directed edge (unit or capacity)
  link_kind  [N, N]     0 = Linear, 1 = Queue
  link_mask  [N, N]     1 where the edge exists
  comp_param [N], comp_kind [N]   computation-cost curves per node

Outputs (in order):
  T [],  F [N,N],  G [N],  dp_link [N,N] (D'),  cp_node [N] (C'),
  dt_plus [S,N],  dt_r [S,N],  t_minus [S,N],  t_plus [S,N]
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import link_cost, prop_step


def _propagate(phi, bias, iters, block_n):
    """Exact loop-free fixed point t = t phi + bias via `iters` waves."""

    def body(_, t):
        return prop_step(t, phi, bias, block_n=block_n)

    t0 = jnp.zeros_like(bias)
    return jax.lax.fori_loop(0, iters, body, t0)


@functools.partial(jax.jit, static_argnames=("iters", "block_n"))
def dense_eval(
    phi_data,
    phi_local,
    phi_result,
    r,
    a,
    w,
    link_param,
    link_kind,
    link_mask,
    comp_param,
    comp_kind,
    *,
    iters,
    block_n=128,
):
    s, n = r.shape

    # ---- forward: data traffic (eq. 1/3), computational input (eq. 4) ----
    t_minus = _propagate(phi_data, r, iters, block_n)
    g = t_minus * phi_local  # [S, N]

    # ---- forward: result traffic (eq. 2/6) ----
    res_src = a[:, None] * g
    t_plus = _propagate(phi_result, res_src, iters, block_n)

    # ---- aggregate flows ----
    f_data = t_minus[:, :, None] * phi_data      # [S, N, N]
    f_res = t_plus[:, :, None] * phi_result
    big_f = jnp.sum(f_data + f_res, axis=0)      # [N, N]
    big_g = jnp.sum(w * g, axis=0)               # [N]

    # ---- costs + first derivatives (L1 kernel) ----
    d_link_flat, dp_link_flat = link_cost(
        big_f.reshape(-1),
        link_param.reshape(-1),
        link_kind.reshape(-1),
        link_mask.reshape(-1),
        block=min(128, n * n),
    )
    d_link = d_link_flat.reshape(n, n)
    dp_link = dp_link_flat.reshape(n, n)
    c_node, cp_node = link_cost(
        big_g,
        comp_param,
        comp_kind,
        jnp.ones_like(big_g),
        block=min(128, n),
    )
    total = jnp.sum(d_link) + jnp.sum(c_node)

    # ---- backward: dT/dt+ (eq. 12) ----
    # bias_plus[s, i] = sum_j phi_result[s,i,j] * D'_ij
    bias_plus = jnp.einsum("sij,ij->si", phi_result, dp_link)
    phi_result_t = jnp.transpose(phi_result, (0, 2, 1))
    dt_plus = _propagate(phi_result_t, bias_plus, iters, block_n)

    # ---- backward: dT/dr (eq. 11) ----
    bias_r = phi_local * (w * cp_node[None, :] + a[:, None] * dt_plus) + jnp.einsum(
        "sij,ij->si", phi_data, dp_link
    )
    phi_data_t = jnp.transpose(phi_data, (0, 2, 1))
    dt_r = _propagate(phi_data_t, bias_r, iters, block_n)

    return (
        total,
        big_f,
        big_g,
        dp_link,
        cp_node,
        dt_plus,
        dt_r,
        t_minus,
        t_plus,
    )


def example_args(n, s):
    """ShapeDtypeStructs for lowering at a given size class."""
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    return (
        sd((s, n, n), f32),  # phi_data
        sd((s, n), f32),     # phi_local
        sd((s, n, n), f32),  # phi_result
        sd((s, n), f32),     # r
        sd((s,), f32),       # a
        sd((s, n), f32),     # w
        sd((n, n), f32),     # link_param
        sd((n, n), f32),     # link_kind
        sd((n, n), f32),     # link_mask
        sd((n,), f32),       # comp_param
        sd((n,), f32),       # comp_kind
    )


INPUT_NAMES = [
    "phi_data",
    "phi_local",
    "phi_result",
    "r",
    "a",
    "w",
    "link_param",
    "link_kind",
    "link_mask",
    "comp_param",
    "comp_kind",
]

OUTPUT_NAMES = [
    "total_cost",
    "link_flow",
    "workload",
    "dp_link",
    "cp_node",
    "dt_plus",
    "dt_r",
    "t_minus",
    "t_plus",
]
