"""L1: Pallas kernels for the per-iteration numeric hot-spots, plus their
pure-jnp oracles (ref)."""

from .link_cost import link_cost, SAT_BIG
from .prop_step import prop_step
from . import ref

__all__ = ["link_cost", "prop_step", "ref", "SAT_BIG"]
