"""L1 Pallas kernel: one flow-propagation wave.

The loop-free fixed point ``t = t Φ + r`` (data traffic, eq. 1/3; result
traffic, eq. 2/6; and the transposed marginal recursions 11/12) is solved
by at most ``N-1`` exact waves of

    t'[s, j] = sum_i t[s, i] * phi[s, i, j] + r[s, j]

i.e. a batched vector-matrix product plus bias. This kernel computes one
wave.

TPU mapping (DESIGN.md §3.4): grid over (task, node-block); each program
computes ``t[s, :] @ phi[s, :, BN-block] + r[s, block]`` as a
``[1, N] x [N, BN]`` dot — an MXU-shaped contraction with the stationary
operand resident in VMEM. VMEM per program: N*BN*4 bytes for the Φ tile
(64 KiB at N=BN=128) plus two vectors; well under the ~16 MiB budget, so
double-buffering the Φ tiles is available to the Mosaic pipeliner.
``interpret=True`` for CPU-PJRT executability.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(t_ref, phi_ref, r_ref, out_ref):
    # t_ref:   [1, N]      (full row for task s)
    # phi_ref: [1, N, BN]  (column block of task s's routing matrix)
    # r_ref:   [1, BN]
    t = t_ref[0, :]
    phi = phi_ref[0, :, :]
    r = r_ref[0, :]
    out_ref[0, :] = jnp.dot(t, phi, preferred_element_type=jnp.float32) + r


@functools.partial(jax.jit, static_argnames=("block_n",))
def prop_step(t, phi, r, *, block_n=128):
    """One propagation wave ``t' = t Φ + r`` batched over tasks.

    t:   [S, N] f32
    phi: [S, N, N] f32 (row-stochastic routing fractions per task)
    r:   [S, N] f32 source term
    """
    s, n = t.shape
    assert phi.shape == (s, n, n), phi.shape
    assert r.shape == (s, n)
    bn = min(block_n, n)
    if n % bn != 0:
        raise ValueError(f"N={n} not divisible by block_n={bn}")
    grid = (s, n // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n), lambda si, bi: (si, 0)),
            pl.BlockSpec((1, n, bn), lambda si, bi: (si, 0, bi)),
            pl.BlockSpec((1, bn), lambda si, bi: (si, bi)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda si, bi: (si, bi)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        interpret=True,
    )(t, phi, r)
