"""L1 Pallas kernel: congestion cost + derivative evaluation.

Evaluates the paper's convex cost families elementwise over a flat array of
flows (links and computation units share the same curve families, §II):

  kind 0 (Linear): D  = param * F            D' = param
  kind 1 (Queue):  D  = F / (param - F)      D' = param / (param - F)^2

Entries with ``mask == 0`` (padding / non-edges) produce zeros. Saturated
queue entries (F >= param) are clamped to a large finite value ``SAT_BIG``
so the AOT artifact stays NaN-free; the rust coordinator treats any value
>= ``SAT_BIG`` as infinite. (The artifact is only queried on feasible
states, where saturation does not occur — the clamp is a safety rail.)

TPU mapping (DESIGN.md §3.4): this is a pure VPU elementwise kernel. The
flat array is tiled in ``BLOCK``-sized chunks via the grid; each tile is a
single VMEM-resident vector op, last-dim aligned to the 128-lane registers.
``interpret=True`` everywhere — the CPU PJRT client cannot execute Mosaic
custom calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Value used to represent "saturated / infinite" inside the f32 artifact.
SAT_BIG = 1e30
# Keep-away margin from the queue pole.
EPS = 1e-30


def _kernel(f_ref, param_ref, kind_ref, mask_ref, d_ref, dp_ref):
    f = f_ref[...]
    param = param_ref[...]
    kind = kind_ref[...]
    mask = mask_ref[...]

    # Linear family
    d_lin = param * f
    dp_lin = param

    # Queue family (guard the pole; saturation clamps to SAT_BIG)
    gap = param - f
    safe_gap = jnp.maximum(gap, EPS)
    d_que = f / safe_gap
    dp_que = param / (safe_gap * safe_gap)
    saturated = gap <= 0.0
    d_que = jnp.where(saturated, SAT_BIG, d_que)
    dp_que = jnp.where(saturated, SAT_BIG, dp_que)

    is_queue = kind > 0.5
    d = jnp.where(is_queue, d_que, d_lin)
    dp = jnp.where(is_queue, dp_que, dp_lin)

    on = mask > 0.5
    d_ref[...] = jnp.where(on, d, 0.0)
    dp_ref[...] = jnp.where(on, dp, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def link_cost(f, param, kind, mask, *, block=128):
    """Evaluate (D(F), D'(F)) elementwise over flat f32 arrays.

    All four inputs share one flat shape whose length must be divisible by
    ``block``. Returns ``(d, dp)`` of the same shape.
    """
    (n,) = f.shape
    if n % block != 0:
        raise ValueError(f"length {n} not divisible by block {block}")
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    d, dp = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(f, param, kind, mask)
    return d, dp
