"""Pure-jnp oracles for the Pallas kernels and the dense evaluator.

These are the correctness references: pytest sweeps shapes/values with
hypothesis and asserts the kernels (and the composed ``model.dense_eval``)
match to float32 tolerance. Nothing here is ever lowered into the
artifacts.
"""

import jax.numpy as jnp

from .link_cost import EPS, SAT_BIG


def link_cost_ref(f, param, kind, mask):
    """(D, D') under the Linear/Queue families, masked — see link_cost."""
    f = jnp.asarray(f, jnp.float32)
    param = jnp.asarray(param, jnp.float32)
    kind = jnp.asarray(kind, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)

    d_lin = param * f
    dp_lin = param
    gap = param - f
    safe_gap = jnp.maximum(gap, EPS)
    d_que = jnp.where(gap <= 0.0, SAT_BIG, f / safe_gap)
    dp_que = jnp.where(gap <= 0.0, SAT_BIG, param / (safe_gap * safe_gap))

    is_queue = kind > 0.5
    d = jnp.where(is_queue, d_que, d_lin)
    dp = jnp.where(is_queue, dp_que, dp_lin)
    on = mask > 0.5
    return jnp.where(on, d, 0.0), jnp.where(on, dp, 0.0)


def prop_step_ref(t, phi, r):
    """t' = t Φ + r, batched over the leading (task) axis."""
    return jnp.einsum("sn,snm->sm", t, phi) + r


def propagate_ref(phi, r, iters):
    """Run ``iters`` waves from t = 0 — the exact loop-free fixed point
    when ``iters >= N - 1``."""
    t = jnp.zeros_like(r)
    for _ in range(iters):
        t = prop_step_ref(t, phi, r)
    return t
