"""AOT lowering: jax (L2+L1) -> HLO text artifacts + manifest.json.

HLO *text* is the interchange format (NOT serialized HloModuleProto): the
xla crate's bundled xla_extension 0.5.1 rejects jax>=0.5 protos whose
instruction ids exceed INT_MAX, while the text parser reassigns ids — see
/opt/xla-example/README.md and gen_hlo.py there.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one artifact per size class:
    dense_eval_small.hlo.txt   N=32,  S=48
    dense_eval_large.hlo.txt   N=128, S=128
plus manifest.json describing tensor shapes/order so the rust runtime can
marshal without recompiling python knowledge.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import INPUT_NAMES, OUTPUT_NAMES, dense_eval, example_args

# (name, N, S): padded size classes. N and S are upper bounds; the rust
# side zero-pads any smaller network into the smallest fitting class.
SIZE_CLASSES = [
    ("small", 32, 48),
    ("large", 128, 128),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_class(n: int, s: int) -> str:
    fn = lambda *args: dense_eval(*args, iters=n, block_n=min(128, n))
    lowered = jax.jit(fn).lower(*example_args(n, s))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    classes = []
    for name, n, s in SIZE_CLASSES:
        text = lower_class(n, s)
        fname = f"dense_eval_{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {path}: {len(text)} chars (N={n}, S={s})")
        classes.append(
            {
                "name": name,
                "file": fname,
                "n": n,
                "s": s,
                "iters": n,
            }
        )

    manifest = {
        "format": "hlo-text",
        "entry": "dense_eval",
        "inputs": INPUT_NAMES,
        "outputs": OUTPUT_NAMES,
        "sat_big": 1e30,
        "classes": classes,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
