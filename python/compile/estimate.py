"""TPU resource estimation for the L1 kernels (DESIGN.md §7).

Pallas kernels run under ``interpret=True`` here (CPU PJRT cannot execute
Mosaic custom calls), so wallclock is meaningless as a TPU proxy. What CAN
be derived exactly from the BlockSpecs is the *structure*: VMEM residency
per program, MXU tile occupancy, arithmetic intensity, and the HBM traffic
of one optimizer iteration. This module computes those numbers per size
class and renders the §Perf table.

Usage:  python -m compile.estimate            # print the table
        (also imported by tests)
"""

from dataclasses import dataclass

# TPU v4-ish reference envelope (per core) used for roofline ratios.
VMEM_BYTES = 16 * 2**20
MXU_DIM = 128
HBM_BW_BYTES = 1.2e12  # 1.2 TB/s
PEAK_F32_FLOPS = 70e12  # ~70 TF/s f32 (MXU)


@dataclass
class PropStepEstimate:
    """One `prop_step` program instance: t'[s, block] = t[s,:] @ Φ[s,:,block] + r."""

    n: int
    s: int
    block_n: int

    @property
    def grid(self):
        return (self.s, self.n // self.block_n)

    @property
    def vmem_bytes(self) -> int:
        # Φ tile [1, N, BN] + t row [1, N] + r block [1, BN] + out [1, BN]
        return 4 * (self.n * self.block_n + self.n + 2 * self.block_n)

    @property
    def vmem_fraction(self) -> float:
        return self.vmem_bytes / VMEM_BYTES

    @property
    def flops_per_program(self) -> int:
        return 2 * self.n * self.block_n  # MAC = 2 flops

    @property
    def bytes_per_program(self) -> int:
        # Φ tile streams from HBM; t/r/out are negligible next to it
        return 4 * self.n * self.block_n

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_program / self.bytes_per_program

    @property
    def mxu_utilization(self) -> float:
        """Fraction of the 128x128 systolic array active per pass.

        The contraction is [1, N] x [N, BN]: one row of the MXU's
        stationary operand dimension is live -> 1/128 per-pass occupancy,
        amortized over the N/128 passes needed for the K dimension. In
        terms of *useful MACs vs the array's capacity over those passes*:
        (1 * BN) / (128 * 128) per pass.
        """
        return min(self.block_n, MXU_DIM) / (MXU_DIM * MXU_DIM)

    @property
    def bandwidth_bound_time(self) -> float:
        """Seconds per full wave (all programs), HBM-roofline."""
        programs = self.grid[0] * self.grid[1]
        return programs * self.bytes_per_program / HBM_BW_BYTES


@dataclass
class IterationEstimate:
    """One dense_eval call: 4 recursions x N waves of prop_step + costs."""

    n: int
    s: int
    block_n: int

    @property
    def total_flops(self) -> float:
        # 4 propagations (t-, t+, dT/dt+, dT/dr) x N waves x S·N·BN-grid
        wave = 2 * self.s * self.n * self.n
        return 4 * self.n * wave

    @property
    def total_hbm_bytes(self) -> float:
        # Φ tensors re-stream every wave unless resident: worst case
        wave_bytes = 4 * self.s * self.n * self.n
        return 4 * self.n * wave_bytes

    @property
    def roofline_seconds(self) -> float:
        return max(
            self.total_flops / PEAK_F32_FLOPS,
            self.total_hbm_bytes / HBM_BW_BYTES,
        )


def size_classes():
    from .aot import SIZE_CLASSES

    return SIZE_CLASSES


def render_table() -> str:
    rows = [
        "class   N    S    VMEM/prog  VMEM%   AI(flop/B)  MXU/pass  wave(BW-bound)  iter roofline",
    ]
    for name, n, s in size_classes():
        p = PropStepEstimate(n=n, s=s, block_n=min(128, n))
        it = IterationEstimate(n=n, s=s, block_n=min(128, n))
        rows.append(
            f"{name:<7}{n:<5}{s:<5}{p.vmem_bytes/1024:>7.0f}KiB"
            f"{100*p.vmem_fraction:>7.2f}%"
            f"{p.arithmetic_intensity:>10.2f}"
            f"{100*p.mxu_utilization:>9.2f}%"
            f"{1e6*p.bandwidth_bound_time:>13.2f}µs"
            f"{1e3*it.roofline_seconds:>12.3f}ms"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    print(render_table())
