"""Build-time python package: L2 jax model + L1 pallas kernels + AOT
lowering. Never imported at runtime — rust loads the emitted HLO text."""
