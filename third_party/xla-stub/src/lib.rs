//! Type-level stub of the PJRT-backed `xla` crate.
//!
//! The real crate links the XLA C libraries (`xla_extension`) to compile
//! and execute HLO on a PJRT client. Those libraries are not available in
//! this build environment, so this stub reproduces exactly the API surface
//! `cecflow::runtime::engine` uses — enough for `cargo check/build
//! --features pjrt` to type-check and link — while every runtime entry
//! point returns a descriptive error instead of executing.
//!
//! To run the accelerated engine for real, replace this path dependency in
//! the root `Cargo.toml` with the real `xla` crate and install its
//! `xla_extension` libraries, then rebuild with `--features pjrt`.

use std::fmt;

/// Error type mirroring `xla::Error` far enough for `?`-conversion into
/// `anyhow::Error`.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub() -> Error {
        Error(
            "the `xla` crate in this workspace is a build stub: the PJRT runtime and \
             XLA C libraries are not installed. Swap in the real `xla` crate (and run \
             `make artifacts`) to execute AOT artifacts"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Parsed HLO module (stub: carries nothing).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO *text* file. Stub: always errors.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host-side literal tensor.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions. Stub: always errors.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub())
    }

    /// Decompose a tuple literal into its elements. Stub: always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::stub())
    }

    /// Copy out as a host vector. Stub: always errors.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }
}

/// Device-side buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer to the host synchronously. Stub: always errors.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute on the client's devices. Stub: always errors.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

/// A PJRT client.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU client. Stub: always errors (no XLA libraries).
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation. Stub: always errors.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_entry_points_error_loudly() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(err.to_string().contains("build stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32]);
        assert!(lit.reshape(&[1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
