//! Vendored, minimal stand-in for the `anyhow` crate (1.x API subset).
//!
//! The build environment for this repository has no crates.io access, so
//! the pieces of `anyhow` the workspace actually uses are reimplemented
//! here with the same names and semantics:
//!
//! * [`Error`] — a boxed dynamic error with a context chain. `Display`
//!   prints the outermost message; the alternate form (`{:#}`) prints the
//!   whole chain separated by `: `, and `Debug` prints a `Caused by:`
//!   listing, matching real-anyhow conventions.
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a defaultable
//!   error parameter.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`], [`format_err!`] macros.
//!
//! Swapping back to the real crate is a one-line change in the root
//! `Cargo.toml`; the call sites in this workspace need no changes.
//!
//! Known divergence from real anyhow: the expression arm of [`anyhow!`]
//! (`anyhow!(some_error_value)`) formats the value as a message instead
//! of preserving it as a typed source (real anyhow keeps the error chain
//! via autoref specialization). No call site in this workspace uses that
//! arm — prefer `Error::new(e)` / `.context(..)` when wrapping an error
//! value, which do preserve the chain here and under the real crate.

use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with a defaultable error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed dynamic error with context. Deliberately does **not** implement
/// `std::error::Error` so the blanket `From<E: std::error::Error>` below
/// stays coherent (same trick as the real crate).
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Wrap any concrete error type.
    pub fn new<E>(error: E) -> Self
    where
        E: StdError + Send + Sync + 'static,
    {
        Error {
            inner: Box::new(error),
        }
    }

    /// Build an error from a printable message.
    pub fn msg<M>(message: M) -> Self
    where
        M: Display + Debug + Send + Sync + 'static,
    {
        Error {
            inner: Box::new(MessageError(message)),
        }
    }

    fn from_display<C>(message: C) -> Self
    where
        C: Display + Send + Sync + 'static,
    {
        Error {
            inner: Box::new(DisplayError(message)),
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C>(self, context: C) -> Self
    where
        C: Display + Send + Sync + 'static,
    {
        Error {
            inner: Box::new(ContextError {
                context,
                source: self.inner,
            }),
        }
    }

    /// Iterate the error chain, outermost context first.
    pub fn chain(&self) -> Chain<'_> {
        let first: &(dyn StdError + 'static) = &*self.inner;
        Chain { next: Some(first) }
    }

    /// The innermost (original) error of the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        self.chain().last().expect("chain is never empty")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        if f.alternate() {
            for cause in self.chain().skip(1) {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut causes = self.chain().skip(1).peekable();
        if causes.peek().is_some() {
            write!(f, "\n\nCaused by:")?;
            for cause in causes {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Iterator over an [`Error`]'s cause chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let current = self.next?;
        self.next = current.source();
        Some(current)
    }
}

// ---- concrete error payloads ------------------------------------------

struct MessageError<M>(M);

impl<M: Display> Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.0, f)
    }
}

impl<M: Debug> Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Debug::fmt(&self.0, f)
    }
}

impl<M> StdError for MessageError<M> where M: Display + Debug {}

struct DisplayError<C>(C);

impl<C: Display> Display for DisplayError<C> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.0, f)
    }
}

impl<C: Display> Debug for DisplayError<C> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.0, f)
    }
}

impl<C> StdError for DisplayError<C> where C: Display {}

struct ContextError<C> {
    context: C,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl<C: Display> Display for ContextError<C> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.context, f)
    }
}

impl<C: Display> Debug for ContextError<C> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.context, f)
    }
}

impl<C> StdError for ContextError<C>
where
    C: Display,
{
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        let src: &(dyn StdError + 'static) = &*self.source;
        Some(src)
    }
}

// ---- Context extension trait ------------------------------------------

mod ext {
    use super::*;

    /// Sealed adapter: anything that can be upgraded to [`Error`] with an
    /// added context frame. Implemented for all `std::error::Error` types
    /// and for [`Error`] itself (coherent because `Error` does not
    /// implement `std::error::Error`).
    pub trait StdErrorExt {
        fn ext_context<C>(self, context: C) -> Error
        where
            C: Display + Send + Sync + 'static;
    }

    impl<E> StdErrorExt for E
    where
        E: StdError + Send + Sync + 'static,
    {
        fn ext_context<C>(self, context: C) -> Error
        where
            C: Display + Send + Sync + 'static,
        {
            Error::new(self).context(context)
        }
    }

    impl StdErrorExt for Error {
        fn ext_context<C>(self, context: C) -> Error
        where
            C: Display + Send + Sync + 'static,
        {
            self.context(context)
        }
    }
}

/// Attach context to failure values (`Result` and `Option`).
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdErrorExt + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|error| error.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|error| error.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::from_display(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::from_display(f()))
    }
}

// ---- macros ------------------------------------------------------------

/// Build an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Alias of [`anyhow!`], kept for API parity with the real crate.
#[macro_export]
macro_rules! format_err {
    ($($t:tt)*) => { $crate::anyhow!($($t)*) };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Leaf;

    impl Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "leaf failure")
        }
    }

    impl StdError for Leaf {}

    fn fails() -> Result<()> {
        Err(Error::new(Leaf))
    }

    #[test]
    fn display_shows_outermost_context() {
        let err = fails().context("outer").unwrap_err();
        assert_eq!(err.to_string(), "outer");
    }

    #[test]
    fn alternate_display_shows_chain() {
        let err = fails().context("mid").context("outer").unwrap_err();
        assert_eq!(format!("{err:#}"), "outer: mid: leaf failure");
    }

    #[test]
    fn debug_lists_causes() {
        let err = fails().context("outer").unwrap_err();
        let dbg = format!("{err:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("leaf failure"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io_fail() -> Result<String> {
            let text = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(text)
        }
        assert!(io_fail().is_err());
    }

    #[test]
    fn option_context_and_with_context() {
        let none: Option<u32> = None;
        let err = none.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
        let none: Option<u32> = None;
        let err = none.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(err.to_string(), "missing thing");
    }

    #[test]
    fn context_on_anyhow_result() {
        let err = fails().context("inner").context("outer").unwrap_err();
        assert_eq!(err.chain().count(), 3);
        assert_eq!(err.root_cause().to_string(), "leaf failure");
    }

    #[test]
    fn macros_build_errors() {
        let x = 7;
        let err = anyhow!("bad value {x}");
        assert_eq!(err.to_string(), "bad value 7");

        fn bails() -> Result<()> {
            bail!("fail {}", 1)
        }
        assert_eq!(bails().unwrap_err().to_string(), "fail 1");

        fn ensures(v: i32) -> Result<i32> {
            ensure!(v > 0, "must be positive, got {v}");
            Ok(v)
        }
        assert!(ensures(1).is_ok());
        assert_eq!(
            ensures(-2).unwrap_err().to_string(),
            "must be positive, got -2"
        );

        fn ensures_bare(v: i32) -> Result<i32> {
            ensure!(v > 0);
            Ok(v)
        }
        assert!(ensures_bare(-1).unwrap_err().to_string().contains("v > 0"));
    }
}
