//! IoT-over-fog scenario (Fig. 1 of the paper): sensors at the leaves of a
//! fog hierarchy feed computations whose results return to user devices.
//! Demonstrates the Fig. 5d placement effect: tasks with small results
//! (compression) are computed near the data; tasks with large results
//! (super-resolution, `a_m > 1`) are computed near the destination.
//!
//! ```bash
//! cargo run --release --example iot_fog
//! ```

use cecflow::algo::{Optimizer, Sgp};
use cecflow::coordinator::metrics::travel_distance;
use cecflow::coordinator::ScenarioSpec;
use cecflow::model::{compute_flows, Strategy};
use cecflow::util::table::Table;

fn main() -> anyhow::Result<()> {
    println!("IoT fog hierarchy (Table II 'fog' topology): a_m sweep\n");
    let mut table = Table::new(&["a_m", "L_data", "L_result", "interpretation"]);

    for (am, label) in [
        (0.2, "tiny results -> compute near sources"),
        (1.0, "balanced"),
        (4.0, "huge results -> compute near destination"),
    ] {
        // Build the fog scenario, then force every task type's result
        // ratio to the sweep value (isolating the a_m effect, Fig. 5d).
        let mut sc = ScenarioSpec::by_name("fog").unwrap().build(7);
        for a in sc.net.result_ratio.iter_mut() {
            *a = am;
        }
        // Large a_m multiplies all result flows: re-apply the scenario
        // builders' head-room guard so the initial point stays feasible.
        for _ in 0..40 {
            let phi0 = Strategy::local_compute_init(&sc.net);
            if compute_flows(&sc.net, &phi0)?.total_cost.is_finite() {
                break;
            }
            for c in sc.net.link_cost.iter_mut() {
                if let cecflow::model::CostFn::Queue { cap } = c {
                    *cap *= 1.3;
                }
            }
        }

        let mut phi = Strategy::local_compute_init(&sc.net);
        let mut sgp = Sgp::new();
        for _ in 0..40 {
            sgp.step(&sc.net, &mut phi)?;
        }
        let flows = compute_flows(&sc.net, &phi)?;
        let td = travel_distance(&sc.net, &flows);
        table.row(vec![
            format!("{am:.1}"),
            format!("{:.3}", td.l_data),
            format!("{:.3}", td.l_result),
            label.to_string(),
        ]);
    }
    table.print();
    println!(
        "\nAs a_m grows, the optimum moves computation toward the destination:\n\
         L_data rises (data travels further) and L_result falls (results\n\
         travel less) — the balance the paper highlights in Fig. 5d."
    );
    Ok(())
}
