//! UAV-swarm scenario (§I motivation): a 100-drone small-world mesh where
//! computation-heavy tasks must reach ground stations through multi-hop
//! routes. Compares SGP against SPOO (shortest-path with optimal
//! offloading) under growing congestion — the regime where joint
//! routing+offloading pays off (Fig. 5c shape).
//!
//! ```bash
//! cargo run --release --example uav_swarm
//! ```

use cecflow::coordinator::{run_algorithm, Algorithm, RunConfig, ScenarioSpec};
use cecflow::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    // The SW scenario of Table II is exactly the swarm shape: ring-like
    // connectivity with short- and long-range links.
    let spec = ScenarioSpec::by_name("sw").unwrap();
    println!("UAV swarm: small-world mesh, |V|=100, 320 links, 120 tasks\n");

    let mut table = Table::new(&["load", "SGP", "SPOO", "LPR", "SPOO/SGP", "LPR/SGP"]);
    let cfg = RunConfig {
        max_iters: 30,
        ..RunConfig::quick()
    };

    for scale in [0.6, 0.8, 1.0] {
        let mut sc = spec.build(2026);
        sc.net.scale_rates(scale);
        let sgp = run_algorithm(&sc.net, Algorithm::Sgp, &cfg)?;
        let spoo = run_algorithm(&sc.net, Algorithm::Spoo, &cfg)?;
        let lpr = run_algorithm(&sc.net, Algorithm::Lpr, &cfg)?;
        table.row(vec![
            format!("{scale:.1}x"),
            fnum(sgp.final_cost),
            fnum(spoo.final_cost),
            fnum(lpr.final_cost),
            format!("{:.2}", spoo.final_cost / sgp.final_cost),
            format!("{:.2}", lpr.final_cost / sgp.final_cost),
        ]);
    }
    table.print();
    println!(
        "\nThe SPOO/SGP and LPR/SGP ratios grow with load: fixed shortest-path\n\
         routing cannot spread flow around congested links, while SGP's\n\
         congestion-aware joint optimization can (the paper's Fig. 5c story)."
    );
    Ok(())
}
