//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! L3 (rust coordinator) runs the SGP optimizer; the per-iteration numeric
//! core — flow propagation, congestion costs, two-stage marginal
//! recursions — executes on a pluggable **dense backend**:
//!
//! * built with `--features pjrt` (and after `make artifacts`), the
//!   Pallas/JAX program AOT-lowered by `python/compile/aot.py` into
//!   `artifacts/*.hlo.txt` runs through the PJRT CPU client — Python is
//!   not running;
//! * in a default build, the exact pure-rust f64 `NativeBackend` drives
//!   the same `optimize_accelerated` loop, so the example always runs.
//!
//! The PJRT driver:
//!  1. loads + compiles the AOT artifacts,
//!  2. checks XLA↔native numerical parity on the live workload,
//!  3. optimizes a Table-II Abilene instance end-to-end on the XLA plane,
//!  4. compares the result against all four baselines,
//!  5. reports per-iteration latency for both data planes.
//!
//! Run:
//! ```bash
//! cargo run --release --example accelerated                   # native backend
//! cargo run --release --features pjrt --example accelerated   # after `make artifacts`
//! ```
//! The run is recorded in EXPERIMENTS.md §End-to-end.

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    use std::time::Instant;

    use cecflow::algo::Sgp;
    use cecflow::coordinator::{
        optimize, optimize_accelerated, run_algorithm, Algorithm, RunConfig, ScenarioSpec,
    };
    use cecflow::model::{compute_flows, compute_marginals, Strategy};
    use cecflow::runtime::{resolve_artifacts_dir, DenseEvaluator, Engine};
    use cecflow::util::table::{fnum, Table};

    // ---- 1. load the AOT artifacts --------------------------------------
    let t_load = Instant::now();
    let engine = Engine::load_filtered(&resolve_artifacts_dir()?, |c| c.name == "small")?;
    println!(
        "loaded + compiled AOT artifacts on PJRT '{}' in {:.2}s",
        engine.platform(),
        t_load.elapsed().as_secs_f64()
    );
    let evaluator = DenseEvaluator::new(&engine);

    // ---- 2. parity check on the live workload ---------------------------
    let sc = ScenarioSpec::by_name("abilene").unwrap().build(2026);
    let net = &sc.net;
    println!(
        "workload: Table II Abilene — |V|={} links={} |S|={} (fits AOT class 'small')",
        net.n(),
        net.e() / 2,
        net.s()
    );
    let phi0 = Strategy::local_compute_init(net);
    let native = compute_flows(net, &phi0)?;
    let marg = compute_marginals(net, &phi0, &native)?;
    let dense = evaluator.evaluate(net, &phi0)?;
    let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(b.abs()).max(1e-9);
    let mut worst = rel(native.total_cost, dense.total_cost);
    for s in 0..net.s() {
        for i in 0..net.n() {
            worst = worst.max(rel(marg.dt_r[s][i], dense.dt_r[s][i]));
        }
    }
    println!("XLA vs native parity on live state: max rel err {worst:.2e}");
    anyhow::ensure!(worst < 1e-3, "parity failure");

    // ---- 3. end-to-end optimization on the XLA plane --------------------
    let cfg = RunConfig {
        max_iters: 40,
        ..RunConfig::default()
    };
    let mut sgp = Sgp::new();
    let accel = optimize_accelerated(net, &mut sgp, &phi0, &cfg, &evaluator)?;
    println!(
        "\nSGP on the XLA data plane: T {} -> {} in {} iterations ({:.2}s, {:.1} ms/iter)",
        fnum(accel.costs[0]),
        fnum(accel.final_cost()),
        accel.costs.len(),
        accel.wall_seconds,
        1e3 * accel.wall_seconds / accel.costs.len() as f64
    );

    // native reference run for latency comparison
    let mut sgp_native = Sgp::new();
    let native_run = optimize(net, &mut sgp_native, &phi0, &cfg)?;
    println!(
        "SGP on the native plane:   T -> {} in {} iterations ({:.2}s, {:.1} ms/iter)",
        fnum(native_run.final_cost()),
        native_run.costs.len(),
        native_run.wall_seconds,
        1e3 * native_run.wall_seconds / native_run.costs.len() as f64
    );
    let agree = rel(accel.final_cost(), native_run.final_cost());
    println!("final-cost agreement: rel err {agree:.2e}");

    // ---- 4. headline comparison vs the baselines ------------------------
    println!("\nsteady-state total cost vs baselines (lower is better):");
    let mut table = Table::new(&["algorithm", "T", "vs SGP"]);
    let sgp_cost = accel.final_cost().min(native_run.final_cost());
    table.row(vec!["sgp (xla)".into(), fnum(accel.final_cost()), "1.00".into()]);
    for algo in [Algorithm::Spoo, Algorithm::Lcor, Algorithm::Lpr] {
        let out = run_algorithm(net, algo, &cfg)?;
        table.row(vec![
            out.algorithm.clone(),
            fnum(out.final_cost),
            format!("{:.2}", out.final_cost / sgp_cost),
        ]);
    }
    table.print();

    // ---- 5. raw data-plane latency --------------------------------------
    let reps = 20;
    let t0 = Instant::now();
    for _ in 0..reps {
        let _ = evaluator.evaluate(net, &phi0)?;
    }
    let xla_ms = 1e3 * t0.elapsed().as_secs_f64() / reps as f64;
    let t1 = Instant::now();
    for _ in 0..reps {
        let f = compute_flows(net, &phi0)?;
        let _ = compute_marginals(net, &phi0, &f)?;
    }
    let native_ms = 1e3 * t1.elapsed().as_secs_f64() / reps as f64;
    println!(
        "\ndata-plane evaluation latency: XLA {xla_ms:.2} ms  vs  native {native_ms:.3} ms \
         (N=32/S=48-padded artifact; the native sparse evaluator wins at this
         scale — see EXPERIMENTS.md §Perf for the crossover analysis)"
    );
    println!("\nEND-TO-END OK");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn main() -> anyhow::Result<()> {
    use cecflow::algo::Sgp;
    use cecflow::coordinator::{optimize, optimize_accelerated, RunConfig, ScenarioSpec};
    use cecflow::model::Strategy;
    use cecflow::runtime::{DenseBackend, NativeBackend};
    use cecflow::util::table::fnum;

    println!(
        "built without the `pjrt` cargo feature — running the accelerated optimization \
         loop on the pure-rust NativeBackend instead of the XLA data plane.\n\
         (rebuild with `--features pjrt` and run `make artifacts` for the PJRT driver)\n"
    );

    let sc = ScenarioSpec::by_name("abilene").unwrap().build(2026);
    let net = &sc.net;
    println!(
        "workload: Table II Abilene — |V|={} links={} |S|={}",
        net.n(),
        net.e() / 2,
        net.s()
    );
    let phi0 = Strategy::local_compute_init(net);
    let cfg = RunConfig {
        max_iters: 40,
        ..RunConfig::default()
    };

    let backend = NativeBackend;
    let mut sgp = Sgp::new();
    let accel = optimize_accelerated(net, &mut sgp, &phi0, &cfg, &backend)?;
    println!(
        "SGP via the '{}' dense backend: T {} -> {} in {} iterations ({:.2}s)",
        backend.name(),
        fnum(accel.costs[0]),
        fnum(accel.final_cost()),
        accel.costs.len(),
        accel.wall_seconds
    );

    let mut sgp_gs = Sgp::new();
    let reference = optimize(net, &mut sgp_gs, &phi0, &cfg)?;
    println!(
        "SGP native Gauss–Seidel reference: T -> {} in {} iterations",
        fnum(reference.final_cost()),
        reference.costs.len()
    );
    let rel = (accel.final_cost() - reference.final_cost()).abs()
        / reference.final_cost().abs().max(1e-9);
    println!("final-cost agreement: rel err {rel:.2e}");
    anyhow::ensure!(rel < 0.05, "dense-backend run diverged from the reference");
    println!("\nEND-TO-END OK (native backend)");
    Ok(())
}
