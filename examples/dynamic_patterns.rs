//! Guided tour of `coordinator::dynamics`: drive one scenario through a
//! time-varying task-pattern schedule and watch the warm-started
//! re-optimization (the paper's §IV "adaptive to changes in task
//! pattern" claim) beat the cold-started baseline epoch for epoch.
//!
//! Run: `cargo run --release --example dynamic_patterns`

use cecflow::coordinator::{AdaptiveRunner, PatternSchedule, RunConfig};
use cecflow::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig::quick();

    // A schedule is `kind:epochs:magnitude` — here a permanent 1.5× step
    // after epoch 0, then a bursty on/off pattern, then source/dest churn
    // that moves demand without changing its total.
    for label in ["step:3:1.5", "bursty:4:2", "churn:3:0.25"] {
        let schedule = PatternSchedule::parse(label)?;
        println!("\n=== abilene under {label} ===");

        // Warm: each epoch re-optimizes from the previous epoch's
        // converged strategy (rate shifts never invalidate it; moved
        // destinations are re-aimed along shortest paths). Cold: every
        // epoch restarts from the all-local point.
        let warm = AdaptiveRunner::warm(cfg).run_scenario("abilene", 42, 1.0, schedule)?;
        let cold = AdaptiveRunner::cold(cfg).run_scenario("abilene", 42, 1.0, schedule)?;

        let mut t = Table::new(&[
            "epoch",
            "shift T (warm)",
            "final T",
            "warm iters",
            "cold iters",
            "warm regret",
            "cold regret",
        ]);
        for (w, c) in warm.epochs.iter().zip(&cold.epochs) {
            t.row(vec![
                w.epoch.to_string(),
                fnum(w.shift_cost),
                fnum(w.final_cost),
                w.iterations.to_string(),
                c.iterations.to_string(),
                fnum(w.transient_regret),
                fnum(c.transient_regret),
            ]);
        }
        t.print();
        println!(
            "re-convergence iterations after the first epoch: warm {} vs cold {}",
            warm.reconvergence_iterations(),
            cold.reconvergence_iterations()
        );
    }

    println!(
        "\nSame engine from the CLI:\n\
         \x20 cecflow dynamic --scenario abilene --schedule step --epochs 3 --mode both\n\
         \x20 cecflow sweep --scenarios abilene,grid-torus --schedules static,step:3:1.5"
    );
    Ok(())
}
