//! Fig. 5b narrative: a Connected-ER network with four major servers loses
//! server S1 mid-run. SGP warm-start adapts and re-converges in a handful
//! of iterations; the non-scaled GP baseline takes many more.
//!
//! ```bash
//! cargo run --release --example failure_adaptation
//! ```

use cecflow::algo::{Gp, Sgp};
use cecflow::coordinator::connected_er_servers;
use cecflow::model::Strategy;
use cecflow::sim::run_with_failure;
use cecflow::util::table::{bar, fnum};

fn main() -> anyhow::Result<()> {
    let sc = connected_er_servers(42);
    let s1 = sc.servers[0];
    let fallback = sc.servers[1];
    println!(
        "Connected-ER (|V|=20, 40 links), servers at {:?}.\n\
         Server S1 = node {s1} fails at iteration 100; its tasks fall back to node {fallback}.\n",
        sc.servers
    );

    let phi0 = Strategy::local_compute_init(&sc.net);
    let fail_at = 100;
    let total = 200;

    let sgp = run_with_failure(&sc.net, Sgp::new, &phi0, fail_at, total, s1, fallback, 0.001)?;
    let gp = run_with_failure(
        &sc.net,
        || Gp::new(1.0),
        &phi0,
        fail_at,
        total,
        s1,
        fallback,
        0.001,
    )?;

    // cold-start convergence: first iteration within 0.1% of the
    // pre-failure steady state
    let cold = |costs: &[f64]| -> usize {
        let steady = costs[fail_at - 1];
        costs[..fail_at]
            .iter()
            .position(|&c| c <= steady * 1.001)
            .map(|p| p + 1)
            .unwrap_or(fail_at)
    };
    println!(
        "cold-start convergence (to within 0.1% of pre-failure steady state):\n\
         \x20 SGP: {} iterations    GP: {} iterations\n",
        cold(&sgp.costs),
        cold(&gp.costs)
    );

    println!("cost trajectory (… = failure point):");
    let max_cost = sgp
        .costs
        .iter()
        .chain(gp.costs.iter())
        .cloned()
        .fold(0.0f64, f64::max);
    for k in (0..total).step_by(10) {
        let marker = if k == fail_at { ">>" } else { "  " };
        println!(
            "{marker} iter {k:>3}  sgp |{}| {}   gp |{}| {}",
            bar(sgp.costs[k], max_cost, 24),
            fnum(sgp.costs[k]),
            bar(gp.costs[k], max_cost, 24),
            fnum(gp.costs[k]),
        );
    }

    println!(
        "\npost-failure re-convergence (to within 1% of the degraded optimum):\n\
         \x20 SGP: {} iterations, recovered by absolute iteration {} (cost {} -> {})\n\
         \x20 GP : {} iterations, recovered by absolute iteration {} (cost {} -> {})",
        sgp.reconverge_iters,
        sgp.recovery_epoch,
        fnum(sgp.cost_after_failure),
        fnum(sgp.final_cost),
        gp.reconverge_iters,
        gp.recovery_epoch,
        fnum(gp.cost_after_failure),
        fnum(gp.final_cost),
    );
    println!(
        "\nSGP's scaling matrices make it adapt to the topology change in far\n\
         fewer iterations — the Fig. 5b claim."
    );
    Ok(())
}
