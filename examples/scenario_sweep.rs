//! Guided tour of `coordinator::sweep`: price SGP against the LPR
//! baseline over a small grid of Table II instances, in parallel, and
//! read the aggregated report.
//!
//! Run: `cargo run --release --example scenario_sweep`

use cecflow::coordinator::{
    run_sweep, Algorithm, CellBackend, PatternSchedule, RunConfig, SweepSpec,
};

fn main() -> anyhow::Result<()> {
    // A sweep is a cross product: every scenario is instantiated at every
    // seed (deterministically — seed in, same network out) and optimized
    // by every algorithm under one stopping rule. SGP cells additionally
    // run once per requested dense backend (`Sparse` is the classic
    // Gauss–Seidel path, `Native` routes through `Sgp::step_dense` and
    // the batched `evaluate_batch` safeguard ladder).
    let spec = SweepSpec {
        scenarios: vec!["abilene".into(), "connected-er".into()],
        seeds: vec![1, 2, 3],
        algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
        backends: vec![CellBackend::Sparse, CellBackend::Native],
        // every cell on the fixed base pattern; see examples/dynamic_patterns.rs
        // for the time-varying schedule axis
        schedules: vec![PatternSchedule::static_()],
        rate_scale: 1.0,
        run: RunConfig::quick(),
        sim: None,
        cache: None,
    };

    // Workers pull cells from a shared cursor; per-cell results are
    // identical for any worker count (only wall times differ).
    let report = run_sweep(&spec, 4)?;

    println!("{}", report.render());
    println!("per-cell detail:");
    for c in &report.cells {
        println!(
            "  {:>13} seed {}  {:<4} @{:<6}  T = {:<12.4} ({} iters, {} to 1%)",
            c.cell.scenario,
            c.cell.seed,
            c.cell.algorithm.name(),
            c.cell.backend.name(),
            c.final_cost,
            c.iterations,
            c.iters_to_1pct
        );
    }

    // The headline of Fig. 4, now as a mean over seeds: SGP at or below
    // the linear-program rounding baseline on every scenario.
    for g in report.groups() {
        if g.algorithm == "sgp" {
            println!(
                "{}: SGP mean T {:.4} over {} seeds",
                g.scenario, g.mean_cost, g.cells
            );
        }
    }
    Ok(())
}
