//! Quickstart: build a small CEC network, run the paper's SGP optimizer,
//! and watch the total cost descend to a Theorem-1 (globally optimal)
//! point.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cecflow::algo::{Optimizer, Sgp};
use cecflow::coordinator::metrics::travel_distance;
use cecflow::graph::from_undirected;
use cecflow::model::{compute_flows, CostFn, Network, Strategy, Task};
use cecflow::util::table::fnum;

fn main() -> anyhow::Result<()> {
    // An 8-node edge cluster: two rings of four bridged in the middle.
    //
    //   0 - 1        4 - 5
    //   |   | — 3 —  |   |
    //   2 --+        6 - 7
    let links = [
        (0, 1),
        (0, 2),
        (1, 3),
        (2, 3),
        (3, 4),
        (3, 6),
        (4, 5),
        (4, 6),
        (5, 7),
        (6, 7),
    ];
    let graph = from_undirected(8, &links);
    let e = graph.edge_count();

    // Two task types: video compression (results half the input size) and
    // super-resolution (results 3x the input).
    let net = Network {
        graph,
        tasks: vec![
            Task { dest: 7, ctype: 0 }, // compress sensor video, deliver to 7
            Task { dest: 0, ctype: 1 }, // upscale thumbnails, deliver to 0
        ],
        num_types: 2,
        input_rate: vec![
            vec![1.2, 0.8, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], // cameras at 0,1
            vec![0.0, 0.0, 0.0, 0.0, 0.0, 0.6, 0.0, 0.9], // requests at 5,7
        ],
        result_ratio: vec![0.5, 3.0],
        comp_weight: vec![vec![1.0, 2.0]; 8],
        link_cost: vec![CostFn::Queue { cap: 8.0 }; e],
        comp_cost: vec![
            // node 3 is the beefy edge server
            CostFn::Queue { cap: 10.0 },
            CostFn::Queue { cap: 10.0 },
            CostFn::Queue { cap: 10.0 },
            CostFn::Queue { cap: 40.0 },
            CostFn::Queue { cap: 10.0 },
            CostFn::Queue { cap: 10.0 },
            CostFn::Queue { cap: 10.0 },
            CostFn::Queue { cap: 10.0 },
        ],
    };
    net.assert_valid();

    // Start from the always-feasible "compute where the data lands" point.
    let mut phi = Strategy::local_compute_init(&net);
    let t0 = compute_flows(&net, &phi)?.total_cost;
    println!("initial (all-local) total cost: {}", fnum(t0));

    let mut sgp = Sgp::new();
    for iter in 1..=30 {
        let st = sgp.step(&net, &mut phi)?;
        if iter % 5 == 0 || iter == 1 {
            println!(
                "iter {iter:>3}: T = {}   Theorem-1 residual = {:.2e}",
                fnum(st.total_cost),
                st.residual
            );
        }
    }

    let flows = compute_flows(&net, &phi)?;
    let td = travel_distance(&net, &flows);
    println!("\nconverged: T = {}", fnum(flows.total_cost));
    println!("improvement over all-local: {:.1}%", 100.0 * (1.0 - flows.total_cost / t0));
    println!("avg data travel distance:   {:.2} hops", td.l_data);
    println!("avg result travel distance: {:.2} hops", td.l_result);

    // Where did the computation go?
    println!("\ncomputation placement (workload per node):");
    for (i, &g) in flows.workload.iter().enumerate() {
        if g > 1e-6 {
            println!("  node {i}: {:.3}", g);
        }
    }
    println!("\n(the big server at node 3 should attract offloaded work)");
    Ok(())
}
