//! Batch/sequential parity for `DenseBackend::evaluate_batch`
//! (ISSUE 2 satellite): on `NativeBackend`, pricing a batch of candidate
//! strategies must be *bitwise* identical to N independent `evaluate`
//! calls — including saturated (`total_cost = +∞`) instances, whose
//! marginal fields may themselves hold `∞`/NaN values that must match
//! bit-for-bit.

use cecflow::algo::{Optimizer, Sgp};
use cecflow::coordinator::ScenarioSpec;
use cecflow::model::network::Network;
use cecflow::model::strategy::Strategy;
use cecflow::runtime::{DenseBackend, DenseEval, NativeBackend};

/// Bitwise equality that treats every NaN payload / infinity sign as
/// significant — the strongest possible parity claim.
fn bits_eq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits()
}

fn assert_vec_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(bits_eq(*x, *y), "{what}[{i}]: {x} vs {y}");
    }
}

fn assert_plane_bits_eq(a: &[Vec<f64>], b: &[Vec<f64>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: tasks");
    for (s, (x, y)) in a.iter().zip(b).enumerate() {
        assert_vec_bits_eq(x, y, &format!("{what} task {s}"));
    }
}

fn assert_eval_bits_eq(a: &DenseEval, b: &DenseEval, what: &str) {
    assert!(
        bits_eq(a.total_cost, b.total_cost),
        "{what}: total_cost {} vs {}",
        a.total_cost,
        b.total_cost
    );
    assert_vec_bits_eq(&a.d_link, &b.d_link, &format!("{what}: d_link"));
    assert_vec_bits_eq(&a.c_node, &b.c_node, &format!("{what}: c_node"));
    assert_vec_bits_eq(&a.link_flow, &b.link_flow, &format!("{what}: link_flow"));
    assert_vec_bits_eq(&a.workload, &b.workload, &format!("{what}: workload"));
    assert_plane_bits_eq(&a.dt_plus, &b.dt_plus, &format!("{what}: dt_plus"));
    assert_plane_bits_eq(&a.dt_r, &b.dt_r, &format!("{what}: dt_r"));
    assert_plane_bits_eq(&a.t_minus, &b.t_minus, &format!("{what}: t_minus"));
    assert_plane_bits_eq(&a.t_plus, &b.t_plus, &format!("{what}: t_plus"));
}

/// A ladder of distinct loop-free strategies: the local-compute and
/// compute-at-dest corners plus the iterates of a short SGP descent —
/// exactly the kind of candidates the safeguard batches.
fn strategy_ladder(net: &Network, steps: usize) -> Vec<Strategy> {
    let mut out = vec![
        Strategy::local_compute_init(net),
        Strategy::compute_at_dest_init(net),
    ];
    let mut phi = Strategy::local_compute_init(net);
    let mut sgp = Sgp::new();
    for _ in 0..steps {
        sgp.step(net, &mut phi).expect("sgp step");
        out.push(phi.clone());
    }
    out
}

fn check_parity(net: &Network, batch: &[Strategy], what: &str) {
    let backend = NativeBackend;
    let sequential: Vec<DenseEval> = batch
        .iter()
        .map(|phi| backend.evaluate(net, phi).expect("evaluate"))
        .collect();
    let batched = backend.evaluate_batch(net, batch).expect("evaluate_batch");
    assert_eq!(batched.len(), sequential.len(), "{what}: batch size");
    for (k, (b, s)) in batched.iter().zip(&sequential).enumerate() {
        assert_eval_bits_eq(b, s, &format!("{what} candidate {k}"));
    }
}

#[test]
fn batch_parity_on_random_table2_instances() {
    for (name, seed, steps) in [
        ("abilene", 1u64, 4usize),
        ("abilene", 7, 3),
        ("connected-er", 3, 3),
        ("balanced-tree", 5, 2),
    ] {
        let sc = ScenarioSpec::by_name(name).unwrap().build(seed);
        let batch = strategy_ladder(&sc.net, steps);
        check_parity(&sc.net, &batch, &format!("{name} seed {seed}"));
    }
}

#[test]
fn batch_parity_includes_saturated_infinity_cases() {
    // Scale the input rates far beyond the feasibility guard: the
    // all-local strategy saturates computation capacity and the
    // evaluation must report +∞ identically on both paths.
    let mut sc = ScenarioSpec::by_name("abilene").unwrap().build(11);
    sc.net.scale_rates(200.0);
    // saturated and (possibly) non-saturated candidates interleaved, with
    // a repeat at the end: a saturated candidate's scratch state must not
    // leak into the candidates priced after it.
    let batch = [
        Strategy::local_compute_init(&sc.net),
        Strategy::compute_at_dest_init(&sc.net),
        Strategy::local_compute_init(&sc.net),
    ];
    let ev = NativeBackend
        .evaluate_batch(&sc.net, &batch)
        .expect("batch on saturated net");
    assert!(
        ev[0].total_cost.is_infinite(),
        "200× rates should saturate local compute (T = {})",
        ev[0].total_cost
    );
    check_parity(&sc.net, &batch, "saturated abilene");
}

#[test]
fn default_trait_impl_matches_native_specialization() {
    /// Wrapper that inherits the *default* `evaluate_batch` (loop over
    /// `evaluate`) — pins the specialized single-pass path to the trait's
    /// reference semantics.
    struct LoopingBackend;

    impl DenseBackend for LoopingBackend {
        fn name(&self) -> &'static str {
            "looping"
        }

        fn evaluate(&self, net: &Network, phi: &Strategy) -> anyhow::Result<DenseEval> {
            NativeBackend.evaluate(net, phi)
        }
    }

    let sc = ScenarioSpec::by_name("connected-er").unwrap().build(9);
    let batch = strategy_ladder(&sc.net, 2);
    let via_default = LoopingBackend
        .evaluate_batch(&sc.net, &batch)
        .expect("default impl");
    let via_native = NativeBackend
        .evaluate_batch(&sc.net, &batch)
        .expect("native impl");
    for (k, (a, b)) in via_default.iter().zip(&via_native).enumerate() {
        assert_eval_bits_eq(a, b, &format!("default-vs-native candidate {k}"));
    }
}

#[test]
fn empty_batch_is_empty() {
    let sc = ScenarioSpec::by_name("abilene").unwrap().build(2);
    assert!(NativeBackend
        .evaluate_batch(&sc.net, &[])
        .unwrap()
        .is_empty());
}
