//! Integration test: the distributed two-stage broadcast protocol
//! (event-driven simulation AND thread-per-node actors) computes exactly
//! the marginals of the centralized evaluator, on real Table II scenarios
//! and on optimized (multi-path) strategies.

use cecflow::algo::Optimizer;
use cecflow::coordinator::ScenarioSpec;
use cecflow::model::{compute_flows, compute_marginals, Strategy};
use cecflow::sim::actors::run_actor_broadcast;
use cecflow::sim::run_broadcast;

fn optimized_strategy(name: &str, seed: u64, steps: usize) -> (cecflow::model::Network, Strategy) {
    let sc = ScenarioSpec::by_name(name).unwrap().build(seed);
    let mut phi = Strategy::local_compute_init(&sc.net);
    let mut sgp = cecflow::algo::Sgp::new();
    for _ in 0..steps {
        sgp.step(&sc.net, &mut phi).unwrap();
    }
    (sc.net, phi)
}

#[test]
fn event_protocol_matches_centralized_on_scenarios() {
    for name in ["abilene", "connected-er", "balanced-tree"] {
        let (net, phi) = optimized_strategy(name, 11, 8);
        let flows = compute_flows(&net, &phi).unwrap();
        let marg = compute_marginals(&net, &phi, &flows).unwrap();
        let res = run_broadcast(&net, &phi, &flows, 1.0);
        let dev = res.max_deviation(&marg);
        assert!(dev < 1e-9, "{name}: protocol deviation {dev}");
        assert_eq!(res.h_plus, marg.h_plus, "{name}: h+ mismatch");
        assert_eq!(res.h_minus, marg.h_minus, "{name}: h- mismatch");
    }
}

#[test]
fn protocol_complexity_claims() {
    // §IV Complexity: ≤ 2|S||E| broadcast messages per iteration and
    // completion within O(h̄ · t_c).
    let (net, phi) = optimized_strategy("geant", 3, 5);
    let flows = compute_flows(&net, &phi).unwrap();
    let res = run_broadcast(&net, &phi, &flows, 1.0);
    let bound = 2 * net.s() as u64 * net.e() as u64;
    assert!(res.messages <= bound, "{} > {bound}", res.messages);
    // every node ends informed
    for s in 0..net.s() {
        for i in 0..net.n() {
            assert!(res.dt_r[s][i].is_finite());
        }
    }
}

#[test]
fn actor_threads_match_centralized_on_scenario() {
    let (net, phi) = optimized_strategy("abilene", 19, 6);
    let flows = compute_flows(&net, &phi).unwrap();
    let marg = compute_marginals(&net, &phi, &flows).unwrap();
    let res = run_actor_broadcast(&net, &phi, &flows);
    for s in 0..net.s() {
        for i in 0..net.n() {
            assert!(
                (res.dt_plus[s][i] - marg.dt_plus[s][i]).abs() < 1e-9,
                "dt_plus[{s}][{i}]"
            );
            assert!(
                (res.dt_r[s][i] - marg.dt_r[s][i]).abs() < 1e-9,
                "dt_r[{s}][{i}]"
            );
        }
    }
}
