//! Closed-loop validation end to end: the discrete-event engine's
//! simulated sojourn must reproduce the analytic M/M/1 steady state on a
//! Queue-cost scenario (Little's law, `W = T/λ`), the hard alarm must
//! fire on an under-capacitated strategy, and in-simulation asynchronous
//! re-optimization (`simulate_adaptive`) must beat the static strategy's
//! tail latency after a mid-run pattern shift — bit-deterministically.

use cecflow::coordinator::{run_algorithm, Algorithm, RunConfig};
use cecflow::graph::from_undirected;
use cecflow::model::cost::CostFn;
use cecflow::model::network::{Network, Task};
use cecflow::model::strategy::Strategy;
use cecflow::sim::{
    simulate, simulate_adaptive, validate, ArrivalSpec, ReoptConfig, SimConfig, SimEpoch, SimPlan,
};
use cecflow::util::json::Json;

/// Two nodes, one bidirectional link; one task whose data enters at node 0
/// and whose results are due at node 0, so under the all-local strategy
/// only node 0's CPU carries load — an isolated M/M/1 queue with arrival
/// rate `lambda` and service rate `cap0`.
fn two_node(cap0: f64, cap1: f64, lambda: f64) -> Network {
    let graph = from_undirected(2, &[(0, 1)]);
    let e = graph.edge_count();
    Network {
        graph,
        tasks: vec![Task { dest: 0, ctype: 0 }],
        num_types: 1,
        input_rate: vec![vec![lambda, 0.0]],
        result_ratio: vec![0.5],
        comp_weight: vec![vec![1.0]; 2],
        link_cost: vec![CostFn::Queue { cap: 10.0 }; e],
        comp_cost: vec![
            CostFn::Queue { cap: cap0 },
            CostFn::Queue { cap: cap1 },
        ],
    }
}

fn poisson() -> ArrivalSpec {
    ArrivalSpec::parse("poisson").unwrap()
}

fn single_epoch(net: &Network, phi: &Strategy) -> SimPlan {
    SimPlan {
        epochs: vec![SimEpoch {
            net: net.clone(),
            phi: phi.clone(),
        }],
    }
}

/// λ = 1, μ = 2 at node 0 under the all-local strategy: the analytic
/// occupancy is `F/(cap−F) = 1`, so Little gives `W = T/λ = 1.0`. The
/// simulated mean must land within the validator's tolerance and the
/// alarm must stay quiet.
#[test]
fn mm1_queue_matches_littles_law() {
    let net = two_node(2.0, 8.0, 1.0);
    net.assert_valid();
    let phi = Strategy::local_compute_init(&net);
    let plan = single_epoch(&net, &phi);
    let cfg = SimConfig {
        requests: 40_000,
        warmup: 0.05,
        seed: 17,
        ..SimConfig::default()
    };
    let t = simulate(&plan, &poisson(), &cfg).unwrap();
    assert_eq!(t.overload_dropped, 0);
    let report = validate(&net, &phi, &t, 0.10).unwrap();
    assert!(
        !report.alarm,
        "expected a quiet alarm, got: {:?}",
        report.alarm_reasons
    );
    assert!(
        (report.analytic_mean_sojourn - 1.0).abs() < 1e-9,
        "closed form drifted: {}",
        report.analytic_mean_sojourn
    );
    assert!(
        report.mean_rel_error <= 0.10,
        "simulated mean {} diverged from analytic 1.0 (rel err {})",
        report.simulated_mean_sojourn,
        report.mean_rel_error
    );
    // the loaded server's own occupancy row agrees too (single class at
    // one queue is exactly M/M/1, no M/G/1 caveat here)
    let cpu0 = report.servers.iter().find(|s| s.name == "cpu:0").unwrap();
    assert!((cpu0.analytic - 1.0).abs() < 1e-9);
    assert!(cpu0.rel_error <= 0.15, "cpu:0 rel err {}", cpu0.rel_error);
}

/// λ = 3 against capacity 2: the analytic flow saturates the queue, the
/// admission cap turns the unbounded backlog into counted drops instead
/// of an abort, and the validator's hard alarm names both conditions.
#[test]
fn under_capacitated_strategy_fires_the_alarm() {
    let net = two_node(2.0, 8.0, 3.0);
    let phi = Strategy::local_compute_init(&net);
    let plan = single_epoch(&net, &phi);
    let cfg = SimConfig {
        requests: 6_000,
        warmup: 0.05,
        seed: 5,
        max_in_flight: 256,
        ..SimConfig::default()
    };
    let t = simulate(&plan, &poisson(), &cfg).unwrap();
    assert!(t.overload_dropped > 0, "overload never hit the ceiling");
    assert_eq!(
        t.completed + t.stranded + t.overload_dropped,
        t.arrived,
        "request conservation broke under overload"
    );
    let report = validate(&net, &phi, &t, 0.5).unwrap();
    assert!(report.alarm);
    assert!(report
        .alarm_reasons
        .iter()
        .any(|r| r.contains("queue divergent")));
    assert!(report
        .alarm_reasons
        .iter()
        .any(|r| r.contains("strategy overloaded")));
    assert!(report.servers.iter().any(|s| s.saturated));
    assert!(report.mean_rel_error.is_infinite());
    // the rendered report carries the verdict for the CLI path
    let txt = report.render();
    assert!(txt.contains("ALARM"), "{txt}");
    assert!(txt.contains("SATURATED"), "{txt}");
}

/// Mid-run pattern shift (epoch 0 lightly loaded, epoch 1 near node 0's
/// capacity): the static epoch-0 strategy keeps everything local and its
/// tail blows up, while in-loop SGP ticks re-route against telemetry-
/// estimated rates and recover a lower p99 — bit-identically across runs.
#[test]
fn in_loop_reoptimization_beats_the_static_strategy() {
    let net0 = two_node(2.0, 8.0, 0.5);
    let net1 = two_node(2.0, 8.0, 1.8);
    let out = run_algorithm(&net0, Algorithm::Sgp, &RunConfig::quick()).unwrap();
    let phi0 = out.phi.expect("SGP returned no strategy");
    // both runs share the identical plan: the *only* difference is the
    // in-simulation re-optimization ticks
    let plan = SimPlan {
        epochs: vec![
            SimEpoch {
                net: net0.clone(),
                phi: phi0.clone(),
            },
            SimEpoch {
                net: net1.clone(),
                phi: phi0.clone(),
            },
        ],
    };
    let cfg = SimConfig {
        requests: 30_000,
        warmup: 0.05,
        seed: 11,
        ..SimConfig::default()
    };
    let t_static = simulate(&plan, &poisson(), &cfg).unwrap();
    let reopt = ReoptConfig::every(20.0).unwrap();
    let t_adaptive = simulate_adaptive(&plan, &poisson(), &cfg, &reopt).unwrap();
    assert!(t_adaptive.reopt_events > 0, "no re-optimization tick fired");
    assert!(t_adaptive.reopt_updates > 0, "ticks fired but applied nothing");
    assert_eq!(
        t_adaptive.completed + t_adaptive.stranded + t_adaptive.overload_dropped,
        t_adaptive.arrived
    );
    let (_, p99_static, _) = t_static.tail();
    let (_, p99_adaptive, _) = t_adaptive.tail();
    assert!(
        p99_adaptive < p99_static,
        "in-loop re-optimization did not beat the static strategy: \
         adaptive p99 {p99_adaptive} vs static p99 {p99_static}"
    );
    // determinism: the tick schedule rides the calendar queue and the SGP
    // update is randomness-free, so repeated runs are bit-identical
    let t_again = simulate_adaptive(&plan, &poisson(), &cfg, &reopt).unwrap();
    assert_eq!(t_adaptive.to_json().dump(), t_again.to_json().dump());
}

/// A run whose every arrival is dropped still emits a parseable artifact:
/// explicit zeros with a zero sample count, never JSON `null` — and the
/// validator reports it as an alarmed measurement, not an error.
#[test]
fn zero_sample_artifacts_stay_parseable() {
    let net = two_node(2.0, 8.0, 1.0);
    let phi = Strategy::local_compute_init(&net);
    let plan = single_epoch(&net, &phi);
    let cfg = SimConfig {
        requests: 200,
        warmup: 0.05,
        seed: 3,
        max_in_flight: 0,
        ..SimConfig::default()
    };
    let t = simulate(&plan, &poisson(), &cfg).unwrap();
    assert_eq!(t.overload_dropped, t.arrived);
    assert_eq!(t.completed, 0);
    let dump = t.to_json().dump();
    assert!(
        !dump.contains("null"),
        "zero-sample telemetry leaked a null: {dump}"
    );
    let doc = Json::parse(&dump).unwrap();
    assert_eq!(doc.path("sojourn.count").as_num(), Some(0.0));
    assert_eq!(doc.path("sojourn.mean").as_num(), Some(0.0));
    let report = validate(&net, &phi, &t, 0.5).unwrap();
    assert!(report.alarm);
    assert_eq!(report.samples, 0);
    assert!(report
        .alarm_reasons
        .iter()
        .any(|r| r.contains("no post-warm-up completions")));
    let vdump = report.to_json().dump();
    assert!(Json::parse(&vdump).is_ok());
}
