//! Randomized property tests over the flow model and the optimizer
//! (proptest is unavailable offline; this is a hand-rolled
//! generate-and-check harness over seeded PCG streams — failures print
//! the offending seed so any case replays deterministically).

use cecflow::algo::{Gp, Optimizer, Sgp};
use cecflow::graph::algorithms::strongly_connected;
use cecflow::graph::from_undirected;
use cecflow::model::{
    compute_flows, compute_marginals, theorem1_residual, CostFn, Network, Strategy, Task,
};
use cecflow::util::rng::Pcg;

/// Random strongly-connected network with random tasks and costs.
fn random_network(rng: &mut Pcg) -> Network {
    let n = rng.int_range(4, 10);
    // ring for connectivity + random chords
    let mut links: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
    for u in 0..n {
        for v in (u + 2)..n {
            if rng.chance(0.3) && !(u == 0 && v == n - 1) {
                links.push((u, v));
            }
        }
    }
    let graph = from_undirected(n, &links);
    assert!(strongly_connected(&graph));

    let num_types = rng.int_range(1, 3);
    let s_count = rng.int_range(1, 4);
    let tasks: Vec<Task> = (0..s_count)
        .map(|_| Task {
            dest: rng.below(n),
            ctype: rng.below(num_types),
        })
        .collect();
    let input_rate: Vec<Vec<f64>> = (0..s_count)
        .map(|_| {
            let mut r = vec![0.0; n];
            let sources = rng.int_range(1, 3.min(n));
            for src in rng.choose_distinct(n, sources) {
                r[src] = rng.uniform(0.2, 1.0);
            }
            r
        })
        .collect();
    let result_ratio: Vec<f64> = (0..num_types)
        .map(|_| rng.exponential_trunc(0.5, 0.1, 5.0))
        .collect();
    let comp_weight: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..num_types).map(|_| rng.uniform(1.0, 5.0)).collect())
        .collect();
    let e = graph.edge_count();
    let link_cost: Vec<CostFn> = (0..e)
        .map(|_| {
            if rng.chance(0.5) {
                CostFn::Linear {
                    unit: rng.uniform(0.1, 3.0),
                }
            } else {
                CostFn::Queue {
                    cap: rng.uniform(20.0, 60.0),
                }
            }
        })
        .collect();
    let comp_cost: Vec<CostFn> = (0..n)
        .map(|_| {
            if rng.chance(0.5) {
                CostFn::Linear {
                    unit: rng.uniform(0.1, 3.0),
                }
            } else {
                CostFn::Queue {
                    cap: rng.uniform(30.0, 80.0),
                }
            }
        })
        .collect();
    let net = Network {
        graph,
        tasks,
        num_types,
        input_rate,
        result_ratio,
        comp_weight,
        link_cost,
        comp_cost,
    };
    net.assert_valid();
    net
}

/// Random feasible loop-free strategy: data/result fractions forward only
/// along a random node ranking (acyclic by construction), with random
/// local-computation splits.
fn random_strategy(net: &Network, rng: &mut Pcg) -> Strategy {
    let n = net.n();
    let mut rank: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut rank);
    let pos = {
        let mut p = vec![0usize; n];
        for (i, &v) in rank.iter().enumerate() {
            p[v] = i;
        }
        p
    };
    let mut phi = Strategy::zeroed(net);
    for s in 0..net.s() {
        let dest = net.tasks[s].dest;
        for i in 0..n {
            // data plane: split between local and "forward" neighbors
            let fwd: Vec<usize> = net
                .graph
                .out_edge_ids(i)
                .iter()
                .enumerate()
                .filter(|(_, &eid)| pos[net.graph.edge(eid).dst] > pos[i])
                .map(|(k, _)| k)
                .collect();
            let mut weights = vec![rng.uniform(0.2, 1.0)];
            for _ in &fwd {
                weights.push(if rng.chance(0.5) {
                    rng.uniform(0.0, 1.0)
                } else {
                    0.0
                });
            }
            let total: f64 = weights.iter().sum();
            phi.data[s][i][0] = weights[0] / total;
            for (w_idx, &k) in fwd.iter().enumerate() {
                phi.data[s][i][k + 1] = weights[w_idx + 1] / total;
            }
            // result plane: forward-only split; fall back to the ranking's
            // guarantee — if no forward neighbor exists give everything to
            // the destination-directed SP (cannot happen for the max-rank
            // node unless it is the destination, handled below).
            if i == dest {
                continue;
            }
            if fwd.is_empty() {
                // route toward dest along any out-edge of minimal pos —
                // may break rank-acyclicity, so instead recompute via SP
                // init for this node (kept rare by the ring structure).
                let w0: Vec<f64> = net.link_cost.iter().map(|c| c.deriv_at_zero()).collect();
                let (_, next) = cecflow::graph::algorithms::dijkstra_to(
                    &net.graph, dest, &w0,
                );
                let nxt = next[i];
                let slot = cecflow::model::out_slot(&net.graph, i, nxt).unwrap();
                phi.result[s][i][slot] = 1.0;
                continue;
            }
            let mut rw: Vec<f64> = fwd.iter().map(|_| rng.uniform(0.1, 1.0)).collect();
            let total: f64 = rw.iter().sum();
            rw.iter_mut().for_each(|x| *x /= total);
            for (w, &k) in rw.iter().zip(&fwd) {
                phi.result[s][i][k] = *w;
            }
        }
        // fix the result plane so everything reaches the destination: the
        // rank-forward construction can strand mass at the max-rank node.
        // Redirect rank-max non-dest nodes straight along the SP tree.
        let w0: Vec<f64> = net.link_cost.iter().map(|c| c.deriv_at_zero()).collect();
        let (_, next) = cecflow::graph::algorithms::dijkstra_to(&net.graph, dest, &w0);
        for i in 0..n {
            if i != dest && phi.result[s][i].iter().sum::<f64>() < 0.5 {
                let slot =
                    cecflow::model::out_slot(&net.graph, i, next[i]).unwrap();
                phi.result[s][i] = vec![0.0; net.graph.out_degree(i)];
                phi.result[s][i][slot] = 1.0;
            }
        }
    }
    // the SP fallback can mix rank directions; accept only loop-free draws
    if !phi.is_loop_free(net) {
        return Strategy::local_compute_init(net);
    }
    phi
}

#[test]
fn flow_conservation_random_instances() {
    for seed in 0..30u64 {
        let mut rng = Pcg::new(1000 + seed);
        let net = random_network(&mut rng);
        let phi = random_strategy(&net, &mut rng);
        assert!(
            phi.is_feasible(&net),
            "seed {seed}: {:?}",
            phi.feasibility_violations(&net)
        );
        let flows = compute_flows(&net, &phi).unwrap();
        let violations = flows.conservation_violations(&net, &phi);
        assert!(violations.is_empty(), "seed {seed}: {violations:?}");
    }
}

#[test]
fn marginals_match_finite_differences_random() {
    let mut checked = 0;
    for seed in 0..12u64 {
        let mut rng = Pcg::new(2000 + seed);
        let net = random_network(&mut rng);
        let phi = random_strategy(&net, &mut rng);
        let flows = compute_flows(&net, &phi).unwrap();
        if !flows.total_cost.is_finite() {
            continue;
        }
        let marg = compute_marginals(&net, &phi, &flows).unwrap();
        let eps = 1e-6;
        // probe a few random (task, node, slot) partial derivatives
        for _ in 0..6 {
            let s = rng.below(net.s());
            let i = rng.below(net.n());
            let analytic = marg.dphi_minus(&net, &flows, s, i);
            let slot = rng.below(analytic.len());
            let mut bumped = phi.clone();
            bumped.data[s][i][slot] += eps;
            let Ok(t1) = compute_flows(&net, &bumped) else { continue };
            if !t1.total_cost.is_finite() {
                continue;
            }
            let numeric = (t1.total_cost - flows.total_cost) / eps;
            assert!(
                (analytic[slot] - numeric).abs() < 1e-3 * (1.0 + numeric.abs()),
                "seed {seed}: dphi_minus[{s}][{i}][{slot}] analytic {} vs numeric {}",
                analytic[slot],
                numeric
            );
            checked += 1;
        }
    }
    assert!(checked > 20, "only {checked} probes ran");
}

#[test]
fn sgp_invariants_random_instances() {
    for seed in 0..10u64 {
        let mut rng = Pcg::new(3000 + seed);
        let net = random_network(&mut rng);
        let mut phi = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        let mut last = f64::INFINITY;
        for iter in 0..25 {
            let st = sgp.step(&net, &mut phi).unwrap();
            assert!(
                st.total_cost <= last + 1e-9,
                "seed {seed} iter {iter}: cost increased {last} -> {}",
                st.total_cost
            );
            last = st.total_cost;
            assert!(phi.is_loop_free(&net), "seed {seed} iter {iter}: loop");
            assert!(
                phi.is_feasible(&net),
                "seed {seed} iter {iter}: {:?}",
                phi.feasibility_violations(&net)
            );
        }
        assert_eq!(sgp.rollbacks, 0, "seed {seed}: loop rollbacks fired");
    }
}

#[test]
fn theorem1_residual_vanishes_at_convergence_random() {
    for seed in 0..6u64 {
        let mut rng = Pcg::new(4000 + seed);
        let net = random_network(&mut rng);
        let mut phi = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        let mut res = f64::INFINITY;
        for _ in 0..80 {
            res = sgp.step(&net, &mut phi).unwrap().residual;
        }
        assert!(
            res < 1e-4,
            "seed {seed}: Theorem-1 residual stuck at {res}"
        );
    }
}

#[test]
fn gp_and_sgp_agree_random() {
    for seed in 0..5u64 {
        let mut rng = Pcg::new(5000 + seed);
        let net = random_network(&mut rng);

        let mut phi_s = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        for _ in 0..60 {
            sgp.step(&net, &mut phi_s).unwrap();
        }
        let ts = compute_flows(&net, &phi_s).unwrap().total_cost;

        let mut phi_g = Strategy::local_compute_init(&net);
        let mut gp = Gp::new(1.0);
        for _ in 0..400 {
            gp.step(&net, &mut phi_g).unwrap();
        }
        let tg = compute_flows(&net, &phi_g).unwrap().total_cost;

        assert!(
            (ts - tg).abs() < 0.02 * ts.max(1e-9),
            "seed {seed}: SGP {ts} vs GP {tg}"
        );
    }
}

#[test]
fn random_strategies_never_beat_converged_sgp() {
    // Global-optimality spot check: no random feasible strategy should
    // undercut the Theorem-1 point SGP converged to.
    for seed in 0..6u64 {
        let mut rng = Pcg::new(6000 + seed);
        let net = random_network(&mut rng);
        let mut phi = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        let mut opt_cost = f64::INFINITY;
        for _ in 0..80 {
            opt_cost = sgp.step(&net, &mut phi).unwrap().total_cost;
        }
        for probe in 0..40 {
            let cand = random_strategy(&net, &mut rng);
            let t = compute_flows(&net, &cand).unwrap().total_cost;
            assert!(
                t >= opt_cost - 1e-6 * opt_cost.abs(),
                "seed {seed} probe {probe}: random strategy beats 'optimum' ({t} < {opt_cost})"
            );
        }
        // and the converged point satisfies Theorem 1
        let flows = compute_flows(&net, &phi).unwrap();
        let marg = compute_marginals(&net, &phi, &flows).unwrap();
        assert!(theorem1_residual(&net, &phi, &marg) < 1e-4, "seed {seed}");
    }
}

#[test]
fn incremental_reflow_matches_full_recompute() {
    use cecflow::model::flows::{recompute_task_flows, refresh_total_cost};
    for seed in 0..15u64 {
        let mut rng = Pcg::new(7000 + seed);
        let net = random_network(&mut rng);
        let phi_a = random_strategy(&net, &mut rng);
        let phi_b = random_strategy(&net, &mut rng);
        // start from A's flows, mutate every task to B via the incremental
        // path, compare against a from-scratch computation of B.
        let mut fs = compute_flows(&net, &phi_a).unwrap();
        for s in 0..net.s() {
            recompute_task_flows(&net, &phi_b, &mut fs, s).unwrap();
        }
        let t_inc = refresh_total_cost(&net, &mut fs);
        let full = compute_flows(&net, &phi_b).unwrap();
        assert!(
            (t_inc - full.total_cost).abs() < 1e-9 * (1.0 + full.total_cost.abs())
                || (t_inc.is_infinite() && full.total_cost.is_infinite()),
            "seed {seed}: incremental {t_inc} vs full {}",
            full.total_cost
        );
        for eid in 0..net.e() {
            assert!(
                (fs.link_flow[eid] - full.link_flow[eid]).abs() < 1e-9,
                "seed {seed}: edge {eid} flow drift"
            );
        }
        for i in 0..net.n() {
            assert!(
                (fs.workload[i] - full.workload[i]).abs() < 1e-9,
                "seed {seed}: node {i} workload drift"
            );
        }
    }
}
