//! Scenario-level integration suite: every (small/medium) Table II
//! instance optimizes cleanly, the §V ordering holds, failure injection
//! stays sound, and the end-to-end CLI building blocks compose.

use cecflow::algo::Optimizer;
use cecflow::coordinator::{
    build_scenario_network, connected_er_servers, run_algorithm, Algorithm, RunConfig,
    ScenarioSpec,
};
use cecflow::model::{compute_flows, compute_marginals, theorem1_residual, Strategy};
use cecflow::sim::run_with_failure;

const SMALL_SCENARIOS: &[&str] = &[
    "connected-er",
    "balanced-tree",
    "fog",
    "abilene",
    "lhc",
    "geant",
];

#[test]
fn sgp_converges_on_all_small_scenarios() {
    for name in SMALL_SCENARIOS {
        let net = build_scenario_network(name, 7, 1.0).unwrap();
        let mut phi = Strategy::local_compute_init(&net);
        let mut sgp = cecflow::algo::Sgp::new();
        let mut last = f64::INFINITY;
        let mut residual = f64::INFINITY;
        for _ in 0..50 {
            let st = sgp.step(&net, &mut phi).unwrap();
            assert!(st.total_cost <= last + 1e-9, "{name}: not monotone");
            last = st.total_cost;
            residual = st.residual;
        }
        assert!(phi.is_loop_free(&net), "{name}: loop after optimization");
        assert!(
            residual < 1e-2 * (1.0 + last),
            "{name}: residual {residual} too large vs cost {last}"
        );
        assert_eq!(sgp.rollbacks, 0, "{name}: rollbacks fired");
    }
}

#[test]
fn sgp_beats_all_baselines_on_three_seeds() {
    let cfg = RunConfig::quick();
    for name in ["abilene", "connected-er", "lhc"] {
        for seed in [1u64, 2, 3] {
            let net = build_scenario_network(name, seed, 1.0).unwrap();
            let sgp = run_algorithm(&net, Algorithm::Sgp, &cfg).unwrap();
            for algo in [Algorithm::Spoo, Algorithm::Lcor, Algorithm::Lpr] {
                let out = run_algorithm(&net, algo, &cfg).unwrap();
                assert!(
                    sgp.final_cost <= out.final_cost * 1.001,
                    "{name} seed {seed}: sgp {} beaten by {} {}",
                    sgp.final_cost,
                    out.algorithm,
                    out.final_cost
                );
            }
        }
    }
}

#[test]
fn failure_injection_all_servers() {
    // failing any of the four servers keeps the experiment sound
    let sc = connected_er_servers(9);
    let phi0 = Strategy::local_compute_init(&sc.net);
    let mut survivable = 0;
    for k in 0..sc.servers.len() {
        let dead = sc.servers[k];
        let fallback = sc.servers[(k + 1) % sc.servers.len()];
        match run_with_failure(
            &sc.net,
            cecflow::algo::Sgp::new,
            &phi0,
            10,
            40,
            dead,
            fallback,
            0.01,
        ) {
            Ok(run) => {
                survivable += 1;
                assert!(run.final_cost.is_finite(), "server {dead}: degraded cost inf");
                for w in run.costs[10..].windows(2) {
                    assert!(w[1] <= w[0] + 1e-9, "server {dead}: post-failure ascent");
                }
            }
            Err(err) => {
                // legitimate outcome: the instance cannot absorb losing
                // this much capacity — must be reported, not mis-optimized
                assert!(
                    err.to_string().contains("cannot absorb"),
                    "unexpected failure mode: {err}"
                );
            }
        }
    }
    assert!(survivable >= 2, "only {survivable} servers survivable");
}

#[test]
fn optimized_strategies_satisfy_theorem1_within_tolerance() {
    let net = build_scenario_network("abilene", 13, 1.0).unwrap();
    let mut phi = Strategy::local_compute_init(&net);
    let mut sgp = cecflow::algo::Sgp::new();
    for _ in 0..120 {
        sgp.step(&net, &mut phi).unwrap();
    }
    let flows = compute_flows(&net, &phi).unwrap();
    let marg = compute_marginals(&net, &phi, &flows).unwrap();
    let res = theorem1_residual(&net, &phi, &marg);
    assert!(res < 1e-4 * (1.0 + flows.total_cost), "residual {res}");

    // δ-consistency: for every loaded slot, its δ equals the node minimum
    for s in 0..net.s() {
        for i in 0..net.n() {
            let dm = marg.delta_minus(&net, s, i);
            let dmin = dm.iter().cloned().fold(f64::INFINITY, f64::min);
            for (slot, &frac) in phi.data[s][i].iter().enumerate() {
                if frac > 1e-6 {
                    assert!(
                        dm[slot] <= dmin + 1e-3 * (1.0 + dmin.abs()),
                        "task {s} node {i} slot {slot}: δ {} vs min {dmin}",
                        dm[slot]
                    );
                }
            }
        }
    }
}

#[test]
fn rate_scaling_monotone_in_cost() {
    // Fig. 5c precondition: optimized total cost grows with load.
    let cfg = RunConfig::quick();
    let mut prev = 0.0;
    for scale in [0.5, 1.0, 1.3] {
        let net = build_scenario_network("abilene", 4, scale).unwrap();
        let out = run_algorithm(&net, Algorithm::Sgp, &cfg).unwrap();
        assert!(
            out.final_cost > prev,
            "cost not increasing at scale {scale}: {} <= {prev}",
            out.final_cost
        );
        prev = out.final_cost;
    }
}

#[test]
fn spoo_lcor_respect_their_restrictions_on_scenarios() {
    let net = build_scenario_network("lhc", 5, 1.0).unwrap();

    let (mut spoo, mut phi_p) = cecflow::algo::spoo_optimizer(&net);
    for _ in 0..10 {
        spoo.step(&net, &mut phi_p).unwrap();
    }
    // SPOO: for each task, each node uses at most one forwarding slot
    for s in 0..net.s() {
        for i in 0..net.n() {
            let used = phi_p.data[s][i]
                .iter()
                .skip(1)
                .filter(|&&f| f > 1e-9)
                .count();
            assert!(used <= 1, "SPOO: task {s} node {i} uses {used} out-edges");
        }
    }

    let (mut lcor, mut phi_l) = cecflow::algo::lcor_optimizer(&net);
    for _ in 0..10 {
        lcor.step(&net, &mut phi_l).unwrap();
    }
    for s in 0..net.s() {
        for i in 0..net.n() {
            assert!(
                (phi_l.data[s][i][0] - 1.0).abs() < 1e-12,
                "LCOR: task {s} node {i} shipped data"
            );
        }
    }
}

#[test]
fn sw_scenario_single_iteration_smoke() {
    // the big one: one full Gauss–Seidel sweep at SW scale stays sound
    let net = build_scenario_network("sw", 3, 1.0).unwrap();
    assert_eq!(net.n(), 100);
    let mut phi = Strategy::local_compute_init(&net);
    let t0 = compute_flows(&net, &phi).unwrap().total_cost;
    let mut sgp = cecflow::algo::Sgp::new();
    let st = sgp.step(&net, &mut phi).unwrap();
    assert!(st.total_cost < t0, "no progress on SW: {t0} -> {}", st.total_cost);
    assert!(phi.is_loop_free(&net));
    assert!(phi.is_feasible(&net));
}
