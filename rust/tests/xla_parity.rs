//! Integration test: dense-backend parity.
//!
//! * Ungated: the default `NativeBackend` must agree field-for-field with
//!   the direct `model::flows` + `model::marginals` computation, and the
//!   dense-backend SGP loop (`optimize_accelerated`) must land where the
//!   native Gauss–Seidel loop lands.
//! * Behind `--features pjrt`: the XLA data plane (AOT `dense_eval`
//!   artifact via PJRT) must agree with the native f64 evaluator on live
//!   workloads — total cost, flows, and both marginal recursions.
//!   Requires `make artifacts`; skips (with a loud message) if the
//!   artifacts are missing so `cargo test` stays runnable pre-build.
//!   Without the feature, the PJRT half is cfg'd out and one placeholder
//!   test prints a loud skip notice.

use cecflow::coordinator::ScenarioSpec;
use cecflow::model::{compute_flows, compute_marginals, Strategy};
use cecflow::runtime::{DenseBackend, NativeBackend};

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-9)
}

/// Shared body for "the dense-backend SGP run lands where the native
/// Gauss–Seidel run lands" — used by both the native and PJRT backends.
///
/// Both descend monotonically and land in the same neighborhood. The
/// dense path uses Jacobi steps (one backend call per sweep) vs the
/// native Gauss–Seidel, so iterate counts differ; costs must agree
/// within a few percent and never increase.
fn check_accelerated_matches_native(backend: &dyn DenseBackend, expect_label: &str) {
    use cecflow::coordinator::{optimize, optimize_accelerated, RunConfig};

    let sc = ScenarioSpec::by_name("abilene").unwrap().build(5);
    let net = &sc.net;
    let phi0 = Strategy::local_compute_init(net);
    let cfg = RunConfig {
        max_iters: 25,
        ..RunConfig::quick()
    };

    let mut sgp_a = cecflow::algo::Sgp::new();
    let accel = optimize_accelerated(net, &mut sgp_a, &phi0, &cfg, backend).unwrap();
    assert_eq!(accel.algorithm, expect_label);

    let mut sgp_n = cecflow::algo::Sgp::new();
    let native = optimize(net, &mut sgp_n, &phi0, &cfg).unwrap();

    for w in accel.costs.windows(2) {
        assert!(w[1] <= w[0] * (1.0 + 1e-4), "dense-backend cost increased");
    }
    let gap = rel(accel.final_cost(), native.final_cost());
    assert!(
        gap < 0.05,
        "dense backend {} vs native {} (gap {gap})",
        accel.final_cost(),
        native.final_cost()
    );
}

// ---- native backend parity (always built) -----------------------------

#[test]
fn native_backend_matches_direct_evaluation_on_scenario() {
    let sc = ScenarioSpec::by_name("abilene").unwrap().build(42);
    let net = &sc.net;
    let mut phi = Strategy::local_compute_init(net);
    // exercise a non-trivial multi-path strategy
    let mut sgp = cecflow::algo::Sgp::new();
    use cecflow::algo::Optimizer;
    for _ in 0..8 {
        sgp.step(net, &mut phi).unwrap();
    }

    let flows = compute_flows(net, &phi).unwrap();
    let marg = compute_marginals(net, &phi, &flows).unwrap();
    let ev = NativeBackend.evaluate(net, &phi).unwrap();

    assert_eq!(ev.total_cost, flows.total_cost);
    assert_eq!(ev.link_flow, flows.link_flow);
    assert_eq!(ev.workload, flows.workload);
    assert_eq!(ev.t_minus, flows.t_minus);
    assert_eq!(ev.t_plus, flows.t_plus);
    assert_eq!(ev.d_link, marg.d_link);
    assert_eq!(ev.c_node, marg.c_node);
    assert_eq!(ev.dt_plus, marg.dt_plus);
    assert_eq!(ev.dt_r, marg.dt_r);
}

#[test]
fn dense_backend_run_matches_native_run() {
    check_accelerated_matches_native(&NativeBackend, "sgp-native");
}

// ---- PJRT/XLA parity (feature-gated) ----------------------------------

#[cfg(not(feature = "pjrt"))]
#[test]
fn xla_parity_skipped_without_pjrt_feature() {
    eprintln!(
        "SKIPPING xla_parity: cecflow was built without the `pjrt` cargo feature. \
         Rebuild with `cargo test --features pjrt` (after `make artifacts`, with the \
         real `xla` crate in place of the stub) to compare the XLA data plane against \
         the native evaluator."
    );
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::rel;
    use cecflow::coordinator::ScenarioSpec;
    use cecflow::model::{compute_flows, compute_marginals, Strategy};
    use cecflow::runtime::{default_artifacts_dir, DenseEvaluator, Engine};

    fn engine_or_skip() -> Option<Engine> {
        match Engine::load_filtered(&default_artifacts_dir(), |c| c.name == "small") {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("SKIPPING xla_parity: {err:#} (run `make artifacts`)");
                None
            }
        }
    }

    fn check_parity(engine: &Engine, seed: u64, optimize_steps: usize) {
        let sc = ScenarioSpec::by_name("abilene").unwrap().build(seed);
        let net = &sc.net;
        let mut phi = Strategy::local_compute_init(net);

        // exercise non-trivial strategies: run a few SGP steps first
        let mut sgp = cecflow::algo::Sgp::new();
        use cecflow::algo::Optimizer;
        for _ in 0..optimize_steps {
            sgp.step(net, &mut phi).unwrap();
        }

        let flows = compute_flows(net, &phi).unwrap();
        let marg = compute_marginals(net, &phi, &flows).unwrap();
        let eval = DenseEvaluator::new(engine);
        let dense = eval.evaluate(net, &phi).unwrap();

        assert!(
            rel(flows.total_cost, dense.total_cost) < 1e-3,
            "seed {seed}: total cost native {} vs xla {}",
            flows.total_cost,
            dense.total_cost
        );
        for (eid, e) in net.graph.edges().iter().enumerate() {
            assert!(
                rel(flows.link_flow[eid], dense.link_flow[eid]) < 1e-3
                    || (flows.link_flow[eid].abs() < 1e-6
                        && dense.link_flow[eid].abs() < 1e-4),
                "seed {seed}: link flow ({},{})",
                e.src,
                e.dst
            );
        }
        for i in 0..net.n() {
            assert!(
                rel(flows.workload[i], dense.workload[i]) < 1e-3
                    || flows.workload[i].abs() < 1e-6,
                "seed {seed}: workload at {i}"
            );
        }
        for s in 0..net.s() {
            for i in 0..net.n() {
                assert!(
                    rel(marg.dt_plus[s][i], dense.dt_plus[s][i]) < 5e-3
                        || marg.dt_plus[s][i].abs() < 1e-6,
                    "seed {seed}: dt_plus[{s}][{i}] {} vs {}",
                    marg.dt_plus[s][i],
                    dense.dt_plus[s][i]
                );
                assert!(
                    rel(marg.dt_r[s][i], dense.dt_r[s][i]) < 5e-3
                        || marg.dt_r[s][i].abs() < 1e-6,
                    "seed {seed}: dt_r[{s}][{i}] {} vs {}",
                    marg.dt_r[s][i],
                    dense.dt_r[s][i]
                );
                assert!(
                    rel(flows.t_minus[s][i], dense.t_minus[s][i]) < 1e-3
                        || flows.t_minus[s][i].abs() < 1e-6,
                    "seed {seed}: t_minus[{s}][{i}]"
                );
                assert!(
                    rel(flows.t_plus[s][i], dense.t_plus[s][i]) < 1e-3
                        || flows.t_plus[s][i].abs() < 1e-6,
                    "seed {seed}: t_plus[{s}][{i}]"
                );
            }
        }
    }

    #[test]
    fn parity_on_initial_strategy() {
        let Some(engine) = engine_or_skip() else { return };
        check_parity(&engine, 42, 0);
    }

    #[test]
    fn parity_on_optimized_strategies() {
        let Some(engine) = engine_or_skip() else { return };
        for seed in [1, 7] {
            check_parity(&engine, seed, 10);
        }
    }

    #[test]
    fn accelerated_run_matches_native_run() {
        let Some(engine) = engine_or_skip() else { return };
        let eval = DenseEvaluator::new(&engine);
        super::check_accelerated_matches_native(&eval, "sgp-pjrt");
    }

    #[test]
    fn saturation_maps_to_infinity() {
        let Some(engine) = engine_or_skip() else { return };
        let mut sc = ScenarioSpec::by_name("abilene").unwrap().build(42);
        // blow up the rates so local computation saturates
        sc.net.scale_rates(1e4);
        let phi = Strategy::local_compute_init(&sc.net);
        let eval = DenseEvaluator::new(&engine);
        let dense = eval.evaluate(&sc.net, &phi).unwrap();
        let native = compute_flows(&sc.net, &phi).unwrap();
        assert!(native.total_cost.is_infinite());
        assert!(
            dense.total_cost.is_infinite(),
            "XLA saturation sentinel not mapped: {}",
            dense.total_cost
        );
    }
}
