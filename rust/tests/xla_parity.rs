//! Integration test: the XLA data plane (AOT `dense_eval` artifact via
//! PJRT) must agree with the native f64 evaluator on live workloads —
//! total cost, flows, and both marginal recursions.
//!
//! Requires `make artifacts`. Skips (with a loud message) if the artifacts
//! are missing so `cargo test` stays runnable pre-build.

use cecflow::coordinator::ScenarioSpec;
use cecflow::model::{compute_flows, compute_marginals, Strategy};
use cecflow::runtime::{default_artifacts_dir, DenseEvaluator, Engine};

fn engine_or_skip() -> Option<Engine> {
    match Engine::load_filtered(&default_artifacts_dir(), |c| c.name == "small") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIPPING xla_parity: {err:#} (run `make artifacts`)");
            None
        }
    }
}

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-9)
}

fn check_parity(engine: &Engine, seed: u64, optimize_steps: usize) {
    let sc = ScenarioSpec::by_name("abilene").unwrap().build(seed);
    let net = &sc.net;
    let mut phi = Strategy::local_compute_init(net);

    // exercise non-trivial strategies: run a few SGP steps first
    let mut sgp = cecflow::algo::Sgp::new();
    use cecflow::algo::Optimizer;
    for _ in 0..optimize_steps {
        sgp.step(net, &mut phi).unwrap();
    }

    let flows = compute_flows(net, &phi).unwrap();
    let marg = compute_marginals(net, &phi, &flows).unwrap();
    let eval = DenseEvaluator::new(engine);
    let dense = eval.evaluate(net, &phi).unwrap();

    assert!(
        rel(flows.total_cost, dense.total_cost) < 1e-3,
        "seed {seed}: total cost native {} vs xla {}",
        flows.total_cost,
        dense.total_cost
    );
    for (eid, e) in net.graph.edges().iter().enumerate() {
        assert!(
            rel(flows.link_flow[eid], dense.link_flow[eid]) < 1e-3
                || (flows.link_flow[eid].abs() < 1e-6
                    && dense.link_flow[eid].abs() < 1e-4),
            "seed {seed}: link flow ({},{})",
            e.src,
            e.dst
        );
    }
    for i in 0..net.n() {
        assert!(
            rel(flows.workload[i], dense.workload[i]) < 1e-3
                || flows.workload[i].abs() < 1e-6,
            "seed {seed}: workload at {i}"
        );
    }
    for s in 0..net.s() {
        for i in 0..net.n() {
            assert!(
                rel(marg.dt_plus[s][i], dense.dt_plus[s][i]) < 5e-3
                    || marg.dt_plus[s][i].abs() < 1e-6,
                "seed {seed}: dt_plus[{s}][{i}] {} vs {}",
                marg.dt_plus[s][i],
                dense.dt_plus[s][i]
            );
            assert!(
                rel(marg.dt_r[s][i], dense.dt_r[s][i]) < 5e-3
                    || marg.dt_r[s][i].abs() < 1e-6,
                "seed {seed}: dt_r[{s}][{i}] {} vs {}",
                marg.dt_r[s][i],
                dense.dt_r[s][i]
            );
            assert!(
                rel(flows.t_minus[s][i], dense.t_minus[s][i]) < 1e-3
                    || flows.t_minus[s][i].abs() < 1e-6,
                "seed {seed}: t_minus[{s}][{i}]"
            );
            assert!(
                rel(flows.t_plus[s][i], dense.t_plus[s][i]) < 1e-3
                    || flows.t_plus[s][i].abs() < 1e-6,
                "seed {seed}: t_plus[{s}][{i}]"
            );
        }
    }
}

#[test]
fn parity_on_initial_strategy() {
    let Some(engine) = engine_or_skip() else { return };
    check_parity(&engine, 42, 0);
}

#[test]
fn parity_on_optimized_strategies() {
    let Some(engine) = engine_or_skip() else { return };
    for seed in [1, 7] {
        check_parity(&engine, seed, 10);
    }
}

#[test]
fn accelerated_run_matches_native_run() {
    let Some(engine) = engine_or_skip() else { return };
    use cecflow::coordinator::{optimize, optimize_accelerated, RunConfig};

    let sc = ScenarioSpec::by_name("abilene").unwrap().build(5);
    let net = &sc.net;
    let phi0 = Strategy::local_compute_init(net);
    let cfg = RunConfig {
        max_iters: 25,
        ..RunConfig::quick()
    };

    let mut sgp_a = cecflow::algo::Sgp::new();
    let eval = DenseEvaluator::new(&engine);
    let accel = optimize_accelerated(net, &mut sgp_a, &phi0, &cfg, &eval).unwrap();

    let mut sgp_n = cecflow::algo::Sgp::new();
    let native = optimize(net, &mut sgp_n, &phi0, &cfg).unwrap();

    // Both descend monotonically and land in the same neighborhood. The
    // accelerated path uses Jacobi steps (one artifact call per sweep) vs
    // the native Gauss–Seidel, so iterate counts differ; costs must agree
    // within a few percent and never increase.
    for w in accel.costs.windows(2) {
        assert!(w[1] <= w[0] * (1.0 + 1e-4), "accelerated cost increased");
    }
    let gap = rel(accel.final_cost(), native.final_cost());
    assert!(
        gap < 0.05,
        "accelerated {} vs native {} (gap {gap})",
        accel.final_cost(),
        native.final_cost()
    );
}

#[test]
fn saturation_maps_to_infinity() {
    let Some(engine) = engine_or_skip() else { return };
    let mut sc = ScenarioSpec::by_name("abilene").unwrap().build(42);
    // blow up the rates so local computation saturates
    sc.net.scale_rates(1e4);
    let phi = Strategy::local_compute_init(&sc.net);
    let eval = DenseEvaluator::new(&engine);
    let dense = eval.evaluate(&sc.net, &phi).unwrap();
    let native = compute_flows(&sc.net, &phi).unwrap();
    assert!(native.total_cost.is_infinite());
    assert!(
        dense.total_cost.is_infinite(),
        "XLA saturation sentinel not mapped: {}",
        dense.total_cost
    );
}
