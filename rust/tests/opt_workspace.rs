//! The workspace-arena determinism contract (PR 10).
//!
//! The allocation-free hot path (`step_ws`, `step_dense_ws`,
//! `update_single_node_ws`, `compute_marginals_into`) must be **bitwise
//! identical** to the legacy allocating entry points: same FP op order,
//! only the storage changed. These tests pin that contract across
//! scenarios and seeds, exercise one workspace reused across
//! differently-shaped networks (grow and shrink), and — via a counting
//! global allocator — certify that the steady-state sparse sweep performs
//! zero heap allocations once warm.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use cecflow::algo::{Gp, OptWorkspace, Optimizer, Sgp};
use cecflow::coordinator::{build_scenario_network, optimize, optimize_ws, RunConfig};
use cecflow::model::flows::compute_flows;
use cecflow::model::marginals::{compute_marginals, compute_marginals_into, MarginalScratch};
use cecflow::model::network::Network;
use cecflow::model::strategy::Strategy;
use cecflow::runtime::NativeBackend;
use cecflow::util::rng::Pcg;

// ---- counting allocator -----------------------------------------------
//
// Thread-local so the count only sees this test thread (the harness runs
// tests on sibling threads). Counts every alloc/realloc/alloc_zeroed;
// frees are irrelevant to the contract.

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(p, l, n)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

// ---- helpers ----------------------------------------------------------

/// Three differently-shaped scenarios (node/edge/task counts all differ)
/// so a single workspace reused across them must both grow and shrink.
const SCENARIOS: [&str; 3] = ["abilene-small", "connected-er", "fog"];

fn nets(seed: u64) -> Vec<Network> {
    SCENARIOS
        .iter()
        .map(|s| build_scenario_network(s, seed, 1.0).unwrap())
        .collect()
}

fn assert_phi_eq(a: &Strategy, b: &Strategy, ctx: &str) {
    assert_eq!(a.data.len(), b.data.len(), "{ctx}: task count");
    for s in 0..a.data.len() {
        for i in 0..a.data[s].len() {
            for (x, y) in a.data[s][i].iter().zip(&b.data[s][i]) {
                assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: data[{s}][{i}]");
            }
            for (x, y) in a.result[s][i].iter().zip(&b.result[s][i]) {
                assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: result[{s}][{i}]");
            }
        }
    }
}

// ---- marginals --------------------------------------------------------

/// `compute_marginals_into` on one scratch reused across every scenario
/// (grow + shrink) reproduces the nested tables bitwise.
#[test]
fn marginals_into_matches_nested_across_scenarios() {
    let mut scratch = MarginalScratch::new();
    for seed in [1u64, 7] {
        // walk big → small → big so the reuse path shrinks and regrows
        let mut all = nets(seed);
        let rev: Vec<Network> = all.iter().rev().cloned().collect();
        all.extend(rev);
        for (k, net) in all.iter().enumerate() {
            let phi = Strategy::local_compute_init(net);
            let flows = compute_flows(net, &phi).unwrap();
            let nested = compute_marginals(net, &phi, &flows).unwrap();
            compute_marginals_into(net, &phi, &flows, &mut scratch).unwrap();
            let flat = scratch.to_marginals();
            let ctx = format!("seed {seed} net {k}");
            assert_eq!(flat.d_link, nested.d_link, "{ctx}: d_link");
            assert_eq!(flat.c_node, nested.c_node, "{ctx}: c_node");
            assert_eq!(flat.dt_plus, nested.dt_plus, "{ctx}: dt_plus");
            assert_eq!(flat.dt_r, nested.dt_r, "{ctx}: dt_r");
            assert_eq!(flat.h_plus, nested.h_plus, "{ctx}: h_plus");
            assert_eq!(flat.h_minus, nested.h_minus, "{ctx}: h_minus");
        }
    }
}

// ---- sparse sweep parity ----------------------------------------------

/// `Sgp::step_ws` with one workspace persisted across iterations AND
/// across differently-shaped networks matches the legacy allocating
/// `step` trajectory bitwise: costs, residuals, retry/trust bookkeeping,
/// and the final strategy.
#[test]
fn sparse_step_parity_across_scenarios_and_seeds() {
    for seed in [1u64, 3, 11] {
        let mut ws = OptWorkspace::new(); // shared across all scenarios
        for net in &nets(seed) {
            let phi0 = Strategy::local_compute_init(net);

            let mut phi_legacy = phi0.clone();
            let mut sgp_legacy = Sgp::new();
            let mut phi_ws = phi0.clone();
            let mut sgp_ws = Sgp::new();

            for it in 0..15 {
                let a = sgp_legacy.step(net, &mut phi_legacy).unwrap();
                let b = sgp_ws.step_ws(net, &mut phi_ws, &mut ws).unwrap();
                let ctx = format!("seed {seed} iter {it}");
                assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits(), "{ctx}: cost");
                assert_eq!(a.residual.to_bits(), b.residual.to_bits(), "{ctx}: residual");
            }
            assert_eq!(sgp_legacy.retries, sgp_ws.retries, "retry ladders diverged");
            assert_phi_eq(&phi_legacy, &phi_ws, &format!("seed {seed}"));
        }
    }
}

/// Same contract for the GP baseline's workspace route.
#[test]
fn gp_step_parity() {
    let net = build_scenario_network("abilene-small", 2, 1.0).unwrap();
    let phi0 = Strategy::local_compute_init(&net);
    let mut ws = OptWorkspace::new();
    let mut phi_legacy = phi0.clone();
    let mut gp_legacy = Gp::new(1.0);
    let mut phi_ws = phi0;
    let mut gp_ws = Gp::new(1.0);
    for it in 0..15 {
        let a = gp_legacy.step(&net, &mut phi_legacy).unwrap();
        let b = gp_ws.step_ws(&net, &mut phi_ws, &mut ws).unwrap();
        assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits(), "iter {it}: cost");
        assert_eq!(a.residual.to_bits(), b.residual.to_bits(), "iter {it}: residual");
    }
    assert_phi_eq(&phi_legacy, &phi_ws, "gp");
}

/// The runner wrappers are the same contract one level up: a full
/// `optimize` run (fresh throwaway workspace) equals `optimize_ws` with a
/// pre-warmed, previously-used workspace.
#[test]
fn optimize_ws_matches_optimize() {
    let net = build_scenario_network("connected-er", 5, 1.0).unwrap();
    let phi0 = Strategy::local_compute_init(&net);
    let cfg = RunConfig::quick();

    let cold = optimize(&net, &mut Sgp::new(), &phi0, &cfg).unwrap();

    // dirty the workspace on a different network first
    let other = build_scenario_network("fog", 1, 1.0).unwrap();
    let mut ws = OptWorkspace::new();
    let _ = optimize_ws(
        &other,
        &mut Sgp::new(),
        &Strategy::local_compute_init(&other),
        &cfg,
        &mut ws,
    )
    .unwrap();

    let warm = optimize_ws(&net, &mut Sgp::new(), &phi0, &cfg, &mut ws).unwrap();
    assert_eq!(cold.costs.len(), warm.costs.len(), "iteration counts");
    for (k, (a, b)) in cold.costs.iter().zip(&warm.costs).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "iter {k}");
    }
    assert_phi_eq(&cold.phi, &warm.phi, "runner");
}

// ---- dense ladder parity ----------------------------------------------

/// `step_dense_ws` with persistent pooled candidates matches the legacy
/// `step_dense` bitwise through the native dense backend.
#[test]
fn dense_step_parity() {
    for seed in [1u64, 4] {
        let net = build_scenario_network("abilene-small", seed, 1.0).unwrap();
        let phi0 = Strategy::local_compute_init(&net);
        let mut ws = OptWorkspace::new();
        let mut phi_legacy = phi0.clone();
        let mut sgp_legacy = Sgp::new();
        let mut phi_ws = phi0;
        let mut sgp_ws = Sgp::new();
        for it in 0..12 {
            let a = sgp_legacy
                .step_dense(&net, &mut phi_legacy, &NativeBackend)
                .unwrap();
            let b = sgp_ws
                .step_dense_ws(&net, &mut phi_ws, &NativeBackend, &mut ws)
                .unwrap();
            let ctx = format!("seed {seed} iter {it}");
            assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits(), "{ctx}: cost");
            assert_eq!(a.residual.to_bits(), b.residual.to_bits(), "{ctx}: residual");
        }
        assert_eq!(sgp_legacy.rollbacks, sgp_ws.rollbacks, "rollback tallies");
        assert_phi_eq(&phi_legacy, &phi_ws, &format!("dense seed {seed}"));
    }
}

// ---- asynchronous single-block parity ----------------------------------

/// `update_single_node_ws` under a randomized (node, task, plane)
/// schedule matches the legacy allocating form bitwise, with the
/// workspace carried across every update (the `sim::tasks` re-opt path).
#[test]
fn update_single_node_parity() {
    for seed in [2u64, 9] {
        let net = build_scenario_network("abilene-small", seed, 1.0).unwrap();
        let phi0 = Strategy::local_compute_init(&net);
        let mut ws = OptWorkspace::new();
        let mut phi_legacy = phi0.clone();
        let mut sgp_legacy = Sgp::new();
        let mut phi_ws = phi0;
        let mut sgp_ws = Sgp::new();
        let mut rng = Pcg::new(seed);
        for k in 0..200 {
            let node = rng.below(net.n());
            let task = rng.below(net.s());
            let plane_result = rng.chance(0.5);
            let a = sgp_legacy
                .update_single_node(&net, &mut phi_legacy, node, task, plane_result)
                .unwrap();
            let b = sgp_ws
                .update_single_node_ws(&net, &mut phi_ws, node, task, plane_result, &mut ws)
                .unwrap();
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "seed {seed} update {k} (node {node}, task {task}, result {plane_result})"
            );
        }
        assert_phi_eq(&phi_legacy, &phi_ws, &format!("async seed {seed}"));
    }
}

// ---- the zero-allocation certificate -----------------------------------

/// Steady-state `step_ws` performs zero heap allocations: after a
/// warm-up sweep sizes every buffer (rows are saved per node, so one full
/// Gauss–Seidel sweep touches the max row width), further sweeps must
/// not allocate at all. This is the acceptance criterion of the arena
/// design, checked mechanically rather than by code audit alone.
#[test]
fn steady_state_step_ws_is_allocation_free() {
    let net = build_scenario_network("abilene-small", 1, 1.0).unwrap();
    let phi0 = Strategy::local_compute_init(&net);
    let mut sgp = Sgp::new();
    let mut phi = phi0;
    let mut ws = OptWorkspace::new();

    // warm-up: three full sweeps (the first sizes the arena, the next two
    // cover retry-ladder depths and acceptance bookkeeping)
    for _ in 0..3 {
        sgp.step_ws(&net, &mut phi, &mut ws).unwrap();
    }

    let before = allocs();
    for _ in 0..5 {
        sgp.step_ws(&net, &mut phi, &mut ws).unwrap();
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "steady-state sparse sweep allocated {} times",
        after - before
    );
}

/// The marginal broadcast alone is likewise allocation-free on a warm
/// scratch.
#[test]
fn steady_state_marginals_into_is_allocation_free() {
    let net = build_scenario_network("abilene-small", 1, 1.0).unwrap();
    let phi = Strategy::local_compute_init(&net);
    let flows = compute_flows(&net, &phi).unwrap();
    let mut scratch = MarginalScratch::new();
    compute_marginals_into(&net, &phi, &flows, &mut scratch).unwrap();

    let before = allocs();
    for _ in 0..10 {
        compute_marginals_into(&net, &phi, &flows, &mut scratch).unwrap();
    }
    assert_eq!(allocs() - before, 0, "warm marginal broadcast allocated");
}
