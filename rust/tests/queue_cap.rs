//! Per-queue finite-capacity admission control end to end: blocking must
//! be monotone non-increasing in the FIFO capacity, every capped run must
//! satisfy the widened conservation invariant
//! `completed + stranded + overload_dropped + queue_dropped == arrived`,
//! an effectively-unbounded cap must reproduce the uncapped engine's
//! headline numbers bit-for-bit (same RNG draws — a cap that never binds
//! must not perturb the event stream), and a capped M/M/1/K scenario must
//! pass the validator's Erlang blocking check.

use cecflow::graph::from_undirected;
use cecflow::model::cost::CostFn;
use cecflow::model::network::{Network, Task};
use cecflow::model::strategy::Strategy;
use cecflow::sim::{simulate, validate, ArrivalSpec, SimConfig, SimEpoch, SimPlan, Telemetry};

/// Two nodes, one bidirectional link; one task whose data enters and
/// completes at node 0, so the all-local strategy drives an isolated
/// M/M/1 (or M/M/1/K when capped) queue at node 0's CPU.
fn two_node(cap0: f64, lambda: f64) -> Network {
    let graph = from_undirected(2, &[(0, 1)]);
    let e = graph.edge_count();
    Network {
        graph,
        tasks: vec![Task { dest: 0, ctype: 0 }],
        num_types: 1,
        input_rate: vec![vec![lambda, 0.0]],
        result_ratio: vec![0.5],
        comp_weight: vec![vec![1.0]; 2],
        link_cost: vec![CostFn::Queue { cap: 10.0 }; e],
        comp_cost: vec![CostFn::Queue { cap: cap0 }, CostFn::Queue { cap: 8.0 }],
    }
}

fn run(net: &Network, cfg: &SimConfig) -> Telemetry {
    let phi = Strategy::local_compute_init(net);
    let plan = SimPlan {
        epochs: vec![SimEpoch {
            net: net.clone(),
            phi: phi.clone(),
        }],
    };
    simulate(&plan, &ArrivalSpec::parse("poisson").unwrap(), cfg).unwrap()
}

fn assert_conserved(t: &Telemetry) {
    assert_eq!(
        t.completed + t.stranded + t.overload_dropped + t.queue_dropped,
        t.arrived,
        "conservation invariant violated"
    );
    let blocked: u64 = t.node_blocked.iter().chain(t.link_blocked.iter()).sum();
    assert_eq!(
        blocked, t.queue_dropped,
        "per-server blocked counters must sum to the global drop count"
    );
}

/// ρ = 0.75 at node 0: every tested capacity binds, and a larger FIFO can
/// only admit more — the drop count must be monotone non-increasing in K.
#[test]
fn blocking_is_monotone_non_increasing_in_capacity() {
    let net = two_node(2.0, 1.5);
    net.assert_valid();
    let mut last = u64::MAX;
    for cap in [1u64, 2, 4, 8] {
        let t = run(
            &net,
            &SimConfig {
                requests: 10_000,
                warmup: 0.0,
                seed: 29,
                queue_cap: Some(cap),
                ..SimConfig::default()
            },
        );
        assert_conserved(&t);
        assert!(t.queue_dropped > 0, "cap {cap} never blocked at ρ = 0.75");
        assert_eq!(t.overload_dropped, 0, "per-queue drops must not double-count");
        assert!(
            t.queue_dropped <= last,
            "blocking increased from {last} to {} when the cap grew to {cap}",
            t.queue_dropped
        );
        last = t.queue_dropped;
        // the FIFO really is bounded: peak in-system never exceeds K
        assert!(t.node_peak.iter().all(|&p| p <= cap), "{:?}", t.node_peak);
    }
}

/// A cap that never binds must not perturb the engine: same RNG draws,
/// same event stream, bit-identical headline telemetry — and the uncapped
/// run's JSON must not grow any admission-control keys (the determinism
/// contract: absent flags reproduce pre-admission-control artifacts).
#[test]
fn unbound_cap_reproduces_uncapped_run_bit_for_bit() {
    let net = two_node(2.0, 1.0);
    let cfg = SimConfig {
        requests: 8_000,
        warmup: 0.05,
        seed: 41,
        ..SimConfig::default()
    };
    let plain = run(&net, &cfg);
    let huge = run(
        &net,
        &SimConfig {
            queue_cap: Some(1 << 40),
            ..cfg
        },
    );
    assert_eq!(plain.queue_caps, None);
    assert_eq!(huge.queue_caps, Some((1 << 40, 1 << 40)));
    assert_eq!(huge.queue_dropped, 0);
    // headline numbers agree bit-for-bit with the uncapped run
    assert_eq!(plain.arrived, huge.arrived);
    assert_eq!(plain.completed, huge.completed);
    assert_eq!(plain.events, huge.events);
    assert_eq!(plain.end_time.to_bits(), huge.end_time.to_bits());
    assert_eq!(
        plain.mean_sojourn().to_bits(),
        huge.mean_sojourn().to_bits()
    );
    let (p50a, p99a, p999a) = plain.tail();
    let (p50b, p99b, p999b) = huge.tail();
    assert_eq!(p50a.to_bits(), p50b.to_bits());
    assert_eq!(p99a.to_bits(), p99b.to_bits());
    assert_eq!(p999a.to_bits(), p999b.to_bits());
    // the uncapped artifact carries none of the new keys...
    let dump = plain.to_json().dump();
    for key in ["queue_cap", "queue_dropped", "node_blocked", "link_blocked"] {
        assert!(!dump.contains(key), "uncapped telemetry grew '{key}'");
    }
    // ...while the capped one is gated on and self-describing
    let dump = huge.to_json().dump();
    assert!(dump.contains("\"queue_dropped\""), "{dump}");
    assert!(dump.contains("\"queue_cap\""), "{dump}");
}

/// λ = 1, μ = 2, K = 2 at node 0: an M/M/1/2 loss queue. The validator
/// must predict Erlang blocking `(1−ρ)ρ²/(1−ρ³) = 1/7`, see simulated
/// blocking within tolerance of it, price the queue with the truncated
/// occupancy `L = 4/7`, and keep the alarm quiet — a saturated-style
/// false alarm here would mean the analytic side still assumes an
/// unbounded FIFO.
#[test]
fn capped_mm1k_run_passes_the_erlang_check() {
    let net = two_node(2.0, 1.0);
    let phi = Strategy::local_compute_init(&net);
    let t = run(
        &net,
        &SimConfig {
            requests: 30_000,
            warmup: 0.05,
            seed: 17,
            queue_cap: Some(2),
            ..SimConfig::default()
        },
    );
    assert_conserved(&t);
    assert!(t.queue_dropped > 0, "K = 2 at ρ = 0.5 must block sometimes");
    let report = validate(&net, &phi, &t, 0.25).unwrap();
    assert!(
        !report.alarm,
        "expected quiet alarm, got: {:?}",
        report.alarm_reasons
    );
    assert_eq!(report.queue_caps, Some((2, 2)));
    assert_eq!(report.queue_dropped, t.queue_dropped);
    let cpu0 = &report.servers[0];
    assert_eq!(cpu0.name, "cpu:0");
    assert!(!cpu0.saturated, "a capped queue is a loss system, not divergent");
    assert_eq!(cpu0.queue_cap, Some(2));
    let eb = cpu0.expected_blocking.unwrap();
    assert!((eb - 1.0 / 7.0).abs() < 1e-9, "Erlang column {eb} != 1/7");
    let sb = cpu0.simulated_blocking.unwrap();
    assert!((sb - eb).abs() < 0.1, "simulated blocking {sb} far from {eb}");
    // truncated-geometric occupancy, not the unbounded M/M/1 form
    assert!(
        (cpu0.analytic - 4.0 / 7.0).abs() < 1e-9,
        "M/M/1/2 occupancy {} != 4/7",
        cpu0.analytic
    );
    // the capped report JSON carries the blocking columns bit-exactly
    let dump = report.to_json().dump();
    assert!(dump.contains("expected_blocking_bits"), "{dump}");
    assert!(dump.contains("\"queue_dropped\""), "{dump}");
    // the render grows the blocking columns too
    let txt = report.render();
    assert!(txt.contains("erlang B"), "{txt}");
    assert!(txt.contains("per-queue admission"), "{txt}");
}
