//! Adaptivity regression suite (ISSUE 4 tentpole contract):
//!
//! * **warm ≤ cold** — after a step change in the task pattern, the
//!   warm-started re-optimization (carrying the previous epoch's
//!   converged strategy, the paper's §IV "adaptive to changes in task
//!   pattern" claim) re-converges in no more iterations than the
//!   cold-started baseline, on every epoch after the first, on at least
//!   two scenarios (one Table-II topology, one extended-library
//!   topology);
//! * **zero-extra-iterations** — an epoch whose pattern did not change
//!   costs exactly the convergence check
//!   (`RunConfig::min_iters_to_converge`), nothing more;
//! * **dynamic cells are deterministic** — per-epoch final costs of
//!   dynamic sweep cells are bitwise identical across worker counts and
//!   across `--shards 1` vs `--shards 2` (in-process shard merge *and*
//!   real `cecflow` child processes), so the shard/merge protocol keeps
//!   holding on the schedule axis.

use std::path::Path;

use cecflow::coordinator::{
    run_sweep, run_sweep_shard, run_sweep_sharded, AdaptiveRunner, Algorithm, CellBackend,
    PatternSchedule, RunConfig, ShardOptions, SweepReport, SweepSpec,
};
use cecflow::util::json::Json;

/// The binary under test — cargo builds and exports it for integration
/// tests.
fn cecflow_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_cecflow"))
}

/// One Table-II row and one extended-library row: the adaptivity claim
/// must hold beyond the original scenario set.
const SCENARIOS: [&str; 2] = ["abilene", "grid-torus"];

#[test]
fn warm_start_reconverges_in_at_most_the_cold_start_iterations() {
    let cfg = RunConfig::quick();
    let schedule = PatternSchedule::parse("step:3:1.5").unwrap();
    for scenario in SCENARIOS {
        let warm = AdaptiveRunner::warm(cfg)
            .run_scenario(scenario, 1, 1.0, schedule)
            .expect("warm dynamic run");
        let cold = AdaptiveRunner::cold(cfg)
            .run_scenario(scenario, 1, 1.0, schedule)
            .expect("cold dynamic run");
        assert_eq!(warm.epochs.len(), 3);
        assert_eq!(cold.epochs.len(), 3);
        // epoch 0 has no history: both modes start all-local and coincide
        assert_eq!(
            warm.epochs[0].final_cost.to_bits(),
            cold.epochs[0].final_cost.to_bits(),
            "{scenario}: epoch 0 must be mode-independent"
        );
        for (w, c) in warm.epochs.iter().zip(&cold.epochs).skip(1) {
            assert!(
                w.iterations <= c.iterations,
                "{scenario} epoch {}: warm start took {} iterations, cold start {} — \
                 the adaptivity claim is violated",
                w.epoch,
                w.iterations,
                c.iterations
            );
            // both must land on (approximately) the same optimum, else the
            // iteration comparison is apples to oranges
            assert!(
                (w.final_cost - c.final_cost).abs() <= 0.01 * c.final_cost.abs(),
                "{scenario} epoch {}: warm settled at {} but cold at {}",
                w.epoch,
                w.final_cost,
                c.final_cost
            );
            assert!(
                !w.warm_fallback,
                "{scenario} epoch {}: a 1.5× step must not saturate",
                w.epoch
            );
        }
        assert!(
            warm.reconvergence_iterations() <= cold.reconvergence_iterations(),
            "{scenario}: warm re-convergence budget {} exceeds cold {}",
            warm.reconvergence_iterations(),
            cold.reconvergence_iterations()
        );
        // a warm start begins at the carried (near-optimal) point: its
        // transient regret after the shift can't exceed the cold start's,
        // which pays the full all-local-to-optimum descent again
        for (w, c) in warm.epochs.iter().zip(&cold.epochs).skip(1) {
            assert!(
                w.transient_regret <= c.transient_regret + 1e-9,
                "{scenario} epoch {}: warm regret {} exceeds cold regret {}",
                w.epoch,
                w.transient_regret,
                c.transient_regret
            );
        }
    }
}

#[test]
fn unchanged_epoch_costs_exactly_the_convergence_check() {
    // Under `step:3`, epochs 1 and 2 run the *same* shifted pattern: a
    // warm-started epoch 2 begins at its own fixed point, so the only
    // iterations it may spend are the ones the convergence window needs
    // to attest a steady state.
    let cfg = RunConfig::quick();
    let schedule = PatternSchedule::parse("step:3:1.5").unwrap();
    for scenario in SCENARIOS {
        let warm = AdaptiveRunner::warm(cfg)
            .run_scenario(scenario, 1, 1.0, schedule)
            .expect("warm dynamic run");
        let unchanged = &warm.epochs[2];
        assert_eq!(
            unchanged.iterations,
            cfg.min_iters_to_converge(),
            "{scenario}: a no-op epoch must cost exactly the convergence check \
             ({} iterations), not {}",
            cfg.min_iters_to_converge(),
            unchanged.iterations
        );
        // starting at the fixed point: no transient to pay down
        assert!(
            unchanged.transient_regret <= 1e-9 * unchanged.final_cost.abs(),
            "{scenario}: no-op epoch paid transient regret {}",
            unchanged.transient_regret
        );
        assert_eq!(unchanged.iters_to_1pct, 1, "{scenario}: already within 1% at iteration 1");
        // and it settles where epoch 1 settled (same pattern, same point)
        let prev = &warm.epochs[1];
        assert!(
            (unchanged.final_cost - prev.final_cost).abs() <= 1e-6 * prev.final_cost.abs(),
            "{scenario}: no-op epoch drifted from {} to {}",
            prev.final_cost,
            unchanged.final_cost
        );
    }
}

/// A mixed static/dynamic grid over both planes of the determinism
/// contract: 2 scenarios × 2 seeds × {static, step} = 8 cells, 3 epochs
/// per dynamic cell.
fn dynamic_spec() -> SweepSpec {
    SweepSpec {
        scenarios: vec!["abilene".into(), "grid-torus".into()],
        seeds: vec![1, 2],
        algorithms: vec![Algorithm::Sgp],
        backends: vec![CellBackend::Sparse],
        schedules: vec![
            PatternSchedule::static_(),
            PatternSchedule::parse("step:3:1.5").unwrap(),
        ],
        rate_scale: 1.0,
        run: RunConfig::quick(),
        sim: None,
        cache: None,
    }
}

#[test]
fn dynamic_cells_are_worker_count_independent() {
    let spec = dynamic_spec();
    let one = run_sweep(&spec, 1).expect("1-worker sweep");
    let four = run_sweep(&spec, 4).expect("4-worker sweep");
    assert_eq!(one.cells.len(), 8);
    // the fingerprint covers per-epoch cost bits — but compare the epochs
    // explicitly too, so a fingerprint regression can't mask a drift
    assert_eq!(one.fingerprint(), four.fingerprint());
    for (a, b) in one.cells.iter().zip(&four.cells) {
        assert_eq!(
            a.epoch_costs.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.epoch_costs.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "per-epoch costs drifted across worker counts for {} seed {} schedule {}",
            a.cell.scenario,
            a.cell.seed,
            a.cell.schedule.label()
        );
        if !a.cell.schedule.is_static() {
            assert_eq!(a.epoch_costs.len(), 3, "dynamic cell must carry 3 epoch costs");
            assert_eq!(
                a.final_cost.to_bits(),
                a.epoch_costs[2].to_bits(),
                "a dynamic cell reports its last epoch's converged cost"
            );
        } else {
            assert!(a.epoch_costs.is_empty(), "static cell grew epoch costs");
        }
    }
}

#[test]
fn dynamic_cells_survive_in_process_shard_merge() {
    let spec = dynamic_spec();
    let whole = run_sweep(&spec, 2).expect("single-process sweep");
    for count in [1usize, 2] {
        let parts: Vec<SweepReport> = (0..count)
            .map(|k| run_sweep_shard(&spec, k, count, 2).expect("shard run"))
            .collect();
        // round-trip through the JSON artifact first — per-epoch cost
        // bits must survive serialization, not just the in-memory path
        let parts: Vec<SweepReport> = parts
            .iter()
            .map(|p| {
                SweepReport::from_json(&Json::parse(&p.to_json().pretty()).unwrap())
                    .expect("shard report round-trip")
            })
            .collect();
        let merged = SweepReport::merge(parts).expect("merge");
        assert_eq!(
            merged.fingerprint(),
            whole.fingerprint(),
            "{count} shard(s) drifted from the single-process dynamic sweep"
        );
    }
}

#[test]
fn dynamic_cells_survive_process_sharding() {
    // --shards 1 vs --shards 2 through real cecflow child processes: the
    // JSON-lines protocol must carry dynamic cells bit-exactly.
    let spec = dynamic_spec();
    let mut fingerprints = Vec::new();
    for shards in [1usize, 2] {
        let report = run_sweep_sharded(
            &spec,
            cecflow_bin(),
            &ShardOptions {
                shards,
                workers: 2,
                ..Default::default()
            },
        )
        .expect("sharded dynamic sweep");
        fingerprints.push(report.fingerprint());
    }
    assert_eq!(
        fingerprints[0], fingerprints[1],
        "--shards 1 and --shards 2 disagree on dynamic cells"
    );
}
