//! Sweep determinism (ISSUE 2 satellite): the same `SweepSpec` run on
//! different worker counts — and run repeatedly — yields identical
//! per-cell results (costs compared bit-for-bit; only wall-clock timing
//! may differ). This is the contract that makes sweep numbers citable.

use cecflow::coordinator::{
    run_sweep, Algorithm, CellBackend, PatternSchedule, RunConfig, SweepSpec,
};

fn small_spec() -> SweepSpec {
    SweepSpec {
        scenarios: vec!["abilene".into()],
        seeds: vec![1, 2],
        algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
        backends: vec![CellBackend::Sparse],
        schedules: vec![PatternSchedule::static_()],
        rate_scale: 1.0,
        run: RunConfig::quick(),
        sim: None,
        cache: None,
    }
}

#[test]
fn identical_results_on_1_and_4_workers() {
    let spec = small_spec();
    let serial = run_sweep(&spec, 1).unwrap();
    let parallel = run_sweep(&spec, 4).unwrap();
    assert_eq!(serial.workers, 1);
    assert_eq!(serial.cells.len(), 4);
    assert_eq!(parallel.cells.len(), 4);
    assert_eq!(
        serial.fingerprint(),
        parallel.fingerprint(),
        "per-cell results must not depend on the worker count"
    );
}

#[test]
fn repeated_runs_are_identical() {
    let spec = small_spec();
    let a = run_sweep(&spec, 2).unwrap();
    let b = run_sweep(&spec, 2).unwrap();
    assert_eq!(a.fingerprint(), b.fingerprint());
    // group aggregates follow from identical cells
    let ga = a.groups();
    let gb = b.groups();
    assert_eq!(ga.len(), gb.len());
    for (x, y) in ga.iter().zip(&gb) {
        assert_eq!(x.scenario, y.scenario);
        assert_eq!(x.algorithm, y.algorithm);
        assert_eq!(x.mean_cost.to_bits(), y.mean_cost.to_bits());
        assert_eq!(x.p95_cost.to_bits(), y.p95_cost.to_bits());
    }
}

#[test]
fn dense_backend_cells_are_worker_count_independent_too() {
    // The per-cell backend routing (SGP through `step_dense` +
    // `NativeBackend`) must uphold the same determinism contract as the
    // sparse path.
    let spec = SweepSpec {
        backends: vec![CellBackend::Sparse, CellBackend::Native],
        ..small_spec()
    };
    let serial = run_sweep(&spec, 1).unwrap();
    let parallel = run_sweep(&spec, 4).unwrap();
    // sgp×sparse, sgp×native, lpr×sparse per seed
    assert_eq!(serial.cells.len(), 6);
    assert_eq!(serial.fingerprint(), parallel.fingerprint());
}

#[test]
fn cells_cover_the_grid_in_canonical_order() {
    let spec = small_spec();
    let report = run_sweep(&spec, 3).unwrap();
    let got: Vec<(String, u64, &str)> = report
        .cells
        .iter()
        .map(|c| {
            (
                c.cell.scenario.clone(),
                c.cell.seed,
                c.cell.algorithm.name(),
            )
        })
        .collect();
    let want: Vec<(String, u64, &str)> = vec![
        ("abilene".into(), 1, "sgp"),
        ("abilene".into(), 1, "lpr"),
        ("abilene".into(), 2, "sgp"),
        ("abilene".into(), 2, "lpr"),
    ];
    assert_eq!(got, want, "results must come back in grid order");
}
