//! Strategy store end-to-end (ISSUE 8): exact-bits `Strategy` serde,
//! tamper-rejection of store entries, and the warm-start determinism
//! contract — a cache-hit sweep is fingerprint-identical to the cold
//! sweep across worker counts and shard counts, in-process and through
//! real `cecflow` child processes sharing one `--cache-dir`.

use std::path::Path;
use std::process::Command;

use cecflow::algo::Sgp;
use cecflow::coordinator::{
    build_scenario_network, optimize, run_sweep, run_sweep_shard, Algorithm, CellBackend, FsStore,
    PatternSchedule, RunConfig, StoredRun, StrategyStore, SweepReport, SweepSpec,
};
use cecflow::model::flows::compute_flows;
use cecflow::model::strategy::Strategy;
use cecflow::util::json::Json;

fn cecflow_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_cecflow"))
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cecflow-store-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Converge SGP on a scenario instance — the source of "random feasible
/// strategies": every (scenario, seed) pair yields a different interior
/// point of the feasible polytope.
fn converged(scenario: &str, seed: u64) -> (cecflow::model::network::Network, Strategy) {
    let net = build_scenario_network(scenario, seed, 1.0).unwrap();
    let phi0 = Strategy::local_compute_init(&net);
    let res = optimize(&net, &mut Sgp::new(), &phi0, &RunConfig::quick()).unwrap();
    (net, res.phi)
}

#[test]
fn strategy_serde_round_trips_bitwise_on_random_feasible_strategies() {
    for (scenario, seed) in [
        ("abilene", 1u64),
        ("abilene", 7),
        ("abilene", 42),
        ("connected-er", 3),
        ("connected-er", 11),
    ] {
        let (net, phi) = converged(scenario, seed);
        let text = phi.to_json().pretty();
        let back = Strategy::from_json(&Json::parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("{scenario} seed {seed}: {e:#}"));
        assert!(back.matches(&net), "{scenario} seed {seed}: shape drifted");
        assert_eq!(
            back.digest(),
            phi.digest(),
            "{scenario} seed {seed}: serde round-trip is not bitwise"
        );
        // the decisive check: the round-tripped strategy re-prices to the
        // exact same cost bits — this is what store verification relies on
        let a = compute_flows(&net, &phi).unwrap().total_cost;
        let b = compute_flows(&net, &back).unwrap().total_cost;
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn tampered_and_truncated_entries_are_counted_misses_not_panics() {
    let dir = temp_dir("tamper");
    let store = FsStore::open(&dir).unwrap();
    let (net, phi) = converged("abilene", 5);
    let price = compute_flows(&net, &phi).unwrap().total_cost;
    let entry = StoredRun::capture("sgp", &[price * 1.5, price], 2, price, &phi);
    let key = 0x5eed_0000_0000_0001u64;
    store.save(key, &entry);
    let path = dir.join(format!("{key:016x}.json"));
    let intact = std::fs::read_to_string(&path).unwrap();

    // the intact entry loads and verifies
    let loaded = store.load(key).expect("intact entry must load");
    assert_eq!(loaded.entry_digest(), entry.entry_digest());
    assert!(loaded.verifies_on(&net));

    // truncated mid-document: parse failure -> miss
    std::fs::write(&path, &intact[..intact.len() / 2]).unwrap();
    assert!(store.load(key).is_none(), "truncated entry must be a miss");

    // tampered field (price_bits edited without re-forging the digest)
    let doctored = intact.replace(
        &format!("{:016x}", price.to_bits()),
        &format!("{:016x}", price.to_bits() ^ 1),
    );
    assert_ne!(doctored, intact, "tamper target not found in entry JSON");
    std::fs::write(&path, doctored).unwrap();
    assert!(store.load(key).is_none(), "tampered entry must be a miss");

    // entry copied under another key's address: key seal -> miss
    let other = key + 1;
    std::fs::write(dir.join(format!("{other:016x}.json")), &intact).unwrap();
    assert!(store.load(other).is_none(), "relocated entry must be a miss");

    // and the original address still works once restored
    std::fs::write(&path, &intact).unwrap();
    assert!(store.load(key).is_some());

    let _ = std::fs::remove_dir_all(&dir);
}

/// sgp (warm-startable, sparse + native routes) and lpr (not
/// warm-startable) over two seeds: six cells, four of which are
/// store-eligible.
fn spec(cache: Option<String>) -> SweepSpec {
    SweepSpec {
        scenarios: vec!["abilene".into()],
        seeds: vec![1, 2],
        algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
        backends: vec![CellBackend::Sparse, CellBackend::Native],
        schedules: vec![PatternSchedule::static_()],
        rate_scale: 1.0,
        run: RunConfig::quick(),
        sim: None,
        cache,
    }
}

fn hits(report: &SweepReport) -> (usize, usize, usize) {
    let caches: Vec<_> = report.cells.iter().filter_map(|c| c.cache).collect();
    (
        caches.len(),
        caches.iter().filter(|k| k.hit).count(),
        caches.iter().map(|k| k.iters_saved).sum(),
    )
}

#[test]
fn cache_hit_sweep_is_fingerprint_identical_across_workers_and_shards() {
    let dir = temp_dir("inproc");
    let cached = spec(Some(dir.display().to_string()));

    // the reference: no store at all
    let cold = run_sweep(&spec(None), 2).expect("store-less sweep");
    assert!(cold.cells.iter().all(|c| c.cache.is_none()));

    // first store-backed run populates the cache (all misses)...
    let first = run_sweep(&cached, 1).expect("populating sweep");
    let (eligible, hit, saved) = hits(&first);
    assert_eq!(eligible, 4, "sgp cells on both backends consult the store");
    assert_eq!((hit, saved), (0, 0), "an empty store cannot hit");
    // ...and measures exactly what the store-less sweep measures
    assert_eq!(first.fingerprint(), cold.fingerprint());

    // warmed re-runs: every eligible cell is a verified hit with saved
    // iterations, on any worker count, with an unchanged fingerprint
    for workers in [1usize, 2, 4] {
        let warm = run_sweep(&cached, workers).expect("warmed sweep");
        let (eligible, hit, saved) = hits(&warm);
        assert_eq!((eligible, hit), (4, 4), "{workers} workers: partial hits");
        assert!(saved > 0, "{workers} workers: hits must save iterations");
        assert_eq!(
            warm.fingerprint(),
            cold.fingerprint(),
            "{workers}-worker warmed sweep drifted from the cold run"
        );
    }

    // shard splits ride the same store: 1-shard and 2-shard runs merge to
    // the cold fingerprint, all hits
    for count in [1usize, 2] {
        let parts: Vec<SweepReport> = (0..count)
            .map(|k| run_sweep_shard(&cached, k, count, 2).expect("shard run"))
            .collect();
        let merged = SweepReport::merge(parts).expect("merge");
        let (eligible, hit, _) = hits(&merged);
        assert_eq!((eligible, hit), (4, 4), "{count} shard(s): partial hits");
        assert_eq!(
            merged.fingerprint(),
            cold.fingerprint(),
            "{count}-shard warmed sweep drifted from the cold run"
        );
    }

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn child_processes_sharing_a_cache_dir_reproduce_the_cold_fingerprint() {
    let dir = temp_dir("cli");
    std::fs::create_dir_all(&dir).unwrap();
    let cache_dir = dir.join("store");

    let spec_flags = [
        "--scenarios",
        "abilene",
        "--seeds",
        "1,2",
        "--algos",
        "sgp,lpr",
        "--backends",
        "sparse,native",
    ];
    let cache_flag = cache_dir.display().to_string();
    let run = |extra: &[&str], out: &Path| {
        let status = Command::new(cecflow_bin())
            .arg("sweep")
            .args(spec_flags)
            .args(["--cache-dir", cache_flag.as_str()])
            .args(extra)
            .arg("--out")
            .arg(out)
            .status()
            .expect("spawn cecflow sweep");
        assert!(status.success(), "sweep {extra:?} failed: {status}");
    };
    let load = |p: &Path| -> SweepReport {
        let text = std::fs::read_to_string(p).unwrap_or_else(|e| panic!("reading {p:?}: {e}"));
        SweepReport::from_json(&Json::parse(&text).expect("report JSON")).expect("report shape")
    };

    // populate cold through one child, then warm through a 2-shard parent
    // whose workers share the same cache directory
    let cold_out = dir.join("cold.json");
    run(&[], &cold_out);
    let warm_out = dir.join("warm.json");
    run(&["--shards", "2", "--shard-timeout", "600"], &warm_out);

    let cold = load(&cold_out);
    let warm = load(&warm_out);
    let (_, cold_hits, cold_saved) = hits(&cold);
    assert_eq!((cold_hits, cold_saved), (0, 0));
    let (eligible, hit, saved) = hits(&warm);
    assert_eq!((eligible, hit), (4, 4), "children missed the shared store");
    assert!(saved > 0, "warmed children must report saved iterations");
    assert_eq!(
        warm.fingerprint(),
        cold.fingerprint(),
        "warmed sharded child run drifted from the cold child run"
    );
    // both equal the in-process store-less reference
    let reference = run_sweep(&spec(None), 2).expect("in-process reference");
    assert_eq!(cold.fingerprint(), reference.fingerprint());

    let _ = std::fs::remove_dir_all(&dir);
}
