//! Process-sharded sweep determinism (ISSUE 3 tentpole, ISSUE 5 retry +
//! work re-stealing):
//!
//! * merging the shard reports of `n ∈ {1, 2, 4}` shards —
//!   in-process (`run_sweep_shard` + `SweepReport::merge`) *and* through
//!   real `cecflow` child processes (`run_sweep_sharded`, JSON-lines
//!   stdout protocol) — is fingerprint-identical to the single-process
//!   `run_sweep` of the same `SweepSpec`;
//! * the `--shards`/`--shard`/`--merge` CLI surface round-trips through
//!   report JSON artifacts bit-exactly;
//! * per-cell dense-backend routing: a `backend: native` sweep cell is
//!   bitwise identical to a direct `optimize_accelerated` run
//!   (`Sgp::step_dense` + `NativeBackend`) of the same instance;
//! * a shard-worker killed mid-sweep (the `CECFLOW_FAIL_SHARD` injection
//!   hook) recovers through work re-stealing with a fingerprint identical
//!   to the single-process run; `retries: 0` restores fail-fast; an
//!   exhausted retry budget surfaces a contextful error naming the cell.

use std::path::Path;
use std::process::Command;

use cecflow::algo::Sgp;
use cecflow::coordinator::{
    build_scenario_network, optimize_accelerated, run_sweep, run_sweep_shard, run_sweep_sharded,
    Algorithm, CellBackend, PatternSchedule, RunConfig, ShardOptions, SweepReport, SweepSpec,
};
use cecflow::model::strategy::Strategy;
use cecflow::runtime::NativeBackend;
use cecflow::util::json::Json;

/// The binary under test — cargo builds and exports it for integration
/// tests.
fn cecflow_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_cecflow"))
}

/// A small grid that still exercises both planes: SGP on the sparse and
/// native-dense routes plus the LPR baseline, two seeds.
fn spec() -> SweepSpec {
    SweepSpec {
        scenarios: vec!["abilene".into()],
        seeds: vec![1, 2],
        algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
        backends: vec![CellBackend::Sparse, CellBackend::Native],
        schedules: vec![PatternSchedule::static_()],
        rate_scale: 1.0,
        run: RunConfig::quick(),
        sim: None,
        cache: None,
    }
}

#[test]
fn in_process_shard_merge_matches_single_process_for_1_2_4_shards() {
    let spec = spec();
    let whole = run_sweep(&spec, 2).expect("single-process sweep");
    assert_eq!(whole.cells.len(), 6); // (sgp×2 backends + lpr) × 2 seeds
    for count in [1usize, 2, 4] {
        let parts: Vec<SweepReport> = (0..count)
            .map(|k| run_sweep_shard(&spec, k, count, 2).expect("shard run"))
            .collect();
        // shard reports are serde round-tripped first: the merge input in
        // real use is a JSON artifact, not an in-memory struct
        let parts: Vec<SweepReport> = parts
            .iter()
            .map(|p| {
                SweepReport::from_json(&Json::parse(&p.to_json().pretty()).unwrap())
                    .expect("shard report round-trip")
            })
            .collect();
        let merged = SweepReport::merge(parts).expect("merge");
        assert_eq!(
            merged.fingerprint(),
            whole.fingerprint(),
            "{count} shard(s) drifted from the single-process sweep"
        );
    }
}

#[test]
fn process_sharded_sweep_matches_single_process() {
    let spec = spec();
    let whole = run_sweep(&spec, 2).expect("single-process sweep");
    for shards in [2usize, 4] {
        let sharded = run_sweep_sharded(
            &spec,
            cecflow_bin(),
            &ShardOptions {
                shards,
                workers: 2,
                ..Default::default()
            },
        )
        .expect("sharded sweep");
        assert_eq!(
            sharded.fingerprint(),
            whole.fingerprint(),
            "{shards}-process sharded sweep drifted from the single-process run"
        );
    }
}

#[test]
fn native_routed_sweep_cell_is_bitwise_the_direct_dense_run() {
    let spec = SweepSpec {
        scenarios: vec!["abilene".into()],
        seeds: vec![3],
        algorithms: vec![Algorithm::Sgp],
        backends: vec![CellBackend::Native],
        schedules: vec![PatternSchedule::static_()],
        rate_scale: 1.0,
        run: RunConfig::quick(),
        sim: None,
        cache: None,
    };
    let report = run_sweep(&spec, 1).expect("sweep");
    assert_eq!(report.cells.len(), 1);
    let cell = &report.cells[0];
    assert_eq!(cell.cell.backend, CellBackend::Native);

    // the exact computation run_cell routes to, performed directly
    let net = build_scenario_network("abilene", 3, 1.0).unwrap();
    let phi0 = Strategy::local_compute_init(&net);
    let mut sgp = Sgp::new();
    let direct =
        optimize_accelerated(&net, &mut sgp, &phi0, &spec.run, &NativeBackend).unwrap();

    assert_eq!(
        cell.final_cost.to_bits(),
        direct.final_cost().to_bits(),
        "sweep-routed dense cell diverged from the direct Sgp::step_dense run"
    );
    assert_eq!(cell.iterations, direct.costs.len());
}

#[test]
fn cli_shard_and_merge_artifacts_match_the_parent_orchestrator() {
    let dir = std::env::temp_dir().join(format!("cecflow-shardcli-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let spec_flags = [
        "--scenarios",
        "abilene",
        "--seeds",
        "1,2",
        "--algos",
        "sgp,lpr",
        "--backends",
        "sparse,native",
    ];

    // parent orchestrator: 2 child processes, one merged artifact
    let parent_out = dir.join("sharded.json");
    let status = Command::new(cecflow_bin())
        .arg("sweep")
        .args(spec_flags)
        .args(["--shards", "2", "--shard-timeout", "600"])
        .arg("--out")
        .arg(&parent_out)
        .status()
        .expect("spawn cecflow sweep --shards");
    assert!(status.success(), "--shards run failed: {status}");

    // manual mode: each shard to its own artifact, then --merge
    for k in [1usize, 2] {
        let status = Command::new(cecflow_bin())
            .arg("sweep")
            .args(spec_flags)
            .arg("--shard")
            .arg(format!("{k}/2"))
            .arg("--out")
            .arg(dir.join(format!("shard{k}.json")))
            .status()
            .expect("spawn cecflow sweep --shard");
        assert!(status.success(), "--shard {k}/2 run failed: {status}");
    }
    let merged_out = dir.join("merged.json");
    let status = Command::new(cecflow_bin())
        .arg("sweep")
        .arg("--merge")
        .arg(format!(
            "{},{}",
            dir.join("shard1.json").display(),
            dir.join("shard2.json").display()
        ))
        .arg("--out")
        .arg(&merged_out)
        .status()
        .expect("spawn cecflow sweep --merge");
    assert!(status.success(), "--merge run failed: {status}");

    let load = |p: &Path| -> SweepReport {
        let text = std::fs::read_to_string(p).unwrap_or_else(|e| panic!("reading {p:?}: {e}"));
        SweepReport::from_json(&Json::parse(&text).expect("report JSON"))
            .expect("report structure")
    };
    let whole = run_sweep(&spec(), 2).expect("in-process reference");
    assert_eq!(load(&parent_out).fingerprint(), whole.fingerprint());
    assert_eq!(load(&merged_out).fingerprint(), whole.fingerprint());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn killed_shard_worker_recovers_via_work_restealing() {
    // CECFLOW_FAIL_SHARD=2 makes the strided worker of shard 2/2 exit
    // abruptly (no protocol goodbye) after streaming its first cell —
    // shard 2 owns 3 of the 6 grid cells, so two are orphaned mid-sweep.
    // With one retry the parent must re-steal them onto a fresh worker
    // and reassemble a report bit-identical to the unkilled runs.
    let spec = spec();
    let whole = run_sweep(&spec, 2).expect("single-process sweep");
    let sharded = run_sweep_sharded(
        &spec,
        cecflow_bin(),
        &ShardOptions {
            shards: 2,
            workers: 2,
            retries: 1,
            extra_env: vec![("CECFLOW_FAIL_SHARD".into(), "2".into())],
            ..Default::default()
        },
    )
    .expect("re-stealing must recover the killed shard's cells");
    assert_eq!(
        sharded.fingerprint(),
        whole.fingerprint(),
        "recovered sharded sweep drifted from the single-process run"
    );
    // and bitwise identical to an (unkilled) --shards 1 engine run too
    let single = run_sweep_sharded(
        &spec,
        cecflow_bin(),
        &ShardOptions {
            shards: 1,
            workers: 2,
            ..Default::default()
        },
    )
    .expect("1-shard sweep");
    assert_eq!(sharded.fingerprint(), single.fingerprint());
}

#[test]
fn zero_retries_restore_fail_fast_on_a_killed_shard() {
    let err = run_sweep_sharded(
        &spec(),
        cecflow_bin(),
        &ShardOptions {
            shards: 2,
            workers: 2,
            retries: 0,
            extra_env: vec![("CECFLOW_FAIL_SHARD".into(), "2".into())],
            ..Default::default()
        },
    )
    .expect_err("retries: 0 must surface the killed shard immediately");
    let msg = format!("{err:#}");
    assert!(msg.contains("shard 2/2"), "{msg}");
}

#[test]
fn failing_cell_in_a_shard_names_the_cell_after_retries_exhaust() {
    // A deterministic cell failure (unknown scenario) fails identically on
    // the re-stolen attempt, exhausting the budget — the surfaced error
    // must name the re-steal attempt and the offending cell.
    let spec = SweepSpec {
        scenarios: vec!["abilene".into(), "no-such-scenario".into()],
        seeds: vec![1],
        algorithms: vec![Algorithm::Lpr],
        backends: vec![CellBackend::Sparse],
        schedules: vec![PatternSchedule::static_()],
        rate_scale: 1.0,
        run: RunConfig::quick(),
        sim: None,
        cache: None,
    };
    let err = run_sweep_sharded(
        &spec,
        cecflow_bin(),
        &ShardOptions {
            shards: 2,
            workers: 2,
            retries: 1,
            ..Default::default()
        },
    )
    .expect_err("unknown scenario must fail the sharded sweep");
    let msg = format!("{err:#}");
    assert!(msg.contains("no-such-scenario"), "{msg}");
    assert!(msg.contains("shard"), "{msg}");
    assert!(msg.contains("re-steal"), "{msg}");
}

#[test]
fn spec_args_roundtrip_through_the_parsers() {
    // the parent → child handoff of the sharded sweep: every
    // result-relevant spec field must survive spec_to_args + the CLI
    // parsers, or children would silently run a different grid
    use cecflow::coordinator::sweep::{
        parse_algorithms, parse_backends, parse_scenarios, parse_schedules, parse_seeds,
        spec_to_args,
    };
    let spec = SweepSpec {
        scenarios: vec!["abilene".into(), "connected-er".into()],
        seeds: vec![1, 5, 9],
        algorithms: vec![Algorithm::Sgp, Algorithm::Gp],
        backends: vec![CellBackend::Sparse, CellBackend::Native],
        schedules: vec![
            PatternSchedule::static_(),
            PatternSchedule::parse("step:3:1.5").unwrap(),
        ],
        rate_scale: 1.25,
        run: RunConfig {
            max_iters: 33,
            tol: 3e-6,
            patience: 4,
        },
        sim: None,
        cache: None,
    };
    let args = spec_to_args(&spec);
    let get = |flag: &str| -> &str {
        let i = args.iter().position(|a| a == flag).unwrap();
        &args[i + 1]
    };
    assert_eq!(parse_scenarios(get("--scenarios")), spec.scenarios);
    assert_eq!(parse_seeds(get("--seeds")).unwrap(), spec.seeds);
    assert_eq!(parse_algorithms(get("--algos")).unwrap(), spec.algorithms);
    assert_eq!(parse_backends(get("--backends")).unwrap(), spec.backends);
    assert_eq!(parse_schedules(get("--schedules")).unwrap(), spec.schedules);
    assert_eq!(get("--scale").parse::<f64>().unwrap(), spec.rate_scale);
    assert_eq!(get("--iters").parse::<usize>().unwrap(), 33);
    assert_eq!(
        get("--tol").parse::<f64>().unwrap().to_bits(),
        3e-6f64.to_bits()
    );
    assert_eq!(get("--patience").parse::<usize>().unwrap(), 4);
}

#[test]
fn shards_of_different_schedule_grids_refuse_to_merge() {
    // Two sweeps identical in every axis *except* the schedule — the
    // grids have the same size and index range, so index coverage alone
    // would interleave them silently. The grid hash must cover the
    // schedule axis (ISSUE 4) and make this merge a loud error.
    let base = SweepSpec {
        scenarios: vec!["abilene".into()],
        seeds: vec![1, 2],
        algorithms: vec![Algorithm::Sgp],
        backends: vec![CellBackend::Sparse],
        schedules: vec![PatternSchedule::parse("step:2:1.5").unwrap()],
        rate_scale: 1.0,
        run: RunConfig::quick(),
        sim: None,
        cache: None,
    };
    let mut other = base.clone();
    other.schedules = vec![PatternSchedule::parse("step:2:2").unwrap()];
    assert_eq!(base.cells().len(), other.cells().len());

    let a = run_sweep_shard(&base, 0, 2, 1).expect("shard 0 of the step:2:1.5 grid");
    let b = run_sweep_shard(&other, 1, 2, 1).expect("shard 1 of the step:2:2 grid");
    // the artifact path must refuse too, not just the in-memory structs
    let reload = |r: &SweepReport| {
        SweepReport::from_json(&Json::parse(&r.to_json().pretty()).unwrap()).unwrap()
    };
    let err = SweepReport::merge(vec![reload(&a), reload(&b)])
        .expect_err("mixed-schedule shard reports must not merge");
    let msg = format!("{err:#}");
    assert!(msg.contains("different sweep specs"), "{msg}");

    // sanity: shards of the *same* schedule grid still merge cleanly
    let b_same = run_sweep_shard(&base, 1, 2, 1).expect("shard 1 of the step:2:1.5 grid");
    SweepReport::merge(vec![reload(&a), reload(&b_same)])
        .expect("same-grid shards must keep merging");
}
