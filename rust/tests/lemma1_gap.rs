//! Fig. 3 of the paper: a strategy satisfying the Lemma-1 (KKT) necessary
//! conditions that is **not** globally optimal — the gap that motivates
//! Theorem 1's augmented sufficient conditions.
//!
//! Construction (mirroring the paper's 4-node example): the single task
//! `(dest=3, type 0)` has data only at node 0. Node 1 carries zero traffic,
//! so the Lemma-1 conditions hold at node 1 *vacuously* no matter where it
//! points — and by pointing it at an expensive detour, node 0 is deterred
//! from routing through it even though the path through node 1 is part of
//! the true optimum. Theorem 1's δ-conditions (which drop the `t_i`
//! factor) detect the misconfiguration; SGP escapes it.

use cecflow::algo::{Optimizer, Sgp};
use cecflow::graph::DiGraph;
use cecflow::model::{
    compute_flows, compute_marginals, lemma1_residual, theorem1_residual, CostFn, Network,
    Strategy, Task,
};

/// Node layout: 0 (source) → {1 (relay), 2 (expensive relay)} → 3 (dest),
/// plus a direct expensive edge 0 → 3.
fn gap_network() -> Network {
    // directed edges only where needed to pin the example
    let graph = DiGraph::new(
        4,
        &[
            (0, 1), // cheap first hop
            (0, 2), // expensive first hop
            (1, 3), // cheap second hop
            (2, 3), // cheap second hop
            (1, 2), // detour node 1 -> 2 (the "wrong" pointer)
            (3, 0), // return edges so the graph is strongly connected
            (3, 1),
            (3, 2),
        ],
    );
    let e = graph.edge_count();
    let mut link_cost = vec![CostFn::Linear { unit: 1.0 }; e];
    link_cost[graph.edge_id(0, 2).unwrap()] = CostFn::Linear { unit: 10.0 };
    link_cost[graph.edge_id(1, 2).unwrap()] = CostFn::Linear { unit: 10.0 };
    // direct edge absent; destination computes for free-ish
    Network {
        graph,
        tasks: vec![Task { dest: 3, ctype: 0 }],
        num_types: 1,
        input_rate: vec![vec![1.0, 0.0, 0.0, 0.0]],
        result_ratio: vec![0.5],
        comp_weight: vec![vec![1.0]; 4],
        link_cost,
        comp_cost: vec![
            // Computing anywhere but the destination must look worse than
            // the expensive detour (unit 12 > 10 + downstream ≈ 11.1), so
            // the misconfigured point is a genuine KKT point.
            CostFn::Linear { unit: 12.0 },
            CostFn::Linear { unit: 12.0 },
            CostFn::Linear { unit: 12.0 },
            CostFn::Linear { unit: 0.1 }, // destination is the cheap place
        ],
    }
}

/// The mis-configured strategy: node 0 ships everything over the expensive
/// edge (0,2) and node 1 (zero traffic) points its data plane at the
/// expensive detour (1,2), making the cheap path look bad through the
/// recursion (11).
fn misconfigured(net: &Network) -> Strategy {
    use cecflow::model::out_slot;
    let mut phi = Strategy::zeroed(net);
    let g = &net.graph;
    // data: 0 -> 2 -> 3 -> compute at 3
    phi.data[0][0][out_slot(g, 0, 2).unwrap() + 1] = 1.0;
    phi.data[0][2][out_slot(g, 2, 3).unwrap() + 1] = 1.0;
    phi.data[0][3][0] = 1.0;
    // node 1 (zero traffic) points at the expensive detour
    phi.data[0][1][out_slot(g, 1, 2).unwrap() + 1] = 1.0;
    // result planes: everything toward 3 (dest sinks results)
    phi.result[0][0][out_slot(g, 0, 1).unwrap()] = 1.0;
    phi.result[0][1][out_slot(g, 1, 3).unwrap()] = 1.0;
    phi.result[0][2][out_slot(g, 2, 3).unwrap()] = 1.0;
    phi
}

#[test]
fn lemma1_holds_but_not_theorem1() {
    let net = gap_network();
    let phi = misconfigured(&net);
    assert!(phi.is_feasible(&net), "{:?}", phi.feasibility_violations(&net));
    assert!(phi.is_loop_free(&net));

    let flows = compute_flows(&net, &phi).unwrap();
    let marg = compute_marginals(&net, &phi, &flows).unwrap();

    // Lemma-1 residual ~ 0: every *loaded* node already uses its
    // min-∂T/∂φ slots; node 1 satisfies KKT vacuously (t_1 = 0).
    let l1 = lemma1_residual(&net, &phi, &flows, &marg);
    assert!(l1 < 1e-9, "Lemma-1 residual should vanish, got {l1}");

    // ...but the Theorem-1 conditions are violated (node 1's δ exposes the
    // detour, and node 0's δ exposes the expensive first hop).
    let t1 = theorem1_residual(&net, &phi, &marg);
    assert!(t1 > 1e-3, "Theorem-1 residual should flag the gap, got {t1}");
}

#[test]
fn misconfiguration_is_suboptimal_and_sgp_escapes() {
    let net = gap_network();
    let phi_bad = misconfigured(&net);
    let t_bad = compute_flows(&net, &phi_bad).unwrap().total_cost;

    let mut phi = phi_bad.clone();
    let mut sgp = Sgp::new();
    for _ in 0..60 {
        sgp.step(&net, &mut phi).unwrap();
    }
    let flows = compute_flows(&net, &phi).unwrap();
    let marg = compute_marginals(&net, &phi, &flows).unwrap();

    assert!(
        flows.total_cost < t_bad * 0.9,
        "SGP failed to escape: {} vs {}",
        flows.total_cost,
        t_bad
    );
    assert!(
        theorem1_residual(&net, &phi, &marg) < 1e-6,
        "SGP did not reach a Theorem-1 point"
    );
    // the optimum routes data over the cheap path 0 -> 1 -> 3
    let e01 = net.graph.edge_id(0, 1).unwrap();
    assert!(
        flows.f_minus[0][e01] > 0.9,
        "cheap path unused: f(0,1) = {}",
        flows.f_minus[0][e01]
    );
}
