//! PR 6 pins for the layered discrete-event engine:
//!
//! * calendar-queue ordering parity against the legacy binary-heap queue
//!   under adversarial random schedules (ties, zero delays, far-future
//!   jumps, interleaved scheduling and popping);
//! * the streaming quantile sketch against exact nearest-rank quantiles,
//!   within its documented relative-error bound;
//! * end-to-end determinism: identical `(scenario, seed, arrival spec)`
//!   inputs produce bit-identical telemetry JSON across repeated runs,
//!   and sweep tail-latency columns are identical across worker counts;
//! * a converged strategy strands no requests (every routing row the
//!   walker visits sums to 1).

use cecflow::coordinator::{
    build_scenario_network, run_algorithm, run_sweep, Algorithm, RunConfig, SimSweepConfig,
    SweepSpec,
};
use cecflow::sim::{core, event, simulate, ArrivalSpec, SimConfig, SimEpoch, SimPlan};
use cecflow::util::rng::Pcg;
use cecflow::util::stats::{percentile_sorted, QuantileSketch};

// ---- calendar queue vs legacy heap ------------------------------------

/// Drive both queue implementations through the same random op sequence
/// and require bit-identical `(time, seq, payload)` pop streams. The
/// schedule deliberately mixes the regimes the calendar queue handles
/// specially: exact ties (FIFO tie-break), zero delays, dense clusters,
/// and sparse far-future jumps that force the bucket-walk fallback.
#[test]
fn calendar_queue_matches_heap_queue_on_random_schedules() {
    for seed in 0..20u64 {
        let mut rng = Pcg::with_stream(seed, 0xca1e_17da);
        let mut heap = event::EventQueue::new();
        let mut cal = core::EventQueue::new();
        let mut next_id = 0u32;
        let mut last_delay = 0.0f64;
        for _ in 0..400 {
            if rng.chance(0.6) || heap.is_empty() {
                // both queues share `now` (pops are mirrored), so the same
                // relative delay lands both events at the same absolute time
                let delay = match rng.below(5) {
                    0 => 0.0,                          // simultaneous with now
                    1 => last_delay,                   // deliberate tie shape
                    2 => rng.uniform(0.0, 1.0),        // dense cluster
                    3 => rng.uniform(0.0, 50.0),       // moderate spread
                    _ => rng.uniform(1.0e4, 1.0e6),    // far-future jump
                };
                last_delay = delay;
                // duplicate payloads under one timestamp: only the seq
                // tie-break can order them
                let copies = 1 + rng.below(3);
                for _ in 0..copies {
                    heap.schedule(delay, next_id);
                    cal.schedule(delay, next_id);
                    next_id += 1;
                }
            } else {
                let a = heap.pop().expect("heap non-empty");
                let b = cal.pop().expect("parity: calendar must match heap len");
                assert_eq!(a.time.to_bits(), b.time.to_bits(), "seed {seed}");
                assert_eq!(a.seq, b.seq, "seed {seed}");
                assert_eq!(a.payload, b.payload, "seed {seed}");
                assert_eq!(heap.now().to_bits(), cal.now().to_bits());
            }
            assert_eq!(heap.len(), cal.len());
        }
        // drain: the full residual order must agree too
        while let Some(a) = heap.pop() {
            let b = cal.pop().expect("calendar drained early");
            assert_eq!(a.time.to_bits(), b.time.to_bits(), "seed {seed}");
            assert_eq!(a.seq, b.seq, "seed {seed}");
            assert_eq!(a.payload, b.payload, "seed {seed}");
        }
        assert!(cal.pop().is_none(), "calendar queue held extra events");
    }
}

/// Simultaneous events pop in scheduling order from both queues — the
/// FIFO guarantee `sim::protocol` relies on for reproducible broadcasts.
#[test]
fn simultaneous_events_pop_in_scheduling_order() {
    let mut heap = event::EventQueue::new();
    let mut cal = core::EventQueue::new();
    for id in 0..100u32 {
        heap.schedule(2.5, id);
        cal.schedule(2.5, id);
    }
    for id in 0..100u32 {
        assert_eq!(heap.pop().unwrap().payload, id);
        assert_eq!(cal.pop().unwrap().payload, id);
    }
}

// ---- sketch vs exact quantiles ----------------------------------------

/// Random heavy-tailed samples: every queried quantile of the sketch must
/// sit within its documented relative-error bound of the exact
/// nearest-rank quantile.
#[test]
fn sketch_matches_exact_quantiles_on_random_heavy_tails() {
    for seed in 0..10u64 {
        let mut rng = Pcg::with_stream(seed, 0x5_e7c4);
        let mut sketch = QuantileSketch::with_default_error();
        let mut exact = Vec::with_capacity(20_000);
        for _ in 0..20_000 {
            // mix of exponential bulk and a polynomial tail
            let x = if rng.chance(0.9) {
                rng.exponential(1.0)
            } else {
                1.0 / (1.0 - rng.f64()).powi(2)
            };
            sketch.record(x);
            exact.push(x);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let bound = sketch.relative_error_bound();
        for q in [0.01, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let approx = sketch.quantile(q);
            let truth = percentile_sorted(&exact, q);
            let rel = (approx - truth).abs() / truth.abs().max(1e-300);
            assert!(
                rel <= bound + 1e-12,
                "seed {seed} q={q}: sketch {approx} vs exact {truth} (rel {rel:.3e} > {bound})"
            );
        }
    }
}

// ---- end-to-end determinism -------------------------------------------

fn table2_plan(scenario: &str, seed: u64) -> SimPlan {
    let net = build_scenario_network(scenario, seed, 1.0).unwrap();
    let out = run_algorithm(&net, Algorithm::Sgp, &RunConfig::quick()).unwrap();
    SimPlan {
        epochs: vec![SimEpoch {
            net,
            phi: out.phi.expect("sgp yields a strategy"),
        }],
    }
}

/// Identical `(scenario, seed, arrival spec)` → bit-identical telemetry
/// JSON, for every arrival family. The dump includes the `_bits` hex
/// fields, so equality here is bit equality of every quantile, counter
/// and utilization figure.
#[test]
fn repeated_simulations_are_bit_identical() {
    let plan = table2_plan("abilene", 7);
    for arrivals in ["poisson", "mmpp:3:2", "diurnal:0.5"] {
        let spec = ArrivalSpec::parse(arrivals).unwrap();
        let cfg = SimConfig {
            requests: 20_000,
            warmup: 0.1,
            seed: 7,
            ..SimConfig::default()
        };
        let a = simulate(&plan, &spec, &cfg).unwrap();
        let b = simulate(&plan, &spec, &cfg).unwrap();
        assert_eq!(
            a.to_json().pretty(),
            b.to_json().pretty(),
            "{arrivals}: telemetry drifted between identical runs"
        );
        // and a different seed actually changes the stream (the contract
        // is determinism, not a constant)
        let c = simulate(
            &plan,
            &spec,
            &SimConfig {
                seed: 8,
                ..cfg
            },
        )
        .unwrap();
        assert_ne!(a.to_json().pretty(), c.to_json().pretty(), "{arrivals}");
    }
}

/// The sweep's simulated tail columns obey the same determinism contract
/// as its analytic columns: fingerprints are identical across worker
/// counts.
#[test]
fn sweep_tail_columns_identical_across_worker_counts() {
    let spec = SweepSpec {
        scenarios: vec!["abilene".into()],
        seeds: vec![1, 2],
        algorithms: vec![Algorithm::Sgp],
        sim: Some(SimSweepConfig {
            requests: 5_000,
            ..SimSweepConfig::default()
        }),
        ..SweepSpec::default()
    };
    let serial = run_sweep(&spec, 1).unwrap();
    let parallel = run_sweep(&spec, 4).unwrap();
    assert_eq!(serial.fingerprint(), parallel.fingerprint());
    // the digest really is in the fingerprint: perturbing it must show
    let mut tampered = serial.clone();
    tampered.cells[0].sim.as_mut().unwrap().p99 += 1.0;
    assert_ne!(tampered.fingerprint(), serial.fingerprint());
}

/// A converged strategy routes every request to completion: flow
/// conservation (Eq. 2) means every routing row the walker can reach
/// sums to one, so no request is ever stranded.
#[test]
fn converged_strategies_strand_no_requests() {
    for scenario in ["abilene", "connected-er"] {
        let plan = table2_plan(scenario, 3);
        let telemetry = simulate(
            &plan,
            &ArrivalSpec::default(),
            &SimConfig {
                requests: 10_000,
                warmup: 0.05,
                seed: 3,
                ..SimConfig::default()
            },
        )
        .unwrap();
        assert_eq!(telemetry.arrived, 10_000, "{scenario}");
        assert_eq!(telemetry.stranded, 0, "{scenario}");
        assert_eq!(telemetry.completed, 10_000, "{scenario}");
        let (p50, p99, p999) = telemetry.tail();
        assert!(
            p50 > 0.0 && p50 <= p99 && p99 <= p999 && p999.is_finite(),
            "{scenario}: quantiles disordered ({p50}, {p99}, {p999})"
        );
    }
}
