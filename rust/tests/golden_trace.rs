//! Golden-trace convergence regression (ISSUE 2 satellite): fixed-seed
//! Abilene / Connected-ER / SW-linear runs snapshot their cost endpoints
//! and full residual trajectory, so backend or sweep refactors cannot
//! silently change convergence behavior.
//!
//! The golden file lives at `rust/tests/golden/convergence_traces.json`.
//! On the first run (or with `CECFLOW_UPDATE_GOLDEN=1`) it is
//! (re)generated from the current implementation; subsequent runs in the
//! same checkout compare against it with a 1e-9 relative tolerance.
//! Independent of the file, every run asserts bit-for-bit re-run
//! determinism and the monotone-descent shape, so the test has teeth even
//! on a bootstrap run.

use std::path::PathBuf;

use cecflow::algo::Sgp;
use cecflow::coordinator::{optimize, optimize_accelerated, RunConfig, RunResult, ScenarioSpec};
use cecflow::model::network::Network;
use cecflow::model::strategy::Strategy;
use cecflow::runtime::NativeBackend;
use cecflow::util::json::Json;

struct TraceSpec {
    /// Stable identifier in the golden file.
    key: &'static str,
    scenario: &'static str,
    seed: u64,
    /// Shrink the task count (used to fit SW into test budget).
    shrink: Option<usize>,
    iters: usize,
    /// Run through `Sgp::step_dense` + `NativeBackend` instead of the
    /// sparse sync path — pins the batched safeguard ladder.
    dense: bool,
}

/// The pinned scenarios: two Table II queue instances, the SW *linear*
/// variant (heavy result-flow — the arXiv:2205.00714 regime), and one
/// dense-path run exercising `evaluate_batch` end to end.
fn trace_specs() -> Vec<TraceSpec> {
    vec![
        TraceSpec {
            key: "abilene-s11-sync",
            scenario: "abilene",
            seed: 11,
            shrink: None,
            iters: 20,
            dense: false,
        },
        TraceSpec {
            key: "connected-er-s7-sync",
            scenario: "connected-er",
            seed: 7,
            shrink: None,
            iters: 15,
            dense: false,
        },
        TraceSpec {
            key: "sw-linear-s5-sync",
            scenario: "sw-linear",
            seed: 5,
            shrink: Some(6),
            iters: 6,
            dense: false,
        },
        TraceSpec {
            key: "abilene-s11-dense",
            scenario: "abilene",
            seed: 11,
            shrink: None,
            iters: 12,
            dense: true,
        },
    ]
}

fn build_net(spec: &TraceSpec) -> Network {
    let mut sc = ScenarioSpec::by_name(spec.scenario)
        .unwrap_or_else(|| panic!("unknown scenario {}", spec.scenario));
    if let Some(s) = spec.shrink {
        sc = sc.shrunk(s);
    }
    sc.build(spec.seed).net
}

fn run_trace(spec: &TraceSpec) -> RunResult {
    let net = build_net(spec);
    let phi0 = Strategy::local_compute_init(&net);
    // patience == max_iters: convergence can never trigger early, so the
    // trajectory has a fixed, comparable length.
    let cfg = RunConfig {
        max_iters: spec.iters,
        tol: 0.0,
        patience: spec.iters,
    };
    let mut sgp = Sgp::new();
    if spec.dense {
        optimize_accelerated(&net, &mut sgp, &phi0, &cfg, &NativeBackend)
            .expect("dense trace run")
    } else {
        optimize(&net, &mut sgp, &phi0, &cfg).expect("sync trace run")
    }
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden/convergence_traces.json")
}

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    if a.to_bits() == b.to_bits() {
        return true;
    }
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1e-12)
}

/// Read a numeric golden value with a diagnostic: `util::json` serializes
/// non-finite numbers as `null`, so a null here means a trace recorded a
/// saturated value — the golden set is meant to stay finite (the shape
/// invariants above enforce that for freshly generated traces).
fn golden_num(v: &Json, what: &str) -> f64 {
    v.as_num().unwrap_or_else(|| {
        panic!(
            "{what} in the golden file is not a finite number ({v:?}) — \
             regenerate with CECFLOW_UPDATE_GOLDEN=1 cargo test --test golden_trace"
        )
    })
}

fn trace_to_json(key: &str, res: &RunResult) -> Json {
    let mut o = Json::obj();
    o.set("key", Json::Str(key.to_string()))
        .set("iters", Json::Num(res.costs.len() as f64))
        .set("first_cost", Json::Num(res.costs[0]))
        .set("last_cost", Json::Num(res.final_cost()))
        .set("residuals", Json::from_f64_slice(&res.residuals));
    o
}

#[test]
fn golden_traces_pin_convergence_behavior() {
    const TOL: f64 = 1e-9;
    let specs = trace_specs();
    let results: Vec<RunResult> = specs.iter().map(run_trace).collect();

    // ---- always-on shape invariants ----
    for (spec, res) in specs.iter().zip(&results) {
        assert_eq!(res.costs.len(), spec.iters, "{}: trajectory length", spec.key);
        assert!(
            res.costs.iter().all(|c| c.is_finite()),
            "{}: non-finite cost in trajectory",
            spec.key
        );
        assert!(
            res.residuals.iter().all(|r| r.is_finite()),
            "{}: non-finite residual in trajectory (goldens must stay finite)",
            spec.key
        );
        let eps = if spec.dense { 1e-5 } else { 1e-9 };
        for (i, w) in res.costs.windows(2).enumerate() {
            assert!(
                w[1] <= w[0] * (1.0 + eps) + eps,
                "{}: cost increased at iter {}: {} -> {}",
                spec.key,
                i + 1,
                w[0],
                w[1]
            );
        }
    }

    // ---- golden comparison / bootstrap ----
    let path = golden_path();
    let update = std::env::var("CECFLOW_UPDATE_GOLDEN").is_ok();
    // CI's second golden run sets CECFLOW_REQUIRE_GOLDEN=1: by then the
    // file must exist (bootstrapped by the first run or committed), so a
    // silent bootstrap can never masquerade as a passing comparison.
    if !update && !path.exists() && std::env::var("CECFLOW_REQUIRE_GOLDEN").is_ok() {
        panic!(
            "golden file {path:?} is missing but CECFLOW_REQUIRE_GOLDEN=1 — run the test \
             once without the variable to bootstrap it, and commit \
             rust/tests/golden/convergence_traces.json so fresh checkouts compare \
             instead of bootstrapping"
        );
    }
    if update || !path.exists() {
        let traces: Vec<Json> = specs
            .iter()
            .zip(&results)
            .map(|(s, r)| trace_to_json(s.key, r))
            .collect();
        let mut doc = Json::obj();
        doc.set("version", Json::Num(1.0))
            .set("traces", Json::Arr(traces));
        std::fs::create_dir_all(path.parent().unwrap()).expect("golden dir");
        std::fs::write(&path, doc.pretty()).expect("write golden file");
        eprintln!(
            "golden_trace: {} {:?} from the current implementation — \
             subsequent runs compare against it",
            if update { "regenerated" } else { "bootstrapped" },
            path
        );
        return;
    }

    let text = std::fs::read_to_string(&path).expect("read golden file");
    let doc = Json::parse(&text).expect("parse golden file");
    let traces = doc.get("traces").as_arr().expect("traces array");
    for (spec, res) in specs.iter().zip(&results) {
        let golden = traces
            .iter()
            .find(|t| t.get("key").as_str() == Some(spec.key))
            .unwrap_or_else(|| {
                panic!(
                    "golden file has no trace '{}' — regenerate with \
                     CECFLOW_UPDATE_GOLDEN=1 cargo test --test golden_trace",
                    spec.key
                )
            });
        assert_eq!(
            golden.get("iters").as_usize(),
            Some(res.costs.len()),
            "{}: iteration count drifted",
            spec.key
        );
        let first = golden_num(golden.get("first_cost"), &format!("{}: first_cost", spec.key));
        let last = golden_num(golden.get("last_cost"), &format!("{}: last_cost", spec.key));
        assert!(
            rel_close(first, res.costs[0], TOL),
            "{}: first cost drifted: golden {} vs {}",
            spec.key,
            first,
            res.costs[0]
        );
        assert!(
            rel_close(last, res.final_cost(), TOL),
            "{}: final cost drifted: golden {} vs {}",
            spec.key,
            last,
            res.final_cost()
        );
        let gres = golden.get("residuals").as_arr().unwrap();
        assert_eq!(gres.len(), res.residuals.len(), "{}: residuals len", spec.key);
        for (i, (g, r)) in gres.iter().zip(&res.residuals).enumerate() {
            let g = golden_num(g, &format!("{}: residual[{i}]", spec.key));
            // residuals shrink toward 0; compare with an absolute floor so
            // ~1e-15 noise at the optimum doesn't fail the relative check
            assert!(
                rel_close(g, *r, TOL) || (g - *r).abs() <= 1e-12,
                "{}: residual[{i}] drifted: golden {g} vs {r}",
                spec.key
            );
        }
    }
}

#[test]
fn traces_are_rerun_deterministic() {
    // Bitwise determinism of the full trajectory — the property the
    // golden file's usefulness rests on (and a refactor tripwire on its
    // own even when the golden file was just bootstrapped).
    for spec in trace_specs().iter() {
        let a = run_trace(spec);
        let b = run_trace(spec);
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.costs), bits(&b.costs), "{}: costs differ", spec.key);
        assert_eq!(
            bits(&a.residuals),
            bits(&b.residuals),
            "{}: residuals differ",
            spec.key
        );
    }
}
