//! Property tests for the extended topology library (ISSUE 4): grid /
//! torus, Barabási–Albert scale-free, and fat-tree generators. Proptest
//! is unavailable offline, so this is the same hand-rolled
//! generate-and-check harness as `prop_model.rs` — seeded PCG streams,
//! failures name the offending parameters so any case replays
//! deterministically.
//!
//! Pinned properties, per the ISSUE 4 checklist:
//! * every generated graph is (strongly) connected — `from_undirected`
//!   symmetrizes, so weak and strong connectivity coincide;
//! * node and directed-edge counts match the closed-form spec;
//! * degree bounds hold where the shape dictates them (torus: all
//!   degrees exactly 4; grid: 2..=4; fat-tree: max degree `k`, edge
//!   nodes `k/2`; BA: minimum degree `m`);
//! * the same seed reproduces the same graph bitwise, and the
//!   `TopologyKind`-level builds feeding the scenario library are
//!   equally reproducible.

use cecflow::graph::algorithms::strongly_connected;
use cecflow::graph::topology::{barabasi_albert, fat_tree, grid_torus};
use cecflow::graph::{DiGraph, TopologyKind};
use cecflow::util::rng::Pcg;

/// Undirected degree of node `i` (out-degree equals in-degree in a
/// symmetrized graph; asserted, not assumed).
fn degree(g: &DiGraph, i: usize) -> usize {
    assert_eq!(g.out_degree(i), g.in_degree(i), "node {i} is not symmetrized");
    g.out_degree(i)
}

fn assert_same_graph(a: &DiGraph, b: &DiGraph, what: &str) {
    assert_eq!(a.node_count(), b.node_count(), "{what}: node counts differ");
    assert_eq!(a.edges(), b.edges(), "{what}: edge lists differ");
}

#[test]
fn grid_and_torus_have_the_closed_form_shape() {
    for (rows, cols) in [(3usize, 3usize), (3, 5), (4, 4), (5, 4), (6, 7)] {
        // plain grid: (rows·(cols−1) + cols·(rows−1)) undirected links
        let grid = grid_torus(rows, cols, false);
        assert_eq!(grid.node_count(), rows * cols);
        let grid_links = rows * (cols - 1) + cols * (rows - 1);
        assert_eq!(grid.edge_count(), 2 * grid_links, "{rows}×{cols} grid edges");
        assert!(strongly_connected(&grid), "{rows}×{cols} grid disconnected");
        for i in 0..grid.node_count() {
            let d = degree(&grid, i);
            assert!((2..=4).contains(&d), "{rows}×{cols} grid node {i}: degree {d}");
        }
        // corners of a non-degenerate grid have degree exactly 2
        assert_eq!(degree(&grid, 0), 2, "{rows}×{cols} grid corner");

        // torus: rows·cols links per direction, every degree exactly 4
        let torus = grid_torus(rows, cols, true);
        assert_eq!(torus.node_count(), rows * cols);
        assert_eq!(torus.edge_count(), 2 * (2 * rows * cols), "{rows}×{cols} torus edges");
        assert!(strongly_connected(&torus), "{rows}×{cols} torus disconnected");
        for i in 0..torus.node_count() {
            assert_eq!(degree(&torus, i), 4, "{rows}×{cols} torus node {i}");
        }
    }
}

#[test]
fn barabasi_albert_matches_spec_across_seeds() {
    for seed in 0..20u64 {
        let mut rng = Pcg::new(seed);
        let n = 10 + rng.below(30);
        let m = 1 + rng.below(3);
        let g = barabasi_albert(n, m, &mut rng);
        assert_eq!(g.node_count(), n, "seed {seed}: BA({n},{m}) nodes");
        // complete seed graph on m+1 nodes, then m links per newcomer
        let links = m * (m + 1) / 2 + (n - m - 1) * m;
        assert_eq!(g.edge_count(), 2 * links, "seed {seed}: BA({n},{m}) edges");
        assert!(strongly_connected(&g), "seed {seed}: BA({n},{m}) disconnected");
        for i in 0..n {
            assert!(
                degree(&g, i) >= m,
                "seed {seed}: BA({n},{m}) node {i} has degree {} < m",
                degree(&g, i)
            );
        }
    }
}

#[test]
fn fat_tree_has_the_closed_form_shape() {
    for k in [2usize, 4, 6, 8] {
        let h = k / 2;
        let g = fat_tree(k);
        let cores = h * h;
        assert_eq!(g.node_count(), cores + k * k, "k={k} fat-tree nodes");
        // per pod: h·h agg–edge links + h·h agg–core links
        assert_eq!(g.edge_count(), 2 * (2 * k * h * h), "k={k} fat-tree edges");
        assert!(strongly_connected(&g), "k={k} fat-tree disconnected");
        for i in 0..g.node_count() {
            assert!(degree(&g, i) <= k, "k={k} fat-tree node {i}: degree {}", degree(&g, i));
        }
        // cores and aggregation saturate the bound, edge nodes sit at k/2
        for c in 0..cores {
            assert_eq!(degree(&g, c), k, "k={k} core {c}");
        }
        for p in 0..k {
            let agg0 = cores + p * k;
            for a in 0..h {
                assert_eq!(degree(&g, agg0 + a), k, "k={k} pod {p} agg {a}");
            }
            for e in 0..h {
                assert_eq!(degree(&g, agg0 + h + e), h, "k={k} pod {p} edge {e}");
            }
        }
    }
}

#[test]
fn same_seed_reproduces_the_same_graph_bitwise() {
    // deterministic generators: identical regardless of RNG state
    assert_same_graph(&grid_torus(5, 4, true), &grid_torus(5, 4, true), "torus");
    assert_same_graph(&grid_torus(4, 6, false), &grid_torus(4, 6, false), "grid");
    assert_same_graph(&fat_tree(4), &fat_tree(4), "fat-tree");
    // seeded generator: same stream state → same graph; different seed →
    // (for this size, in practice) a different attachment pattern
    for seed in [1u64, 7, 42] {
        let a = barabasi_albert(25, 2, &mut Pcg::new(seed));
        let b = barabasi_albert(25, 2, &mut Pcg::new(seed));
        assert_same_graph(&a, &b, "BA");
    }
    let a = barabasi_albert(25, 2, &mut Pcg::new(1));
    let b = barabasi_albert(25, 2, &mut Pcg::new(2));
    assert_ne!(a.edges(), b.edges(), "distinct seeds collided — suspicious RNG plumbing");
}

#[test]
fn topology_kind_builds_are_reproducible_and_connected() {
    for kind in [TopologyKind::Torus, TopologyKind::ScaleFree, TopologyKind::FatTree] {
        let a = kind.build(&mut Pcg::new(11));
        let b = kind.build(&mut Pcg::new(11));
        assert_same_graph(&a, &b, kind.name());
        assert!(strongly_connected(&a), "{} disconnected", kind.name());
        // the name round-trips through the CLI parser
        assert_eq!(TopologyKind::parse(kind.name()), Some(kind));
    }
    // the library sizes the scenario specs rely on
    assert_eq!(TopologyKind::Torus.build(&mut Pcg::new(0)).node_count(), 20);
    assert_eq!(TopologyKind::ScaleFree.build(&mut Pcg::new(0)).node_count(), 25);
    assert_eq!(TopologyKind::FatTree.build(&mut Pcg::new(0)).node_count(), 20);
}
