//! Randomized invariant suite for the per-node projection QP
//! (`algo/simplex_qp.rs`), the numerical kernel every SGP/SPOO/LCOR
//! update runs through. Across seeded random instances — including the
//! extreme scalings the optimizer produces near capacity poles — the
//! projected strategy row must:
//!
//!  1. be non-negative in every slot,
//!  2. sum to exactly 1 (within float renormalization tolerance),
//!  3. keep blocked slots at exactly 0.0 (bitwise, not just small:
//!     blocked entries are what guarantees loop-freedom),
//!  4. never increase the QP objective relative to staying at `φ`
//!     (v = φ is feasible with objective 0).
//!
//! Failures print the offending seed so any case replays deterministically.

use cecflow::algo::simplex_qp::{qp_objective, scaled_simplex_qp};
use cecflow::util::rng::Pcg;

/// Draw a random feasible row: φ on the simplex restricted to unblocked
/// slots, plus marginals and scaling diagonals in optimizer-realistic
/// ranges.
#[allow(clippy::type_complexity)]
fn random_instance(
    rng: &mut Pcg,
    extreme: bool,
) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<bool>) {
    let n = rng.int_range(1, 9);
    let mut blocked: Vec<bool> = (0..n).map(|_| rng.chance(0.3)).collect();
    if blocked.iter().all(|&b| b) {
        blocked[rng.below(n)] = false;
    }

    // φ: random mass on the unblocked slots, normalized
    let mut phi = vec![0.0; n];
    let mut total = 0.0;
    for j in 0..n {
        if !blocked[j] && rng.chance(0.7) {
            phi[j] = rng.uniform(0.0, 1.0);
            total += phi[j];
        }
    }
    if total <= 0.0 {
        let j = (0..n).find(|&j| !blocked[j]).unwrap();
        phi[j] = 1.0;
        total = 1.0;
    }
    for p in phi.iter_mut() {
        *p /= total;
    }

    let (delta_lo, delta_hi, scale_lo, scale_hi) = if extreme {
        // capacity-pole regime: huge marginals, near-floor and
        // near-clamp scaling diagonals (sgp.rs floors at 1e-6·inflate
        // and clamps at 1e12)
        (-1e6, 1e8, 1e-6, 1e12)
    } else {
        (-5.0, 10.0, 0.05, 5.0)
    };
    let delta: Vec<f64> = (0..n).map(|_| rng.uniform(delta_lo, delta_hi)).collect();
    let scale: Vec<f64> = (0..n)
        .map(|_| {
            if extreme {
                // log-uniform so tiny and enormous diagonals both appear
                let e = rng.uniform(scale_lo.log10(), scale_hi.log10());
                10f64.powf(e)
            } else {
                rng.uniform(scale_lo, scale_hi)
            }
        })
        .collect();
    (phi, delta, scale, blocked)
}

fn check_invariants(
    seed: u64,
    phi: &[f64],
    delta: &[f64],
    scale: &[f64],
    blocked: &[bool],
) {
    let v = scaled_simplex_qp(phi, delta, scale, blocked);
    assert_eq!(v.len(), phi.len(), "seed {seed}: arity changed");

    // (1) non-negativity
    for (j, &x) in v.iter().enumerate() {
        assert!(
            x >= 0.0,
            "seed {seed}: negative fraction {x} at slot {j} (v = {v:?})"
        );
        assert!(x.is_finite(), "seed {seed}: non-finite fraction at slot {j}");
    }

    // (2) simplex constraint
    let sum: f64 = v.iter().sum();
    assert!(
        (sum - 1.0).abs() < 1e-9,
        "seed {seed}: row sums to {sum} (v = {v:?})"
    );

    // (3) blocked slots are *exactly* zero
    for (j, &b) in blocked.iter().enumerate() {
        if b {
            assert_eq!(
                v[j], 0.0,
                "seed {seed}: blocked slot {j} carries mass {} (v = {v:?})",
                v[j]
            );
        }
    }

    // (4) never worse than staying put (v = φ is feasible, objective 0)
    let obj = qp_objective(phi, delta, scale, &v);
    let tol = 1e-6
        * (1.0
            + delta.iter().fold(0.0f64, |a, &d| a.max(d.abs()))
            + scale.iter().fold(0.0f64, |a, &s| a.max(s.abs())) * 1e-9);
    assert!(
        obj <= tol,
        "seed {seed}: projection increased the QP objective: {obj} (v = {v:?})"
    );
}

#[test]
fn qp_invariants_hold_across_random_seeds() {
    for seed in 0..400u64 {
        let mut rng = Pcg::new(90_000 + seed);
        let (phi, delta, scale, blocked) = random_instance(&mut rng, false);
        check_invariants(seed, &phi, &delta, &scale, &blocked);
    }
}

#[test]
fn qp_invariants_hold_under_extreme_scalings() {
    for seed in 0..400u64 {
        let mut rng = Pcg::new(91_000 + seed);
        let (phi, delta, scale, blocked) = random_instance(&mut rng, true);
        check_invariants(seed, &phi, &delta, &scale, &blocked);
    }
}

#[test]
fn qp_single_free_slot_takes_all_mass() {
    // Degenerate rows (one unblocked slot) are common at tree leaves:
    // the answer must be exactly the indicator of that slot.
    for seed in 0..50u64 {
        let mut rng = Pcg::new(92_000 + seed);
        let n = rng.int_range(1, 6);
        let free = rng.below(n);
        let blocked: Vec<bool> = (0..n).map(|j| j != free).collect();
        let mut phi = vec![0.0; n];
        phi[free] = 1.0;
        let delta: Vec<f64> = (0..n).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let scale: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        let v = scaled_simplex_qp(&phi, &delta, &scale, &blocked);
        for (j, &x) in v.iter().enumerate() {
            assert_eq!(x, if j == free { 1.0 } else { 0.0 }, "seed {seed} slot {j}");
        }
    }
}

#[test]
fn qp_moves_mass_toward_cheaper_marginals() {
    // Directional sanity across seeds: the slot with the strictly lowest
    // marginal never loses mass.
    for seed in 0..100u64 {
        let mut rng = Pcg::new(93_000 + seed);
        let (phi, mut delta, scale, blocked) = random_instance(&mut rng, false);
        let free: Vec<usize> = (0..phi.len()).filter(|&j| !blocked[j]).collect();
        if free.len() < 2 {
            continue;
        }
        let best = free[rng.below(free.len())];
        delta[best] = delta.iter().cloned().fold(f64::INFINITY, f64::min) - 1.0;
        let v = scaled_simplex_qp(&phi, &delta, &scale, &blocked);
        assert!(
            v[best] >= phi[best] - 1e-9,
            "seed {seed}: min-marginal slot lost mass ({} -> {})",
            phi[best],
            v[best]
        );
    }
}
