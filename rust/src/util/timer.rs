//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `bench_fn` runs a closure under a warmup + timed-batch protocol and
//! returns per-iteration timing statistics; `BenchReport` collects rows and
//! renders them for the `benches/*.rs` binaries (built with
//! `harness = false`).

use std::time::{Duration, Instant};

use super::stats::{summarize, Summary};

/// Result of measuring one benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration wall time statistics, in seconds.
    pub per_iter: Summary,
    /// Total iterations timed (across all batches).
    pub iters: u64,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        self.per_iter.mean * 1e9
    }
}

/// Human-friendly duration formatting for reports.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Measure `f`, auto-calibrating the batch size so each timed batch lasts
/// at least ~2 ms. `budget` caps total measurement time.
pub fn bench_fn<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Measurement {
    // Warm up + calibrate batch size.
    let mut batch: u64 = 1;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed();
        if dt >= Duration::from_millis(2) || batch >= 1 << 20 {
            break;
        }
        batch *= 4;
    }

    let mut samples = Vec::new();
    let mut iters = 0u64;
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 5 {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64() / batch as f64;
        samples.push(dt);
        iters += batch;
        if samples.len() >= 200 {
            break;
        }
    }
    Measurement {
        name: name.to_string(),
        per_iter: summarize(&samples),
        iters,
    }
}

/// Collects measurements / metric rows and renders a plain-text report.
#[derive(Default)]
pub struct BenchReport {
    title: String,
    rows: Vec<(String, String)>,
}

impl BenchReport {
    pub fn new(title: &str) -> Self {
        BenchReport {
            title: title.to_string(),
            rows: Vec::new(),
        }
    }

    pub fn add_measurement(&mut self, m: &Measurement) {
        self.rows.push((
            m.name.clone(),
            format!(
                "{} / iter (±{}, n={})",
                fmt_duration(m.per_iter.mean),
                fmt_duration(m.per_iter.std),
                m.per_iter.n
            ),
        ));
    }

    pub fn add_row(&mut self, key: &str, value: String) {
        self.rows.push((key.to_string(), value));
    }

    pub fn render(&self) -> String {
        let width = self
            .rows
            .iter()
            .map(|(k, _)| k.len())
            .max()
            .unwrap_or(0)
            .max(self.title.len());
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        for (k, v) in &self.rows {
            out.push_str(&format!("{k:<width$}  {v}\n"));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let m = bench_fn("noop-ish", Duration::from_millis(20), || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(m.per_iter.mean > 0.0);
        assert!(m.iters > 0);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(5e-9).contains("ns"));
        assert!(fmt_duration(5e-6).contains("µs"));
        assert!(fmt_duration(5e-3).contains("ms"));
        assert!(fmt_duration(5.0).contains(" s"));
    }

    #[test]
    fn report_renders_rows() {
        let mut r = BenchReport::new("t");
        r.add_row("alpha", "1".into());
        r.add_row("beta", "2".into());
        let text = r.render();
        assert!(text.contains("alpha"));
        assert!(text.contains("== t =="));
    }
}
