//! Minimal JSON value model, parser and serializer.
//!
//! Used for experiment configs, the `artifacts/manifest.json` produced by
//! the python AOT step, and machine-readable result files under `results/`.
//! serde is unavailable in this offline build, and the subset of JSON we
//! need (no exotic escapes beyond \uXXXX, numbers as f64) is small enough
//! that a recursive-descent parser is the simplest dependable option.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for debuggability.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors ---------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object value (panics if not an object).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_str_slice(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ----- accessors ------------------------------------------------------

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_num().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; returns Null for missing keys on non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `get` chained through a dotted path, e.g. `"classes.small.n"`.
    pub fn path(&self, dotted: &str) -> &Json {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part);
        }
        cur
    }

    // ----- parsing --------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- serialization --------------------------------------------------

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indents.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no ∞/NaN literal; emit null (as serde_json
                    // does) so documents with saturated costs stay parsable.
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multibyte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let tail = &self.bytes[start..];
                    let ch_len = utf8_len(c);
                    let chunk = std::str::from_utf8(&tail[..ch_len.min(tail.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = chunk.chars().next().unwrap();
                    s.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn nonfinite_numbers_serialize_as_null_and_stay_parsable() {
        let mut o = Json::obj();
        o.set("sat", Json::Num(f64::INFINITY))
            .set("bad", Json::Num(f64::NAN))
            .set("ok", Json::Num(2.5));
        let text = o.dump();
        let back = Json::parse(&text).expect("∞/NaN must not break parsing");
        assert_eq!(back.get("sat"), &Json::Null);
        assert_eq!(back.get("bad"), &Json::Null);
        assert_eq!(back.get("ok").as_num(), Some(2.5));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn parse_whitespace_tolerant() {
        let v = Json::parse(" { \"k\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\"A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo→"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,"s"],"flag":true,"n":null,"nested":{"x":-3}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn roundtrip_pretty() {
        let mut v = Json::obj();
        v.set("xs", Json::from_f64_slice(&[1.0, 2.0]))
            .set("name", Json::Str("cecflow".into()));
        let re = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn path_lookup() {
        let v = Json::parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(v.path("a.b.c").as_usize(), Some(7));
        assert_eq!(v.path("a.b.zzz"), &Json::Null);
    }

    #[test]
    fn as_usize_rejects_fraction() {
        assert_eq!(Json::Num(2.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(9.0).as_usize(), Some(9));
    }

    #[test]
    fn integer_formatting_stays_integral() {
        assert_eq!(Json::Num(5.0).dump(), "5");
        assert_eq!(Json::Num(5.25).dump(), "5.25");
    }
}
