//! Plain-text table rendering for experiment reports.
//!
//! Benches print paper-style rows (Fig. 4 bars, Table II summaries) to
//! stdout; this renderer keeps columns aligned and can draw normalized
//! horizontal bars, mirroring the paper's normalized bar charts in text.

/// A simple column-aligned table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity mismatch: {cells:?}"
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = width[i] - c.chars().count();
                line.push_str(c);
                for _ in 0..pad {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Render a horizontal bar of `value/max` scaled to `width` characters,
/// e.g. `bar(0.5, 1.0, 10)` → `"█████     "`. Used for the normalized
/// cost bars of Fig. 4.
pub fn bar(value: f64, max: f64, width: usize) -> String {
    let frac = if max > 0.0 {
        (value / max).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let filled = (frac * width as f64).round() as usize;
    let mut s = String::new();
    for _ in 0..filled {
        s.push('█');
    }
    for _ in filled..width {
        s.push(' ');
    }
    s
}

/// Format a float with engineering-friendly precision for tables.
pub fn fnum(x: f64) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    let a = x.abs();
    if a == 0.0 {
        "0".to_string()
    } else if a >= 1000.0 {
        format!("{x:.0}")
    } else if a >= 10.0 {
        format!("{x:.2}")
    } else if a >= 0.01 {
        format!("{x:.4}")
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "22".into()]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bar_extremes() {
        assert_eq!(bar(0.0, 1.0, 4), "    ");
        assert_eq!(bar(1.0, 1.0, 4), "████");
        assert_eq!(bar(0.5, 1.0, 4).chars().filter(|&c| c == '█').count(), 2);
        assert_eq!(bar(2.0, 1.0, 4), "████"); // clamped
        assert_eq!(bar(1.0, 0.0, 3), "   "); // degenerate max
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(12345.6), "12346");
        assert_eq!(fnum(12.345), "12.35");
        assert_eq!(fnum(0.5), "0.5000");
        assert!(fnum(1e-5).contains('e'));
        assert_eq!(fnum(f64::INFINITY), "inf");
    }
}
