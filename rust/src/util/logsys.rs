//! Leveled stderr logging, controlled by `CECFLOW_LOG` (error|warn|info|debug).
//!
//! The `log` crate facade plus an impl would be an option, but a 60-line
//! in-tree logger keeps the offline dependency set minimal and gives the
//! benches deterministic, easily-silenced output.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn max_level() -> u8 {
    let cur = MAX_LEVEL.load(Ordering::Relaxed);
    if cur != u8::MAX {
        return cur;
    }
    let lvl = match std::env::var("CECFLOW_LOG").as_deref() {
        Ok("error") => Level::Error as u8,
        Ok("warn") => Level::Warn as u8,
        Ok("debug") => Level::Debug as u8,
        Ok("info") => Level::Info as u8,
        _ => Level::Warn as u8, // default: quiet enough for benches
    };
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Override the log level programmatically (tests, CLI `--verbose`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= max_level()
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[cecflow {tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logsys::log($crate::util::logsys::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logsys::log($crate::util::logsys::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logsys::log($crate::util::logsys::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logsys::log($crate::util::logsys::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
    }
}
