//! Small statistics helpers used by metrics, benches and tests.

/// Summary statistics of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

/// Compute summary statistics (sample std, nearest-rank percentiles).
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize of empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 0.50),
        p95: percentile_sorted(&sorted, 0.95),
    }
}

/// Nearest-rank percentile of an already-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (0 for fewer than 2 observations).
    pub fn variance(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Least-squares slope/intercept of y over x. Returns (slope, intercept).
///
/// Used by benches to check monotone *trends* (e.g. the Fig. 5d claim that
/// `L_data` grows with `a_m`) without pinning absolute values.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx).powi(2)).sum();
    let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    (slope, my - slope * mx)
}

/// Spearman rank correlation — robust trend detector for bench assertions.
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let rx = ranks(x);
    let ry = ranks(y);
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = rx.iter().sum::<f64>() / n;
    let my = ry.iter().sum::<f64>() / n;
    let num: f64 = rx.iter().zip(&ry).map(|(a, b)| (a - mx) * (b - my)).sum();
    let dx: f64 = rx.iter().map(|a| (a - mx).powi(2)).sum::<f64>().sqrt();
    let dy: f64 = ry.iter().map(|b| (b - my).powi(2)).sum::<f64>().sqrt();
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx * dy)
    }
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // average ranks over ties
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Streaming quantile sketch over non-negative samples with a guaranteed
/// relative-error bound and bounded memory (log-bucketed, DDSketch-style).
///
/// Values are binned into geometric buckets `(γ^(i-1), γ^i]` with
/// `γ = (1+α)/(1−α)`; a quantile query walks the cumulative counts to the
/// nearest-rank bucket (the same rank convention as [`percentile_sorted`])
/// and answers with the bucket midpoint `2γ^i/(γ+1)`. Any value in the
/// bucket is within relative error `(γ−1)/(γ+1) = α` of that midpoint, so
/// every quantile estimate is within `α` *relative* error of the exact
/// nearest-rank quantile — the bound the property test in
/// `rust/tests/sim_engine.rs` pins.
///
/// Chosen over the P² and CKMS sketches named in the literature because
/// (a) its error bound is a one-line algebraic fact rather than an
/// asymptotic argument, which makes the property test exact instead of
/// statistical, and (b) inserts are integer bucket increments — the sketch
/// state is a pure function of the *multiset* of inputs, so telemetry is
/// bit-reproducible across runs and worker counts (P² interpolates with
/// floating-point marker updates that depend on arrival order).
///
/// Memory is independent of the sample count: bucket count is bounded by
/// the dynamic range (~2 300 buckets span 1e-9..1e9 at α = 1%) and hard
/// capped at `max_buckets` by collapsing the lowest pair, which biases
/// only extreme low quantiles — tail latencies (p99/p999) are unaffected.
#[derive(Clone, Debug)]
pub struct QuantileSketch {
    alpha: f64,
    gamma: f64,
    ln_gamma: f64,
    counts: std::collections::BTreeMap<i32, u64>,
    /// Samples below [`QuantileSketch::MIN_POS`], reported as 0.0.
    zero: u64,
    total: u64,
    min: f64,
    max: f64,
    max_buckets: usize,
}

impl QuantileSketch {
    /// Values below this collapse into the zero bucket.
    pub const MIN_POS: f64 = 1e-12;

    /// Sketch with relative-error bound `alpha` in (0, 1).
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha < 1.0, "alpha must be in (0,1)");
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        QuantileSketch {
            alpha,
            gamma,
            ln_gamma: gamma.ln(),
            counts: std::collections::BTreeMap::new(),
            zero: 0,
            total: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            max_buckets: 4096,
        }
    }

    /// Default 1% relative error — the bound documented in the README.
    pub fn with_default_error() -> Self {
        Self::new(0.01)
    }

    /// The documented relative-error bound α.
    pub fn relative_error_bound(&self) -> f64 {
        self.alpha
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// True before any insert. Serialization layers must gate on this:
    /// `quantile`/`min`/`max` return NaN on an empty sketch, and the JSON
    /// layer writes NaN as `null` — emit explicit zeros with a zero count
    /// marker instead (see `sim::telemetry`).
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Smallest recorded value (NaN before any insert).
    pub fn min(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Largest recorded value (NaN before any insert).
    pub fn max(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Record one sample. Panics on negative or non-finite input — sojourn
    /// times and queue lengths are non-negative by construction, so either
    /// is an upstream bug.
    pub fn record(&mut self, x: f64) {
        assert!(
            x.is_finite() && x >= 0.0,
            "sketch samples must be finite and non-negative, got {x}"
        );
        self.total += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if x < Self::MIN_POS {
            self.zero += 1;
            return;
        }
        let idx = (x.ln() / self.ln_gamma).ceil() as i32;
        *self.counts.entry(idx).or_insert(0) += 1;
        if self.counts.len() > self.max_buckets {
            // Merge the two lowest buckets; low-quantile bias only.
            let (&lo, &c) = self.counts.iter().next().unwrap();
            self.counts.remove(&lo);
            let (&next, _) = self.counts.iter().next().unwrap();
            *self.counts.get_mut(&next).unwrap() += c;
        }
    }

    /// Nearest-rank quantile estimate, q in [0, 1]. NaN before any insert.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile q must be in [0,1]");
        if self.total == 0 {
            return f64::NAN;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        if rank <= self.zero {
            return 0.0;
        }
        let mut cum = self.zero;
        for (&idx, &c) in &self.counts {
            cum += c;
            if cum >= rank {
                let mid = 2.0 * self.gamma.powi(idx) / (self.gamma + 1.0);
                // The exact value lives in this bucket ∩ [min, max];
                // clamping can only tighten the estimate.
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// Relative difference |a-b| / max(|a|,|b|,eps) — convergence checks.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let scale = a.abs().max(b.abs()).max(1e-12);
    (a - b).abs() / scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn summary_std_matches_definition() {
        let s = summarize(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        // sample std of this classic set is ~2.138
        assert!((s.std - 2.138).abs() < 0.01, "std={}", s.std);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let s = summarize(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-12);
    }

    #[test]
    fn fit_recovers_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (m, b) = linear_fit(&x, &y);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_is_one() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [10.0, 100.0, 101.0, 500.0, 1e4];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
        let ydec: Vec<f64> = y.iter().map(|v| -v).collect();
        assert!((spearman(&x, &ydec) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [5.0, 5.0, 6.0, 7.0];
        let r = spearman(&x, &y);
        assert!(r > 0.9, "r={r}");
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 0.5), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.95), 10.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
    }

    #[test]
    fn sketch_tracks_exact_quantiles_within_alpha() {
        // 1..=10000 scaled: exact quantiles are known in closed form.
        let mut sk = QuantileSketch::with_default_error();
        let xs: Vec<f64> = (1..=10_000).map(|i| i as f64 * 0.37).collect();
        for &x in &xs {
            sk.record(x);
        }
        for q in [0.01, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let exact = percentile_sorted(&xs, q);
            let est = sk.quantile(q);
            let rel = (est - exact).abs() / exact;
            assert!(rel <= sk.relative_error_bound() + 1e-12, "q={q} rel={rel}");
        }
        assert_eq!(sk.count(), 10_000);
        assert_eq!(sk.min(), 0.37);
    }

    #[test]
    fn sketch_zero_and_empty_behaviour() {
        let sk = QuantileSketch::with_default_error();
        assert!(sk.quantile(0.5).is_nan());
        let mut sk = QuantileSketch::with_default_error();
        sk.record(0.0);
        sk.record(5.0);
        assert_eq!(sk.quantile(0.25), 0.0);
        assert!((sk.quantile(1.0) - 5.0).abs() / 5.0 <= 0.01);
    }

    #[test]
    fn sketch_emptiness_is_queryable() {
        let mut sk = QuantileSketch::with_default_error();
        assert!(sk.is_empty());
        // The NaN contract stands — is_empty is how serializers gate it.
        assert!(sk.min().is_nan() && sk.max().is_nan());
        sk.record(1.0);
        assert!(!sk.is_empty());
    }

    #[test]
    fn sketch_state_is_order_independent() {
        let mut a = QuantileSketch::new(0.02);
        let mut b = QuantileSketch::new(0.02);
        let xs = [3.0, 1.5, 99.0, 0.4, 7.7, 1.5, 42.0];
        for &x in &xs {
            a.record(x);
        }
        for &x in xs.iter().rev() {
            b.record(x);
        }
        for q in [0.0, 0.3, 0.5, 0.9, 1.0] {
            assert_eq!(a.quantile(q).to_bits(), b.quantile(q).to_bits());
        }
    }

    #[test]
    #[should_panic]
    fn sketch_rejects_negative_samples() {
        QuantileSketch::with_default_error().record(-1.0);
    }

    #[test]
    fn rel_diff_scales() {
        assert!(rel_diff(100.0, 101.0) < 0.011);
        assert!(rel_diff(0.0, 0.0) == 0.0);
        assert!(rel_diff(1e-20, 0.0) > 0.0);
    }
}
