//! Small statistics helpers used by metrics, benches and tests.

/// Summary statistics of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

/// Compute summary statistics (sample std, nearest-rank percentiles).
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize of empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 0.50),
        p95: percentile_sorted(&sorted, 0.95),
    }
}

/// Nearest-rank percentile of an already-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (0 for fewer than 2 observations).
    pub fn variance(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Least-squares slope/intercept of y over x. Returns (slope, intercept).
///
/// Used by benches to check monotone *trends* (e.g. the Fig. 5d claim that
/// `L_data` grows with `a_m`) without pinning absolute values.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx).powi(2)).sum();
    let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    (slope, my - slope * mx)
}

/// Spearman rank correlation — robust trend detector for bench assertions.
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let rx = ranks(x);
    let ry = ranks(y);
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = rx.iter().sum::<f64>() / n;
    let my = ry.iter().sum::<f64>() / n;
    let num: f64 = rx.iter().zip(&ry).map(|(a, b)| (a - mx) * (b - my)).sum();
    let dx: f64 = rx.iter().map(|a| (a - mx).powi(2)).sum::<f64>().sqrt();
    let dy: f64 = ry.iter().map(|b| (b - my).powi(2)).sum::<f64>().sqrt();
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx * dy)
    }
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // average ranks over ties
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Relative difference |a-b| / max(|a|,|b|,eps) — convergence checks.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let scale = a.abs().max(b.abs()).max(1e-12);
    (a - b).abs() / scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn summary_std_matches_definition() {
        let s = summarize(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        // sample std of this classic set is ~2.138
        assert!((s.std - 2.138).abs() < 0.01, "std={}", s.std);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let s = summarize(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-12);
    }

    #[test]
    fn fit_recovers_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (m, b) = linear_fit(&x, &y);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_is_one() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [10.0, 100.0, 101.0, 500.0, 1e4];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
        let ydec: Vec<f64> = y.iter().map(|v| -v).collect();
        assert!((spearman(&x, &ydec) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [5.0, 5.0, 6.0, 7.0];
        let r = spearman(&x, &y);
        assert!(r > 0.9, "r={r}");
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 0.5), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.95), 10.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
    }

    #[test]
    fn rel_diff_scales() {
        assert!(rel_diff(100.0, 101.0) < 0.011);
        assert!(rel_diff(0.0, 0.0) == 0.0);
        assert!(rel_diff(1e-20, 0.0) > 0.0);
    }
}
