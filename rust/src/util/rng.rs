//! Deterministic pseudo-random number generation.
//!
//! All stochastic elements of the reproduction (topology generation, task
//! placement, rate/capacity draws per Table II of the paper) flow through
//! this module so that every experiment is reproducible bit-for-bit from a
//! `u64` seed. The generator is PCG-XSH-RR 64/32 (O'Neill 2014), chosen for
//! its tiny state, solid statistical quality and trivial portability — the
//! `rand` crate family is unavailable in this offline build.

/// PCG-XSH-RR 64/32 pseudo-random generator.
///
/// 64-bit LCG state advanced with the standard PCG multiplier, output
/// permuted with an xorshift-high + random rotate to 32 bits. Two `next_u32`
/// draws are combined for `next_u64`.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and a stream selector.
    ///
    /// Distinct `stream` values yield statistically independent sequences
    /// for the same `seed` (the LCG increment must be odd; that is forced
    /// internally).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a bare seed (stream 0xda3e39cb94b95bdb).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive a child generator for an independent sub-experiment.
    ///
    /// Used to give each scenario/task/trial its own stream so that adding
    /// draws in one place never perturbs another (important when comparing
    /// algorithms on *identical* random instances).
    pub fn fork(&mut self, tag: u64) -> Pcg {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Pcg::with_stream(seed, tag.wrapping_add(0x5851f42d4c957f2d))
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit halves).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) by Lemire's multiply-shift with rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0) is undefined");
        let n = n as u64;
        // Rejection sampling to remove modulo bias.
        let zone = u64::MAX - (u64::MAX - n + 1) % n;
        loop {
            let v = self.next_u64();
            if v <= zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential random variable with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        assert!(mean > 0.0);
        // Inverse CDF; guard the log argument away from 0.
        let u = 1.0 - self.f64();
        -mean * u.ln()
    }

    /// Exponential with given mean, truncated (by re-draw) to [lo, hi].
    ///
    /// This matches the paper's draw of the result-size ratios
    /// `a_m ~ Exp(0.5)` truncated into `[0.1, 5]` (§V).
    pub fn exponential_trunc(&mut self, mean: f64, lo: f64, hi: f64) -> f64 {
        assert!(lo < hi);
        for _ in 0..10_000 {
            let v = self.exponential(mean);
            if v >= lo && v <= hi {
                return v;
            }
        }
        // Probability of reaching here is astronomically small for the
        // parameter ranges we use; clamp as a safe fallback.
        lo.max(mean.min(hi))
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices out of `n` (k <= n), in random order.
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot choose {k} distinct out of {n}");
        // Partial Fisher–Yates over an index vector.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element of a slice uniformly at random.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "seeds should decorrelate, {same} collisions");
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg::with_stream(7, 1);
        let mut b = Pcg::with_stream(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg::new(3);
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut rng = Pcg::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut rng = Pcg::new(5);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!(
                (c as i64 - expect as i64).abs() < (expect as i64) / 10,
                "count {c} vs expected {expect}"
            );
        }
    }

    #[test]
    fn int_range_inclusive_bounds_hit() {
        let mut rng = Pcg::new(6);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = rng.int_range(3, 5);
            assert!((3..=5).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg::new(7);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exponential_trunc_respects_bounds() {
        let mut rng = Pcg::new(8);
        for _ in 0..10_000 {
            let v = rng.exponential_trunc(0.5, 0.1, 5.0);
            assert!((0.1..=5.0).contains(&v), "v={v}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct_no_duplicates() {
        let mut rng = Pcg::new(10);
        for _ in 0..100 {
            let picks = rng.choose_distinct(20, 8);
            assert_eq!(picks.len(), 8);
            let mut s = picks.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8, "duplicates in {picks:?}");
            assert!(picks.iter().all(|&p| p < 20));
        }
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Pcg::new(11);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn chance_extremes() {
        let mut rng = Pcg::new(12);
        assert!((0..100).all(|_| !rng.chance(0.0)));
        assert!((0..100).all(|_| rng.chance(1.0)));
    }
}
