//! Minimal SVG chart rendering — the benches emit real figure files
//! (`results/*.svg`) alongside CSV/JSON, so the paper's plots can be
//! compared visually without any plotting toolchain.
//!
//! Two chart types cover everything in §V: line charts (Fig. 5b/5c/5d
//! trajectories and sweeps) and grouped horizontal bars (Fig. 4).

use std::fmt::Write as _;

const PALETTE: &[&str] = &[
    "#1f77b4", "#d62728", "#2ca02c", "#ff7f0e", "#9467bd", "#8c564b",
];

/// A named line for [`line_chart`].
pub struct Line<'a> {
    pub label: &'a str,
    pub points: Vec<(f64, f64)>,
}

fn nice_num(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let a = x.abs();
    if a >= 1000.0 {
        format!("{x:.0}")
    } else if a >= 1.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

/// Render a line chart. Non-finite y values are dropped from their line.
/// Returns the SVG document as a string.
pub fn line_chart(title: &str, x_label: &str, y_label: &str, lines: &[Line]) -> String {
    let (w, h) = (640.0, 400.0);
    let (ml, mr, mt, mb) = (70.0, 20.0, 40.0, 50.0);
    let pw = w - ml - mr;
    let ph = h - mt - mb;

    let finite: Vec<(f64, f64)> = lines
        .iter()
        .flat_map(|l| l.points.iter().cloned())
        .filter(|p| p.0.is_finite() && p.1.is_finite())
        .collect();
    let (mut x0, mut x1, mut y0, mut y1) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for &(x, y) in &finite {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if !x0.is_finite() || x0 == x1 {
        x0 = 0.0;
        x1 = 1.0;
    }
    if !y0.is_finite() || y0 == y1 {
        y0 = 0.0;
        y1 = y0 + 1.0;
    }
    // pad the y range a little
    let ypad = 0.05 * (y1 - y0);
    let (y0, y1) = (y0 - ypad, y1 + ypad);

    let sx = move |x: f64| ml + (x - x0) / (x1 - x0) * pw;
    let sy = move |y: f64| mt + (1.0 - (y - y0) / (y1 - y0)) * ph;

    let mut s = String::new();
    let _ = write!(
        s,
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}">"#
    );
    let _ = write!(
        s,
        r#"<rect width="{w}" height="{h}" fill="white"/><text x="{}" y="24" text-anchor="middle" font-family="sans-serif" font-size="16">{}</text>"#,
        w / 2.0,
        xml_escape(title)
    );
    // axes + grid + ticks
    for k in 0..=4 {
        let fy = y0 + (y1 - y0) * k as f64 / 4.0;
        let py = sy(fy);
        let _ = write!(
            s,
            r##"<line x1="{ml}" y1="{py}" x2="{}" y2="{py}" stroke="#ddd"/><text x="{}" y="{}" text-anchor="end" font-family="sans-serif" font-size="11">{}</text>"##,
            w - mr,
            ml - 6.0,
            py + 4.0,
            nice_num(fy)
        );
        let fx = x0 + (x1 - x0) * k as f64 / 4.0;
        let px = sx(fx);
        let _ = write!(
            s,
            r#"<text x="{px}" y="{}" text-anchor="middle" font-family="sans-serif" font-size="11">{}</text>"#,
            h - mb + 18.0,
            nice_num(fx)
        );
    }
    let _ = write!(
        s,
        r#"<line x1="{ml}" y1="{mt}" x2="{ml}" y2="{}" stroke="black"/><line x1="{ml}" y1="{0}" x2="{1}" y2="{0}" stroke="black"/>"#,
        h - mb,
        w - mr,
    );
    // axis labels
    let _ = write!(
        s,
        r#"<text x="{}" y="{}" text-anchor="middle" font-family="sans-serif" font-size="12">{}</text>"#,
        ml + pw / 2.0,
        h - 12.0,
        xml_escape(x_label)
    );
    let _ = write!(
        s,
        r#"<text x="16" y="{}" text-anchor="middle" font-family="sans-serif" font-size="12" transform="rotate(-90 16 {})">{}</text>"#,
        mt + ph / 2.0,
        mt + ph / 2.0,
        xml_escape(y_label)
    );
    // lines + legend
    for (li, line) in lines.iter().enumerate() {
        let color = PALETTE[li % PALETTE.len()];
        let pts: Vec<String> = line
            .points
            .iter()
            .filter(|p| p.0.is_finite() && p.1.is_finite())
            .map(|&(x, y)| format!("{:.1},{:.1}", sx(x), sy(y)))
            .collect();
        if pts.len() >= 2 {
            let _ = write!(
                s,
                r#"<polyline points="{}" fill="none" stroke="{color}" stroke-width="2"/>"#,
                pts.join(" ")
            );
        }
        let ly = mt + 16.0 * li as f64;
        let _ = write!(
            s,
            r#"<line x1="{}" y1="{ly}" x2="{}" y2="{ly}" stroke="{color}" stroke-width="3"/><text x="{}" y="{}" font-family="sans-serif" font-size="11">{}</text>"#,
            w - mr - 120.0,
            w - mr - 95.0,
            w - mr - 90.0,
            ly + 4.0,
            xml_escape(line.label)
        );
    }
    s.push_str("</svg>");
    s
}

/// Render grouped horizontal bars (Fig. 4 style): one group per scenario,
/// one bar per algorithm, values normalized within the group. Infinite
/// values render as full-width hatched bars labelled "saturated".
pub fn grouped_bars(
    title: &str,
    groups: &[String],
    series: &[String],
    // values[group][series]
    values: &[Vec<f64>],
) -> String {
    let bar_h = 16.0;
    let group_gap = 18.0;
    let group_h = series.len() as f64 * bar_h + group_gap;
    let (ml, mr, mt, mb) = (110.0, 90.0, 50.0, 20.0);
    let pw = 420.0;
    let w = ml + pw + mr;
    let h = mt + groups.len() as f64 * group_h + mb;

    let mut s = String::new();
    let _ = write!(
        s,
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}">"#
    );
    let _ = write!(
        s,
        r#"<rect width="{w}" height="{h}" fill="white"/><text x="{}" y="28" text-anchor="middle" font-family="sans-serif" font-size="16">{}</text>"#,
        w / 2.0,
        xml_escape(title)
    );
    for (gi, gname) in groups.iter().enumerate() {
        let gy = mt + gi as f64 * group_h;
        let worst = values[gi]
            .iter()
            .cloned()
            .filter(|v| v.is_finite())
            .fold(0.0f64, f64::max);
        let _ = write!(
            s,
            r#"<text x="{}" y="{}" text-anchor="end" font-family="sans-serif" font-size="12">{}</text>"#,
            ml - 8.0,
            gy + group_h / 2.0 - group_gap / 2.0,
            xml_escape(gname)
        );
        for (si, sname) in series.iter().enumerate() {
            let v = values[gi][si];
            let y = gy + si as f64 * bar_h;
            let color = PALETTE[si % PALETTE.len()];
            let (bw, label) = if v.is_finite() && worst > 0.0 {
                (pw * (v / worst).min(1.0), format!("{:.2}", v / worst))
            } else {
                (pw, "saturated".to_string())
            };
            let _ = write!(
                s,
                r#"<rect x="{ml}" y="{y}" width="{bw:.1}" height="{}" fill="{color}" fill-opacity="{}"/><text x="{}" y="{}" font-family="sans-serif" font-size="10">{} {}</text>"#,
                bar_h - 3.0,
                if v.is_finite() { 0.85 } else { 0.35 },
                ml + bw + 5.0,
                y + bar_h - 6.0,
                xml_escape(sname),
                label
            );
        }
    }
    s.push_str("</svg>");
    s
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;")
        .replace('<', "&lt;")
        .replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_well_formed() {
        let svg = line_chart(
            "t",
            "x",
            "y",
            &[
                Line {
                    label: "a",
                    points: vec![(0.0, 1.0), (1.0, 2.0), (2.0, 1.5)],
                },
                Line {
                    label: "b",
                    points: vec![(0.0, 3.0), (2.0, 0.5)],
                },
            ],
        );
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert_eq!(svg.matches("<polyline").count(), 2);
        assert!(svg.contains(">a</text>"));
    }

    #[test]
    fn line_chart_drops_nonfinite() {
        let svg = line_chart(
            "t",
            "x",
            "y",
            &[Line {
                label: "a",
                points: vec![(0.0, 1.0), (1.0, f64::INFINITY), (2.0, 2.0)],
            }],
        );
        // still renders the finite points as one polyline
        assert_eq!(svg.matches("<polyline").count(), 1);
        assert!(!svg.contains("inf"));
    }

    #[test]
    fn line_chart_degenerate_ranges() {
        // single point / constant series must not divide by zero
        let svg = line_chart(
            "t",
            "x",
            "y",
            &[Line {
                label: "c",
                points: vec![(1.0, 5.0)],
            }],
        );
        assert!(svg.contains("</svg>"));
        assert!(!svg.contains("NaN"));
    }

    #[test]
    fn bars_normalize_and_mark_saturation() {
        let svg = grouped_bars(
            "fig",
            &["s1".into()],
            &["sgp".into(), "lpr".into()],
            &[vec![1.0, f64::INFINITY]],
        );
        assert!(svg.contains("saturated"));
        assert!(svg.contains("sgp 1.00") || svg.contains("sgp 0."));
        assert!(!svg.contains("NaN"));
    }

    #[test]
    fn escaping() {
        let svg = line_chart("a<b&c", "x", "y", &[]);
        assert!(svg.contains("a&lt;b&amp;c"));
    }
}
