//! Self-contained utility substrates (the offline build has no access to
//! rand / serde / criterion / log, so the pieces we need are in-tree).

pub mod json;
pub mod logsys;
pub mod rng;
pub mod stats;
pub mod svg;
pub mod table;
pub mod timer;
