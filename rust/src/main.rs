//! `cecflow` — CLI launcher for the congestion-aware routing/offloading
//! framework.
//!
//! ```text
//! cecflow run        --scenario geant --algo sgp [--seed 42] [--iters 200]
//!                    [--scale 1.0] [--schedule sync|async|accelerated]
//!                    [--config path.json] [--out results/run.json]
//! cecflow sweep      [--scenarios a,b] [--seeds 1,2,3 | 1..8] [--algos sgp,gp,lpr]
//!                    [--backends sparse,native,pjrt] [--schedules static,step:3:1.5]
//!                    [--workers N] [--iters N] [--cache-dir DIR]
//!                    [--tol X] [--patience N] [--scale X] [--out results/sweep.json]
//!                    [--shards N [--shard-timeout SECS] [--shard-retries N]]
//!                                                          process-sharded parent
//!                    [--shard i/n]                         run one shard in-process
//!                    [--shard-worker i/n]                  JSON-lines child protocol
//!                    [--steal-cells i,j,…]                 re-steal child (internal)
//!                    [--merge a.json,b.json]               merge shard reports
//! cecflow dynamic    [--scenario abilene] [--seed 42] [--algo sgp|gp]
//!                    [--backend sparse|native|pjrt] [--schedule step|bursty|diurnal|churn|rescale]
//!                    [--epochs N] [--magnitude X] [--mode warm|cold|both] [--cache-dir DIR]
//!                    [--iters N] [--tol X] [--patience N] [--scale X] [--out trace.json]
//! cecflow simulate   [--scenario abilene] [--seed 42] [--algo sgp|gp|spoo|lcor]
//!                    [--requests N] [--arrivals poisson|mmpp[:b[:s]]|diurnal[:d]]
//!                    [--warmup F] [--pattern static|step:3:1.5|…] [--scale X]
//!                    [--validate TOL] [--reoptimize-every T] [--max-in-flight N]
//!                    [--queue-cap K] [--cpu-queue-cap K] [--link-queue-cap K]
//!                    [--iters N] [--tol X] [--patience N] [--out telemetry.json]
//! cecflow experiment fig4|fig5b|fig5c|fig5d|table2  (see benches/ too)
//! cecflow validate   [--scenario abilene] — XLA data plane vs native
//! cecflow info       — environment, scenarios, artifact status
//! ```

use anyhow::{bail, Context, Result};

use cecflow::cli::Args;
use cecflow::coordinator::{
    build_scenario_network, config::ExperimentConfig, connected_er_servers, run_algorithm,
    Algorithm, RunConfig, RunResult, Schedule, ScenarioSpec,
};
use cecflow::model::network::Network;
use cecflow::model::strategy::Strategy;
use cecflow::sim::run_with_failure;
use cecflow::util::json::Json;
use cecflow::util::table::{fnum, Table};

#[cfg(feature = "pjrt")]
use cecflow::runtime::{resolve_artifacts_dir, DenseEvaluator, Engine};

fn main() {
    let args = Args::from_env(true);
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(err) => {
            eprintln!("error: {err:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(args),
        Some("sweep") => cmd_sweep(args),
        Some("dynamic") => cmd_dynamic(args),
        Some("simulate") => cmd_simulate(args),
        Some("validate") => cmd_validate(args),
        Some("info") => cmd_info(),
        Some("experiment") => cmd_experiment(args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}' (try `cecflow help`)"),
    }
}

fn print_help() {
    println!(
        "cecflow — optimal congestion-aware routing and offloading in CEC\n\
         \n\
         subcommands:\n\
         \x20 run         optimize one scenario with one algorithm\n\
         \x20 sweep       scenario × seed × algorithm grid on worker threads\n\
         \x20 dynamic     time-varying task pattern: warm vs cold re-optimization\n\
         \x20 simulate    request-level discrete-event run of a converged strategy\n\
         \x20 experiment  regenerate a paper figure (fig4|fig5b|fig5c|fig5d|table2)\n\
         \x20 validate    XLA dense data plane vs native evaluator parity\n\
         \x20 info        environment + scenario inventory\n\
         \n\
         common flags: --scenario NAME --algo sgp|gp|spoo|lcor|lpr --seed N\n\
         \x20            --iters N --scale X --schedule sync|async|accelerated\n\
         \x20            --config FILE --out FILE\n\
         sweep flags:  --scenarios a,b --seeds 1,2,3|1..8 --algos sgp,gp,lpr\n\
         \x20            --backends sparse,native,pjrt --workers N --iters N\n\
         \x20            --schedules static,step:3:1.5 --tol X --patience N\n\
         \x20            --scale X --out FILE\n\
         \x20            --sim-requests N [--sim-arrivals SPEC] [--sim-warmup F]\n\
         \x20                                               tail-latency columns per cell\n\
         \x20            --sim-validate TOL                 closed-loop divergence columns\n\
         \x20            --sim-queue-cap K                  per-queue FIFO caps in the sim\n\
         \x20                                               columns (folded into the grid\n\
         \x20                                               hash: capped/uncapped artifacts\n\
         \x20                                               refuse to merge)\n\
         \x20            --cache-dir DIR                    content-addressed strategy store:\n\
         \x20                                               adopt verified previous solves,\n\
         \x20                                               report cache hit columns\n\
         sweep shards: --shards N [--shard-timeout SECS]  spawn N child processes\n\
         \x20            --shard-retries N                  re-steal budget per failed\n\
         \x20                                               shard (default 1; 0 = fail fast)\n\
         \x20            --shard i/n [--out FILE]           run shard i of n here\n\
         \x20            --merge a.json,b.json              merge shard reports\n\
         \x20            --shard-worker i/n                 (internal JSON-lines child)\n\
         \x20            --steal-cells i,j,…                (internal re-steal child)\n\
         dynamic flags: --schedule step|bursty|diurnal|churn|rescale --epochs N\n\
         \x20            --magnitude X --mode warm|cold|both --backend sparse|native|pjrt\n\
         \x20            --cache-dir DIR  per-epoch strategy store (adopt verified solves)\n\
         simulate flags: --requests N --arrivals poisson|mmpp[:burst[:switch]]|diurnal[:depth]\n\
         \x20            --warmup F --pattern static|step:3:1.5|… --out FILE\n\
         \x20            --validate TOL         analytic-vs-simulated divergence report\n\
         \x20                                   (static pattern; nonzero exit on alarm)\n\
         \x20            --reoptimize-every T   in-simulation SGP re-optimization ticks\n\
         \x20            --max-in-flight N      admission cap; excess arrivals are\n\
         \x20                                   dropped and counted, never fatal\n\
         \x20            --queue-cap K          finite per-queue FIFO capacity: arrivals\n\
         \x20                                   to a full CPU/link queue are dropped and\n\
         \x20                                   counted per server (M/M/1/K semantics)\n\
         \x20            --cpu-queue-cap K      per-kind overrides of --queue-cap\n\
         \x20            --link-queue-cap K"
    );
}

fn load_cfg(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(s) = args.opt("scenario") {
        cfg.scenario = s.to_string();
    }
    if let Some(a) = args.opt("algo") {
        cfg.algorithm = Algorithm::parse(a).with_context(|| format!("unknown algo '{a}'"))?;
    }
    cfg.seed = args.opt_u64("seed", cfg.seed);
    cfg.max_iters = args.opt_usize("iters", cfg.max_iters);
    cfg.rate_scale = args.opt_f64("scale", cfg.rate_scale);
    if let Some(s) = args.opt("schedule") {
        cfg.schedule = Schedule::parse(s).with_context(|| format!("unknown schedule '{s}'"))?;
    }
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let net = build_scenario_network(&cfg.scenario, cfg.seed, cfg.rate_scale)?;
    println!(
        "scenario {} (seed {}): |V|={} |E|={} |S|={} scale={}",
        cfg.scenario,
        cfg.seed,
        net.n(),
        net.e() / 2,
        net.s(),
        cfg.rate_scale
    );

    let run_cfg = RunConfig {
        max_iters: cfg.max_iters,
        ..RunConfig::default()
    };

    let outcome = match cfg.schedule {
        Schedule::Sync => run_algorithm(&net, cfg.algorithm, &run_cfg)?,
        Schedule::Async => {
            anyhow::ensure!(
                cfg.algorithm == Algorithm::Sgp,
                "async schedule is defined for SGP"
            );
            let phi0 = Strategy::local_compute_init(&net);
            let updates = cfg.max_iters * net.n();
            let trace = cecflow::sim::run_async(&net, &phi0, updates, cfg.seed)?;
            let flows = cecflow::model::flows::compute_flows(&net, &trace.phi)?;
            let td = cecflow::coordinator::metrics::travel_distance(&net, &flows);
            cecflow::coordinator::AlgoOutcome {
                algorithm: "sgp-async".into(),
                final_cost: *trace.costs.last().unwrap(),
                iterations: trace.costs.len(),
                costs: trace.costs,
                l_data: td.l_data,
                l_result: td.l_result,
                wall_seconds: 0.0,
                phi: Some(trace.phi),
            }
        }
        Schedule::Accelerated => {
            anyhow::ensure!(
                cfg.algorithm == Algorithm::Sgp,
                "accelerated schedule is defined for SGP"
            );
            let phi0 = Strategy::local_compute_init(&net);
            let mut sgp = cecflow::algo::Sgp::new();
            let res = run_accelerated(&net, &mut sgp, &phi0, &run_cfg)?;
            let flows = cecflow::model::flows::compute_flows(&net, &res.phi)?;
            let td = cecflow::coordinator::metrics::travel_distance(&net, &flows);
            cecflow::coordinator::AlgoOutcome {
                algorithm: res.algorithm.clone(),
                final_cost: res.final_cost(),
                iterations: res.costs.len(),
                costs: res.costs,
                l_data: td.l_data,
                l_result: td.l_result,
                wall_seconds: res.wall_seconds,
                phi: Some(res.phi),
            }
        }
    };

    println!(
        "{}: T = {} after {} iterations  (L_data={:.3}, L_result={:.3}, {:.2}s)",
        outcome.algorithm,
        fnum(outcome.final_cost),
        outcome.iterations,
        outcome.l_data,
        outcome.l_result,
        outcome.wall_seconds
    );

    if let Some(out) = args.opt("out") {
        let mut doc = Json::obj();
        doc.set("config", cfg.to_json())
            .set("algorithm", Json::Str(outcome.algorithm.clone()))
            .set("final_cost", Json::Num(outcome.final_cost))
            .set("iterations", Json::Num(outcome.iterations as f64))
            .set("costs", Json::from_f64_slice(&outcome.costs))
            .set("l_data", Json::Num(outcome.l_data))
            .set("l_result", Json::Num(outcome.l_result));
        std::fs::write(out, doc.pretty()).with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Write a sweep report to `--out` as pretty JSON, creating parent
/// directories so `--out results/sweep.json` works on a fresh checkout.
fn write_sweep_report(report: &cecflow::coordinator::SweepReport, out: &str) -> Result<()> {
    if let Some(parent) = std::path::Path::new(out).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    std::fs::write(out, report.to_json().pretty()).with_context(|| format!("writing {out}"))
}

/// `cecflow sweep`: run a `scenario × seed × algorithm × backend` grid on
/// worker threads — optionally sharded across child processes — and print
/// the aggregated [`cecflow::coordinator::SweepReport`].
fn cmd_sweep(args: &Args) -> Result<()> {
    use cecflow::coordinator::sweep::{
        cell_line, done_line, error_line, parse_algorithms, parse_backends, parse_cell_list,
        parse_scenarios, parse_schedules, parse_seeds, parse_shard_arg, run_sweep_cells_with,
        run_sweep_shard, run_sweep_shard_with,
    };
    use cecflow::coordinator::{run_sweep, run_sweep_sharded, ShardOptions, SweepReport, SweepSpec};

    // ---- merge mode: reassemble shard report artifacts ----
    if let Some(list) = args.opt("merge") {
        let mut parts = Vec::new();
        for path in list.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            let doc = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
            parts.push(
                SweepReport::from_json(&doc)
                    .with_context(|| format!("loading shard report {path}"))?,
            );
        }
        anyhow::ensure!(!parts.is_empty(), "--merge needs at least one report file");
        let report = SweepReport::merge(parts)?;
        println!("{}", report.render());
        if let Some(out) = args.opt("out") {
            write_sweep_report(&report, out)?;
            println!("wrote {out}");
        }
        return Ok(());
    }

    let mut spec = SweepSpec::default();
    if let Some(s) = args.opt("scenarios") {
        spec.scenarios = parse_scenarios(s);
    }
    if let Some(s) = args.opt("seeds") {
        spec.seeds = parse_seeds(s)?;
    }
    if let Some(s) = args.opt("algos") {
        spec.algorithms = parse_algorithms(s)?;
    }
    if let Some(s) = args.opt("backends") {
        spec.backends = parse_backends(s)?;
    }
    if let Some(s) = args.opt("schedules") {
        spec.schedules = parse_schedules(s)?;
    }
    spec.rate_scale = args.opt_f64("scale", spec.rate_scale);
    spec.run.max_iters = args.opt_usize("iters", spec.run.max_iters);
    spec.run.tol = args.opt_f64("tol", spec.run.tol);
    spec.run.patience = args.opt_usize("patience", spec.run.patience);
    // request-level simulation opt-in: --sim-requests switches it on, the
    // other two flags refine it (and are rejected without it — silently
    // ignoring them would misreport what the sweep measured)
    if let Some(n) = args.opt("sim-requests") {
        let mut sim = cecflow::coordinator::SimSweepConfig {
            requests: n
                .parse()
                .with_context(|| format!("--sim-requests expects an integer, got '{n}'"))?,
            ..Default::default()
        };
        if let Some(a) = args.opt("sim-arrivals") {
            sim.arrivals = cecflow::sim::ArrivalSpec::parse(a)?;
        }
        sim.warmup = args.opt_f64("sim-warmup", sim.warmup);
        if let Some(v) = args.opt("sim-validate") {
            sim.validate = Some(cecflow::coordinator::config::parse_positive_f64(
                "--sim-validate",
                v,
            )?);
        }
        if let Some(k) = args.opt("sim-queue-cap") {
            sim.queue_cap = Some(k.parse().with_context(|| {
                format!("--sim-queue-cap expects an integer, got '{k}'")
            })?);
        }
        spec.sim = Some(sim);
    } else {
        anyhow::ensure!(
            args.opt("sim-arrivals").is_none()
                && args.opt("sim-warmup").is_none()
                && args.opt("sim-validate").is_none()
                && args.opt("sim-queue-cap").is_none(),
            "--sim-arrivals/--sim-warmup/--sim-validate/--sim-queue-cap require \
             --sim-requests"
        );
    }
    // strategy-store opt-in: warm-start cells from a content-addressed
    // cache directory. Parsed before the child-protocol modes below so
    // shard workers and steal children honor the parent's store.
    if let Some(dir) = args.opt("cache-dir") {
        spec.cache = Some(dir.to_string());
    }

    let default_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let workers = args.opt_usize("workers", default_workers);

    // ---- child protocol modes: JSON-lines cell results on stdout ----
    // (stdout carries only protocol lines; any chatter goes to stderr)
    let finish_worker = |shard: usize, res: anyhow::Result<SweepReport>| -> Result<()> {
        use std::io::Write as _;
        let stdout = std::io::stdout();
        match res {
            Ok(report) => {
                let mut h = stdout.lock();
                let _ = writeln!(h, "{}", done_line(shard, report.cells.len()));
                let _ = h.flush();
                Ok(())
            }
            Err(err) => {
                // the parent reads the error from the protocol stream; the
                // nonzero exit (via the returned Err) is the backstop
                let mut h = stdout.lock();
                let _ = writeln!(h, "{}", error_line(&format!("{err:#}")));
                let _ = h.flush();
                drop(h);
                Err(err)
            }
        }
    };
    if let Some(sw) = args.opt("shard-worker") {
        use std::io::Write as _;
        let (shard, count) = parse_shard_arg(sw)?;
        // Failure-injection hook for the retry tests and the `retry-smoke`
        // CI job: CECFLOW_FAIL_SHARD=k makes strided worker k (1-based)
        // die abruptly after streaming its first cell — a stand-in for an
        // OOM-kill. Steal-workers ignore the variable, so the parent's
        // work re-stealing can prove recovery end to end.
        let fail_here = std::env::var("CECFLOW_FAIL_SHARD")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            == Some(shard + 1);
        let streamed = std::sync::atomic::AtomicUsize::new(0);
        let stdout = std::io::stdout();
        let res = run_sweep_shard_with(&spec, shard, count, workers, |cell| {
            let mut h = stdout.lock();
            let _ = writeln!(h, "{}", cell_line(cell));
            let _ = h.flush();
            drop(h);
            if fail_here && streamed.fetch_add(1, std::sync::atomic::Ordering::Relaxed) == 0 {
                std::process::exit(101);
            }
        });
        return finish_worker(shard, res);
    }

    // ---- re-steal mode: re-run the exact cells a failed shard orphaned ----
    if let Some(list) = args.opt("steal-cells") {
        use std::io::Write as _;
        let indices = parse_cell_list(list)?;
        let stdout = std::io::stdout();
        let res = run_sweep_cells_with(&spec, &indices, workers, |cell| {
            let mut h = stdout.lock();
            let _ = writeln!(h, "{}", cell_line(cell));
            let _ = h.flush();
        });
        return finish_worker(0, res);
    }

    // ---- manual shard mode: run shard i of n in this process ----
    if let Some(sh) = args.opt("shard") {
        let (shard, count) = parse_shard_arg(sh)?;
        let total = spec.cells().len();
        println!(
            "sweep shard {}/{count}: {} of {total} cells on up to {workers} worker(s)",
            shard + 1,
            cecflow::coordinator::sweep::shard_cell_indices(total, shard, count).len(),
        );
        let report = run_sweep_shard(&spec, shard, count, workers)?;
        println!("{}", report.render());
        if let Some(out) = args.opt("out") {
            write_sweep_report(&report, out)?;
            println!("wrote {out} (reassemble with `cecflow sweep --merge a.json,b.json`)");
        }
        return Ok(());
    }

    let total = spec.cells().len();
    println!(
        "sweep: {} scenario(s) × {} seed(s) × {} algorithm(s) × {} backend(s) × {} \
         schedule(s) = {} cells",
        spec.scenarios.len(),
        spec.seeds.len(),
        spec.algorithms.len(),
        spec.backends.len(),
        spec.schedules.len(),
        total,
    );
    let start = std::time::Instant::now();

    // ---- parent mode: partition cells over child processes ----
    let report = if let Some(n) = args.opt("shards") {
        let shards: usize = n
            .parse()
            .with_context(|| format!("--shards expects an integer, got '{n}'"))?;
        anyhow::ensure!(shards >= 1, "--shards must be at least 1");
        let timeout_s = args.opt_f64("shard-timeout", 0.0);
        let timeout = if timeout_s > 0.0 {
            Some(std::time::Duration::from_secs_f64(timeout_s))
        } else {
            None
        };
        // re-steal budget per failed shard; 0 restores fail-fast
        let retries = args.opt_usize("shard-retries", 1);
        let exe = std::env::current_exe()
            .context("locating the cecflow binary to spawn sweep shards")?;
        println!("spawning {} process shard(s) ...", shards.min(total.max(1)));
        run_sweep_sharded(
            &spec,
            &exe,
            &ShardOptions {
                shards,
                workers,
                timeout,
                retries,
                extra_env: Vec::new(),
            },
        )?
    } else {
        run_sweep(&spec, workers)?
    };

    println!("{}", report.render());
    println!(
        "sweep wall time: {:.2}s on {} worker(s)",
        start.elapsed().as_secs_f64(),
        report.workers
    );

    if let Some(out) = args.opt("out") {
        write_sweep_report(&report, out)?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `cecflow dynamic`: drive one scenario through a time-varying
/// task-pattern schedule, re-optimizing at every epoch boundary —
/// warm-started from the previous strategy, cold-started from the
/// all-local point, or both side by side (the paper's "adaptive to
/// changes in task pattern" claim, §IV, made observable). The modes are
/// a two-cell [`cecflow::coordinator::DynamicSpec`] grid routed through
/// the execution engine's worker pool, so warm and cold price
/// concurrently.
fn cmd_dynamic(args: &Args) -> Result<()> {
    use cecflow::coordinator::{CellBackend, DynamicSpec, DynamicTrace, PatternSchedule};

    let scenario = args.opt_or("scenario", "abilene");
    let seed = args.opt_u64("seed", 42);
    let rate_scale = args.opt_f64("scale", 1.0);
    let algorithm = {
        let a = args.opt_or("algo", "sgp");
        Algorithm::parse(a).with_context(|| format!("unknown algo '{a}'"))?
    };
    let backend = {
        let b = args.opt_or("backend", "sparse");
        CellBackend::parse(b).with_context(|| format!("unknown backend '{b}'"))?
    };
    let schedule = {
        let mut s = PatternSchedule::parse(args.opt_or("schedule", "step"))?;
        if let Some(e) = args.opt("epochs") {
            let epochs: usize = e
                .parse()
                .with_context(|| format!("--epochs expects an integer, got '{e}'"))?;
            s = s.with_epochs(epochs)?;
        }
        if let Some(m) = args.opt("magnitude") {
            let magnitude: f64 = m
                .parse()
                .with_context(|| format!("--magnitude expects a number, got '{m}'"))?;
            s = s.with_magnitude(magnitude)?;
        }
        s
    };
    let run_cfg = RunConfig {
        max_iters: args.opt_usize("iters", 120),
        tol: args.opt_f64("tol", RunConfig::default().tol),
        patience: args.opt_usize("patience", RunConfig::default().patience),
    };
    let mode = args.opt_or("mode", "both");
    let (run_warm, run_cold) = match mode {
        "warm" => (true, false),
        "cold" => (false, true),
        "both" => (true, true),
        other => bail!("--mode expects warm|cold|both, got '{other}'"),
    };

    println!(
        "dynamic: {scenario} (seed {seed}) under schedule {} ({} epoch(s), algo {}, \
         backend {})",
        schedule.label(),
        schedule.epochs(),
        algorithm.name(),
        backend.name()
    );

    let mut modes = Vec::new();
    if run_warm {
        modes.push(true);
    }
    if run_cold {
        modes.push(false);
    }
    let spec = DynamicSpec {
        scenario: scenario.to_string(),
        seed,
        rate_scale,
        algorithm,
        backend,
        schedule,
        run: run_cfg,
        modes,
        cache: args.opt("cache-dir").map(str::to_string),
    };
    // one pool worker per mode: warm and cold trace concurrently
    let traces: Vec<DynamicTrace> = spec.run(2)?;
    if spec.cache.is_some() {
        for trace in &traces {
            let hits = trace
                .epochs
                .iter()
                .filter(|e| e.cache_hit == Some(true))
                .count();
            let saved: usize = trace
                .epochs
                .iter()
                .filter(|e| e.cache_hit == Some(true))
                .map(|e| e.iterations)
                .sum();
            println!(
                "strategy store ({} start): {hits}/{} epoch(s) adopted, {saved} \
                 iteration(s) of solving avoided",
                if trace.warm { "warm" } else { "cold" },
                trace.epochs.len()
            );
        }
    }
    for trace in &traces {
        let label = if trace.warm { "warm" } else { "cold" };
        let mut t = Table::new(&[
            "epoch",
            "shift T",
            "final T",
            "iters",
            "iters->1%",
            "regret",
        ]);
        for e in &trace.epochs {
            t.row(vec![
                e.epoch.to_string(),
                fnum(e.shift_cost),
                fnum(e.final_cost),
                e.iterations.to_string(),
                e.iters_to_1pct.to_string(),
                fnum(e.transient_regret),
            ]);
        }
        println!("\n{label} start ({}):", trace.algorithm);
        t.print();
    }

    if traces.len() == 2 {
        let (warm, cold) = (&traces[0], &traces[1]);
        println!(
            "\nre-convergence iterations after the first epoch: warm {} vs cold {}",
            warm.reconvergence_iterations(),
            cold.reconvergence_iterations()
        );
        for (w, c) in warm.epochs.iter().zip(&cold.epochs).skip(1) {
            if w.iterations > c.iterations {
                println!(
                    "note: epoch {}: warm took {} iterations vs cold {} — adaptivity \
                     claim violated on this instance",
                    w.epoch, w.iterations, c.iterations
                );
            }
        }
    }

    if let Some(out) = args.opt("out") {
        let mut doc = Json::obj();
        doc.set("scenario", Json::Str(scenario.to_string()))
            .set("seed", Json::Num(seed as f64))
            .set("schedule", Json::Str(schedule.label()))
            .set("rate_scale", Json::Num(rate_scale))
            .set(
                "runs",
                Json::Arr(traces.iter().map(DynamicTrace::to_json).collect()),
            );
        if let Some(parent) = std::path::Path::new(out).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        std::fs::write(out, doc.pretty()).with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `cecflow simulate`: optimize a scenario to convergence, then release a
/// stream of stochastic requests through the converged strategy's routing
/// splits on the discrete-event engine (`sim::tasks`) and report
/// streaming sojourn quantiles. With a non-static `--pattern`, the
/// warm-started adaptive loop ([`cecflow::coordinator::AdaptiveRunner`])
/// converges every epoch first and each request is routed by its arrival
/// epoch's strategy.
///
/// Closed-loop extensions ([`cecflow::sim::closedloop`]):
/// `--validate TOL` compares the simulated sojourn against the converged
/// strategy's analytic steady state and exits nonzero on alarm (after
/// writing `--out`, so the divergence report survives the failure);
/// `--reoptimize-every T` skips per-epoch offline convergence and instead
/// schedules asynchronous SGP update ticks on the simulation clock.
fn cmd_simulate(args: &Args) -> Result<()> {
    use cecflow::coordinator::config::parse_positive_f64;
    use cecflow::coordinator::{AdaptiveRunner, CellBackend, PatternSchedule};
    use cecflow::sim::{simulate, ArrivalSpec, ReoptConfig, SimConfig, SimEpoch, SimPlan};

    let scenario = args.opt_or("scenario", "abilene");
    let seed = args.opt_u64("seed", 42);
    let rate_scale = args.opt_f64("scale", 1.0);
    let algorithm = {
        let a = args.opt_or("algo", "sgp");
        Algorithm::parse(a).with_context(|| format!("unknown algo '{a}'"))?
    };
    anyhow::ensure!(
        algorithm.supports_simulation(),
        "algorithm {} produces no strategy to simulate — pick an iterative optimizer \
         (sgp|gp|spoo|lcor)",
        algorithm.name()
    );
    let arrivals = ArrivalSpec::parse(args.opt_or("arrivals", "poisson"))?;
    let pattern = PatternSchedule::parse(args.opt_or("pattern", "static"))?;
    let run_cfg = RunConfig {
        max_iters: args.opt_usize("iters", 200),
        tol: args.opt_f64("tol", RunConfig::default().tol),
        patience: args.opt_usize("patience", RunConfig::default().patience),
    };
    // Optional per-queue FIFO caps: absent flags leave the run uncapped and
    // bit-identical to pre-admission-control artifacts.
    let opt_cap = |name: &str| -> Result<Option<u64>> {
        args.opt(name)
            .map(|s| {
                s.parse::<u64>()
                    .with_context(|| format!("--{name} expects an integer, got '{s}'"))
            })
            .transpose()
    };
    let sim_cfg = SimConfig {
        requests: args.opt_u64("requests", 100_000),
        warmup: args.opt_f64("warmup", 0.05),
        seed,
        max_in_flight: args.opt_usize("max-in-flight", SimConfig::default().max_in_flight),
        queue_cap: opt_cap("queue-cap")?,
        cpu_queue_cap: opt_cap("cpu-queue-cap")?,
        link_queue_cap: opt_cap("link-queue-cap")?,
    };
    let validate_tol = match args.opt("validate") {
        Some(v) => Some(parse_positive_f64("--validate", v)?),
        None => None,
    };
    let reopt = match args.opt("reoptimize-every") {
        Some(v) => Some(ReoptConfig::every(parse_positive_f64(
            "--reoptimize-every",
            v,
        )?)?),
        None => None,
    };
    anyhow::ensure!(
        !(validate_tol.is_some() && reopt.is_some()),
        "--validate compares against the *converged* strategy's analytic flows; \
         --reoptimize-every deliberately walks away from that strategy mid-run, so the \
         two cannot combine"
    );
    if validate_tol.is_some() {
        anyhow::ensure!(
            pattern.is_static(),
            "--validate needs a steady state to compare against — use the static \
             pattern (got {})",
            pattern.label()
        );
    }

    let net = build_scenario_network(scenario, seed, rate_scale)?;
    println!(
        "simulate: {scenario} (seed {seed}) algo {} pattern {} arrivals {} — optimizing ...",
        algorithm.name(),
        pattern.label(),
        arrivals.label()
    );
    let opt_start = std::time::Instant::now();
    let plan = if pattern.is_static() {
        let out = run_algorithm(&net, algorithm, &run_cfg)?;
        let phi = out.phi.context("optimizer returned no strategy")?;
        println!(
            "converged: T = {} after {} iteration(s) ({:.2}s)",
            fnum(out.final_cost),
            out.iterations,
            opt_start.elapsed().as_secs_f64()
        );
        SimPlan {
            epochs: vec![SimEpoch { net, phi }],
        }
    } else if reopt.is_some() {
        // in-simulation re-optimization: converge only epoch 0 offline;
        // later epochs start from the retargeted epoch-0 strategy and
        // adapt through the SGP ticks riding the calendar queue
        let out = run_algorithm(&net, algorithm, &run_cfg)?;
        let phi0 = out.phi.context("optimizer returned no strategy")?;
        println!(
            "converged epoch 0: T = {} after {} iteration(s) ({:.2}s); later epochs \
             adapt in-simulation",
            fnum(out.final_cost),
            out.iterations,
            opt_start.elapsed().as_secs_f64()
        );
        let epochs = (0..pattern.epochs())
            .map(|e| {
                let net_e = pattern.network_at(&net, seed, e);
                let phi_e = phi0.retarget(&net, &net_e);
                SimEpoch {
                    net: net_e,
                    phi: phi_e,
                }
            })
            .collect();
        SimPlan { epochs }
    } else {
        let runner = AdaptiveRunner {
            algorithm,
            backend: CellBackend::Sparse,
            warm: true,
            run: run_cfg,
        };
        let epochs = runner.converged_epochs(scenario, &net, seed, &pattern)?;
        println!(
            "converged {} epoch(s) in {:.2}s",
            epochs.len(),
            opt_start.elapsed().as_secs_f64()
        );
        SimPlan {
            epochs: epochs
                .into_iter()
                .map(|(net, phi)| SimEpoch { net, phi })
                .collect(),
        }
    };

    let sim_start = std::time::Instant::now();
    let telemetry = match &reopt {
        Some(r) => cecflow::sim::simulate_adaptive(&plan, &arrivals, &sim_cfg, r)?,
        None => simulate(&plan, &arrivals, &sim_cfg)?,
    };
    let (p50, p99, p999) = telemetry.tail();
    println!(
        "released {} request(s), {} completed, {} stranded — {} events over {:.1} \
         simulated time unit(s) in {:.2}s",
        telemetry.arrived,
        telemetry.completed,
        telemetry.stranded,
        telemetry.events,
        telemetry.end_time,
        sim_start.elapsed().as_secs_f64()
    );
    println!(
        "sojourn: mean {}  p50 {}  p99 {}  p99.9 {}",
        fnum(telemetry.mean_sojourn()),
        fnum(p50),
        fnum(p99),
        fnum(p999)
    );
    if telemetry.overload_dropped > 0 {
        println!(
            "overload: {} arrival(s) dropped at the admission cap ({}) — the strategy \
             is infeasible at this load",
            telemetry.overload_dropped, sim_cfg.max_in_flight
        );
    }
    if let Some((cpu_cap, link_cap)) = telemetry.queue_caps {
        let show = |c: u64| {
            if c == u64::MAX {
                "unbounded".to_string()
            } else {
                c.to_string()
            }
        };
        println!(
            "per-queue admission (cpu cap {}, link cap {}): {} request(s) dropped at \
             full FIFOs",
            show(cpu_cap),
            show(link_cap),
            telemetry.queue_dropped
        );
    }
    if telemetry.reopt_events > 0 {
        println!(
            "re-optimization: {} tick(s), {} node update(s) applied, {} skipped",
            telemetry.reopt_events, telemetry.reopt_updates, telemetry.reopt_skipped
        );
    }

    let report = match validate_tol {
        Some(tol) => {
            let ep = &plan.epochs[0];
            let r = cecflow::sim::validate(&ep.net, &ep.phi, &telemetry, tol)?;
            println!("{}", r.render());
            Some(r)
        }
        None => None,
    };

    if let Some(out) = args.opt("out") {
        let mut doc = Json::obj();
        doc.set("scenario", Json::Str(scenario.to_string()))
            .set("seed", Json::Num(seed as f64))
            .set("algorithm", Json::Str(algorithm.name().to_string()))
            .set("pattern", Json::Str(pattern.label()))
            .set("arrivals", Json::Str(arrivals.label()))
            .set("requests", Json::Num(sim_cfg.requests as f64))
            .set("warmup", Json::Num(sim_cfg.warmup))
            .set("rate_scale", Json::Num(rate_scale))
            .set("telemetry", telemetry.to_json());
        if let Some(r) = &report {
            doc.set("validation", r.to_json());
        }
        if let Some(parent) = std::path::Path::new(out).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        std::fs::write(out, doc.pretty()).with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    // the hard alarm: nonzero exit *after* the artifact is on disk, so an
    // alarmed CI run still leaves the divergence report to inspect
    if let Some(r) = &report {
        anyhow::ensure!(
            !r.alarm,
            "closed-loop validation alarm: {}",
            r.alarm_reasons.join("; ")
        );
    }
    Ok(())
}

/// Run the accelerated schedule on the best available dense backend:
/// the PJRT engine when built with `--features pjrt`, the pure-rust
/// native backend otherwise.
#[cfg(feature = "pjrt")]
fn run_accelerated(
    net: &Network,
    sgp: &mut cecflow::algo::Sgp,
    phi0: &Strategy,
    run_cfg: &RunConfig,
) -> Result<RunResult> {
    let engine = Engine::load(&resolve_artifacts_dir()?)?;
    let eval = DenseEvaluator::new(&engine);
    cecflow::coordinator::optimize_accelerated(net, sgp, phi0, run_cfg, &eval)
}

#[cfg(not(feature = "pjrt"))]
fn run_accelerated(
    net: &Network,
    sgp: &mut cecflow::algo::Sgp,
    phi0: &Strategy,
    run_cfg: &RunConfig,
) -> Result<RunResult> {
    eprintln!(
        "note: cecflow was built without the `pjrt` cargo feature; the accelerated \
         schedule runs on the native dense backend (rebuild with `--features pjrt` \
         and run `make artifacts` for the XLA data plane)"
    );
    cecflow::coordinator::optimize_accelerated(
        net,
        sgp,
        phi0,
        run_cfg,
        &cecflow::runtime::NativeBackend,
    )
}

#[cfg(not(feature = "pjrt"))]
fn cmd_validate(_args: &Args) -> Result<()> {
    bail!(
        "`cecflow validate` compares the PJRT/XLA data plane against the native \
         evaluator and requires a build with `--features pjrt` (plus AOT artifacts \
         from `make artifacts`). This binary was built with the native backend only, \
         which is the reference being validated."
    )
}

#[cfg(feature = "pjrt")]
fn cmd_validate(args: &Args) -> Result<()> {
    let scenario = args.opt_or("scenario", "abilene");
    let seed = args.opt_u64("seed", 42);
    let net = build_scenario_network(scenario, seed, 1.0)?;
    anyhow::ensure!(
        net.n() <= 128 && net.s() <= 128,
        "validate currently covers networks within the large AOT class"
    );
    let engine = Engine::load(&resolve_artifacts_dir()?)?;
    println!("PJRT platform: {}", engine.platform());
    let eval = DenseEvaluator::new(&engine);

    let phi = Strategy::local_compute_init(&net);
    let native = cecflow::model::flows::compute_flows(&net, &phi)?;
    let marg = cecflow::model::marginals::compute_marginals(&net, &phi, &native)?;
    let dense = eval.evaluate(&net, &phi)?;

    let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(b.abs()).max(1e-9);
    let cost_err = rel(native.total_cost, dense.total_cost);
    let mut marg_err = 0.0f64;
    for s in 0..net.s() {
        for i in 0..net.n() {
            marg_err = marg_err.max(rel(marg.dt_plus[s][i], dense.dt_plus[s][i]));
            marg_err = marg_err.max(rel(marg.dt_r[s][i], dense.dt_r[s][i]));
        }
    }
    println!(
        "total cost:   native {} vs XLA {}  (rel err {:.2e})",
        fnum(native.total_cost),
        fnum(dense.total_cost),
        cost_err
    );
    println!("marginals:    max rel err {marg_err:.2e}");
    anyhow::ensure!(cost_err < 1e-3, "total-cost parity failure");
    anyhow::ensure!(marg_err < 5e-3, "marginal parity failure");
    println!("VALIDATION OK (f32 data plane vs f64 native)");
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("cecflow {}", env!("CARGO_PKG_VERSION"));
    print_engine_info();
    println!("\nTable II scenarios:");
    let mut t = Table::new(&["name", "|V|", "links", "|S|", "|R|", "cost"]);
    for spec in ScenarioSpec::table2() {
        let sc = spec.build(1);
        t.row(vec![
            spec.name.to_string(),
            sc.net.n().to_string(),
            (sc.net.e() / 2).to_string(),
            sc.net.s().to_string(),
            spec.sources_per_task.to_string(),
            format!("{:?}/{:?}", spec.link_kind, spec.comp_kind),
        ]);
    }
    t.print();
    Ok(())
}

#[cfg(feature = "pjrt")]
fn print_engine_info() {
    println!("dense backends: native (default), pjrt (enabled)");
    let dir = cecflow::runtime::default_artifacts_dir();
    match Engine::load(&dir) {
        Ok(engine) => {
            println!("artifacts: {} (platform {})", dir.display(), engine.platform());
            for c in engine.classes() {
                println!("  class {:<6} N={} S={}", c.name, c.n, c.s);
            }
        }
        Err(err) => println!("artifacts: unavailable ({err:#})"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn print_engine_info() {
    println!("dense backends: native (default)");
    println!(
        "pjrt engine: disabled at build time — rebuild with `--features pjrt` and run \
         `make artifacts` to enable the XLA data plane"
    );
}

/// Lightweight experiment driver (the full sweeps live in `benches/`).
fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .context("experiment name required: fig4|fig5b|fig5c|fig5d|table2")?;
    match which {
        "fig5b" => {
            let sc = connected_er_servers(args.opt_u64("seed", 42));
            let s1 = sc.servers[0];
            let fallback = sc.servers[1];
            let phi0 = Strategy::local_compute_init(&sc.net);
            println!("Connected-ER with servers {:?}; failing S1={s1} at iter 100", sc.servers);
            let sgp_run = run_with_failure(
                &sc.net,
                cecflow::algo::Sgp::new,
                &phi0,
                100,
                200,
                s1,
                fallback,
                0.01,
            )?;
            let gp_run = run_with_failure(
                &sc.net,
                || cecflow::algo::Gp::new(1.0),
                &phi0,
                100,
                200,
                s1,
                fallback,
                0.01,
            )?;
            for (name, run) in [("sgp", &sgp_run), ("gp", &gp_run)] {
                println!(
                    "{name}: post-failure cost {} -> {} in {} iterations",
                    fnum(run.cost_after_failure),
                    fnum(run.final_cost),
                    run.reconverge_iters
                );
            }
            Ok(())
        }
        other => bail!(
            "experiment '{other}' is driven by the bench harness: \
             cargo bench --bench {other}"
        ),
    }
}
