//! # cecflow
//!
//! A production-grade reproduction of *"Optimal Congestion-aware Routing
//! and Offloading in Collaborative Edge Computing"* (Zhang, Liu, Yeh 2022)
//! as a three-layer Rust + JAX/Pallas system:
//!
//! * **L3 (this crate)** — the distributed joint routing/offloading
//!   optimizer: flow model, marginal-cost broadcast, blocked-node
//!   loop-freedom, the Scaled Gradient Projection algorithm and the
//!   GP/SPOO/LCOR/LPR baselines, a discrete-event protocol simulator, and
//!   experiment drivers for every table/figure of the paper.
//! * **L2/L1 (python/, build-time only)** — the dense per-iteration
//!   numeric core (flow propagation + congestion costs + marginal
//!   recursions) written in JAX with Pallas kernels, AOT-lowered to HLO
//!   text and executed from Rust through the PJRT CPU client
//!   ([`runtime`], behind the `pjrt` cargo feature). Default builds run
//!   the same loop on the pure-rust [`runtime::NativeBackend`], so the
//!   crate builds and tests with no XLA libraries and no artifacts.
//!
//! Start at [`coordinator::scenario`] for paper-faithful network
//! instances, [`algo::sgp`] for the optimizer, and `examples/quickstart.rs`
//! for a guided tour.

pub mod algo;
pub mod cli;
pub mod coordinator;
pub mod graph;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod util;
