//! LCOR — Local Computation Optimal Routing baseline (§V).
//!
//! All exogenous input is computed at its data source
//! (`φ⁻_i0 ≡ 1`); only the *result* routing `φ⁺` is optimized, using the
//! scaled-gradient-projection machinery of the paper's reference [25]
//! (Bertsekas–Gafni–Gallager second-derivative routing). The paper
//! simulates scenarios where pure-local computation is feasible, which the
//! scenario builders guarantee.
//!
//! Implemented as SGP with the data plane frozen at the all-local
//! strategy — the result-plane update then *is* the classic optimal-routing
//! algorithm (no offloading interplay).

use crate::model::network::Network;
use crate::model::strategy::Strategy;

use super::sgp::{Restriction, Sgp};

/// Build the LCOR optimizer and its initial strategy.
pub fn lcor_optimizer(net: &Network) -> (Sgp, Strategy) {
    debug_assert!(
        net.local_computation_feasible(),
        "LCOR requires locally-feasible computation (paper §V)"
    );
    let phi = Strategy::local_compute_init(net);
    let sgp = Sgp::with_restriction(Restriction {
        freeze_data: true,
        freeze_result: false,
        extra_blocked_data: None,
    });
    (sgp, phi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Optimizer;
    use crate::model::flows::compute_flows;
    use crate::model::network::testnet::{diamond, line3};

    #[test]
    fn data_plane_stays_local() {
        let net = diamond(true);
        let (mut opt, mut phi) = lcor_optimizer(&net);
        for _ in 0..30 {
            opt.step(&net, &mut phi).unwrap();
        }
        for s in 0..net.s() {
            for i in 0..net.n() {
                assert_eq!(phi.data[s][i][0], 1.0);
            }
        }
    }

    #[test]
    fn result_routing_descends() {
        let net = line3();
        let (mut opt, mut phi) = lcor_optimizer(&net);
        let mut last = f64::INFINITY;
        for _ in 0..40 {
            let st = opt.step(&net, &mut phi).unwrap();
            assert!(st.total_cost <= last + 1e-9);
            last = st.total_cost;
            assert!(phi.is_loop_free(&net));
        }
    }

    #[test]
    fn lcor_never_beats_sgp() {
        let net = diamond(true);
        let (mut lcor, mut phi_l) = lcor_optimizer(&net);
        for _ in 0..100 {
            lcor.step(&net, &mut phi_l).unwrap();
        }
        let tl = compute_flows(&net, &phi_l).unwrap().total_cost;

        let mut sgp = crate::algo::Sgp::new();
        let mut phi_s = Strategy::local_compute_init(&net);
        for _ in 0..100 {
            sgp.step(&net, &mut phi_s).unwrap();
        }
        let ts = compute_flows(&net, &phi_s).unwrap().total_cost;
        assert!(ts <= tl + 1e-6, "SGP {ts} vs LCOR {tl}");
    }
}
