//! Reusable scratch arena for the optimizer hot path.
//!
//! [`OptWorkspace`] owns every buffer the SGP/GP inner loops need —
//! flat marginal tables, a double-buffered flow pair, blocked-set rows,
//! QP scratch, per-attempt flag vectors — so a steady-state
//! [`Sgp::step_ws`](super::Sgp) sweep performs **zero heap allocation**
//! after warm-up. One workspace per worker thread (or per sweep cell /
//! dynamics run / re-optimization state); never share one across threads.
//!
//! Results are **bitwise identical** to the allocating paths: the
//! workspace only changes where intermediate values live, never the
//! order of floating-point operations (pinned by
//! `tests/opt_workspace.rs`).
//!
//! # Zero-allocation audit of the steady-state sparse sweep
//!
//! Every buffer a `step_ws` iteration touches, and why it cannot
//! allocate once warm (warm = one prior full sweep on the same-shaped
//! network; certified mechanically by the counting `#[global_allocator]`
//! in `tests/opt_workspace.rs`):
//!
//! * `flows` / `shadow` — shaped by [`FlowState::zeroed`] in
//!   [`OptWorkspace::ensure`]; `compute_flows_with`,
//!   `recompute_task_flows_with`, `copy_task_from`, and
//!   `copy_aggregates_from` only overwrite in place.
//! * `flow_scratch` / `marg` / `block_scratch` / `topo` — self-ensuring
//!   scratch types; their `ensure` paths resize only on a dimension
//!   change.
//! * `tags` / `node_blocked` — count-shaped in `ensure`; per-row `Vec`s
//!   inside are `clear` + `resize`d to the same lengths every use.
//! * `saved_data` / `saved_result` — one row per task, refilled with
//!   `clone_from`; row capacity grows to the sweep's max row width
//!   during the first full sweep and is never exceeded after.
//! * `bufs` (`delta`/`scale`/`blocked` + QP scratch) — `clear` +
//!   `reserve(deg+1)`-style refills bounded by the max out-degree seen
//!   in the first sweep.
//! * `added_*` / `task_dirty` / `dirty` / `mask` / `order` — `clear` +
//!   `resize`/`extend` bounded by task/edge/node counts.
//! * `cand_pool` — dense/GP path only; slots are created on first use
//!   and refilled with `clone_from` after (the dense path's backend
//!   evaluation itself is exempt from the contract — see
//!   [`Sgp::step_dense_ws`](super::Sgp::step_dense_ws)).
//!
//! Only error paths (`anyhow!`/`bail!`) allocate; they abort the sweep.

use crate::graph::algorithms::TopoScratch;
use crate::model::flows::{FlowScratch, FlowState};
use crate::model::marginals::MarginalScratch;
use crate::model::network::Network;
use crate::model::strategy::Strategy;

use super::blocked::{BlockScratch, NodeBlocked, PlaneTags};
use super::simplex_qp::QpScratch;

/// Per-row QP input/output buffers shared by the sparse sweep, the dense
/// batched proposer, and the async single-node update.
#[derive(Debug, Default)]
pub(crate) struct ProposeBufs {
    /// Marginal vector `δ±` for the row being projected.
    pub(crate) delta: Vec<f64>,
    /// Scaling-matrix diagonal for the row being projected.
    pub(crate) scale: Vec<f64>,
    /// Blocked-slot overlay (base blocked row ∪ restriction extras, minus
    /// currently-active slots).
    pub(crate) blocked: Vec<bool>,
    /// Breakpoint / free-set scratch of the simplex QP.
    pub(crate) qp: QpScratch,
}

/// The optimizer scratch arena. Construct once with
/// [`OptWorkspace::new`] and pass to every `step_ws` /
/// `update_single_node_ws` call; [`OptWorkspace::ensure`] reshapes the
/// buffers whenever the network dimensions change, so one workspace can
/// serve differently-shaped networks back to back (grow or shrink).
#[derive(Debug)]
pub struct OptWorkspace {
    /// Current flow state (the optimizer's working copy).
    pub(crate) flows: FlowState,
    /// Shadow flow state: rollback snapshot for the Gauss–Seidel
    /// safeguard, candidate pricing for the async update.
    pub(crate) shadow: FlowState,
    /// Mask/topo scratch of the flow computations.
    pub(crate) flow_scratch: FlowScratch,
    /// Flat marginal tables (`δ` ingredients, `h±`).
    pub(crate) marg: MarginalScratch,
    /// Improper-link tags per task.
    pub(crate) tags: Vec<PlaneTags>,
    /// Mask/topo scratch of the tag construction.
    pub(crate) block_scratch: BlockScratch,
    /// Blocked rows of the node currently being updated, per task.
    pub(crate) node_blocked: Vec<NodeBlocked>,
    /// Saved data-plane rows of the node being updated (rollback + QP
    /// input), per task.
    pub(crate) saved_data: Vec<Vec<f64>>,
    /// Saved result-plane rows, per task.
    pub(crate) saved_result: Vec<Vec<f64>>,
    /// Row-level QP buffers.
    pub(crate) bufs: ProposeBufs,
    /// Per-task "gained a previously-inactive data edge" flags.
    pub(crate) added_data: Vec<bool>,
    /// Per-task "gained a previously-inactive result edge" flags.
    pub(crate) added_result: Vec<bool>,
    /// Per-task "flows affected" flags.
    pub(crate) task_dirty: Vec<bool>,
    /// Dirty-task index list (compacted from `task_dirty`).
    pub(crate) dirty: Vec<usize>,
    /// Active-edge mask for the safeguard's cycle re-check.
    pub(crate) mask: Vec<bool>,
    /// Topo scratch for the cycle re-check.
    pub(crate) topo: TopoScratch,
    /// Topo order output for the cycle re-check.
    pub(crate) order: Vec<usize>,
    /// Candidate-strategy pool for the dense batched ladder (and GP's
    /// single candidate) — refilled with `clone_from`, so row shapes
    /// adapt without reallocating on same-shaped networks.
    pub(crate) cand_pool: Vec<Strategy>,
    /// Network shape `(n, e, s)` the sized buffers currently match.
    shape: Option<(usize, usize, usize)>,
}

fn empty_flow_state() -> FlowState {
    FlowState {
        t_minus: Vec::new(),
        t_plus: Vec::new(),
        g: Vec::new(),
        f_minus: Vec::new(),
        f_plus: Vec::new(),
        link_flow: Vec::new(),
        workload: Vec::new(),
        total_cost: 0.0,
    }
}

impl OptWorkspace {
    /// An empty workspace; buffers are shaped lazily by
    /// [`OptWorkspace::ensure`] on first use.
    pub fn new() -> OptWorkspace {
        OptWorkspace {
            flows: empty_flow_state(),
            shadow: empty_flow_state(),
            flow_scratch: FlowScratch::default(),
            marg: MarginalScratch::new(),
            tags: Vec::new(),
            block_scratch: BlockScratch::default(),
            node_blocked: Vec::new(),
            saved_data: Vec::new(),
            saved_result: Vec::new(),
            bufs: ProposeBufs::default(),
            added_data: Vec::new(),
            added_result: Vec::new(),
            task_dirty: Vec::new(),
            dirty: Vec::new(),
            mask: Vec::new(),
            topo: TopoScratch::default(),
            order: Vec::new(),
            cand_pool: Vec::new(),
            shape: None,
        }
    }

    /// Reshape the dimension-sized buffers for `net` if its `(n, e, s)`
    /// shape differs from the last use. Buffers that are fully rewritten
    /// on every use (masks, rows, QP scratch) are left alone — they
    /// resize themselves in place.
    pub fn ensure(&mut self, net: &Network) {
        let key = (net.n(), net.e(), net.s());
        if self.shape == Some(key) {
            return;
        }
        self.flows = FlowState::zeroed(net);
        self.shadow = FlowState::zeroed(net);
        self.tags.clear();
        self.tags.resize_with(net.s(), PlaneTags::default);
        self.node_blocked.clear();
        self.node_blocked.resize_with(net.s(), NodeBlocked::default);
        self.saved_data.clear();
        self.saved_data.resize_with(net.s(), Vec::new);
        self.saved_result.clear();
        self.saved_result.resize_with(net.s(), Vec::new);
        // Pool candidates are cloned from live strategies; shapes from a
        // previous network must not survive a dimension change.
        self.cand_pool.clear();
        self.shape = Some(key);
    }
}

impl Default for OptWorkspace {
    fn default() -> Self {
        OptWorkspace::new()
    }
}
