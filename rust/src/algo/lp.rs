//! Dense two-phase primal simplex LP solver — the substrate for the LPR
//! baseline (§V, paper ref [8]).
//!
//! Solves `min cᵀx  s.t.  A_le x ≤ b_le, A_eq x = b_eq, x ≥ 0` by the
//! textbook tableau method with Bland's anti-cycling rule. Problem sizes in
//! cecflow are small (tens of variables × tens of constraints per task), so
//! a dense tableau is the simplest dependable choice; the solver is still
//! written for general problems and brute-force-validated in tests.

/// An LP in inequality/equality form (minimization).
#[derive(Clone, Debug, Default)]
pub struct LpProblem {
    /// Objective coefficients (length = number of structural variables).
    pub objective: Vec<f64>,
    /// `row · x ≤ rhs` constraints.
    pub le_rows: Vec<(Vec<f64>, f64)>,
    /// `row · x = rhs` constraints.
    pub eq_rows: Vec<(Vec<f64>, f64)>,
}

/// Solver outcome.
#[derive(Clone, Debug, PartialEq)]
pub enum LpOutcome {
    Optimal { x: Vec<f64>, value: f64 },
    Infeasible,
    Unbounded,
}

impl LpProblem {
    pub fn new(num_vars: usize) -> LpProblem {
        LpProblem {
            objective: vec![0.0; num_vars],
            le_rows: Vec::new(),
            eq_rows: Vec::new(),
        }
    }

    pub fn num_vars(&self) -> usize {
        self.objective.len()
    }

    pub fn add_le(&mut self, row: Vec<f64>, rhs: f64) {
        assert_eq!(row.len(), self.num_vars());
        self.le_rows.push((row, rhs));
    }

    pub fn add_eq(&mut self, row: Vec<f64>, rhs: f64) {
        assert_eq!(row.len(), self.num_vars());
        self.eq_rows.push((row, rhs));
    }

    /// Solve with two-phase simplex.
    pub fn solve(&self) -> LpOutcome {
        let n = self.num_vars();
        let m_le = self.le_rows.len();
        let m = m_le + self.eq_rows.len();
        if m == 0 {
            // No constraints: optimum is 0 unless some c_j < 0 (unbounded).
            if self.objective.iter().any(|&c| c < -1e-12) {
                return LpOutcome::Unbounded;
            }
            return LpOutcome::Optimal {
                x: vec![0.0; n],
                value: 0.0,
            };
        }

        // Columns: [structural n][slack m_le][artificial m].
        let n_slack = m_le;
        let total = n + n_slack + m;
        // tableau rows: m constraint rows + 1 objective row (phase-dependent)
        let mut tab = vec![vec![0.0f64; total + 1]; m + 1];
        let mut basis = vec![0usize; m];

        for (r, (row, rhs)) in self
            .le_rows
            .iter()
            .chain(self.eq_rows.iter())
            .enumerate()
        {
            let mut rhs = *rhs;
            let mut coef = row.clone();
            let is_le = r < m_le;
            let mut slack_sign = 1.0;
            if rhs < 0.0 {
                // normalize to nonnegative rhs
                rhs = -rhs;
                coef.iter_mut().for_each(|c| *c = -*c);
                slack_sign = -1.0;
            }
            for (j, &c) in coef.iter().enumerate() {
                tab[r][j] = c;
            }
            if is_le {
                tab[r][n + r] = slack_sign;
            }
            tab[r][n + n_slack + r] = 1.0; // artificial
            tab[r][total] = rhs;
            basis[r] = n + n_slack + r;
        }

        // ---- Phase I: minimize sum of artificials ----
        // objective row = -Σ (constraint rows) restricted to non-artificials
        for j in 0..=total {
            let mut s = 0.0;
            for r in 0..m {
                s += tab[r][j];
            }
            tab[m][j] = -s;
        }
        for r in 0..m {
            let a = n + n_slack + r;
            tab[m][a] = 0.0;
        }
        if !simplex_iterate(&mut tab, &mut basis, total) {
            return LpOutcome::Unbounded; // cannot happen in phase I
        }
        let phase1 = -tab[m][total];
        if phase1 > 1e-7 {
            return LpOutcome::Infeasible;
        }
        // Drive any artificial still in the basis out (degenerate rows).
        for r in 0..m {
            if basis[r] >= n + n_slack {
                // pivot on any nonzero non-artificial column
                if let Some(j) = (0..n + n_slack).find(|&j| tab[r][j].abs() > 1e-9) {
                    pivot(&mut tab, &mut basis, r, j, total);
                }
                // else: the row is all-zero — redundant constraint; leave it.
            }
        }

        // ---- Phase II: original objective ----
        for j in 0..=total {
            tab[m][j] = 0.0;
        }
        for j in 0..n {
            tab[m][j] = self.objective[j];
        }
        // zero out artificial columns so they never re-enter
        for r in 0..m {
            for j in (n + n_slack)..total {
                tab[r][j] = 0.0;
            }
        }
        // express objective in terms of non-basic variables
        for r in 0..m {
            let b = basis[r];
            if b < total {
                let factor = tab[m][b];
                if factor.abs() > 1e-12 {
                    for j in 0..=total {
                        tab[m][j] -= factor * tab[r][j];
                    }
                }
            }
        }
        if !simplex_iterate(&mut tab, &mut basis, total) {
            return LpOutcome::Unbounded;
        }

        let mut x = vec![0.0; n];
        for r in 0..m {
            if basis[r] < n {
                x[basis[r]] = tab[r][total];
            }
        }
        let value: f64 = self
            .objective
            .iter()
            .zip(&x)
            .map(|(c, v)| c * v)
            .sum();
        LpOutcome::Optimal { x, value }
    }
}

/// Run simplex pivots until optimal. Returns false on unboundedness.
/// Bland's rule: entering = smallest index with negative reduced cost;
/// leaving = smallest ratio, ties by smallest basis index.
fn simplex_iterate(tab: &mut [Vec<f64>], basis: &mut [usize], total: usize) -> bool {
    let m = basis.len();
    for _iter in 0..200_000 {
        // entering column (Bland)
        let enter = match (0..total).find(|&j| tab[m][j] < -1e-9) {
            Some(j) => j,
            None => return true, // optimal
        };
        // ratio test
        let mut leave: Option<usize> = None;
        let mut best = f64::INFINITY;
        for r in 0..m {
            if tab[r][enter] > 1e-9 {
                let ratio = tab[r][total] / tab[r][enter];
                if ratio < best - 1e-12
                    || (ratio < best + 1e-12
                        && leave.map(|l| basis[r] < basis[l]).unwrap_or(false))
                {
                    best = ratio;
                    leave = Some(r);
                }
            }
        }
        let leave = match leave {
            Some(r) => r,
            None => return false, // unbounded
        };
        pivot(tab, basis, leave, enter, total);
    }
    panic!("simplex did not terminate (cycling?)");
}

fn pivot(tab: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, total: usize) {
    let m = basis.len();
    let p = tab[row][col];
    debug_assert!(p.abs() > 1e-12);
    for j in 0..=total {
        tab[row][j] /= p;
    }
    for r in 0..=m {
        if r != row {
            let f = tab[r][col];
            if f.abs() > 1e-12 {
                for j in 0..=total {
                    tab[r][j] -= f * tab[row][j];
                }
            }
        }
    }
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn assert_optimal(outcome: &LpOutcome, expect_value: f64, tol: f64) -> Vec<f64> {
        match outcome {
            LpOutcome::Optimal { x, value } => {
                assert!(
                    (value - expect_value).abs() < tol,
                    "value {value} vs expected {expect_value}"
                );
                x.clone()
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn simple_2d() {
        // min -x - y  s.t. x + y <= 1, x,y >= 0  → value -1 on the edge
        let mut lp = LpProblem::new(2);
        lp.objective = vec![-1.0, -1.0];
        lp.add_le(vec![1.0, 1.0], 1.0);
        let x = assert_optimal(&lp.solve(), -1.0, 1e-9);
        assert!((x[0] + x[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn equality_constraint() {
        // min x + 2y s.t. x + y = 1 → x=1
        let mut lp = LpProblem::new(2);
        lp.objective = vec![1.0, 2.0];
        lp.add_eq(vec![1.0, 1.0], 1.0);
        let x = assert_optimal(&lp.solve(), 1.0, 1e-9);
        assert!((x[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn transport_problem() {
        // classic 2x2 transportation: supplies [3,2], demands [2,3]
        // costs [[1,4],[2,1]] → optimal: x00=2, x01=1, x11=2 → 2+4+2=8
        let mut lp = LpProblem::new(4); // x00 x01 x10 x11
        lp.objective = vec![1.0, 4.0, 2.0, 1.0];
        lp.add_eq(vec![1.0, 1.0, 0.0, 0.0], 3.0);
        lp.add_eq(vec![0.0, 0.0, 1.0, 1.0], 2.0);
        lp.add_eq(vec![1.0, 0.0, 1.0, 0.0], 2.0);
        lp.add_eq(vec![0.0, 1.0, 0.0, 1.0], 3.0);
        assert_optimal(&lp.solve(), 8.0, 1e-8);
    }

    #[test]
    fn detects_infeasible() {
        let mut lp = LpProblem::new(1);
        lp.objective = vec![1.0];
        lp.add_eq(vec![1.0], 2.0);
        lp.add_le(vec![1.0], 1.0);
        assert_eq!(lp.solve(), LpOutcome::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        let mut lp = LpProblem::new(2);
        lp.objective = vec![-1.0, 0.0];
        lp.add_le(vec![0.0, 1.0], 1.0); // x0 unconstrained above
        assert_eq!(lp.solve(), LpOutcome::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // x >= 2 written as -x <= -2; min x → 2
        let mut lp = LpProblem::new(1);
        lp.objective = vec![1.0];
        lp.add_le(vec![-1.0], -2.0);
        assert_optimal(&lp.solve(), 2.0, 1e-9);
    }

    #[test]
    fn no_constraints() {
        let lp = LpProblem::new(2);
        assert_optimal(&lp.solve(), 0.0, 1e-12);
        let mut lp2 = LpProblem::new(1);
        lp2.objective = vec![-1.0];
        assert_eq!(lp2.solve(), LpOutcome::Unbounded);
    }

    #[test]
    fn degenerate_redundant_constraints() {
        // duplicated equality rows must not break phase I
        let mut lp = LpProblem::new(2);
        lp.objective = vec![1.0, 1.0];
        lp.add_eq(vec![1.0, 1.0], 1.0);
        lp.add_eq(vec![1.0, 1.0], 1.0);
        assert_optimal(&lp.solve(), 1.0, 1e-8);
    }

    /// Randomized cross-check against brute force over the vertices of
    /// box+budget polytopes: min cᵀx s.t. x ≤ u (elementwise), Σx = b.
    #[test]
    fn random_budget_boxes_match_greedy() {
        let mut rng = Pcg::new(31);
        for trial in 0..40 {
            let n = rng.int_range(2, 6);
            let c: Vec<f64> = (0..n).map(|_| rng.uniform(-5.0, 5.0)).collect();
            let u: Vec<f64> = (0..n).map(|_| rng.uniform(0.5, 2.0)).collect();
            let budget = rng.uniform(0.1, u.iter().sum::<f64>() * 0.9);
            let mut lp = LpProblem::new(n);
            lp.objective = c.clone();
            for j in 0..n {
                let mut row = vec![0.0; n];
                row[j] = 1.0;
                lp.add_le(row, u[j]);
            }
            lp.add_eq(vec![1.0; n], budget);
            // greedy optimum: fill cheapest coordinates first
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| c[a].partial_cmp(&c[b]).unwrap());
            let mut left = budget;
            let mut best = 0.0;
            for &j in &order {
                let take = left.min(u[j]);
                best += c[j] * take;
                left -= take;
                if left <= 0.0 {
                    break;
                }
            }
            assert_optimal(&lp.solve(), best, 1e-6 * (1.0 + best.abs()));
            let _ = trial;
        }
    }
}
