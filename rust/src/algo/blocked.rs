//! Blocked-node sets (§IV "Blocked nodes"): the loop-freedom mechanism.
//!
//! For the result plane of task `(d,m)`: at a Theorem-1 point,
//! `∂T/∂t⁺` decreases strictly along every active result path toward the
//! destination. To keep every iterate loop-free, node `i` must not *start*
//! forwarding results to a neighbor `j` when either
//!
//! 1. `∂T/∂t⁺_j ≥ ∂T/∂t⁺_i` (adding `(i,j)` could invert the ordering), or
//! 2. `j` has an active result path containing an *improper* link `(p,q)`
//!    with `∂T/∂t⁺_q ≥ ∂T/∂t⁺_p` (the ordering is already inverted
//!    downstream of `j`, so new flow through `j` could close a cycle while
//!    the inversion unwinds).
//!
//! Neighbors that already receive flow (`φ_ij > 0`) are never blocked —
//! gradient descent shrinks them smoothly; forcibly zeroing them could
//! *increase* cost and break Theorem 2 monotonicity. (Gallager 1977 uses
//! the same convention.) The same construction applies to the data plane
//! with `∂T/∂r` and data paths.
//!
//! In the distributed implementation the improper tag is piggybacked on
//! the broadcast messages (§IV); here we compute it centrally with one
//! reverse-topological sweep per task and plane.
//!
//! All entry points are generic over [`MargView`], so they accept both the
//! nested [`crate::model::marginals::Marginals`] and the flat workspace
//! scratch with identical results, and each has an `_into` form writing
//! into caller-owned buffers for the allocation-free optimizer loop.

use std::cmp::Ordering;

use crate::graph::algorithms::{topo_order_masked_into, TopoScratch};
use crate::graph::DiGraph;
use crate::model::marginals::MargView;
use crate::model::network::Network;
use crate::model::strategy::Strategy;

/// Blocked sets for one task: `data[i][slot]` / `result[i][slot]` are
/// aligned with the strategy's slot layout (data slot 0 = local compute,
/// never blocked).
#[derive(Clone, Debug)]
pub struct BlockedSets {
    pub data: Vec<Vec<bool>>,
    pub result: Vec<Vec<bool>>,
}

/// Improper-link tags for both planes of one task — the global O(N+E)
/// part of blocked-set construction, computed once and shared by every
/// node's row query (the per-node Gauss–Seidel sweep would otherwise pay
/// O(N) full reconstructions per task per position).
#[derive(Clone, Debug, Default)]
pub struct PlaneTags {
    pub data_tag: Vec<bool>,
    pub result_tag: Vec<bool>,
}

/// Mask/topo scratch for [`plane_tags_into`] — one per worker thread.
#[derive(Clone, Debug, Default)]
pub struct BlockScratch {
    mask: Vec<bool>,
    topo: TopoScratch,
    order: Vec<usize>,
}

/// Compute the improper tags for `task` under the current marginals.
pub fn plane_tags<M: MargView + ?Sized>(
    net: &Network,
    phi: &Strategy,
    marg: &M,
    task: usize,
) -> PlaneTags {
    let mut scratch = BlockScratch::default();
    let mut tags = PlaneTags::default();
    plane_tags_into(net, phi, marg, task, &mut scratch, &mut tags);
    tags
}

/// [`plane_tags`] into caller-owned buffers — allocation-free after
/// warm-up, identical tags.
pub fn plane_tags_into<M: MargView + ?Sized>(
    net: &Network,
    phi: &Strategy,
    marg: &M,
    task: usize,
    scratch: &mut BlockScratch,
    tags: &mut PlaneTags,
) {
    let g = &net.graph;
    phi.result_active_mask_into(net, task, &mut scratch.mask);
    tagged_nodes_into(
        g,
        &scratch.mask,
        marg.dt_plus_task(task),
        &mut scratch.topo,
        &mut scratch.order,
        &mut tags.result_tag,
    );
    phi.data_active_mask_into(net, task, &mut scratch.mask);
    tagged_nodes_into(
        g,
        &scratch.mask,
        marg.dt_r_task(task),
        &mut scratch.topo,
        &mut scratch.order,
        &mut tags.data_tag,
    );
}

/// Blocked slots of one node for one task (slot layouts match Strategy).
#[derive(Clone, Debug, Default)]
pub struct NodeBlocked {
    /// `[1 + out_degree]`, slot 0 = local computation (never blocked).
    pub data: Vec<bool>,
    /// `[out_degree]`.
    pub result: Vec<bool>,
}

/// Per-node blocked rows given precomputed tags — O(out_degree).
pub fn blocked_rows_for_node<M: MargView + ?Sized>(
    net: &Network,
    phi: &Strategy,
    marg: &M,
    tags: &PlaneTags,
    task: usize,
    i: usize,
) -> NodeBlocked {
    let mut out = NodeBlocked::default();
    blocked_rows_for_node_into(net, phi, marg, tags, task, i, &mut out);
    out
}

/// [`blocked_rows_for_node`] into a caller-owned row pair —
/// allocation-free after warm-up, identical rows.
pub fn blocked_rows_for_node_into<M: MargView + ?Sized>(
    net: &Network,
    phi: &Strategy,
    marg: &M,
    tags: &PlaneTags,
    task: usize,
    i: usize,
    out: &mut NodeBlocked,
) {
    let g = &net.graph;
    let deg = g.out_degree(i);
    let dt_plus = marg.dt_plus_task(task);
    let dt_r = marg.dt_r_task(task);
    let d_link = marg.d_link();

    let result = &mut out.result;
    result.clear();
    result.resize(deg, false);
    if i != net.tasks[task].dest {
        for (k, &eid) in g.out_edge_ids(i).iter().enumerate() {
            let j = g.edge(eid).dst;
            if phi.result[task][i][k] > 0.0 {
                continue; // active neighbors stay available
            }
            if dt_plus[j] >= dt_plus[i] || tags.result_tag[j] {
                result[k] = true;
            }
        }
        // Never block every slot: if the heuristics blocked everything,
        // unblock the minimum-marginal neighbor (first wins on ties, the
        // convention `Iterator::min_by` used here before).
        if !result.is_empty() && result.iter().all(|&b| b) {
            let mut best_k = 0usize;
            let mut best_v = f64::INFINITY;
            let mut first = true;
            for (k, &eid) in g.out_edge_ids(i).iter().enumerate() {
                let val = d_link[eid] + dt_plus[g.edge(eid).dst];
                if first || val.partial_cmp(&best_v).unwrap() == Ordering::Less {
                    best_k = k;
                    best_v = val;
                    first = false;
                }
            }
            result[best_k] = false;
        }
    }

    // slot 0 (local computation) is never blocked: it cannot create a
    // routing loop.
    let data = &mut out.data;
    data.clear();
    data.resize(deg + 1, false);
    for (k, &eid) in g.out_edge_ids(i).iter().enumerate() {
        let j = g.edge(eid).dst;
        if phi.data[task][i][k + 1] > 0.0 {
            continue;
        }
        if dt_r[j] >= dt_r[i] || tags.data_tag[j] {
            data[k + 1] = true;
        }
    }
}

/// Compute the per-task blocked sets (all nodes) from the current
/// marginals — the Jacobi-style full construction used by `step_dense`.
pub fn blocked_sets<M: MargView + ?Sized>(
    net: &Network,
    phi: &Strategy,
    marg: &M,
    task: usize,
) -> BlockedSets {
    let tags = plane_tags(net, phi, marg, task);
    let n = net.n();
    let mut data = Vec::with_capacity(n);
    let mut result = Vec::with_capacity(n);
    for i in 0..n {
        let rows = blocked_rows_for_node(net, phi, marg, &tags, task, i);
        data.push(rows.data);
        result.push(rows.result);
    }
    BlockedSets { data, result }
}

/// Mark nodes having an active path to an *improper* link — a link `(p,q)`
/// with `marginal[q] ≥ marginal[p]`. One reverse-topological sweep: node
/// `p` is tagged if one of its active out-links is improper or leads to a
/// tagged node.
fn tagged_nodes_into(
    g: &DiGraph,
    active: &[bool],
    marginal: &[f64],
    topo: &mut TopoScratch,
    order: &mut Vec<usize>,
    tag: &mut Vec<bool>,
) {
    assert!(
        topo_order_masked_into(g, active, topo, order),
        "active subgraph must be loop-free"
    );
    tag.clear();
    tag.resize(g.node_count(), false);
    for &p in order.iter().rev() {
        for &eid in g.out_edge_ids(p) {
            if !active[eid] {
                continue;
            }
            let q = g.edge(eid).dst;
            if marginal[q] >= marginal[p] || tag[q] {
                tag[p] = true;
                break;
            }
        }
    }
}

#[cfg(test)]
fn tagged_nodes(g: &DiGraph, active: &[bool], marginal: &[f64]) -> Vec<bool> {
    let mut topo = TopoScratch::default();
    let mut order = Vec::new();
    let mut tag = Vec::new();
    tagged_nodes_into(g, active, marginal, &mut topo, &mut order, &mut tag);
    tag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flows::compute_flows;
    use crate::model::marginals::{compute_marginals, Marginals};
    use crate::model::network::testnet::diamond;
    use crate::model::strategy::out_slot;

    fn setup(net: &Network, phi: &Strategy) -> Marginals {
        let fs = compute_flows(net, phi).unwrap();
        compute_marginals(net, phi, &fs).unwrap()
    }

    #[test]
    fn active_neighbors_never_blocked() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let m = setup(&net, &phi);
        let b = blocked_sets(&net, &phi, &m, 0);
        for i in 0..net.n() {
            for (k, &frac) in phi.result[0][i].iter().enumerate() {
                if frac > 0.0 {
                    assert!(!b.result[i][k], "active result slot ({i},{k}) blocked");
                }
            }
            for (k, &frac) in phi.data[0][i].iter().enumerate() {
                if frac > 0.0 {
                    assert!(!b.data[i][k], "active data slot ({i},{k}) blocked");
                }
            }
        }
    }

    #[test]
    fn local_compute_slot_never_blocked() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let m = setup(&net, &phi);
        let b = blocked_sets(&net, &phi, &m, 0);
        for i in 0..net.n() {
            assert!(!b.data[i][0]);
        }
    }

    #[test]
    fn upstream_neighbor_blocked_on_result_plane() {
        // With results flowing 0 -> (SP tree) -> 3, the marginal at 0 is the
        // largest; 3's upstream neighbors must not route results to 0.
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let m = setup(&net, &phi);
        let b = blocked_sets(&net, &phi, &m, 0);
        // node 1 has out-neighbors 0 and 3; dt_plus[0] > dt_plus[1] so the
        // slot toward 0 must be blocked (φ_10 = 0 on the result plane).
        let s10 = out_slot(&net.graph, 1, 0).unwrap();
        if phi.result[0][1][s10] == 0.0 {
            assert!(
                b.result[1][s10],
                "slot 1->0 should be blocked: dt_plus[0]={} dt_plus[1]={}",
                m.dt_plus[0][0], m.dt_plus[0][1]
            );
        }
    }

    #[test]
    fn destination_has_no_result_blocks_needed() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let m = setup(&net, &phi);
        let b = blocked_sets(&net, &phi, &m, 0);
        // destination's result plane is identically zero; blocked set is
        // all-false by construction
        assert!(b.result[3].iter().all(|&x| !x));
    }

    #[test]
    fn never_blocks_everything() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let m = setup(&net, &phi);
        let b = blocked_sets(&net, &phi, &m, 0);
        for i in 0..net.n() {
            assert!(
                b.data[i].iter().any(|&x| !x),
                "node {i} data plane fully blocked"
            );
            if i != 3 && !b.result[i].is_empty() {
                assert!(
                    b.result[i].iter().any(|&x| !x),
                    "node {i} result plane fully blocked"
                );
            }
        }
    }

    #[test]
    fn into_forms_match_allocating_forms() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let m = setup(&net, &phi);
        let mut scratch = BlockScratch::default();
        let mut tags_buf = PlaneTags::default();
        let mut row_buf = NodeBlocked::default();
        for task in 0..net.s() {
            let tags = plane_tags(&net, &phi, &m, task);
            // reused (dirty) buffers must match the fresh computation
            plane_tags_into(&net, &phi, &m, task, &mut scratch, &mut tags_buf);
            assert_eq!(tags.data_tag, tags_buf.data_tag);
            assert_eq!(tags.result_tag, tags_buf.result_tag);
            for i in 0..net.n() {
                let rows = blocked_rows_for_node(&net, &phi, &m, &tags, task, i);
                blocked_rows_for_node_into(
                    &net, &phi, &m, &tags, task, i, &mut row_buf,
                );
                assert_eq!(rows.data, row_buf.data);
                assert_eq!(rows.result, row_buf.result);
            }
        }
    }

    #[test]
    fn tagging_detects_improper_downstream() {
        // chain 0 -> 1 -> 2 active; marginals inverted on (1,2)
        let g = crate::graph::DiGraph::new(3, &[(0, 1), (1, 2)]);
        let active = vec![true, true];
        let marginal = vec![3.0, 1.0, 2.0]; // (1,2) improper: m[2] >= m[1]
        let tag = tagged_nodes(&g, &active, &marginal);
        assert!(tag[1], "node 1 owns the improper link");
        assert!(tag[0], "node 0 reaches it");
        assert!(!tag[2], "node 2 has no outgoing active links");
    }
}
