//! Blocked-node sets (§IV "Blocked nodes"): the loop-freedom mechanism.
//!
//! For the result plane of task `(d,m)`: at a Theorem-1 point,
//! `∂T/∂t⁺` decreases strictly along every active result path toward the
//! destination. To keep every iterate loop-free, node `i` must not *start*
//! forwarding results to a neighbor `j` when either
//!
//! 1. `∂T/∂t⁺_j ≥ ∂T/∂t⁺_i` (adding `(i,j)` could invert the ordering), or
//! 2. `j` has an active result path containing an *improper* link `(p,q)`
//!    with `∂T/∂t⁺_q ≥ ∂T/∂t⁺_p` (the ordering is already inverted
//!    downstream of `j`, so new flow through `j` could close a cycle while
//!    the inversion unwinds).
//!
//! Neighbors that already receive flow (`φ_ij > 0`) are never blocked —
//! gradient descent shrinks them smoothly; forcibly zeroing them could
//! *increase* cost and break Theorem 2 monotonicity. (Gallager 1977 uses
//! the same convention.) The same construction applies to the data plane
//! with `∂T/∂r` and data paths.
//!
//! In the distributed implementation the improper tag is piggybacked on
//! the broadcast messages (§IV); here we compute it centrally with one
//! reverse-topological sweep per task and plane.

use crate::graph::DiGraph;
use crate::model::marginals::Marginals;
use crate::model::network::Network;
use crate::model::strategy::Strategy;

/// Blocked sets for one task: `data[i][slot]` / `result[i][slot]` are
/// aligned with the strategy's slot layout (data slot 0 = local compute,
/// never blocked).
#[derive(Clone, Debug)]
pub struct BlockedSets {
    pub data: Vec<Vec<bool>>,
    pub result: Vec<Vec<bool>>,
}

/// Improper-link tags for both planes of one task — the global O(N+E)
/// part of blocked-set construction, computed once and shared by every
/// node's row query (the per-node Gauss–Seidel sweep would otherwise pay
/// O(N) full reconstructions per task per position).
#[derive(Clone, Debug)]
pub struct PlaneTags {
    pub data_tag: Vec<bool>,
    pub result_tag: Vec<bool>,
}

/// Compute the improper tags for `task` under the current marginals.
pub fn plane_tags(net: &Network, phi: &Strategy, marg: &Marginals, task: usize) -> PlaneTags {
    let g = &net.graph;
    let rmask = phi.result_active_mask(net, task);
    let result_tag = tagged_nodes(g, &rmask, &marg.dt_plus[task]);
    let dmask = phi.data_active_mask(net, task);
    let data_tag = tagged_nodes(g, &dmask, &marg.dt_r[task]);
    PlaneTags {
        data_tag,
        result_tag,
    }
}

/// Blocked slots of one node for one task (slot layouts match Strategy).
#[derive(Clone, Debug)]
pub struct NodeBlocked {
    /// `[1 + out_degree]`, slot 0 = local computation (never blocked).
    pub data: Vec<bool>,
    /// `[out_degree]`.
    pub result: Vec<bool>,
}

/// Per-node blocked rows given precomputed tags — O(out_degree).
pub fn blocked_rows_for_node(
    net: &Network,
    phi: &Strategy,
    marg: &Marginals,
    tags: &PlaneTags,
    task: usize,
    i: usize,
) -> NodeBlocked {
    let g = &net.graph;
    let deg = g.out_degree(i);

    let mut result = vec![false; deg];
    if i != net.tasks[task].dest {
        for (k, &eid) in g.out_edge_ids(i).iter().enumerate() {
            let j = g.edge(eid).dst;
            if phi.result[task][i][k] > 0.0 {
                continue; // active neighbors stay available
            }
            if marg.dt_plus[task][j] >= marg.dt_plus[task][i] || tags.result_tag[j] {
                result[k] = true;
            }
        }
        // never block every slot: keep the minimum-marginal neighbor
        ensure_one_free(&mut result, || {
            g.out_edge_ids(i)
                .iter()
                .enumerate()
                .map(|(k, &eid)| (k, marg.d_link[eid] + marg.dt_plus[task][g.edge(eid).dst]))
                .collect()
        });
    }

    // slot 0 (local computation) is never blocked: it cannot create a
    // routing loop.
    let mut data = vec![false; deg + 1];
    for (k, &eid) in g.out_edge_ids(i).iter().enumerate() {
        let j = g.edge(eid).dst;
        if phi.data[task][i][k + 1] > 0.0 {
            continue;
        }
        if marg.dt_r[task][j] >= marg.dt_r[task][i] || tags.data_tag[j] {
            data[k + 1] = true;
        }
    }

    NodeBlocked { data, result }
}

/// Compute the per-task blocked sets (all nodes) from the current
/// marginals — the Jacobi-style full construction used by `step_dense`.
pub fn blocked_sets(
    net: &Network,
    phi: &Strategy,
    marg: &Marginals,
    task: usize,
) -> BlockedSets {
    let tags = plane_tags(net, phi, marg, task);
    let n = net.n();
    let mut data = Vec::with_capacity(n);
    let mut result = Vec::with_capacity(n);
    for i in 0..n {
        let rows = blocked_rows_for_node(net, phi, marg, &tags, task, i);
        data.push(rows.data);
        result.push(rows.result);
    }
    BlockedSets { data, result }
}

/// Mark nodes having an active path to an *improper* link — a link `(p,q)`
/// with `marginal[q] ≥ marginal[p]`. One reverse-topological sweep: node
/// `p` is tagged if one of its active out-links is improper or leads to a
/// tagged node.
fn tagged_nodes(g: &DiGraph, active: &[bool], marginal: &[f64]) -> Vec<bool> {
    let order = crate::graph::algorithms::topo_order_masked(g, active)
        .expect("active subgraph must be loop-free");
    let mut tag = vec![false; g.node_count()];
    for &p in order.iter().rev() {
        for &eid in g.out_edge_ids(p) {
            if !active[eid] {
                continue;
            }
            let q = g.edge(eid).dst;
            if marginal[q] >= marginal[p] || tag[q] {
                tag[p] = true;
                break;
            }
        }
    }
    tag
}

/// If the heuristics blocked every slot, unblock the one with the lowest
/// Theorem-1 marginal so the node always has a feasible strategy.
fn ensure_one_free<F: FnOnce() -> Vec<(usize, f64)>>(slots: &mut [bool], candidates: F) {
    if !slots.is_empty() && slots.iter().all(|&b| b) {
        let cands = candidates();
        if let Some((k, _)) = cands
            .into_iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        {
            slots[k] = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flows::compute_flows;
    use crate::model::marginals::compute_marginals;
    use crate::model::network::testnet::diamond;
    use crate::model::strategy::out_slot;

    fn setup(net: &Network, phi: &Strategy) -> Marginals {
        let fs = compute_flows(net, phi).unwrap();
        compute_marginals(net, phi, &fs).unwrap()
    }

    #[test]
    fn active_neighbors_never_blocked() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let m = setup(&net, &phi);
        let b = blocked_sets(&net, &phi, &m, 0);
        for i in 0..net.n() {
            for (k, &frac) in phi.result[0][i].iter().enumerate() {
                if frac > 0.0 {
                    assert!(!b.result[i][k], "active result slot ({i},{k}) blocked");
                }
            }
            for (k, &frac) in phi.data[0][i].iter().enumerate() {
                if frac > 0.0 {
                    assert!(!b.data[i][k], "active data slot ({i},{k}) blocked");
                }
            }
        }
    }

    #[test]
    fn local_compute_slot_never_blocked() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let m = setup(&net, &phi);
        let b = blocked_sets(&net, &phi, &m, 0);
        for i in 0..net.n() {
            assert!(!b.data[i][0]);
        }
    }

    #[test]
    fn upstream_neighbor_blocked_on_result_plane() {
        // With results flowing 0 -> (SP tree) -> 3, the marginal at 0 is the
        // largest; 3's upstream neighbors must not route results to 0.
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let m = setup(&net, &phi);
        let b = blocked_sets(&net, &phi, &m, 0);
        // node 1 has out-neighbors 0 and 3; dt_plus[0] > dt_plus[1] so the
        // slot toward 0 must be blocked (φ_10 = 0 on the result plane).
        let s10 = out_slot(&net.graph, 1, 0).unwrap();
        if phi.result[0][1][s10] == 0.0 {
            assert!(
                b.result[1][s10],
                "slot 1->0 should be blocked: dt_plus[0]={} dt_plus[1]={}",
                m.dt_plus[0][0], m.dt_plus[0][1]
            );
        }
    }

    #[test]
    fn destination_has_no_result_blocks_needed() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let m = setup(&net, &phi);
        let b = blocked_sets(&net, &phi, &m, 0);
        // destination's result plane is identically zero; blocked set is
        // all-false by construction
        assert!(b.result[3].iter().all(|&x| !x));
    }

    #[test]
    fn never_blocks_everything() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let m = setup(&net, &phi);
        let b = blocked_sets(&net, &phi, &m, 0);
        for i in 0..net.n() {
            assert!(
                b.data[i].iter().any(|&x| !x),
                "node {i} data plane fully blocked"
            );
            if i != 3 && !b.result[i].is_empty() {
                assert!(
                    b.result[i].iter().any(|&x| !x),
                    "node {i} result plane fully blocked"
                );
            }
        }
    }

    #[test]
    fn tagging_detects_improper_downstream() {
        // chain 0 -> 1 -> 2 active; marginals inverted on (1,2)
        let g = crate::graph::DiGraph::new(3, &[(0, 1), (1, 2)]);
        let active = vec![true, true];
        let marginal = vec![3.0, 1.0, 2.0]; // (1,2) improper: m[2] >= m[1]
        let tag = tagged_nodes(&g, &active, &marginal);
        assert!(tag[1], "node 1 owns the improper link");
        assert!(tag[0], "node 0 reaches it");
        assert!(!tag[2], "node 2 has no outgoing active links");
    }
}
