//! The per-node QP (15): a diagonally-scaled projection onto the simplex.
//!
//! Each SGP iteration solves, for every node/task/plane,
//!
//! ```text
//! min_v  δᵀ(v − φ) + (v − φ)ᵀ M (v − φ)
//! s.t.   Σ v_j = 1,  v ≥ 0,  v_j = 0 ∀ j ∈ blocked
//! ```
//!
//! with `M = diag(m)`, `m_j > 0`. Completing the square, this is the
//! weighted projection of the unconstrained minimizer
//! `y_j = φ_j − δ_j / (2 m_j)` onto the restricted simplex under the norm
//! `‖·‖_M`. The KKT solution is `v_j = max(0, y_j − λ/(2 m_j))` with `λ`
//! the multiplier of the sum constraint — a 1-D monotone root-finding
//! problem solved *exactly* by sorting breakpoints (the classic weighted
//! simplex-projection algorithm, cf. Held–Wolfe–Crowder), with a bisection
//! fallback exercised in tests for cross-validation.

use std::cmp::Ordering;

/// Reusable buffers for [`scaled_simplex_qp_into`] — one per worker
/// thread, owned by the optimizer workspace.
#[derive(Clone, Debug, Default)]
pub struct QpScratch {
    free: Vec<usize>,
    y: Vec<f64>,
    u: Vec<f64>,
    bps: Vec<(f64, usize)>,
}

/// Solve the scaled projection QP. `phi`, `delta`, `scale` are parallel
/// slot vectors; `blocked[j]` forces `v_j = 0`. `scale` entries must be
/// positive for unblocked slots (callers floor them at an epsilon).
///
/// Returns the new simplex vector `v` (sums to 1 over unblocked slots).
///
/// Panics if every slot is blocked.
pub fn scaled_simplex_qp(
    phi: &[f64],
    delta: &[f64],
    scale: &[f64],
    blocked: &[bool],
) -> Vec<f64> {
    let mut scratch = QpScratch::default();
    let mut v = Vec::new();
    scaled_simplex_qp_into(phi, delta, scale, blocked, &mut scratch, &mut v);
    v
}

/// [`scaled_simplex_qp`] into caller-owned scratch and output buffers —
/// allocation-free after warm-up, bitwise-identical result. The breakpoint
/// sort is a stable insertion sort under the same descending comparator,
/// so it yields exactly the permutation the allocating form's stable
/// `sort_by` produced (equal keys keep their relative order in both).
pub fn scaled_simplex_qp_into(
    phi: &[f64],
    delta: &[f64],
    scale: &[f64],
    blocked: &[bool],
    scratch: &mut QpScratch,
    v: &mut Vec<f64>,
) {
    let n = phi.len();
    assert_eq!(delta.len(), n);
    assert_eq!(scale.len(), n);
    assert_eq!(blocked.len(), n);
    let QpScratch { free, y, u, bps } = scratch;
    free.clear();
    free.extend((0..n).filter(|&j| !blocked[j]));
    assert!(!free.is_empty(), "all slots blocked");

    // Unconstrained minimizer y_j and its inverse weights u_j = 1/(2 m_j).
    // v_j(λ) = max(0, y_j − λ u_j) is non-increasing in λ; find λ* with
    // Σ v_j(λ*) = 1.
    y.clear();
    y.resize(n, 0.0);
    u.clear();
    u.resize(n, 0.0);
    for &j in free.iter() {
        debug_assert!(scale[j] > 0.0, "non-positive scale {} at slot {j}", scale[j]);
        u[j] = 1.0 / (2.0 * scale[j]);
        y[j] = phi[j] - delta[j] * u[j];
    }

    // Breakpoints: λ_j = y_j / u_j is where slot j hits zero.
    // Sort descending; scan adding slots to the active set.
    bps.clear();
    bps.extend(free.iter().map(|&j| (y[j] / u[j], j)));
    for i in 1..bps.len() {
        let mut k = i;
        while k > 0 && bps[k - 1].0.partial_cmp(&bps[k].0).unwrap() == Ordering::Less {
            bps.swap(k - 1, k);
            k -= 1;
        }
    }

    // With active set A: Σ_{j∈A} (y_j − λ u_j) = 1
    //   ⇒ λ = (Σ_A y_j − 1) / Σ_A u_j.
    // The correct active set is the largest prefix of the descending
    // breakpoint order whose induced λ keeps all prefix slots positive.
    let mut sum_y = 0.0;
    let mut sum_u = 0.0;
    let mut lambda = f64::NEG_INFINITY;
    for (k, &(bp, j)) in bps.iter().enumerate() {
        sum_y += y[j];
        sum_u += u[j];
        let cand = (sum_y - 1.0) / sum_u;
        // slot j stays nonnegative iff cand <= bp; the next breakpoint
        // (if any) must be <= cand for the prefix to be maximal.
        let next_bp = bps.get(k + 1).map(|p| p.0).unwrap_or(f64::NEG_INFINITY);
        if cand <= bp && cand >= next_bp {
            lambda = cand;
            break;
        }
    }
    if !lambda.is_finite() {
        // Breakpoint scan can miss a prefix under extreme scalings (ties,
        // near-infinite diagonals from saturated curvature). Bisection is
        // slower but unconditionally robust.
        lambda = bisect_lambda(y, u, free);
    }

    v.clear();
    v.resize(n, 0.0);
    let mut sum = 0.0;
    for &j in free.iter() {
        v[j] = (y[j] - lambda * u[j]).max(0.0);
        sum += v[j];
    }
    // Renormalize away accumulated floating-point error (sum ≈ 1).
    if sum > 0.0 {
        for &j in free.iter() {
            v[j] /= sum;
        }
    } else {
        // Degenerate: put everything on the min-δ free slot.
        let best = free
            .iter()
            .cloned()
            .min_by(|&a, &b| delta[a].partial_cmp(&delta[b]).unwrap())
            .unwrap();
        v[best] = 1.0;
    }
}

/// Bisection fallback for λ (cross-validation in tests + defensive path).
fn bisect_lambda(y: &[f64], u: &[f64], free: &[usize]) -> f64 {
    let total = |lam: f64| -> f64 {
        free.iter()
            .map(|&j| (y[j] - lam * u[j]).max(0.0))
            .sum::<f64>()
    };
    let mut lo = -1.0;
    let mut hi = 1.0;
    while total(lo) < 1.0 {
        lo *= 2.0;
        if lo < -1e18 {
            break;
        }
    }
    while total(hi) > 1.0 {
        hi *= 2.0;
        if hi > 1e18 {
            break;
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if total(mid) > 1.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Objective value of (15) at `v` — used by tests and the descent
/// safeguard: `δᵀ(v − φ) + (v − φ)ᵀ M (v − φ)`.
pub fn qp_objective(phi: &[f64], delta: &[f64], scale: &[f64], v: &[f64]) -> f64 {
    let mut obj = 0.0;
    for j in 0..phi.len() {
        let d = v[j] - phi[j];
        obj += delta[j] * d + scale[j] * d * d;
    }
    obj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    /// Brute-force grid minimizer over the restricted simplex (tests only).
    fn brute_force(
        phi: &[f64],
        delta: &[f64],
        scale: &[f64],
        blocked: &[bool],
        grid: usize,
    ) -> f64 {
        let n = phi.len();
        let free: Vec<usize> = (0..n).filter(|&j| !blocked[j]).collect();
        let mut best = f64::INFINITY;
        // enumerate compositions of `grid` over the free slots
        fn rec(
            free: &[usize],
            k: usize,
            left: usize,
            grid: usize,
            v: &mut Vec<f64>,
            best: &mut f64,
            phi: &[f64],
            delta: &[f64],
            scale: &[f64],
        ) {
            if k == free.len() - 1 {
                v[free[k]] = left as f64 / grid as f64;
                let obj = qp_objective(phi, delta, scale, v);
                if obj < *best {
                    *best = obj;
                }
                return;
            }
            for take in 0..=left {
                v[free[k]] = take as f64 / grid as f64;
                rec(free, k + 1, left - take, grid, v, best, phi, delta, scale);
            }
        }
        let mut v = vec![0.0; n];
        rec(
            &free, 0, grid, grid, &mut v, &mut best, phi, delta, scale,
        );
        best
    }

    fn check_kkt(v: &[f64], phi: &[f64], delta: &[f64], scale: &[f64], blocked: &[bool]) {
        // gradient of the QP at v: δ_j + 2 m_j (v_j − φ_j); optimality means
        // equal for all v_j > 0, and ≥ that level for v_j = 0.
        let grads: Vec<f64> = (0..v.len())
            .map(|j| delta[j] + 2.0 * scale[j] * (v[j] - phi[j]))
            .collect();
        let level = (0..v.len())
            .filter(|&j| !blocked[j] && v[j] > 1e-9)
            .map(|j| grads[j])
            .fold(f64::INFINITY, f64::min);
        for j in 0..v.len() {
            if blocked[j] {
                assert_eq!(v[j], 0.0);
            } else if v[j] > 1e-9 {
                assert!(
                    (grads[j] - level).abs() < 1e-6,
                    "active slot {j} grad {} vs level {level}",
                    grads[j]
                );
            } else {
                assert!(
                    grads[j] >= level - 1e-6,
                    "inactive slot {j} grad {} below level {level}",
                    grads[j]
                );
            }
        }
    }

    #[test]
    fn stays_on_simplex() {
        let phi = [0.5, 0.3, 0.2];
        let delta = [1.0, 2.0, 0.5];
        let scale = [1.0, 1.0, 1.0];
        let blocked = [false, false, false];
        let v = scaled_simplex_qp(&phi, &delta, &scale, &blocked);
        assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(v.iter().all(|&x| x >= 0.0));
        check_kkt(&v, &phi, &delta, &scale, &blocked);
    }

    #[test]
    fn blocked_slots_zeroed() {
        let phi = [0.5, 0.5, 0.0];
        let delta = [0.1, 5.0, -10.0];
        let scale = [1.0, 1.0, 1.0];
        let blocked = [false, false, true];
        let v = scaled_simplex_qp(&phi, &delta, &scale, &blocked);
        assert_eq!(v[2], 0.0);
        assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // strong pull toward slot 0 (lower δ)
        assert!(v[0] > phi[0]);
    }

    #[test]
    fn zero_step_when_already_optimal() {
        // equal marginals: current point is optimal, v == φ
        let phi = [0.25, 0.75];
        let delta = [1.0, 1.0];
        let scale = [2.0, 2.0];
        let v = scaled_simplex_qp(&phi, &delta, &scale, &[false, false]);
        assert!((v[0] - 0.25).abs() < 1e-9 && (v[1] - 0.75).abs() < 1e-9);
    }

    #[test]
    fn single_free_slot() {
        let v = scaled_simplex_qp(
            &[0.2, 0.8],
            &[3.0, 1.0],
            &[1.0, 1.0],
            &[true, false],
        );
        assert_eq!(v, vec![0.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn all_blocked_panics() {
        scaled_simplex_qp(&[1.0], &[0.0], &[1.0], &[true]);
    }

    #[test]
    fn matches_brute_force_small() {
        let mut rng = Pcg::new(21);
        for trial in 0..50 {
            let n = rng.int_range(2, 4);
            let mut phi: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let s: f64 = phi.iter().sum();
            phi.iter_mut().for_each(|x| *x /= s);
            let delta: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 5.0)).collect();
            let scale: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 3.0)).collect();
            let blocked = vec![false; n];
            let v = scaled_simplex_qp(&phi, &delta, &scale, &blocked);
            let exact = qp_objective(&phi, &delta, &scale, &v);
            let grid = brute_force(&phi, &delta, &scale, &blocked, 60);
            assert!(
                exact <= grid + 1e-3,
                "trial {trial}: exact {exact} worse than grid {grid}"
            );
            check_kkt(&v, &phi, &delta, &scale, &blocked);
        }
    }

    #[test]
    fn matches_bisection_fallback() {
        let mut rng = Pcg::new(22);
        for _ in 0..200 {
            let n = rng.int_range(2, 8);
            let mut phi: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let s: f64 = phi.iter().sum();
            phi.iter_mut().for_each(|x| *x /= s);
            let delta: Vec<f64> = (0..n).map(|_| rng.uniform(-3.0, 6.0)).collect();
            let scale: Vec<f64> = (0..n).map(|_| rng.uniform(0.05, 4.0)).collect();
            let mut blocked = vec![false; n];
            // randomly block some slots but keep at least one free
            for b in blocked.iter_mut() {
                *b = rng.chance(0.25);
            }
            if blocked.iter().all(|&b| b) {
                blocked[0] = false;
            }
            // zero out blocked φ mass and renormalize onto free slots
            let mut phi2 = phi.clone();
            let mut free_mass = 0.0;
            for j in 0..n {
                if blocked[j] {
                    phi2[j] = 0.0;
                } else {
                    free_mass += phi2[j];
                }
            }
            if free_mass == 0.0 {
                continue;
            }
            for j in 0..n {
                phi2[j] /= free_mass;
            }

            let v = scaled_simplex_qp(&phi2, &delta, &scale, &blocked);

            // cross-validate λ via bisection path
            let free: Vec<usize> = (0..n).filter(|&j| !blocked[j]).collect();
            let mut y = vec![0.0; n];
            let mut u = vec![0.0; n];
            for &j in &free {
                u[j] = 1.0 / (2.0 * scale[j]);
                y[j] = phi2[j] - delta[j] * u[j];
            }
            let lam = bisect_lambda(&y, &u, &free);
            for &j in &free {
                let vb = (y[j] - lam * u[j]).max(0.0);
                assert!(
                    (v[j] - vb).abs() < 1e-6,
                    "slot {j}: exact {} vs bisect {vb}",
                    v[j]
                );
            }
        }
    }

    #[test]
    fn into_form_reuse_is_bitwise_identical() {
        let mut rng = Pcg::new(77);
        let mut scratch = QpScratch::default();
        let mut out = Vec::new();
        for trial in 0..300 {
            let n = rng.int_range(1, 9);
            let mut phi: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let s: f64 = phi.iter().sum();
            if s == 0.0 {
                continue;
            }
            phi.iter_mut().for_each(|x| *x /= s);
            // discrete values on some trials force duplicate breakpoints,
            // exercising sort stability
            let discrete = trial % 3 == 0;
            let delta: Vec<f64> = (0..n)
                .map(|_| {
                    if discrete {
                        rng.int_range(0, 3) as f64
                    } else {
                        rng.uniform(-2.0, 5.0)
                    }
                })
                .collect();
            let scale: Vec<f64> = (0..n)
                .map(|_| {
                    if discrete {
                        1.0
                    } else {
                        rng.uniform(0.1, 3.0)
                    }
                })
                .collect();
            let mut blocked = vec![false; n];
            for b in blocked.iter_mut() {
                *b = rng.chance(0.2);
            }
            if blocked.iter().all(|&b| b) {
                blocked[0] = false;
            }
            let fresh = scaled_simplex_qp(&phi, &delta, &scale, &blocked);
            // reused (dirty) scratch must reproduce the fresh result bitwise
            scaled_simplex_qp_into(&phi, &delta, &scale, &blocked, &mut scratch, &mut out);
            assert_eq!(fresh.len(), out.len(), "trial {trial}");
            for j in 0..n {
                assert_eq!(
                    fresh[j].to_bits(),
                    out[j].to_bits(),
                    "trial {trial} slot {j}"
                );
            }
        }
    }

    #[test]
    fn descent_direction_property() {
        // the QP solution never increases the local linear model δᵀ(v−φ)
        // beyond zero: δᵀ(v−φ) + quadratic ≤ 0 at the optimum since v=φ is
        // feasible with objective 0.
        let mut rng = Pcg::new(23);
        for _ in 0..100 {
            let n = rng.int_range(2, 6);
            let mut phi: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let s: f64 = phi.iter().sum();
            phi.iter_mut().for_each(|x| *x /= s);
            let delta: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 5.0)).collect();
            let scale: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
            let v = scaled_simplex_qp(&phi, &delta, &scale, &vec![false; n]);
            let obj = qp_objective(&phi, &delta, &scale, &v);
            assert!(obj <= 1e-10, "objective {obj} should be ≤ 0");
        }
    }
}
