//! Optimization algorithms: the paper's SGP (Algorithm 1) and the §V
//! baselines (GP, SPOO, LCOR, LPR), over a common [`Optimizer`] interface,
//! plus the numerical substrates they need (simplex projection QP, blocked
//! sets, a dense LP solver).

pub mod blocked;
pub mod gp;
pub mod lcor;
pub mod lp;
pub mod lpr;
pub mod sgp;
pub mod simplex_qp;
pub mod spoo;
pub mod workspace;

use anyhow::Result;

use crate::model::network::Network;
use crate::model::strategy::Strategy;

/// Per-iteration progress of an optimizer.
#[derive(Clone, Copy, Debug)]
pub struct IterationStats {
    /// Total cost `T` after the iteration.
    pub total_cost: f64,
    /// Theorem-1 complementarity residual after the iteration (0 ⇔ the
    /// sufficient global-optimality conditions hold).
    pub residual: f64,
}

/// A routing/offloading optimizer stepping a strategy in place.
pub trait Optimizer {
    fn name(&self) -> &'static str;

    /// One synchronous network-wide iteration.
    fn step(&mut self, net: &Network, phi: &mut Strategy) -> Result<IterationStats>;

    /// [`Optimizer::step`] with a caller-owned [`OptWorkspace`] scratch
    /// arena, reused across iterations so the hot path is
    /// allocation-free after warm-up. Results are bitwise identical to
    /// `step`. Optimizers without a workspace-aware path fall back to
    /// `step` and ignore the workspace.
    fn step_ws(
        &mut self,
        net: &Network,
        phi: &mut Strategy,
        ws: &mut workspace::OptWorkspace,
    ) -> Result<IterationStats> {
        let _ = ws;
        self.step(net, phi)
    }
}

pub use gp::Gp;
pub use lcor::lcor_optimizer;
pub use lpr::Lpr;
pub use sgp::{Restriction, Sgp};
pub use spoo::spoo_optimizer;
pub use workspace::OptWorkspace;
