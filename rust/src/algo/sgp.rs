//! Scaled Gradient Projection (Algorithm 1) — the paper's optimizer —
//! plus the restriction hooks that turn it into the SPOO and LCOR
//! baselines.
//!
//! Per synchronous iteration:
//!
//! 1. compute flows and marginals (`δ±`, `h±` — the centralized mirror of
//!    the two-stage broadcast);
//! 2. compute blocked sets per task/plane;
//! 3. per node/task/plane, build the diagonal scaling matrix (16) and
//!    solve the projection QP (15);
//! 4. **descent safeguard**: accept the joint update only if it stays
//!    loop-free and does not increase `T`; otherwise retry with the
//!    scaling inflated (step shrunk), which preserves Theorem 2's
//!    monotone descent even under the heuristic curvature bound used for
//!    the local-computation slot (the paper's eq. 16 only covers link
//!    entries; see DESIGN.md §3.3).
//!
//! The hot path is allocation-free after warm-up: all intermediate state
//! lives in a caller-owned [`OptWorkspace`] threaded through
//! [`Optimizer::step_ws`] / [`Sgp::update_single_node_ws`]. The
//! workspace changes only where values live, never the floating-point
//! operation order, so results are bitwise identical to the allocating
//! wrappers (pinned by `tests/opt_workspace.rs`).
//!
//! Asynchronous (one node at a time) updates — Theorem 2's schedule — are
//! driven by `sim::async_run` through [`Sgp::update_single_node`].

use anyhow::{bail, Result};

use crate::graph::algorithms::has_cycle_masked_into;
use crate::model::flows::{
    compute_flows_with, recompute_task_flows_with, refresh_total_cost, FlowState,
};
use crate::model::marginals::{
    compute_marginals_into, delta_minus_into, delta_plus_into, theorem1_residual,
    theorem1_residual_with, MargView, Marginals,
};
use crate::model::network::Network;
use crate::model::strategy::Strategy;

use super::blocked::{blocked_rows_for_node_into, blocked_sets, plane_tags_into, BlockedSets};
use super::simplex_qp::scaled_simplex_qp_into;
use super::workspace::{OptWorkspace, ProposeBufs};
use super::{IterationStats, Optimizer};

/// Which planes an optimizer instance may update — the restriction hook
/// reused by the SPOO (data offloading only) and LCOR (result routing
/// only) baselines.
#[derive(Clone, Debug, Default)]
pub struct Restriction {
    /// Do not update the data plane at all.
    pub freeze_data: bool,
    /// Do not update the result plane at all.
    pub freeze_result: bool,
    /// Additional permanently-blocked data slots `[task][node][slot]`
    /// (slot 0 = local computation).
    pub extra_blocked_data: Option<Vec<Vec<Vec<bool>>>>,
}

/// Scaled gradient projection optimizer state.
pub struct Sgp {
    /// Floor for scaling-matrix diagonals (keeps the QP strictly convex on
    /// linear-cost networks where `A ≡ 0`, and makes zero-traffic nodes
    /// take the full jump to their min-marginal slot — the behaviour
    /// Theorem 1 needs from zero-traffic nodes).
    pub min_scale: f64,
    /// Enable the descent safeguard (ablation switch).
    pub safeguard: bool,
    /// Plane restrictions (SPOO / LCOR reuse).
    pub restriction: Restriction,
    /// Count of safeguard step-shrink retries across the run.
    pub retries: usize,
    /// Count of loop-rollback events (should stay 0; tested).
    pub rollbacks: usize,
    /// Recompute marginals + improper tags every `marg_refresh` node
    /// positions of the Gauss–Seidel sweep (1 = every position). The
    /// distributed algorithm broadcasts once per iteration, so values a
    /// few positions stale are faithful to the paper; the explicit cycle
    /// check in the safeguard keeps loop-freedom sound regardless, and
    /// the descent test keeps monotonicity. Values of 4–8 cut the sweep
    /// cost substantially at SW scale (EXPERIMENTS.md §Perf).
    /// `0` = auto: every position on small networks (where marginals move
    /// fast and staleness costs retries), every `N/25` positions on large
    /// ones.
    pub marg_refresh: usize,
    /// Adaptive trust factor multiplying the eq-16 scaling matrices.
    ///
    /// Eq. 16 is a *majorization* bound built from the worst-case global
    /// curvature `A(T⁰)`; on heterogeneous-capacity networks (one
    /// tiny-capacity link makes `A(T⁰)` enormous) it is severely
    /// conservative and the projected steps all but vanish. Because the
    /// descent safeguard independently guarantees `T^{t+1} ≤ T^t`, the
    /// scaling only needs to be a good *step-size heuristic*: we start
    /// each iteration at `trust × (eq-16 scale)` with `trust ≤ 1`, inflate
    /// by 4× on each safeguard rejection (never exceeding the provably
    /// safe eq-16 level and beyond), and let `trust` adapt between
    /// iterations toward the largest step the safeguard accepts.
    trust: f64,
}

/// Put the node's saved plane rows back into `phi` — the row-level
/// rollback of a rejected Gauss–Seidel attempt.
fn restore_rows(
    phi: &mut Strategy,
    node: usize,
    saved_data: &[Vec<f64>],
    saved_result: &[Vec<f64>],
) {
    for s in 0..saved_data.len() {
        phi.data[s][node].clone_from(&saved_data[s]);
        phi.result[s][node].clone_from(&saved_result[s]);
    }
}

impl Sgp {
    pub fn new() -> Sgp {
        Sgp {
            min_scale: 1e-6,
            safeguard: true,
            restriction: Restriction::default(),
            retries: 0,
            rollbacks: 0,
            marg_refresh: 0,
            trust: 1e-2,
        }
    }

    pub fn with_restriction(restriction: Restriction) -> Sgp {
        Sgp {
            restriction,
            ..Sgp::new()
        }
    }

    /// Does `cand` differ from `phi` in any block that currently carries
    /// traffic? Equal-cost candidates are accepted only when this is
    /// false: re-pointing zero-traffic blocks is free and *required* for
    /// Theorem-1 optimality (zero-traffic nodes must aim at their
    /// min-marginal neighbor — the Fig. 3 gap), while equal-cost changes
    /// to loaded blocks are plateau swaps that would cycle forever (e.g.
    /// flipping all result flow between two symmetric equal-cost paths).
    fn positive_traffic_changed(
        net: &Network,
        flows: &FlowState,
        phi: &Strategy,
        cand: &Strategy,
    ) -> bool {
        const TRAFFIC_EPS: f64 = 1e-12;
        for s in 0..net.s() {
            for i in 0..net.n() {
                if flows.t_minus[s][i] > TRAFFIC_EPS
                    && phi.data[s][i] != cand.data[s][i]
                {
                    return true;
                }
                if flows.t_plus[s][i] > TRAFFIC_EPS
                    && phi.result[s][i] != cand.result[s][i]
                {
                    return true;
                }
            }
        }
        false
    }

    /// The safeguard acceptance rule: strict descent for changes to
    /// loaded blocks; free (equal-cost, within `slack`) moves allowed only
    /// on zero-traffic blocks. With `safeguard` disabled (ablation), any
    /// finite candidate is accepted.
    fn accepts(
        &self,
        net: &Network,
        flows: &FlowState,
        phi: &Strategy,
        cand: &Strategy,
        cand_cost: f64,
        slack: f64,
    ) -> bool {
        if !self.safeguard {
            return true;
        }
        if cand_cost < flows.total_cost - slack {
            return true;
        }
        cand_cost <= flows.total_cost + slack
            && !Self::positive_traffic_changed(net, flows, phi, cand)
    }

    /// Scaling-matrix diagonal for the data plane of `(task, node)`, into
    /// a caller-owned buffer aligned with the strategy slot layout —
    /// allocation-free after warm-up.
    ///
    /// Eq. 16 builds the diagonal from worst-case curvature bounds
    /// `A_ij(T⁰)`; we use the *current* second derivatives instead
    /// (`D''(F_ij)`, `C''(G_i)` — the Bertsekas–Gafni–Gallager
    /// second-derivative scaling the paper's reference [25] uses), with
    /// the same `(1 + h)` path-length amplification to account for
    /// curvature accumulated along downstream paths. The global `A(T⁰)`
    /// bound is dramatically over-conservative on heterogeneous-capacity
    /// networks (one tiny-capacity link dominates the max and freezes all
    /// steps); the descent safeguard + trust adaptation supply the
    /// convergence guarantee the bound was providing. See DESIGN.md §3.3.
    fn data_scale_into<M: MargView + ?Sized>(
        &self,
        net: &Network,
        flows: &FlowState,
        marg: &M,
        task: usize,
        node: usize,
        inflate: f64,
        out: &mut Vec<f64>,
    ) {
        let g = &net.graph;
        let t_i = flows.t_minus[task][node];
        let a_m = net.a_of(task);
        let w_im = net.w_of(node, task);
        out.clear();
        out.reserve(g.out_degree(node) + 1);
        // slot 0: local computation. Curvature from C'' (chain factor w²)
        // plus the induced result-plane curvature (chain factor a_m²)
        // accumulated along the node's result path.
        let d2_comp = net.comp_cost[node].second_deriv(flows.workload[node]);
        let out_d2_max = g
            .out_edge_ids(node)
            .iter()
            .map(|&eid| net.link_cost[eid].second_deriv(flows.link_flow[eid]))
            .fold(0.0f64, f64::max);
        let comp_entry = w_im * w_im * d2_comp
            + a_m * a_m * (1.0 + marg.h_plus_task(task)[node] as f64) * out_d2_max;
        out.push(self.floor(t_i / 2.0 * inflate * comp_entry, inflate));
        let h_minus = marg.h_minus_task(task);
        for &eid in g.out_edge_ids(node) {
            let j = g.edge(eid).dst;
            let d2 = net.link_cost[eid].second_deriv(flows.link_flow[eid]);
            let entry = d2 * (1.0 + h_minus[j] as f64);
            out.push(self.floor(t_i / 2.0 * inflate * entry, inflate));
        }
    }

    /// Scaling-matrix diagonal for the result plane (same construction on
    /// `t⁺` and `h⁺`), into a caller-owned buffer.
    fn result_scale_into<M: MargView + ?Sized>(
        &self,
        net: &Network,
        flows: &FlowState,
        marg: &M,
        task: usize,
        node: usize,
        inflate: f64,
        out: &mut Vec<f64>,
    ) {
        let g = &net.graph;
        let t_i = flows.t_plus[task][node];
        out.clear();
        out.reserve(g.out_degree(node));
        let h_plus = marg.h_plus_task(task);
        for &eid in g.out_edge_ids(node) {
            let j = g.edge(eid).dst;
            let d2 = net.link_cost[eid].second_deriv(flows.link_flow[eid]);
            let entry = d2 * (1.0 + h_plus[j] as f64);
            out.push(self.floor(t_i / 2.0 * inflate * entry, inflate));
        }
    }

    fn floor(&self, x: f64, inflate: f64) -> f64 {
        // Upper clamp keeps the QP solvable when curvature blows up near
        // a capacity pole (D'' → ∞ would zero the step *and* break the
        // breakpoint arithmetic).
        x.max(self.min_scale * inflate).min(1e12)
    }

    /// One tentative joint (all nodes, all tasks) update with the given
    /// scaling inflation, written into the pooled candidate `cand`
    /// (`clone_from(phi)` then row-wise QP overwrites — no per-candidate
    /// strategy allocation once the pool is warm).
    #[allow(clippy::too_many_arguments)]
    fn propose_into<M: MargView + ?Sized>(
        &self,
        net: &Network,
        phi: &Strategy,
        flows: &FlowState,
        marg: &M,
        blocked_all: &[BlockedSets],
        inflate: f64,
        bufs: &mut ProposeBufs,
        cand: &mut Strategy,
    ) {
        cand.clone_from(phi);
        let ProposeBufs {
            delta,
            scale,
            blocked: blocked_buf,
            qp,
        } = bufs;
        for s in 0..net.s() {
            let blocked = &blocked_all[s];
            for i in 0..net.n() {
                if !self.restriction.freeze_data {
                    blocked_buf.clone_from(&blocked.data[i]);
                    if let Some(extra) = &self.restriction.extra_blocked_data {
                        for (b, &x) in blocked_buf.iter_mut().zip(&extra[s][i]) {
                            *b |= x;
                        }
                    }
                    // keep currently-active slots available even under
                    // extra restrictions (they hold mass)
                    for (slot, b) in blocked_buf.iter_mut().enumerate() {
                        if phi.data[s][i][slot] > 0.0 {
                            *b = false;
                        }
                    }
                    if blocked_buf.iter().any(|&b| !b) {
                        delta_minus_into(marg, net, s, i, delta);
                        self.data_scale_into(net, flows, marg, s, i, inflate, scale);
                        scaled_simplex_qp_into(
                            &phi.data[s][i],
                            delta,
                            scale,
                            blocked_buf,
                            qp,
                            &mut cand.data[s][i],
                        );
                    }
                }
                if !self.restriction.freeze_result
                    && i != net.tasks[s].dest
                    && net.graph.out_degree(i) > 0
                {
                    let blocked_slots = &blocked.result[i];
                    if blocked_slots.iter().any(|&b| !b) {
                        delta_plus_into(marg, net, s, i, delta);
                        self.result_scale_into(net, flows, marg, s, i, inflate, scale);
                        scaled_simplex_qp_into(
                            &phi.result[s][i],
                            delta,
                            scale,
                            blocked_slots,
                            qp,
                            &mut cand.result[s][i],
                        );
                    }
                }
            }
        }
    }

    /// Asynchronous single-node update (Theorem 2 schedule): recompute the
    /// global state, then update only `(node, task, plane)`.
    /// `plane_result=false` updates the data plane.
    ///
    /// Allocating wrapper over [`Sgp::update_single_node_ws`].
    pub fn update_single_node(
        &mut self,
        net: &Network,
        phi: &mut Strategy,
        node: usize,
        task: usize,
        plane_result: bool,
    ) -> Result<f64> {
        let mut ws = OptWorkspace::new();
        self.update_single_node_ws(net, phi, node, task, plane_result, &mut ws)
    }

    /// [`Sgp::update_single_node`] with a caller-owned workspace —
    /// allocation-free after warm-up, bitwise-identical updates. The
    /// candidate row is projected in place (the QP input is the saved
    /// row, constant across the retry ladder, exactly as the cloning
    /// form's input was) and priced through the workspace's shadow flow
    /// state; a failed ladder restores the saved row.
    pub fn update_single_node_ws(
        &mut self,
        net: &Network,
        phi: &mut Strategy,
        node: usize,
        task: usize,
        plane_result: bool,
        ws: &mut OptWorkspace,
    ) -> Result<f64> {
        ws.ensure(net);
        let OptWorkspace {
            flows,
            shadow,
            flow_scratch,
            marg,
            tags,
            block_scratch,
            node_blocked,
            saved_data,
            saved_result,
            bufs,
            ..
        } = ws;
        let ProposeBufs {
            delta, scale, qp, ..
        } = bufs;

        compute_flows_with(net, phi, flows, flow_scratch).map_err(anyhow::Error::new)?;
        if !flows.total_cost.is_finite() {
            bail!("infinite cost at async update start");
        }
        compute_marginals_into(net, phi, flows, marg).map_err(anyhow::Error::new)?;
        if plane_result && (node == net.tasks[task].dest || net.graph.out_degree(node) == 0) {
            return Ok(flows.total_cost);
        }
        plane_tags_into(net, phi, marg, task, block_scratch, &mut tags[task]);
        blocked_rows_for_node_into(net, phi, marg, &tags[task], task, node, &mut node_blocked[task]);

        // The QP input of every ladder attempt is the *current* row; save
        // it once (the in-place projection overwrites the live row).
        if plane_result {
            saved_result[task].clone_from(&phi.result[task][node]);
        } else {
            saved_data[task].clone_from(&phi.data[task][node]);
        }

        let mut inflate = self.trust;
        for _attempt in 0..40 {
            if plane_result {
                delta_plus_into(marg, net, task, node, delta);
                self.result_scale_into(net, flows, marg, task, node, inflate, scale);
                scaled_simplex_qp_into(
                    &saved_result[task],
                    delta,
                    scale,
                    &node_blocked[task].result,
                    qp,
                    &mut phi.result[task][node],
                );
            } else {
                delta_minus_into(marg, net, task, node, delta);
                self.data_scale_into(net, flows, marg, task, node, inflate, scale);
                scaled_simplex_qp_into(
                    &saved_data[task],
                    delta,
                    scale,
                    &node_blocked[task].data,
                    qp,
                    &mut phi.data[task][node],
                );
            }
            let priced = match compute_flows_with(net, phi, shadow, flow_scratch) {
                Ok(()) => shadow.total_cost.is_finite(),
                Err(_) => false,
            };
            if priced {
                // Safeguard acceptance, specialized to a single changed
                // row: the candidate differs from the saved strategy only
                // at `(task, node, plane)`, so the loaded-block test of
                // `positive_traffic_changed` reduces to that one row.
                let cand_cost = shadow.total_cost;
                let accept = if !self.safeguard {
                    true
                } else if cand_cost < flows.total_cost - 1e-12 {
                    true
                } else if cand_cost <= flows.total_cost + 1e-12 {
                    let changed = if plane_result {
                        flows.t_plus[task][node] > 1e-12
                            && phi.result[task][node] != saved_result[task]
                    } else {
                        flows.t_minus[task][node] > 1e-12
                            && phi.data[task][node] != saved_data[task]
                    };
                    !changed
                } else {
                    false
                };
                if accept {
                    return Ok(cand_cost);
                }
            }
            self.retries += 1;
            inflate *= 4.0;
        }
        // No improving step found: keep the current point.
        if plane_result {
            phi.result[task][node].clone_from(&saved_result[task]);
        } else {
            phi.data[task][node].clone_from(&saved_data[task]);
        }
        Ok(flows.total_cost)
    }
}

impl Sgp {
    /// One synchronous iteration with flows + marginals evaluated by a
    /// pluggable [`crate::runtime::DenseBackend`] — the accelerated hot
    /// path. The default backend is the pure-rust
    /// [`crate::runtime::NativeBackend`]; with the `pjrt` cargo feature
    /// the AOT `dense_eval` artifact (XLA data plane) drops in instead.
    /// The control plane (blocked sets, scaling, QP, safeguard) stays in
    /// rust; candidate costs inside the safeguard are also priced by the
    /// backend.
    ///
    /// Allocating wrapper over [`Sgp::step_dense_ws`].
    pub fn step_dense(
        &mut self,
        net: &Network,
        phi: &mut Strategy,
        evaluator: &dyn crate::runtime::DenseBackend,
    ) -> Result<IterationStats> {
        let mut ws = OptWorkspace::new();
        self.step_dense_ws(net, phi, evaluator, &mut ws)
    }

    /// [`Sgp::step_dense`] with a caller-owned workspace: the ladder's
    /// candidate strategies come from the workspace pool (`clone_from`
    /// reuse) and each row projection runs through the shared QP buffers.
    /// Backend evaluations still allocate (their output crosses an FFI
    /// boundary on accelerated backends); the dense path is not under the
    /// zero-allocation contract, only the sparse sweep is.
    pub fn step_dense_ws(
        &mut self,
        net: &Network,
        phi: &mut Strategy,
        evaluator: &dyn crate::runtime::DenseBackend,
        ws: &mut OptWorkspace,
    ) -> Result<IterationStats> {
        use crate::graph::algorithms::longest_path_to_sink;

        ws.ensure(net);
        let cand_pool = &mut ws.cand_pool;
        let bufs = &mut ws.bufs;

        let assemble = |ev: crate::runtime::DenseEval,
                        phi: &Strategy|
         -> Result<(FlowState, Marginals)> {
            // h± are pure graph DPs over the φ-active masks — cheap native.
            let mut h_plus = Vec::with_capacity(net.s());
            let mut h_minus = Vec::with_capacity(net.s());
            for s in 0..net.s() {
                h_plus.push(
                    longest_path_to_sink(&net.graph, &phi.result_active_mask(net, s))
                        .ok_or_else(|| anyhow::anyhow!("result loop in task {s}"))?,
                );
                h_minus.push(
                    longest_path_to_sink(&net.graph, &phi.data_active_mask(net, s))
                        .ok_or_else(|| anyhow::anyhow!("data loop in task {s}"))?,
                );
            }
            let flows = FlowState {
                t_minus: ev.t_minus,
                t_plus: ev.t_plus,
                // per-edge/per-task splits are implied by (t, φ) and not
                // needed by the update; left empty in the dense path.
                g: vec![],
                f_minus: vec![],
                f_plus: vec![],
                link_flow: ev.link_flow,
                workload: ev.workload,
                total_cost: ev.total_cost,
            };
            let marg = Marginals {
                d_link: ev.d_link,
                c_node: ev.c_node,
                dt_plus: ev.dt_plus,
                dt_r: ev.dt_r,
                h_plus,
                h_minus,
            };
            Ok((flows, marg))
        };

        let (flows, marg) = assemble(evaluator.evaluate(net, phi)?, phi)?;
        if !flows.total_cost.is_finite() {
            bail!("initial strategy has infinite cost (dense path)");
        }
        let blocked_all: Vec<BlockedSets> = (0..net.s())
            .map(|s| blocked_sets(net, phi, &marg, s))
            .collect();

        // Safeguard retry ladder, priced in *batches*: the first probe (the
        // adapted trust step, accepted in the common case) goes alone, and
        // every retry round prices `RETRY_BATCH` escalating-inflation
        // candidates through one `evaluate_batch` call instead of N
        // sequential `evaluate` calls. Candidates are scanned in ladder
        // order, so the accepted step (and the retry/rollback counters up
        // to it) are exactly those of the sequential ladder.
        const MAX_ATTEMPTS: usize = 40;
        const RETRY_BATCH: usize = 4;
        // f32 data plane: allow relative rounding slack in the descent
        // test (see DESIGN.md §3.7).
        let slack = 1e-5 * flows.total_cost.abs().max(1.0);

        let mut inflate = self.trust;
        let mut attempts = 0usize;
        let mut accepted: Option<(crate::runtime::DenseEval, f64, usize)> = None;
        while attempts < MAX_ATTEMPTS && accepted.is_none() {
            let chunk = if attempts == 0 { 1 } else { RETRY_BATCH };
            // (inflation, 1-based attempt index) per batched candidate
            let mut meta: Vec<(f64, usize)> = Vec::with_capacity(chunk);
            // attempt indices of loop-forming (dropped) candidates; the
            // sequential ladder would only have proposed those *before*
            // the accepted attempt, so rollbacks are tallied after the
            // scan decides where acceptance lands.
            let mut loop_attempts: Vec<usize> = Vec::new();
            let mut batch_len = 0usize;
            while batch_len < chunk && attempts < MAX_ATTEMPTS {
                attempts += 1;
                if cand_pool.len() == batch_len {
                    cand_pool.push(phi.clone());
                }
                let cand = &mut cand_pool[batch_len];
                self.propose_into(net, phi, &flows, &marg, &blocked_all, inflate, bufs, cand);
                let cand_inflate = inflate;
                inflate *= 4.0;
                if !cand.is_loop_free(net) {
                    loop_attempts.push(attempts);
                    continue;
                }
                meta.push((cand_inflate, attempts));
                batch_len += 1;
            }
            let mut evals = evaluator.evaluate_batch(net, &cand_pool[..batch_len])?;
            let mut chosen: Option<usize> = None;
            for k in 0..batch_len {
                let cand_cost = evals[k].total_cost;
                if cand_cost.is_finite()
                    && self.accepts(net, &flows, phi, &cand_pool[k], cand_cost, slack)
                {
                    chosen = Some(k);
                    break;
                }
                self.retries += 1;
            }
            let accepted_attempt = chosen.map(|k| meta[k].1).unwrap_or(usize::MAX);
            self.rollbacks += loop_attempts
                .iter()
                .filter(|&&a| a < accepted_attempt)
                .count();
            if let Some(k) = chosen {
                phi.clone_from(&cand_pool[k]);
                accepted = Some((evals.swap_remove(k), meta[k].0, meta[k].1));
            }
        }

        // Final stats: the accepted candidate's evaluation *is* the state
        // of the updated φ, so it is reused instead of re-evaluating (and
        // with no accepted step, φ — hence `flows`/`marg` — is unchanged).
        let (total, marg2) = match accepted {
            Some((ev, acc_inflate, acc_attempt)) => {
                self.trust = if acc_attempt == 1 {
                    (self.trust * 0.5).max(1e-5)
                } else {
                    (acc_inflate * 0.25).min(1e6)
                };
                let total = ev.total_cost;
                let (_, marg2) = assemble(ev, phi)?;
                (total, marg2)
            }
            None => (flows.total_cost, marg),
        };
        Ok(IterationStats {
            total_cost: total,
            residual: theorem1_residual(net, phi, &marg2),
        })
    }
}

impl Default for Sgp {
    fn default() -> Self {
        Sgp::new()
    }
}

impl Optimizer for Sgp {
    fn name(&self) -> &'static str {
        "sgp"
    }

    /// Allocating wrapper over [`Optimizer::step_ws`] with a throwaway
    /// workspace — identical results; use `step_ws` with a persistent
    /// workspace on hot paths.
    fn step(&mut self, net: &Network, phi: &mut Strategy) -> Result<IterationStats> {
        let mut ws = OptWorkspace::new();
        self.step_ws(net, phi, &mut ws)
    }

    /// One iteration = one **Gauss–Seidel sweep**: every node solves its
    /// individual QP (15) against *fresh* flows and marginals (the
    /// distributed algorithm re-broadcasts between individual updates —
    /// Theorem 2's asynchronous schedule; a Jacobi all-at-once update is
    /// only stable with far smaller steps). Each node's joint
    /// (all tasks, both planes) update passes the descent safeguard
    /// before the sweep moves on.
    ///
    /// The entire sweep runs out of the workspace arena: flat marginal
    /// tables, per-node blocked rows, row-save buffers, QP scratch, and a
    /// double-buffered flow pair for the safeguard's exact rollback. In
    /// steady state (workspace warm, shapes unchanged) the per-node inner
    /// loop performs **zero heap allocations**.
    fn step_ws(
        &mut self,
        net: &Network,
        phi: &mut Strategy,
        ws: &mut OptWorkspace,
    ) -> Result<IterationStats> {
        ws.ensure(net);
        let OptWorkspace {
            flows,
            shadow,
            flow_scratch,
            marg,
            tags,
            block_scratch,
            node_blocked,
            saved_data,
            saved_result,
            bufs,
            added_data,
            added_result,
            task_dirty,
            dirty,
            mask,
            topo,
            order,
            ..
        } = ws;
        let ProposeBufs {
            delta,
            scale,
            blocked: blocked_buf,
            qp,
        } = bufs;

        compute_flows_with(net, phi, flows, flow_scratch).map_err(anyhow::Error::new)?;
        if !flows.total_cost.is_finite() {
            bail!("initial strategy has infinite cost");
        }

        let refresh = if self.marg_refresh == 0 {
            (net.n() / 25).max(1)
        } else {
            self.marg_refresh
        };
        compute_marginals_into(net, phi, flows, marg).map_err(anyhow::Error::new)?;
        for s in 0..net.s() {
            plane_tags_into(net, phi, marg, s, block_scratch, &mut tags[s]);
        }
        for node in 0..net.n() {
            if node > 0 && node % refresh == 0 {
                compute_marginals_into(net, phi, flows, marg).map_err(anyhow::Error::new)?;
                for s in 0..net.s() {
                    plane_tags_into(net, phi, marg, s, block_scratch, &mut tags[s]);
                }
            }
            // Only this node's blocked rows are needed (O(deg) given tags).
            for s in 0..net.s() {
                blocked_rows_for_node_into(
                    net,
                    phi,
                    marg,
                    &tags[s],
                    s,
                    node,
                    &mut node_blocked[s],
                );
            }

            // A node's candidate differs from φ only in its own rows, so
            // the safeguard swaps rows in place instead of cloning the
            // whole strategy (a 100×+ memory-traffic saving at SW scale —
            // EXPERIMENTS.md §Perf).
            for s in 0..net.s() {
                saved_data[s].clone_from(&phi.data[s][node]);
                saved_result[s].clone_from(&phi.result[s][node]);
            }

            let mut inflate = self.trust;
            let mut attempts = 0usize;
            let mut accepted = false;
            for _attempt in 0..40 {
                attempts += 1;
                let mut changed_loaded = false;
                // Which planes gained a previously-inactive edge? Only
                // those can create a routing loop, so the (expensive)
                // cycle re-check is restricted to them.
                added_data.clear();
                added_data.resize(net.s(), false);
                added_result.clear();
                added_result.resize(net.s(), false);
                // Which tasks' flows are affected at all? (row changed AND
                // the node carries traffic on that plane) — only those are
                // re-flowed incrementally.
                task_dirty.clear();
                task_dirty.resize(net.s(), false);
                for s in 0..net.s() {
                    let nb = &node_blocked[s];
                    if !self.restriction.freeze_data {
                        blocked_buf.clone_from(&nb.data);
                        if let Some(extra) = &self.restriction.extra_blocked_data {
                            for (b, &x) in blocked_buf.iter_mut().zip(&extra[s][node]) {
                                *b |= x;
                            }
                        }
                        for (slot, b) in blocked_buf.iter_mut().enumerate() {
                            if saved_data[s][slot] > 0.0 {
                                *b = false;
                            }
                        }
                        if blocked_buf.iter().any(|&b| !b) {
                            delta_minus_into(marg, net, s, node, delta);
                            self.data_scale_into(net, flows, marg, s, node, inflate, scale);
                            scaled_simplex_qp_into(
                                &saved_data[s],
                                delta,
                                scale,
                                blocked_buf,
                                qp,
                                &mut phi.data[s][node],
                            );
                            if flows.t_minus[s][node] > 1e-12
                                && phi.data[s][node] != saved_data[s]
                            {
                                changed_loaded = true;
                            }
                            for (slot, &v) in phi.data[s][node].iter().enumerate().skip(1) {
                                if v > 0.0 && saved_data[s][slot] <= 0.0 {
                                    added_data[s] = true;
                                }
                            }
                            if flows.t_minus[s][node] > 0.0
                                && phi.data[s][node] != saved_data[s]
                            {
                                task_dirty[s] = true;
                            }
                        }
                    }
                    if !self.restriction.freeze_result
                        && node != net.tasks[s].dest
                        && net.graph.out_degree(node) > 0
                        && nb.result.iter().any(|&b| !b)
                    {
                        delta_plus_into(marg, net, s, node, delta);
                        self.result_scale_into(net, flows, marg, s, node, inflate, scale);
                        scaled_simplex_qp_into(
                            &saved_result[s],
                            delta,
                            scale,
                            &nb.result,
                            qp,
                            &mut phi.result[s][node],
                        );
                        if flows.t_plus[s][node] > 1e-12
                            && phi.result[s][node] != saved_result[s]
                        {
                            changed_loaded = true;
                        }
                        for (slot, &v) in phi.result[s][node].iter().enumerate() {
                            if v > 0.0 && saved_result[s][slot] <= 0.0 {
                                added_result[s] = true;
                            }
                        }
                        if flows.t_plus[s][node] > 0.0
                            && phi.result[s][node] != saved_result[s]
                        {
                            task_dirty[s] = true;
                        }
                    }
                }

                // Cycle re-check, restricted to planes that gained edges
                // (mass removal/shifting among active edges cannot close a
                // loop). With blocked sets this almost never fires.
                let mut loop_found = false;
                for s in 0..net.s() {
                    if added_data[s] {
                        phi.data_active_mask_into(net, s, mask);
                        if has_cycle_masked_into(&net.graph, mask, topo, order) {
                            loop_found = true;
                            break;
                        }
                    }
                    if added_result[s] {
                        phi.result_active_mask_into(net, s, mask);
                        if has_cycle_masked_into(&net.graph, mask, topo, order) {
                            loop_found = true;
                            break;
                        }
                    }
                }
                if loop_found {
                    self.rollbacks += 1;
                    restore_rows(phi, node, saved_data, saved_result);
                    inflate *= 4.0;
                    continue;
                }
                // Incrementally re-flow only the dirty tasks; snapshot the
                // previous state into the shadow flow buffer so a
                // rejection can roll back exactly.
                dirty.clear();
                dirty.extend((0..net.s()).filter(|&s| task_dirty[s]));
                if dirty.is_empty() {
                    // zero-traffic re-pointing only: flows (and cost) are
                    // unchanged; accept iff nothing loaded moved.
                    if !self.safeguard || !changed_loaded {
                        accepted = true;
                        break;
                    }
                    restore_rows(phi, node, saved_data, saved_result);
                    inflate *= 4.0;
                    self.retries += 1;
                    continue;
                }
                let old_cost = flows.total_cost;
                for &s in dirty.iter() {
                    shadow.copy_task_from(flows, s);
                }
                shadow.copy_aggregates_from(flows);
                let mut flow_err = false;
                for &s in dirty.iter() {
                    if recompute_task_flows_with(net, phi, flows, s, flow_scratch).is_err() {
                        flow_err = true;
                        break;
                    }
                }
                let new_cost = if flow_err {
                    f64::INFINITY
                } else {
                    refresh_total_cost(net, flows)
                };
                if new_cost.is_finite()
                    && (!self.safeguard
                        || new_cost < old_cost - 1e-12
                        || (new_cost <= old_cost + 1e-12 && !changed_loaded))
                {
                    accepted = true;
                    break;
                }
                // rollback flows + rows
                for &s in dirty.iter() {
                    flows.copy_task_from(shadow, s);
                }
                flows.copy_aggregates_from(shadow);
                restore_rows(phi, node, saved_data, saved_result);
                self.retries += 1;
                inflate *= 4.0;
            }
            if accepted {
                self.trust = if attempts == 1 {
                    (self.trust * 0.5).max(1e-5)
                } else {
                    (inflate * 0.25).min(1e6)
                };
            }
        }

        compute_marginals_into(net, phi, flows, marg).map_err(anyhow::Error::new)?;
        Ok(IterationStats {
            total_cost: flows.total_cost,
            residual: theorem1_residual_with(net, phi, marg, delta),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flows::compute_flows;
    use crate::model::network::testnet::{diamond, line3};

    fn run(net: &Network, iters: usize) -> (Strategy, Vec<IterationStats>) {
        let mut phi = Strategy::local_compute_init(net);
        let mut sgp = Sgp::new();
        let mut hist = Vec::new();
        for _ in 0..iters {
            hist.push(sgp.step(net, &mut phi).unwrap());
        }
        (phi, hist)
    }

    #[test]
    fn monotone_descent_diamond() {
        let net = diamond(true);
        let (_, hist) = run(&net, 30);
        for w in hist.windows(2) {
            assert!(
                w[1].total_cost <= w[0].total_cost + 1e-9,
                "cost increased: {} -> {}",
                w[0].total_cost,
                w[1].total_cost
            );
        }
    }

    #[test]
    fn residual_shrinks_diamond() {
        let net = diamond(true);
        let (_, hist) = run(&net, 60);
        let first = hist.first().unwrap().residual;
        let last = hist.last().unwrap().residual;
        assert!(
            last < 1e-6 || last < first * 1e-3,
            "residual did not shrink: {first} -> {last}"
        );
    }

    #[test]
    fn loop_free_all_iterations() {
        let net = line3();
        let mut phi = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        for _ in 0..40 {
            sgp.step(&net, &mut phi).unwrap();
            assert!(phi.is_loop_free(&net));
            assert!(phi.is_feasible(&net), "{:?}", phi.feasibility_violations(&net));
        }
        assert_eq!(sgp.rollbacks, 0, "loop rollback fired");
    }

    #[test]
    fn linear_costs_find_shortest_path_structure() {
        // On the linear diamond, offloading everything at the cheapest
        // place and shipping over shortest paths is optimal; SGP must reach
        // a Theorem-1 point (residual ~ 0).
        let net = diamond(false);
        let (_, hist) = run(&net, 60);
        assert!(hist.last().unwrap().residual < 1e-8);
    }

    #[test]
    fn improves_over_initial_cost() {
        let net = diamond(true);
        let mut phi = Strategy::local_compute_init(&net);
        let t0 = compute_flows(&net, &phi).unwrap().total_cost;
        let mut sgp = Sgp::new();
        for _ in 0..50 {
            sgp.step(&net, &mut phi).unwrap();
        }
        let t1 = compute_flows(&net, &phi).unwrap().total_cost;
        assert!(t1 < t0, "no improvement: {t0} -> {t1}");
    }

    #[test]
    fn async_single_node_updates_descend() {
        let net = diamond(true);
        let mut phi = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        let mut last = f64::INFINITY;
        // sweep nodes round-robin, alternating planes
        for round in 0..30 {
            for i in 0..net.n() {
                let t = sgp
                    .update_single_node(&net, &mut phi, i, 0, round % 2 == 0)
                    .unwrap();
                assert!(t <= last + 1e-9, "async step increased cost");
                last = t;
                assert!(phi.is_loop_free(&net));
            }
        }
    }

    #[test]
    fn persistent_workspace_matches_throwaway_step() {
        // The workspace is a layout change only: a persistent, reused
        // arena must produce bit-for-bit the trajectory of per-call fresh
        // workspaces (which is what `step` uses).
        let net = diamond(true);
        let mut phi_a = Strategy::local_compute_init(&net);
        let mut phi_b = phi_a.clone();
        let mut sgp_a = Sgp::new();
        let mut sgp_b = Sgp::new();
        let mut ws = OptWorkspace::new();
        for it in 0..25 {
            let sa = sgp_a.step(&net, &mut phi_a).unwrap();
            let sb = sgp_b.step_ws(&net, &mut phi_b, &mut ws).unwrap();
            assert_eq!(sa.total_cost.to_bits(), sb.total_cost.to_bits(), "iter {it}");
            assert_eq!(sa.residual.to_bits(), sb.residual.to_bits(), "iter {it}");
            assert_eq!(phi_a.data, phi_b.data, "iter {it}");
            assert_eq!(phi_a.result, phi_b.result, "iter {it}");
        }
        assert_eq!(sgp_a.retries, sgp_b.retries);
        assert_eq!(sgp_a.rollbacks, sgp_b.rollbacks);
    }

    #[test]
    fn restriction_freezes_planes() {
        let net = diamond(true);
        let mut phi = Strategy::local_compute_init(&net);
        let before = phi.clone();
        let mut sgp = Sgp::with_restriction(Restriction {
            freeze_data: true,
            freeze_result: false,
            extra_blocked_data: None,
        });
        for _ in 0..5 {
            sgp.step(&net, &mut phi).unwrap();
        }
        // data plane untouched
        assert_eq!(phi.data, before.data);
    }
}

#[cfg(test)]
mod convergence_tests {
    use super::*;
    use crate::coordinator::build_scenario_network;
    use crate::model::network::testnet::diamond;

    #[test]
    fn diamond_reaches_theorem1_point() {
        let net = diamond(true);
        let mut phi = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        let mut last = f64::INFINITY;
        let mut res = f64::INFINITY;
        for _ in 0..40 {
            let st = sgp.step(&net, &mut phi).unwrap();
            assert!(st.total_cost <= last + 1e-9);
            last = st.total_cost;
            res = st.residual;
        }
        assert!(res < 1e-6, "residual {res}");
        assert_eq!(sgp.rollbacks, 0);
    }

    #[test]
    fn abilene_beats_gp_in_few_iterations() {
        // Fig. 5b shape on a Table II instance: SGP must reach (or beat)
        // GP's 80-iteration cost within 25 iterations.
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let mut phi_g = Strategy::local_compute_init(&net);
        let mut gp = crate::algo::Gp::new(1.0);
        let mut t_gp = f64::INFINITY;
        for _ in 0..80 {
            t_gp = gp.step(&net, &mut phi_g).unwrap().total_cost;
        }

        let mut phi_s = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        let mut t_sgp = f64::INFINITY;
        for _ in 0..25 {
            t_sgp = sgp.step(&net, &mut phi_s).unwrap().total_cost;
        }
        assert!(
            t_sgp <= t_gp * 1.001,
            "SGP@25 {t_sgp} vs GP@80 {t_gp}"
        );
    }
}
