//! GP — the non-scaled gradient projection baseline (§V).
//!
//! The paper defines GP by replacing the SGP scaling matrices with
//! `M_i = (t_i/β)·diag(1,…,1,0,1,…,1)` where the zero sits at the
//! min-marginal slot. The induced projection step has the classic Gallager
//! (1977) closed form: every non-minimal slot sheds
//! `Δ_j = min(φ_j, β·(δ_j − δ_min)/t_i)` and the minimum-marginal slot
//! collects the total. GP shares SGP's blocked sets and fixed points but
//! converges markedly slower — Fig. 5b.

use anyhow::{bail, Result};

use crate::model::flows::compute_flows_with;
use crate::model::marginals::{
    compute_marginals_into, delta_minus_into, delta_plus_into, theorem1_residual_with,
};
use crate::model::network::Network;
use crate::model::strategy::Strategy;

use super::blocked::{blocked_sets, BlockedSets};
use super::workspace::OptWorkspace;
use super::{IterationStats, Optimizer};

/// Non-scaled gradient projection with step parameter `β`.
pub struct Gp {
    /// Step size β (the paper leaves it unspecified; 1.0 with the descent
    /// safeguard is a faithful, stable choice).
    pub beta: f64,
    /// Safeguard: shrink β on cost increase (keeps Theorem 2 descent).
    pub safeguard: bool,
    pub retries: usize,
}

impl Gp {
    pub fn new(beta: f64) -> Gp {
        Gp {
            beta,
            safeguard: true,
            retries: 0,
        }
    }

    /// Gallager-style shift on one simplex vector. `delta` and `blocked`
    /// are slot-aligned with `phi_vec`; `traffic` is `t_i`.
    /// Allocating wrapper over [`Gp::shift_into`].
    #[cfg(test)]
    fn shift(
        phi_vec: &[f64],
        delta: &[f64],
        blocked: &[bool],
        traffic: f64,
        beta: f64,
    ) -> Vec<f64> {
        let mut v = Vec::new();
        Self::shift_into(phi_vec, delta, blocked, traffic, beta, &mut v);
        v
    }

    /// Gallager shift into a caller-owned output vector — allocation-free
    /// after warm-up, identical arithmetic.
    fn shift_into(
        phi_vec: &[f64],
        delta: &[f64],
        blocked: &[bool],
        traffic: f64,
        beta: f64,
        v: &mut Vec<f64>,
    ) {
        v.clear();
        v.extend_from_slice(phi_vec);
        // receiving slot: min marginal among unblocked
        let jmin = match (0..v.len())
            .filter(|&j| !blocked[j])
            .min_by(|&a, &b| delta[a].partial_cmp(&delta[b]).unwrap())
        {
            Some(j) => j,
            None => return,
        };
        if traffic <= 0.0 {
            // zero-traffic node: jump entirely to the best slot (needed to
            // satisfy Theorem 1 where Lemma 1 is vacuous)
            v.iter_mut().for_each(|x| *x = 0.0);
            v[jmin] = 1.0;
            return;
        }
        let mut moved = 0.0;
        for j in 0..v.len() {
            if j == jmin || v[j] <= 0.0 {
                continue;
            }
            let want = beta * (delta[j] - delta[jmin]).max(0.0) / traffic;
            let take = want.min(v[j]);
            v[j] -= take;
            moved += take;
        }
        v[jmin] += moved;
    }
}

impl Optimizer for Gp {
    fn name(&self) -> &'static str {
        "gp"
    }

    /// Allocating wrapper over [`Optimizer::step_ws`] with a throwaway
    /// workspace — identical results.
    fn step(&mut self, net: &Network, phi: &mut Strategy) -> Result<IterationStats> {
        let mut ws = OptWorkspace::new();
        self.step_ws(net, phi, &mut ws)
    }

    fn step_ws(
        &mut self,
        net: &Network,
        phi: &mut Strategy,
        ws: &mut OptWorkspace,
    ) -> Result<IterationStats> {
        ws.ensure(net);
        compute_flows_with(net, phi, &mut ws.flows, &mut ws.flow_scratch)
            .map_err(anyhow::Error::new)?;
        if !ws.flows.total_cost.is_finite() {
            bail!("initial strategy has infinite cost");
        }
        compute_marginals_into(net, phi, &ws.flows, &mut ws.marg).map_err(anyhow::Error::new)?;
        // Jacobi full blocked-set construction (GP proposes all nodes at
        // once); this path keeps the allocating form — GP is a baseline,
        // only the SGP sweep is under the zero-allocation contract.
        let blocked_all: Vec<BlockedSets> = (0..net.s())
            .map(|s| blocked_sets(net, phi, &ws.marg, s))
            .collect();

        if ws.cand_pool.is_empty() {
            ws.cand_pool.push(phi.clone());
        }
        let mut beta = self.beta;
        for _attempt in 0..40 {
            let cand = &mut ws.cand_pool[0];
            cand.clone_from(phi);
            for s in 0..net.s() {
                let blocked = &blocked_all[s];
                for i in 0..net.n() {
                    delta_minus_into(&ws.marg, net, s, i, &mut ws.bufs.delta);
                    Self::shift_into(
                        &phi.data[s][i],
                        &ws.bufs.delta,
                        &blocked.data[i],
                        ws.flows.t_minus[s][i],
                        beta,
                        &mut cand.data[s][i],
                    );
                    if i != net.tasks[s].dest && net.graph.out_degree(i) > 0 {
                        delta_plus_into(&ws.marg, net, s, i, &mut ws.bufs.delta);
                        Self::shift_into(
                            &phi.result[s][i],
                            &ws.bufs.delta,
                            &blocked.result[i],
                            ws.flows.t_plus[s][i],
                            beta,
                            &mut cand.result[s][i],
                        );
                    }
                }
            }
            if cand.is_loop_free(net) {
                let priced =
                    match compute_flows_with(net, cand, &mut ws.shadow, &mut ws.flow_scratch) {
                        Ok(()) => ws.shadow.total_cost.is_finite(),
                        Err(_) => false,
                    };
                if priced
                    && (!self.safeguard
                        || ws.shadow.total_cost <= ws.flows.total_cost + 1e-12)
                {
                    phi.clone_from(&ws.cand_pool[0]);
                    break;
                }
            }
            self.retries += 1;
            beta *= 0.25;
        }

        compute_flows_with(net, phi, &mut ws.flows, &mut ws.flow_scratch)
            .map_err(anyhow::Error::new)?;
        compute_marginals_into(net, phi, &ws.flows, &mut ws.marg).map_err(anyhow::Error::new)?;
        Ok(IterationStats {
            total_cost: ws.flows.total_cost,
            residual: theorem1_residual_with(net, phi, &ws.marg, &mut ws.bufs.delta),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::sgp::Sgp;
    use crate::model::flows::compute_flows;
    use crate::model::network::testnet::diamond;

    #[test]
    fn monotone_descent() {
        let net = diamond(true);
        let mut phi = Strategy::local_compute_init(&net);
        let mut gp = Gp::new(1.0);
        let mut last = f64::INFINITY;
        for _ in 0..50 {
            let st = gp.step(&net, &mut phi).unwrap();
            assert!(st.total_cost <= last + 1e-9);
            last = st.total_cost;
            assert!(phi.is_loop_free(&net));
        }
    }

    #[test]
    fn persistent_workspace_matches_throwaway_step() {
        let net = diamond(true);
        let mut phi_a = Strategy::local_compute_init(&net);
        let mut phi_b = phi_a.clone();
        let mut gp_a = Gp::new(1.0);
        let mut gp_b = Gp::new(1.0);
        let mut ws = OptWorkspace::new();
        for it in 0..25 {
            let sa = gp_a.step(&net, &mut phi_a).unwrap();
            let sb = gp_b.step_ws(&net, &mut phi_b, &mut ws).unwrap();
            assert_eq!(sa.total_cost.to_bits(), sb.total_cost.to_bits(), "iter {it}");
            assert_eq!(sa.residual.to_bits(), sb.residual.to_bits(), "iter {it}");
            assert_eq!(phi_a.data, phi_b.data, "iter {it}");
        }
        assert_eq!(gp_a.retries, gp_b.retries);
    }

    #[test]
    fn same_fixed_point_as_sgp() {
        // GP and SGP are "supposed to converge to the same global strategy
        // with different convergence speed" (§V). Compare final costs.
        let net = diamond(true);

        let mut phi_s = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        for _ in 0..80 {
            sgp.step(&net, &mut phi_s).unwrap();
        }
        let ts = compute_flows(&net, &phi_s).unwrap().total_cost;

        let mut phi_g = Strategy::local_compute_init(&net);
        let mut gp = Gp::new(1.0);
        for _ in 0..800 {
            gp.step(&net, &mut phi_g).unwrap();
        }
        let tg = compute_flows(&net, &phi_g).unwrap().total_cost;

        assert!(
            (ts - tg).abs() < 5e-3 * ts.max(1e-9),
            "SGP {ts} vs GP {tg} diverge"
        );
    }

    #[test]
    fn sgp_converges_faster() {
        // Count iterations to reach within 1% of the (deep-run) optimum.
        let net = diamond(true);
        let target = {
            let mut phi = Strategy::local_compute_init(&net);
            let mut sgp = Sgp::new();
            for _ in 0..200 {
                sgp.step(&net, &mut phi).unwrap();
            }
            compute_flows(&net, &phi).unwrap().total_cost
        };
        let thresh = target * 1.01;

        let count_iters = |mut opt: Box<dyn Optimizer>| -> usize {
            let mut phi = Strategy::local_compute_init(&net);
            for k in 1..=400 {
                let st = opt.step(&net, &mut phi).unwrap();
                if st.total_cost <= thresh {
                    return k;
                }
            }
            400
        };
        let sgp_iters = count_iters(Box::new(Sgp::new()));
        let gp_iters = count_iters(Box::new(Gp::new(1.0)));
        assert!(
            sgp_iters <= gp_iters,
            "SGP took {sgp_iters} vs GP {gp_iters}"
        );
    }

    #[test]
    fn zero_traffic_jumps_to_best() {
        let v = Gp::shift(&[0.2, 0.8], &[5.0, 1.0], &[false, false], 0.0, 1.0);
        assert_eq!(v, vec![0.0, 1.0]);
    }

    #[test]
    fn shift_respects_blocked_receiver() {
        // best slot blocked -> second best receives
        let v = Gp::shift(&[0.5, 0.5, 0.0], &[3.0, 2.0, 1.0], &[false, false, true], 1.0, 10.0);
        assert_eq!(v[2], 0.0);
        assert!(v[1] > 0.5);
        assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn shift_preserves_simplex() {
        let v = Gp::shift(
            &[0.3, 0.3, 0.4],
            &[2.0, 1.0, 3.0],
            &[false, false, false],
            2.0,
            0.5,
        );
        assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(v.iter().all(|&x| x >= 0.0));
    }
}
