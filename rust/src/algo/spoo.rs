//! SPOO — Shortest Path Optimal Offloading baseline (§V).
//!
//! Routing is frozen to shortest-path trees toward each destination,
//! measured with the zero-flow marginal `D'(0)` ("propagation delay
//! without queueing effect"); only the offloading split `φ⁻_i0 ∈ [0,1]`
//! at each node on the path is optimized. Results follow the same
//! shortest-path tree (`φ⁺ = 1` along it).
//!
//! Implemented as a *restricted* SGP: every data slot except
//! `{local computation, SP next hop}` is permanently blocked, and the
//! result plane is frozen at the SP tree — so the same projection/descent
//! machinery optimizes exactly the paper's SPOO variable set. A similar
//! restriction appears in the paper's reference [12] (linear topology
//! partial offloading).

use crate::graph::algorithms::dijkstra_to;
use crate::model::network::Network;
use crate::model::strategy::{out_slot, Strategy};

use super::sgp::{Restriction, Sgp};

/// Build the SPOO optimizer and its initial strategy (all-local
/// computation on the SP trees).
pub fn spoo_optimizer(net: &Network) -> (Sgp, Strategy) {
    let n = net.n();
    let w0: Vec<f64> = net.link_cost.iter().map(|c| c.deriv_at_zero()).collect();

    // start from the all-local strategy whose result plane already follows
    // the SP trees
    let phi = Strategy::local_compute_init(net);

    // Blocked mask: for each task, allow only {slot 0, SP next hop}.
    let mut extra = Vec::with_capacity(net.s());
    for task in net.tasks.iter() {
        let (_, next) = dijkstra_to(&net.graph, task.dest, &w0);
        let mut per_node = Vec::with_capacity(n);
        for i in 0..n {
            let deg = net.graph.out_degree(i);
            let mut slots = vec![true; deg + 1];
            slots[0] = false; // offloading split stays free
            if i != task.dest {
                let nxt = next[i];
                if nxt != usize::MAX {
                    if let Some(k) = out_slot(&net.graph, i, nxt) {
                        slots[k + 1] = false; // SP next hop stays free
                    }
                }
            }
            per_node.push(slots);
        }
        extra.push(per_node);
    }

    let sgp = Sgp::with_restriction(Restriction {
        freeze_data: false,
        freeze_result: true,
        extra_blocked_data: Some(extra),
    });
    (sgp, phi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Optimizer;
    use crate::model::flows::compute_flows;
    use crate::model::network::testnet::diamond;
    use crate::model::strategy::out_slot;

    #[test]
    fn only_path_slots_used() {
        let net = diamond(true);
        let (mut opt, mut phi) = spoo_optimizer(&net);
        for _ in 0..40 {
            opt.step(&net, &mut phi).unwrap();
        }
        // data plane of node 0 may only use {local, SP next hop}
        let w0: Vec<f64> = net.link_cost.iter().map(|c| c.deriv_at_zero()).collect();
        let (_, next) = dijkstra_to(&net.graph, 3, &w0);
        let nxt = next[0];
        let allowed = out_slot(&net.graph, 0, nxt).unwrap() + 1;
        for (slot, &frac) in phi.data[0][0].iter().enumerate() {
            if slot != 0 && slot != allowed {
                assert!(
                    frac < 1e-12,
                    "slot {slot} carries data {frac} off the SP"
                );
            }
        }
    }

    #[test]
    fn result_plane_frozen_to_sp_tree() {
        let net = diamond(true);
        let (mut opt, mut phi) = spoo_optimizer(&net);
        let before = phi.result.clone();
        for _ in 0..20 {
            opt.step(&net, &mut phi).unwrap();
        }
        assert_eq!(phi.result, before);
    }

    #[test]
    fn improves_on_all_local_within_restriction() {
        let net = diamond(true);
        let (mut opt, mut phi) = spoo_optimizer(&net);
        let t0 = compute_flows(&net, &phi).unwrap().total_cost;
        let mut last = t0;
        for _ in 0..60 {
            let st = opt.step(&net, &mut phi).unwrap();
            assert!(st.total_cost <= last + 1e-9);
            last = st.total_cost;
        }
        assert!(phi.is_feasible(&net));
        assert!(phi.is_loop_free(&net));
        assert!(last <= t0);
    }

    #[test]
    fn spoo_never_beats_sgp() {
        // SPOO optimizes a subset of SGP's variables from the same start:
        // its steady-state cost can't be lower.
        let net = diamond(true);
        let (mut spoo, mut phi_p) = spoo_optimizer(&net);
        for _ in 0..100 {
            spoo.step(&net, &mut phi_p).unwrap();
        }
        let tp = compute_flows(&net, &phi_p).unwrap().total_cost;

        let mut sgp = crate::algo::Sgp::new();
        let mut phi_s = Strategy::local_compute_init(&net);
        for _ in 0..100 {
            sgp.step(&net, &mut phi_s).unwrap();
        }
        let ts = compute_flows(&net, &phi_s).unwrap().total_cost;
        assert!(ts <= tp + 1e-6, "SGP {ts} vs SPOO {tp}");
    }
}
