//! LPR — Linear Program Rounded baseline ([8], adapted per §V).
//!
//! The reference method jointly picks, for each (task, data source), a
//! single compute node and a single path, without partial offloading,
//! congestible links or result flows. The paper's adaptation, reproduced
//! here:
//!
//! * costs are linearized at zero flow (`D'(0)`, `C'(0)`);
//! * a *saturation factor* of 0.7 caps the data flow admitted onto each
//!   queueing link (`data ≤ 0.7 · capacity`), giving headroom for result
//!   flows;
//! * result flows use shortest-path routing from the compute node to the
//!   destination;
//! * the fractional assignment LP is rounded to an integral compute-node
//!   choice per source, largest fraction first, re-checking capacities.
//!
//! The LP couples all tasks through the link capacities; to keep the dense
//! simplex tableau small we decompose it **sequentially by task** (each
//! task's LP sees the capacity left by the previous ones — documented
//! substitution, DESIGN.md §3.6). Candidate compute nodes per source are
//! capped at the `K` cheapest under the linearized metric.
//!
//! Because LPR's decisions are path-based (per-source single paths), the
//! evaluation builds link/computation loads directly instead of a per-node
//! strategy `φ`, and prices them under the **true convex costs** — exactly
//! the regime where Fig. 4/5c show LPR collapsing on congestible networks.

use crate::graph::algorithms::{dijkstra_to, path_from_next};
use crate::model::cost::CostFn;
use crate::model::network::Network;

use super::lp::{LpOutcome, LpProblem};

/// One rounded assignment: all data of `(task, source)` is computed at
/// `compute_node`.
#[derive(Clone, Debug)]
pub struct Assignment {
    pub task: usize,
    pub source: usize,
    pub compute_node: usize,
    pub rate: f64,
    /// Data path `source -> ... -> compute_node` (node ids).
    pub data_path: Vec<usize>,
    /// Result path `compute_node -> ... -> dest`.
    pub result_path: Vec<usize>,
}

/// LPR solution: the loads it induces and their true-cost evaluation.
#[derive(Clone, Debug)]
pub struct LprSolution {
    pub assignments: Vec<Assignment>,
    pub link_flow: Vec<f64>,
    pub workload: Vec<f64>,
    /// Total cost under the true convex cost functions.
    pub total_cost: f64,
    /// Average data / result travel distance in hops (rate-weighted) —
    /// the Fig. 5d metrics for this baseline.
    pub l_data: f64,
    pub l_result: f64,
}

/// LPR solver configuration.
pub struct Lpr {
    /// Saturation factor for queueing-link data-flow caps (paper: 0.7).
    pub saturate: f64,
    /// Candidate compute nodes per (task, source).
    pub candidates: usize,
}

impl Default for Lpr {
    fn default() -> Self {
        Lpr {
            saturate: 0.7,
            candidates: 8,
        }
    }
}

impl Lpr {
    pub fn solve(&self, net: &Network) -> LprSolution {
        let n = net.n();
        let e = net.e();
        let w0: Vec<f64> = net.link_cost.iter().map(|c| c.deriv_at_zero()).collect();

        // Remaining data capacity per link (∞ for non-capacitated links).
        let mut cap_left: Vec<f64> = net
            .link_cost
            .iter()
            .map(|c| match c.capacity() {
                Some(cap) => self.saturate * cap,
                None => f64::INFINITY,
            })
            .collect();

        let mut assignments: Vec<Assignment> = Vec::new();

        for (s, task) in net.tasks.iter().enumerate() {
            let a_m = net.a_of(s);
            let ctype = task.ctype;
            // SP tree toward the destination for result flows
            let (dist_to_dest, next_to_dest) = dijkstra_to(&net.graph, task.dest, &w0);

            // sources of this task
            let sources: Vec<(usize, f64)> = (0..n)
                .filter(|&i| net.input_rate[s][i] > 0.0)
                .map(|i| (i, net.input_rate[s][i]))
                .collect();
            if sources.is_empty() {
                continue;
            }

            // SP trees toward every candidate compute node are needed;
            // compute per-candidate on demand and cache.
            let mut tree_cache: Vec<Option<(Vec<f64>, Vec<usize>)>> = vec![None; n];
            let tree =
                |v: usize, cache: &mut Vec<Option<(Vec<f64>, Vec<usize>)>>| -> (Vec<f64>, Vec<usize>) {
                    if cache[v].is_none() {
                        cache[v] = Some(dijkstra_to(&net.graph, v, &w0));
                    }
                    cache[v].clone().unwrap()
                };

            // candidate compute nodes per source: K cheapest by the
            // linearized end-to-end cost
            let mut cand: Vec<Vec<usize>> = Vec::with_capacity(sources.len());
            for &(u, _) in &sources {
                let mut scored: Vec<(f64, usize)> = (0..n)
                    .map(|v| {
                        let (du, _) = tree(v, &mut tree_cache);
                        let comp = net.comp_weight[v][ctype] * net.comp_cost[v].deriv_at_zero();
                        let cost = du[u] + comp + a_m * dist_to_dest[v];
                        (cost, v)
                    })
                    .collect();
                scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                let mut picks: Vec<usize> =
                    scored.iter().take(self.candidates).map(|&(_, v)| v).collect();
                // always allow computing at the source and at the destination
                for must in [u, task.dest] {
                    if !picks.contains(&must) {
                        picks.push(must);
                    }
                }
                cand.push(picks);
            }

            // ---- the per-task assignment LP ----
            // variables x[q][k] = fraction of source q's data computed at
            // candidate k; columns flattened in (q, k) order.
            let cols: Vec<(usize, usize)> = cand
                .iter()
                .enumerate()
                .flat_map(|(q, picks)| (0..picks.len()).map(move |k| (q, k)))
                .collect();
            let mut lp = LpProblem::new(cols.len());

            // objective: linearized data + comp + result cost per unit,
            // scaled by the source rate
            for (col, &(q, k)) in cols.iter().enumerate() {
                let (u, rate) = sources[q];
                let v = cand[q][k];
                let (du, _) = tree(v, &mut tree_cache);
                let comp = net.comp_weight[v][ctype] * net.comp_cost[v].deriv_at_zero();
                lp.objective[col] = rate * (du[u] + comp + a_m * dist_to_dest[v]);
            }
            // Σ_k x[q][k] = 1
            for q in 0..sources.len() {
                let row: Vec<f64> = cols
                    .iter()
                    .map(|&(qq, _)| if qq == q { 1.0 } else { 0.0 })
                    .collect();
                lp.add_eq(row, 1.0);
            }
            // link capacity rows: data flow over SP(u -> v) edges
            // build usage map per column, then one row per capacitated link
            let mut usage: Vec<Vec<f64>> = vec![vec![0.0; cols.len()]; e];
            for (col, &(q, k)) in cols.iter().enumerate() {
                let (u, rate) = sources[q];
                let v = cand[q][k];
                let (_, nxt) = tree(v, &mut tree_cache);
                if let Some(path) = path_from_next(&nxt, u, v) {
                    for hop in path.windows(2) {
                        if let Some(eid) = net.graph.edge_id(hop[0], hop[1]) {
                            usage[eid][col] += rate;
                        }
                    }
                }
            }
            for eid in 0..e {
                if cap_left[eid].is_finite() && usage[eid].iter().any(|&x| x > 0.0) {
                    lp.add_le(usage[eid].clone(), cap_left[eid].max(0.0));
                }
            }

            // solve; on infeasibility fall back to the unconstrained
            // cheapest candidate per source (LPR then pays the congestion)
            let x = match lp.solve() {
                LpOutcome::Optimal { x, .. } => x,
                _ => {
                    let mut x = vec![0.0; cols.len()];
                    for q in 0..sources.len() {
                        let best = cols
                            .iter()
                            .enumerate()
                            .filter(|(_, &(qq, _))| qq == q)
                            .min_by(|(a, _), (b, _)| {
                                lp.objective[*a].partial_cmp(&lp.objective[*b]).unwrap()
                            })
                            .map(|(col, _)| col)
                            .unwrap();
                        x[best] = 1.0;
                    }
                    x
                }
            };

            // ---- rounding: per source, largest fraction wins ----
            for (q, &(u, rate)) in sources.iter().enumerate() {
                let (best_col, _) = cols
                    .iter()
                    .enumerate()
                    .filter(|(_, &(qq, _))| qq == q)
                    .map(|(col, _)| (col, x[col]))
                    .fold((usize::MAX, f64::NEG_INFINITY), |acc, cur| {
                        if cur.1 > acc.1 {
                            cur
                        } else {
                            acc
                        }
                    });
                let (_, k) = cols[best_col];
                let v = cand[q][k];
                let (_, nxt) = tree(v, &mut tree_cache);
                let data_path = path_from_next(&nxt, u, v).unwrap_or_else(|| vec![u]);
                let result_path =
                    path_from_next(&next_to_dest, v, task.dest).unwrap_or_else(|| vec![v]);
                // consume data capacity
                for hop in data_path.windows(2) {
                    if let Some(eid) = net.graph.edge_id(hop[0], hop[1]) {
                        cap_left[eid] -= rate;
                    }
                }
                assignments.push(Assignment {
                    task: s,
                    source: u,
                    compute_node: v,
                    rate,
                    data_path,
                    result_path,
                });
            }
        }

        Self::evaluate(net, assignments)
    }

    /// Price a set of assignments under the true convex costs.
    fn evaluate(net: &Network, assignments: Vec<Assignment>) -> LprSolution {
        let mut link_flow = vec![0.0; net.e()];
        let mut workload = vec![0.0; net.n()];
        let mut data_hops = 0.0;
        let mut res_hops = 0.0;
        let mut data_rate = 0.0;
        let mut res_rate = 0.0;
        for a in &assignments {
            let am = net.a_of(a.task);
            let ctype = net.tasks[a.task].ctype;
            for hop in a.data_path.windows(2) {
                let eid = net.graph.edge_id(hop[0], hop[1]).unwrap();
                link_flow[eid] += a.rate;
            }
            for hop in a.result_path.windows(2) {
                let eid = net.graph.edge_id(hop[0], hop[1]).unwrap();
                link_flow[eid] += am * a.rate;
            }
            workload[a.compute_node] += net.comp_weight[a.compute_node][ctype] * a.rate;
            data_hops += a.rate * (a.data_path.len() - 1) as f64;
            res_hops += am * a.rate * (a.result_path.len() - 1) as f64;
            data_rate += a.rate;
            res_rate += am * a.rate;
        }
        let mut total = 0.0;
        for (eid, &f) in link_flow.iter().enumerate() {
            total += net.link_cost[eid].value(f);
        }
        for (i, &g) in workload.iter().enumerate() {
            total += net.comp_cost[i].value(g);
        }
        LprSolution {
            assignments,
            link_flow,
            workload,
            total_cost: total,
            l_data: if data_rate > 0.0 { data_hops / data_rate } else { 0.0 },
            l_result: if res_rate > 0.0 { res_hops / res_rate } else { 0.0 },
        }
    }
}

/// Convenience: capped true cost (∞ → a large finite number) so Fig. 4
/// normalization stays renderable when LPR saturates a link.
pub fn finite_or(cost: f64, cap: f64) -> f64 {
    if cost.is_finite() {
        cost
    } else {
        cap
    }
}

// Re-exported for LPR tests / diagnostics.
pub fn linearized_link_weights(net: &Network) -> Vec<f64> {
    net.link_cost.iter().map(CostFn::deriv_at_zero).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::network::testnet::{diamond, line3};

    #[test]
    fn produces_assignment_per_source() {
        let net = diamond(true);
        let sol = Lpr::default().solve(&net);
        assert_eq!(sol.assignments.len(), 1); // one task, one source
        let a = &sol.assignments[0];
        assert_eq!(a.task, 0);
        assert_eq!(a.source, 0);
        assert_eq!(*a.result_path.last().unwrap(), 3);
        assert!(sol.total_cost.is_finite());
    }

    #[test]
    fn paths_are_graph_paths() {
        let net = line3();
        let sol = Lpr::default().solve(&net);
        for a in &sol.assignments {
            for hop in a.data_path.windows(2) {
                assert!(net.graph.has_edge(hop[0], hop[1]));
            }
            for hop in a.result_path.windows(2) {
                assert!(net.graph.has_edge(hop[0], hop[1]));
            }
            assert_eq!(*a.data_path.first().unwrap(), a.source);
            assert_eq!(*a.data_path.last().unwrap(), a.compute_node);
            assert_eq!(*a.result_path.first().unwrap(), a.compute_node);
        }
    }

    #[test]
    fn respects_saturation_in_lp() {
        // Link capacity 10, saturate 0.7: at most 7 units of data per link
        // can be *planned*; with a 1.0-rate task this is never binding, so
        // simply check the solve succeeds and loads stay below caps.
        let net = diamond(true);
        let sol = Lpr::default().solve(&net);
        for (eid, &f) in sol.link_flow.iter().enumerate() {
            if let Some(cap) = net.link_cost[eid].capacity() {
                assert!(f < cap, "edge {eid} overloaded: {f} >= {cap}");
            }
        }
    }

    #[test]
    fn workload_accounts_all_input() {
        let net = line3();
        let sol = Lpr::default().solve(&net);
        // every unit of input is computed somewhere
        let total_assigned: f64 = sol.assignments.iter().map(|a| a.rate).sum();
        let total_input: f64 = (0..net.s()).map(|s| net.task_input(s)).sum();
        assert!((total_assigned - total_input).abs() < 1e-9);
    }

    #[test]
    fn hop_metrics_nonnegative() {
        let net = diamond(true);
        let sol = Lpr::default().solve(&net);
        assert!(sol.l_data >= 0.0);
        assert!(sol.l_result >= 0.0);
    }

    #[test]
    fn finite_or_caps() {
        assert_eq!(finite_or(5.0, 100.0), 5.0);
        assert_eq!(finite_or(f64::INFINITY, 100.0), 100.0);
    }
}
