//! Hand-rolled command-line argument parser (clap is unavailable offline).
//!
//! Supports the shapes the `cecflow` binary and examples need:
//! `prog SUBCOMMAND [--flag] [--key value] [--key=value] positional...`.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, options, flags and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable); `first_is_subcommand`
    /// treats the first bare word as the subcommand.
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I, first_is_subcommand: bool) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.opts.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else if first_is_subcommand && args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env(first_is_subcommand: bool) -> Args {
        Args::parse_from(std::env::args().skip(1), first_is_subcommand)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .map(|s| {
                s.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .map(|s| {
                s.parse::<usize>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name)
            .map(|s| {
                s.parse::<u64>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str], sub: bool) -> Args {
        Args::parse_from(words.iter().map(|s| s.to_string()), sub)
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["run", "--topology", "geant", "--iters=50"], true);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.opt("topology"), Some("geant"));
        assert_eq!(a.opt_usize("iters", 0), 50);
    }

    #[test]
    fn flags_vs_options() {
        let a = parse(&["x", "--verbose", "--seed", "7"], true);
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_u64("seed", 0), 7);
        assert!(!a.flag("seed"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--check"], false);
        assert!(a.flag("check"));
    }

    #[test]
    fn positionals_collected() {
        let a = parse(&["cmd", "one", "two", "--k", "v"], true);
        assert_eq!(a.positional, vec!["one", "two"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], false);
        assert_eq!(a.opt_or("missing", "d"), "d");
        assert_eq!(a.opt_f64("scale", 1.5), 1.5);
    }

    #[test]
    #[should_panic]
    fn bad_number_panics() {
        let a = parse(&["--n", "abc"], false);
        a.opt_usize("n", 0);
    }
}
