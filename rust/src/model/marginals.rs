//! Marginal costs and the Theorem-1 quantities `δ±` (§III).
//!
//! `∂T/∂t⁺_i(d,m)` and `∂T/∂r_i(d,m)` satisfy the recursions (12) and (11),
//! which are well-founded precisely because the strategy is loop-free: they
//! are reverse-topological dynamic programs over the active result/data
//! subgraphs. This module is the *centralized* computation used by the
//! optimizer loop; `sim::protocol` implements the same recursions as the
//! paper's two-stage distributed broadcast and an integration test pins
//! them to each other.

use crate::graph::algorithms::{longest_path_to_sink, topo_order_masked};

use super::flows::{FlowError, FlowState};
use super::network::Network;
use super::strategy::Strategy;

/// Marginal-cost state for one `(network, strategy, flows)` triple.
#[derive(Clone, Debug)]
pub struct Marginals {
    /// `D'_ij(F_ij)` per directed edge.
    pub d_link: Vec<f64>,
    /// `C'_i(G_i)` per node.
    pub c_node: Vec<f64>,
    /// `∂T/∂t⁺_i(d,m)`, `[task][node]` (eq. 12; 0 at the destination).
    pub dt_plus: Vec<Vec<f64>>,
    /// `∂T/∂r_i(d,m)`, `[task][node]` (eq. 11).
    pub dt_r: Vec<Vec<f64>>,
    /// Max result-path hop count from each node to the destination over
    /// active result edges (`h⁺` in eq. 16).
    pub h_plus: Vec<Vec<usize>>,
    /// Max data-path hop count from each node to a computation exit (`h⁻`).
    pub h_minus: Vec<Vec<usize>>,
}

/// Compute all marginal quantities. Fails only on routing loops (which
/// [`super::flows::compute_flows`] would already have rejected).
pub fn compute_marginals(
    net: &Network,
    phi: &Strategy,
    flows: &FlowState,
) -> Result<Marginals, FlowError> {
    let n = net.n();
    let s_count = net.s();
    let g_ref = &net.graph;

    let d_link: Vec<f64> = (0..net.e())
        .map(|eid| net.link_cost[eid].deriv(flows.link_flow[eid]))
        .collect();
    let c_node: Vec<f64> = (0..n)
        .map(|i| net.comp_cost[i].deriv(flows.workload[i]))
        .collect();

    let mut dt_plus = vec![vec![0.0; n]; s_count];
    let mut dt_r = vec![vec![0.0; n]; s_count];
    let mut h_plus = vec![vec![0usize; n]; s_count];
    let mut h_minus = vec![vec![0usize; n]; s_count];

    for s in 0..s_count {
        let a_m = net.a_of(s);
        let ctype = net.tasks[s].ctype;

        // ---- result plane: ∂T/∂t⁺ via (12), destination pinned to 0 ----
        let rmask = phi.result_active_mask(net, s);
        let order =
            topo_order_masked(g_ref, &rmask).ok_or(FlowError::ResultLoop { task: s })?;
        for &i in order.iter().rev() {
            if i == net.tasks[s].dest {
                dt_plus[s][i] = 0.0;
                continue;
            }
            let mut acc = 0.0;
            for (k, &eid) in g_ref.out_edge_ids(i).iter().enumerate() {
                let frac = phi.result[s][i][k];
                if frac > 0.0 {
                    let j = g_ref.edge(eid).dst;
                    acc += frac * (d_link[eid] + dt_plus[s][j]);
                }
            }
            dt_plus[s][i] = acc;
        }
        h_plus[s] = longest_path_to_sink(g_ref, &rmask)
            .ok_or(FlowError::ResultLoop { task: s })?;

        // ---- data plane: ∂T/∂r via (11) ----
        let dmask = phi.data_active_mask(net, s);
        let order =
            topo_order_masked(g_ref, &dmask).ok_or(FlowError::DataLoop { task: s })?;
        for &i in order.iter().rev() {
            let mut acc = phi.data[s][i][0]
                * (net.comp_weight[i][ctype] * c_node[i] + a_m * dt_plus[s][i]);
            for (k, &eid) in g_ref.out_edge_ids(i).iter().enumerate() {
                let frac = phi.data[s][i][k + 1];
                if frac > 0.0 {
                    let j = g_ref.edge(eid).dst;
                    acc += frac * (d_link[eid] + dt_r[s][j]);
                }
            }
            dt_r[s][i] = acc;
        }
        h_minus[s] = longest_path_to_sink(g_ref, &dmask)
            .ok_or(FlowError::DataLoop { task: s })?;
    }

    Ok(Marginals {
        d_link,
        c_node,
        dt_plus,
        dt_r,
        h_plus,
        h_minus,
    })
}

impl Marginals {
    /// Theorem-1 data-plane marginals `δ⁻_i(d,m)` for node `i`, task `s`:
    /// slot 0 is the local-computation entry
    /// `w_im C'_i + a_m ∂T/∂t⁺_i`, slot `k+1` is
    /// `D'_ij + ∂T/∂r_j` for the k-th out-edge (eq. 13).
    pub fn delta_minus(&self, net: &Network, s: usize, i: usize) -> Vec<f64> {
        let ctype = net.tasks[s].ctype;
        let a_m = net.a_of(s);
        let g_ref = &net.graph;
        let mut out = Vec::with_capacity(g_ref.out_degree(i) + 1);
        out.push(net.comp_weight[i][ctype] * self.c_node[i] + a_m * self.dt_plus[s][i]);
        for &eid in g_ref.out_edge_ids(i) {
            let j = g_ref.edge(eid).dst;
            out.push(self.d_link[eid] + self.dt_r[s][j]);
        }
        out
    }

    /// Theorem-1 result-plane marginals `δ⁺_i(d,m)`: slot `k` is
    /// `D'_ij + ∂T/∂t⁺_j` for the k-th out-edge (eq. 13).
    pub fn delta_plus(&self, net: &Network, s: usize, i: usize) -> Vec<f64> {
        let g_ref = &net.graph;
        let mut out = Vec::with_capacity(g_ref.out_degree(i));
        for &eid in g_ref.out_edge_ids(i) {
            let j = g_ref.edge(eid).dst;
            out.push(self.d_link[eid] + self.dt_plus[s][j]);
        }
        out
    }

    /// Lemma-1 partial derivative `∂T/∂φ⁻_ij` (eq. 9): `t⁻_i · δ⁻_ij`.
    pub fn dphi_minus(
        &self,
        net: &Network,
        flows: &FlowState,
        s: usize,
        i: usize,
    ) -> Vec<f64> {
        self.delta_minus(net, s, i)
            .into_iter()
            .map(|d| flows.t_minus[s][i] * d)
            .collect()
    }

    /// Lemma-1 partial derivative `∂T/∂φ⁺_ij` (eq. 10): `t⁺_i · δ⁺_ij`.
    pub fn dphi_plus(
        &self,
        net: &Network,
        flows: &FlowState,
        s: usize,
        i: usize,
    ) -> Vec<f64> {
        self.delta_plus(net, s, i)
            .into_iter()
            .map(|d| flows.t_plus[s][i] * d)
            .collect()
    }
}

/// Maximum complementarity violation of the Theorem-1 conditions:
/// `max over (s,i) active slots of φ · (δ − min_k δ_k)`.
/// Zero (≤ tol) ⇔ the sufficient optimality conditions hold ⇔ `φ` is
/// globally optimal.
pub fn theorem1_residual(net: &Network, phi: &Strategy, marg: &Marginals) -> f64 {
    let mut worst = 0.0f64;
    for s in 0..net.s() {
        for i in 0..net.n() {
            let dm = marg.delta_minus(net, s, i);
            let dmin = dm.iter().cloned().fold(f64::INFINITY, f64::min);
            for (slot, &d) in dm.iter().enumerate() {
                let frac = phi.data[s][i][slot];
                if frac > 0.0 {
                    worst = worst.max(frac * (d - dmin));
                }
            }
            if i != net.tasks[s].dest && net.graph.out_degree(i) > 0 {
                let dp = marg.delta_plus(net, s, i);
                let pmin = dp.iter().cloned().fold(f64::INFINITY, f64::min);
                for (slot, &d) in dp.iter().enumerate() {
                    let frac = phi.result[s][i][slot];
                    if frac > 0.0 {
                        worst = worst.max(frac * (d - pmin));
                    }
                }
            }
        }
    }
    worst
}

/// Lemma-1 (KKT) residual: same complementarity check but on the *scaled*
/// derivatives `∂T/∂φ = t·δ`. Satisfied trivially at zero-traffic nodes —
/// exactly the gap Fig. 3 exhibits.
pub fn lemma1_residual(
    net: &Network,
    phi: &Strategy,
    flows: &FlowState,
    marg: &Marginals,
) -> f64 {
    let mut worst = 0.0f64;
    for s in 0..net.s() {
        for i in 0..net.n() {
            let dm = marg.dphi_minus(net, flows, s, i);
            let dmin = dm.iter().cloned().fold(f64::INFINITY, f64::min);
            for (slot, &d) in dm.iter().enumerate() {
                if phi.data[s][i][slot] > 0.0 {
                    worst = worst.max(phi.data[s][i][slot] * (d - dmin));
                }
            }
            if i != net.tasks[s].dest && net.graph.out_degree(i) > 0 {
                let dp = marg.dphi_plus(net, flows, s, i);
                let pmin = dp.iter().cloned().fold(f64::INFINITY, f64::min);
                for (slot, &d) in dp.iter().enumerate() {
                    if phi.result[s][i][slot] > 0.0 {
                        worst = worst.max(phi.result[s][i][slot] * (d - pmin));
                    }
                }
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flows::compute_flows;
    use crate::model::network::testnet::{diamond, line3};
    use crate::model::strategy::out_slot;

    fn setup(net: &Network, phi: &Strategy) -> (FlowState, Marginals) {
        let fs = compute_flows(net, phi).unwrap();
        let m = compute_marginals(net, phi, &fs).unwrap();
        (fs, m)
    }

    #[test]
    fn destination_marginal_is_zero() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let (_, m) = setup(&net, &phi);
        assert_eq!(m.dt_plus[0][3], 0.0);
        // all other nodes see positive result marginals (they must pay to
        // move results toward 3)
        for i in 0..3 {
            assert!(m.dt_plus[0][i] > 0.0, "dt_plus[{i}]");
        }
    }

    #[test]
    fn recursion_12_holds() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let (_, m) = setup(&net, &phi);
        let g = &net.graph;
        for i in 0..net.n() {
            if i == 3 {
                continue;
            }
            let mut expect = 0.0;
            for (k, &eid) in g.out_edge_ids(i).iter().enumerate() {
                let j = g.edge(eid).dst;
                expect += phi.result[0][i][k] * (m.d_link[eid] + m.dt_plus[0][j]);
            }
            assert!((m.dt_plus[0][i] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn recursion_11_holds() {
        let net = line3();
        let phi = Strategy::local_compute_init(&net);
        let (_, m) = setup(&net, &phi);
        for s in 0..net.s() {
            let a = net.a_of(s);
            let ct = net.tasks[s].ctype;
            for i in 0..net.n() {
                // local-compute init: φ_i0 = 1
                let expect = net.comp_weight[i][ct] * m.c_node[i] + a * m.dt_plus[s][i];
                assert!(
                    (m.dt_r[s][i] - expect).abs() < 1e-12,
                    "task {s} node {i}: {} vs {}",
                    m.dt_r[s][i],
                    expect
                );
            }
        }
    }

    /// The core correctness check: ∂T/∂φ from (9)/(10) matches numeric
    /// differentiation of T under an off-simplex bump of one fraction.
    #[test]
    fn partials_match_finite_differences() {
        let net = diamond(true);
        let mut phi = Strategy::compute_at_dest_init(&net);
        // make an interior point so every plane carries traffic:
        // node 0 splits 30% local / 40% ->1 / 30% ->2
        let s1 = out_slot(&net.graph, 0, 1).unwrap();
        let s2 = out_slot(&net.graph, 0, 2).unwrap();
        phi.data[0][0] = vec![0.0; net.graph.out_degree(0) + 1];
        phi.data[0][0][0] = 0.3;
        phi.data[0][0][s1 + 1] = 0.4;
        phi.data[0][0][s2 + 1] = 0.3;
        // node 0's results go via 2 (so a test bump of 1→0 on the result
        // plane cannot close a loop through 0→1)
        let r2 = out_slot(&net.graph, 0, 2).unwrap();
        phi.result[0][0] = vec![0.0; net.graph.out_degree(0)];
        phi.result[0][0][r2] = 1.0;
        // node 1 results to 3 (already from compute_at_dest_init), data too
        let (fs, m) = setup(&net, &phi);
        assert!(fs.conservation_violations(&net, &phi).is_empty());

        let eps = 1e-6;
        // data-plane slots of node 0
        let analytic = m.dphi_minus(&net, &fs, 0, 0);
        for slot in 0..analytic.len() {
            let mut bumped = phi.clone();
            bumped.data[0][0][slot] += eps;
            let t1 = compute_flows(&net, &bumped).unwrap().total_cost;
            let t0 = fs.total_cost;
            let numeric = (t1 - t0) / eps;
            assert!(
                (analytic[slot] - numeric).abs() < 1e-4 * (1.0 + numeric.abs()),
                "slot {slot}: analytic {} vs numeric {}",
                analytic[slot],
                numeric
            );
        }
        // result-plane slots of node 1
        let analytic = m.dphi_plus(&net, &fs, 0, 1);
        for slot in 0..analytic.len() {
            let mut bumped = phi.clone();
            bumped.result[0][1][slot] += eps;
            let t1 = compute_flows(&net, &bumped).unwrap().total_cost;
            let numeric = (t1 - fs.total_cost) / eps;
            assert!(
                (analytic[slot] - numeric).abs() < 1e-4 * (1.0 + numeric.abs()),
                "slot {slot}: analytic {} vs numeric {}",
                analytic[slot],
                numeric
            );
        }
    }

    #[test]
    fn h_statistics() {
        let net = diamond(true);
        let phi = Strategy::compute_at_dest_init(&net);
        let (_, m) = setup(&net, &phi);
        // data path 0 -> 1|2 -> 3: longest data path from 0 is 2 hops
        assert_eq!(m.h_minus[0][0], 2);
        assert_eq!(m.h_minus[0][3], 0);
        // no result flows: h_plus still reflects φ⁺ tree
        assert!(m.h_plus[0][0] >= 1);
    }

    #[test]
    fn residuals_nonnegative_and_zero_only_when_optimal_shape() {
        let net = diamond(false); // linear costs: SP is optimal
        let phi = Strategy::compute_at_dest_init(&net);
        let (fs, m) = setup(&net, &phi);
        let r1 = lemma1_residual(&net, &phi, &fs, &m);
        let rt = theorem1_residual(&net, &phi, &m);
        assert!(r1 >= 0.0 && rt >= 0.0);
    }

    #[test]
    fn delta_minus_slot0_formula() {
        let net = line3();
        let phi = Strategy::local_compute_init(&net);
        let (_, m) = setup(&net, &phi);
        for s in 0..net.s() {
            for i in 0..net.n() {
                let d = m.delta_minus(&net, s, i);
                let expect =
                    net.w_of(i, s) * m.c_node[i] + net.a_of(s) * m.dt_plus[s][i];
                assert!((d[0] - expect).abs() < 1e-12);
            }
        }
    }
}
