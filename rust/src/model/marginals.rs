//! Marginal costs and the Theorem-1 quantities `δ±` (§III).
//!
//! `∂T/∂t⁺_i(d,m)` and `∂T/∂r_i(d,m)` satisfy the recursions (12) and (11),
//! which are well-founded precisely because the strategy is loop-free: they
//! are reverse-topological dynamic programs over the active result/data
//! subgraphs. This module is the *centralized* computation used by the
//! optimizer loop; `sim::protocol` implements the same recursions as the
//! paper's two-stage distributed broadcast and an integration test pins
//! them to each other.

use crate::graph::algorithms::{
    longest_path_to_sink_into, topo_order_masked_into, TopoScratch,
};

use super::flows::{FlowError, FlowState};
use super::network::Network;
use super::strategy::Strategy;

/// Read-only view over marginal-cost state, implemented by both the nested
/// [`Marginals`] and the flat [`MarginalScratch`], so the optimizer layers
/// (blocked sets, scaling matrices, Theorem-1 residuals) are generic over
/// the storage layout and bit-identical on either.
pub trait MargView {
    /// `D'_ij(F_ij)` per directed edge.
    fn d_link(&self) -> &[f64];
    /// `C'_i(G_i)` per node.
    fn c_node(&self) -> &[f64];
    /// `∂T/∂t⁺` row of task `s` (length `n`).
    fn dt_plus_task(&self, s: usize) -> &[f64];
    /// `∂T/∂r` row of task `s` (length `n`).
    fn dt_r_task(&self, s: usize) -> &[f64];
    /// `h⁺` row of task `s`.
    fn h_plus_task(&self, s: usize) -> &[usize];
    /// `h⁻` row of task `s`.
    fn h_minus_task(&self, s: usize) -> &[usize];
}

/// Marginal-cost state for one `(network, strategy, flows)` triple.
#[derive(Clone, Debug)]
pub struct Marginals {
    /// `D'_ij(F_ij)` per directed edge.
    pub d_link: Vec<f64>,
    /// `C'_i(G_i)` per node.
    pub c_node: Vec<f64>,
    /// `∂T/∂t⁺_i(d,m)`, `[task][node]` (eq. 12; 0 at the destination).
    pub dt_plus: Vec<Vec<f64>>,
    /// `∂T/∂r_i(d,m)`, `[task][node]` (eq. 11).
    pub dt_r: Vec<Vec<f64>>,
    /// Max result-path hop count from each node to the destination over
    /// active result edges (`h⁺` in eq. 16).
    pub h_plus: Vec<Vec<usize>>,
    /// Max data-path hop count from each node to a computation exit (`h⁻`).
    pub h_minus: Vec<Vec<usize>>,
}

/// Flat, row-major scratch arena for marginal computation: the nested
/// `Vec<Vec<..>>` tables of [`Marginals`] become `len = s·n` buffers with
/// stride-`n` indexing, plus the mask/topo scratch the recursions need, so
/// [`compute_marginals_into`] performs zero heap allocation after warm-up.
/// One per worker thread; never shared.
#[derive(Clone, Debug, Default)]
pub struct MarginalScratch {
    d_link: Vec<f64>,
    c_node: Vec<f64>,
    /// Flat `[task][node]` with stride `n`: `dt_plus[s*n + i]`.
    dt_plus: Vec<f64>,
    dt_r: Vec<f64>,
    h_plus: Vec<usize>,
    h_minus: Vec<usize>,
    /// Row stride (node count of the network last `ensure`d).
    n: usize,
    mask: Vec<bool>,
    topo: TopoScratch,
    order: Vec<usize>,
}

impl MarginalScratch {
    pub fn new() -> MarginalScratch {
        MarginalScratch::default()
    }

    /// Resize every buffer for `net`'s shape. Growing and shrinking are
    /// both fine — a workspace may be reused across differently-shaped
    /// networks; [`compute_marginals_into`] re-fills every row it reads.
    pub fn ensure(&mut self, net: &Network) {
        let n = net.n();
        let e = net.e();
        let s = net.s();
        self.n = n;
        self.d_link.resize(e, 0.0);
        self.c_node.resize(n, 0.0);
        self.dt_plus.resize(s * n, 0.0);
        self.dt_r.resize(s * n, 0.0);
        self.h_plus.resize(s * n, 0);
        self.h_minus.resize(s * n, 0);
        // shrink paths: resize only truncates, lengths must match exactly
        self.d_link.truncate(e);
        self.c_node.truncate(n);
        self.dt_plus.truncate(s * n);
        self.dt_r.truncate(s * n);
        self.h_plus.truncate(s * n);
        self.h_minus.truncate(s * n);
    }

    /// Unpack into the nested [`Marginals`] layout (pure copies — every
    /// value is bitwise the one the flat computation produced).
    pub fn to_marginals(&self) -> Marginals {
        let n = self.n;
        let unpack_f = |flat: &[f64]| -> Vec<Vec<f64>> {
            if n == 0 {
                return Vec::new();
            }
            flat.chunks(n).map(|row| row.to_vec()).collect()
        };
        let unpack_u = |flat: &[usize]| -> Vec<Vec<usize>> {
            if n == 0 {
                return Vec::new();
            }
            flat.chunks(n).map(|row| row.to_vec()).collect()
        };
        Marginals {
            d_link: self.d_link.clone(),
            c_node: self.c_node.clone(),
            dt_plus: unpack_f(&self.dt_plus),
            dt_r: unpack_f(&self.dt_r),
            h_plus: unpack_u(&self.h_plus),
            h_minus: unpack_u(&self.h_minus),
        }
    }
}

impl MargView for MarginalScratch {
    fn d_link(&self) -> &[f64] {
        &self.d_link
    }
    fn c_node(&self) -> &[f64] {
        &self.c_node
    }
    fn dt_plus_task(&self, s: usize) -> &[f64] {
        &self.dt_plus[s * self.n..(s + 1) * self.n]
    }
    fn dt_r_task(&self, s: usize) -> &[f64] {
        &self.dt_r[s * self.n..(s + 1) * self.n]
    }
    fn h_plus_task(&self, s: usize) -> &[usize] {
        &self.h_plus[s * self.n..(s + 1) * self.n]
    }
    fn h_minus_task(&self, s: usize) -> &[usize] {
        &self.h_minus[s * self.n..(s + 1) * self.n]
    }
}

impl MargView for Marginals {
    fn d_link(&self) -> &[f64] {
        &self.d_link
    }
    fn c_node(&self) -> &[f64] {
        &self.c_node
    }
    fn dt_plus_task(&self, s: usize) -> &[f64] {
        &self.dt_plus[s]
    }
    fn dt_r_task(&self, s: usize) -> &[f64] {
        &self.dt_r[s]
    }
    fn h_plus_task(&self, s: usize) -> &[usize] {
        &self.h_plus[s]
    }
    fn h_minus_task(&self, s: usize) -> &[usize] {
        &self.h_minus[s]
    }
}

/// Compute all marginal quantities. Fails only on routing loops (which
/// [`super::flows::compute_flows`] would already have rejected).
pub fn compute_marginals(
    net: &Network,
    phi: &Strategy,
    flows: &FlowState,
) -> Result<Marginals, FlowError> {
    let mut scratch = MarginalScratch::new();
    compute_marginals_into(net, phi, flows, &mut scratch)?;
    Ok(scratch.to_marginals())
}

/// [`compute_marginals`] into a reusable flat scratch arena —
/// allocation-free after warm-up. Arithmetic is identical to the nested
/// form: the recursions walk the same deterministic topological order and
/// accumulate in the same slot order, and each `dt` row is re-zeroed
/// before its recursion so fractions in `(0, ACTIVE_EPS]` (excluded from
/// the active mask but read with `> 0.0`) see exactly the zeros a fresh
/// allocation would give them.
pub fn compute_marginals_into(
    net: &Network,
    phi: &Strategy,
    flows: &FlowState,
    scratch: &mut MarginalScratch,
) -> Result<(), FlowError> {
    scratch.ensure(net);
    let n = net.n();
    let s_count = net.s();
    let g_ref = &net.graph;

    let MarginalScratch {
        d_link,
        c_node,
        dt_plus,
        dt_r,
        h_plus,
        h_minus,
        mask,
        topo,
        order,
        ..
    } = scratch;

    for (eid, d) in d_link.iter_mut().enumerate() {
        *d = net.link_cost[eid].deriv(flows.link_flow[eid]);
    }
    for (i, c) in c_node.iter_mut().enumerate() {
        *c = net.comp_cost[i].deriv(flows.workload[i]);
    }

    for s in 0..s_count {
        let a_m = net.a_of(s);
        let ctype = net.tasks[s].ctype;
        let base = s * n;

        // ---- result plane: ∂T/∂t⁺ via (12), destination pinned to 0 ----
        phi.result_active_mask_into(net, s, mask);
        if !topo_order_masked_into(g_ref, mask, topo, order) {
            return Err(FlowError::ResultLoop { task: s });
        }
        let dtp = &mut dt_plus[base..base + n];
        dtp.fill(0.0);
        for &i in order.iter().rev() {
            if i == net.tasks[s].dest {
                dtp[i] = 0.0;
                continue;
            }
            let mut acc = 0.0;
            for (k, &eid) in g_ref.out_edge_ids(i).iter().enumerate() {
                let frac = phi.result[s][i][k];
                if frac > 0.0 {
                    let j = g_ref.edge(eid).dst;
                    acc += frac * (d_link[eid] + dtp[j]);
                }
            }
            dtp[i] = acc;
        }
        longest_path_to_sink_into(g_ref, mask, order, &mut h_plus[base..base + n]);

        // ---- data plane: ∂T/∂r via (11) ----
        phi.data_active_mask_into(net, s, mask);
        if !topo_order_masked_into(g_ref, mask, topo, order) {
            return Err(FlowError::DataLoop { task: s });
        }
        let dtp = &dt_plus[base..base + n];
        let dtr = &mut dt_r[base..base + n];
        dtr.fill(0.0);
        for &i in order.iter().rev() {
            let mut acc = phi.data[s][i][0]
                * (net.comp_weight[i][ctype] * c_node[i] + a_m * dtp[i]);
            for (k, &eid) in g_ref.out_edge_ids(i).iter().enumerate() {
                let frac = phi.data[s][i][k + 1];
                if frac > 0.0 {
                    let j = g_ref.edge(eid).dst;
                    acc += frac * (d_link[eid] + dtr[j]);
                }
            }
            dtr[i] = acc;
        }
        longest_path_to_sink_into(g_ref, mask, order, &mut h_minus[base..base + n]);
    }
    Ok(())
}

/// Theorem-1 data-plane marginals `δ⁻_i(d,m)` written into a caller-owned
/// buffer: slot 0 is the local-computation entry `w_im C'_i + a_m ∂T/∂t⁺_i`,
/// slot `k+1` is `D'_ij + ∂T/∂r_j` for the k-th out-edge (eq. 13).
/// Allocation-free once `out`'s capacity covers the out-degree.
pub fn delta_minus_into<M: MargView + ?Sized>(
    marg: &M,
    net: &Network,
    s: usize,
    i: usize,
    out: &mut Vec<f64>,
) {
    let ctype = net.tasks[s].ctype;
    let a_m = net.a_of(s);
    let g_ref = &net.graph;
    out.clear();
    out.reserve(g_ref.out_degree(i) + 1);
    out.push(net.comp_weight[i][ctype] * marg.c_node()[i] + a_m * marg.dt_plus_task(s)[i]);
    let d_link = marg.d_link();
    let dt_r = marg.dt_r_task(s);
    for &eid in g_ref.out_edge_ids(i) {
        let j = g_ref.edge(eid).dst;
        out.push(d_link[eid] + dt_r[j]);
    }
}

/// Theorem-1 result-plane marginals `δ⁺_i(d,m)` into a caller-owned buffer:
/// slot `k` is `D'_ij + ∂T/∂t⁺_j` for the k-th out-edge (eq. 13).
pub fn delta_plus_into<M: MargView + ?Sized>(
    marg: &M,
    net: &Network,
    s: usize,
    i: usize,
    out: &mut Vec<f64>,
) {
    let g_ref = &net.graph;
    out.clear();
    out.reserve(g_ref.out_degree(i));
    let d_link = marg.d_link();
    let dt_plus = marg.dt_plus_task(s);
    for &eid in g_ref.out_edge_ids(i) {
        let j = g_ref.edge(eid).dst;
        out.push(d_link[eid] + dt_plus[j]);
    }
}

impl Marginals {
    /// Theorem-1 data-plane marginals `δ⁻_i(d,m)` for node `i`, task `s`
    /// (see [`delta_minus_into`]).
    pub fn delta_minus(&self, net: &Network, s: usize, i: usize) -> Vec<f64> {
        let mut out = Vec::new();
        delta_minus_into(self, net, s, i, &mut out);
        out
    }

    /// Theorem-1 result-plane marginals `δ⁺_i(d,m)`: slot `k` is
    /// `D'_ij + ∂T/∂t⁺_j` for the k-th out-edge (eq. 13).
    pub fn delta_plus(&self, net: &Network, s: usize, i: usize) -> Vec<f64> {
        let mut out = Vec::new();
        delta_plus_into(self, net, s, i, &mut out);
        out
    }

    /// Lemma-1 partial derivative `∂T/∂φ⁻_ij` (eq. 9): `t⁻_i · δ⁻_ij`.
    pub fn dphi_minus(
        &self,
        net: &Network,
        flows: &FlowState,
        s: usize,
        i: usize,
    ) -> Vec<f64> {
        self.delta_minus(net, s, i)
            .into_iter()
            .map(|d| flows.t_minus[s][i] * d)
            .collect()
    }

    /// Lemma-1 partial derivative `∂T/∂φ⁺_ij` (eq. 10): `t⁺_i · δ⁺_ij`.
    pub fn dphi_plus(
        &self,
        net: &Network,
        flows: &FlowState,
        s: usize,
        i: usize,
    ) -> Vec<f64> {
        self.delta_plus(net, s, i)
            .into_iter()
            .map(|d| flows.t_plus[s][i] * d)
            .collect()
    }
}

/// Maximum complementarity violation of the Theorem-1 conditions:
/// `max over (s,i) active slots of φ · (δ − min_k δ_k)`.
/// Zero (≤ tol) ⇔ the sufficient optimality conditions hold ⇔ `φ` is
/// globally optimal.
pub fn theorem1_residual<M: MargView + ?Sized>(
    net: &Network,
    phi: &Strategy,
    marg: &M,
) -> f64 {
    let mut buf = Vec::new();
    theorem1_residual_with(net, phi, marg, &mut buf)
}

/// [`theorem1_residual`] with a caller-owned δ buffer (allocation-free
/// after warm-up). `δ⁻` is fully consumed before `δ⁺` overwrites the
/// buffer, so one buffer serves both planes with identical arithmetic.
pub fn theorem1_residual_with<M: MargView + ?Sized>(
    net: &Network,
    phi: &Strategy,
    marg: &M,
    buf: &mut Vec<f64>,
) -> f64 {
    let mut worst = 0.0f64;
    for s in 0..net.s() {
        for i in 0..net.n() {
            delta_minus_into(marg, net, s, i, buf);
            let dmin = buf.iter().cloned().fold(f64::INFINITY, f64::min);
            for (slot, &d) in buf.iter().enumerate() {
                let frac = phi.data[s][i][slot];
                if frac > 0.0 {
                    worst = worst.max(frac * (d - dmin));
                }
            }
            if i != net.tasks[s].dest && net.graph.out_degree(i) > 0 {
                delta_plus_into(marg, net, s, i, buf);
                let pmin = buf.iter().cloned().fold(f64::INFINITY, f64::min);
                for (slot, &d) in buf.iter().enumerate() {
                    let frac = phi.result[s][i][slot];
                    if frac > 0.0 {
                        worst = worst.max(frac * (d - pmin));
                    }
                }
            }
        }
    }
    worst
}

/// Lemma-1 (KKT) residual: same complementarity check but on the *scaled*
/// derivatives `∂T/∂φ = t·δ`. Satisfied trivially at zero-traffic nodes —
/// exactly the gap Fig. 3 exhibits.
pub fn lemma1_residual(
    net: &Network,
    phi: &Strategy,
    flows: &FlowState,
    marg: &Marginals,
) -> f64 {
    let mut worst = 0.0f64;
    for s in 0..net.s() {
        for i in 0..net.n() {
            let dm = marg.dphi_minus(net, flows, s, i);
            let dmin = dm.iter().cloned().fold(f64::INFINITY, f64::min);
            for (slot, &d) in dm.iter().enumerate() {
                if phi.data[s][i][slot] > 0.0 {
                    worst = worst.max(phi.data[s][i][slot] * (d - dmin));
                }
            }
            if i != net.tasks[s].dest && net.graph.out_degree(i) > 0 {
                let dp = marg.dphi_plus(net, flows, s, i);
                let pmin = dp.iter().cloned().fold(f64::INFINITY, f64::min);
                for (slot, &d) in dp.iter().enumerate() {
                    if phi.result[s][i][slot] > 0.0 {
                        worst = worst.max(phi.result[s][i][slot] * (d - pmin));
                    }
                }
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flows::compute_flows;
    use crate::model::network::testnet::{diamond, line3};
    use crate::model::strategy::out_slot;

    fn setup(net: &Network, phi: &Strategy) -> (FlowState, Marginals) {
        let fs = compute_flows(net, phi).unwrap();
        let m = compute_marginals(net, phi, &fs).unwrap();
        (fs, m)
    }

    #[test]
    fn destination_marginal_is_zero() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let (_, m) = setup(&net, &phi);
        assert_eq!(m.dt_plus[0][3], 0.0);
        // all other nodes see positive result marginals (they must pay to
        // move results toward 3)
        for i in 0..3 {
            assert!(m.dt_plus[0][i] > 0.0, "dt_plus[{i}]");
        }
    }

    #[test]
    fn recursion_12_holds() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let (_, m) = setup(&net, &phi);
        let g = &net.graph;
        for i in 0..net.n() {
            if i == 3 {
                continue;
            }
            let mut expect = 0.0;
            for (k, &eid) in g.out_edge_ids(i).iter().enumerate() {
                let j = g.edge(eid).dst;
                expect += phi.result[0][i][k] * (m.d_link[eid] + m.dt_plus[0][j]);
            }
            assert!((m.dt_plus[0][i] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn recursion_11_holds() {
        let net = line3();
        let phi = Strategy::local_compute_init(&net);
        let (_, m) = setup(&net, &phi);
        for s in 0..net.s() {
            let a = net.a_of(s);
            let ct = net.tasks[s].ctype;
            for i in 0..net.n() {
                // local-compute init: φ_i0 = 1
                let expect = net.comp_weight[i][ct] * m.c_node[i] + a * m.dt_plus[s][i];
                assert!(
                    (m.dt_r[s][i] - expect).abs() < 1e-12,
                    "task {s} node {i}: {} vs {}",
                    m.dt_r[s][i],
                    expect
                );
            }
        }
    }

    /// The core correctness check: ∂T/∂φ from (9)/(10) matches numeric
    /// differentiation of T under an off-simplex bump of one fraction.
    #[test]
    fn partials_match_finite_differences() {
        let net = diamond(true);
        let mut phi = Strategy::compute_at_dest_init(&net);
        // make an interior point so every plane carries traffic:
        // node 0 splits 30% local / 40% ->1 / 30% ->2
        let s1 = out_slot(&net.graph, 0, 1).unwrap();
        let s2 = out_slot(&net.graph, 0, 2).unwrap();
        phi.data[0][0] = vec![0.0; net.graph.out_degree(0) + 1];
        phi.data[0][0][0] = 0.3;
        phi.data[0][0][s1 + 1] = 0.4;
        phi.data[0][0][s2 + 1] = 0.3;
        // node 0's results go via 2 (so a test bump of 1→0 on the result
        // plane cannot close a loop through 0→1)
        let r2 = out_slot(&net.graph, 0, 2).unwrap();
        phi.result[0][0] = vec![0.0; net.graph.out_degree(0)];
        phi.result[0][0][r2] = 1.0;
        // node 1 results to 3 (already from compute_at_dest_init), data too
        let (fs, m) = setup(&net, &phi);
        assert!(fs.is_conserved(&net, &phi));

        // the flat `_into` form must reproduce the nested tables bitwise,
        // so the finite-difference comparisons below cover both paths
        let mut scratch = MarginalScratch::new();
        compute_marginals_into(&net, &phi, &fs, &mut scratch).unwrap();
        for s in 0..net.s() {
            assert_eq!(scratch.dt_plus_task(s), m.dt_plus[s].as_slice());
            assert_eq!(scratch.dt_r_task(s), m.dt_r[s].as_slice());
            assert_eq!(scratch.h_plus_task(s), m.h_plus[s].as_slice());
            assert_eq!(scratch.h_minus_task(s), m.h_minus[s].as_slice());
        }
        assert_eq!(scratch.d_link(), m.d_link.as_slice());
        assert_eq!(scratch.c_node(), m.c_node.as_slice());

        let eps = 1e-6;
        // data-plane slots of node 0, analytic δ⁻ through the flat view
        let mut dm_flat = Vec::new();
        delta_minus_into(&scratch, &net, 0, 0, &mut dm_flat);
        let analytic = m.dphi_minus(&net, &fs, 0, 0);
        let flat_scaled: Vec<f64> =
            dm_flat.iter().map(|d| fs.t_minus[0][0] * d).collect();
        assert_eq!(flat_scaled, analytic);
        for slot in 0..analytic.len() {
            let mut bumped = phi.clone();
            bumped.data[0][0][slot] += eps;
            let t1 = compute_flows(&net, &bumped).unwrap().total_cost;
            let t0 = fs.total_cost;
            let numeric = (t1 - t0) / eps;
            assert!(
                (analytic[slot] - numeric).abs() < 1e-4 * (1.0 + numeric.abs()),
                "slot {slot}: analytic {} vs numeric {}",
                analytic[slot],
                numeric
            );
        }
        // result-plane slots of node 1, again checked through the flat view
        let mut dp_flat = Vec::new();
        delta_plus_into(&scratch, &net, 0, 1, &mut dp_flat);
        let analytic = m.dphi_plus(&net, &fs, 0, 1);
        let flat_scaled: Vec<f64> =
            dp_flat.iter().map(|d| fs.t_plus[0][1] * d).collect();
        assert_eq!(flat_scaled, analytic);
        for slot in 0..analytic.len() {
            let mut bumped = phi.clone();
            bumped.result[0][1][slot] += eps;
            let t1 = compute_flows(&net, &bumped).unwrap().total_cost;
            let numeric = (t1 - fs.total_cost) / eps;
            assert!(
                (analytic[slot] - numeric).abs() < 1e-4 * (1.0 + numeric.abs()),
                "slot {slot}: analytic {} vs numeric {}",
                analytic[slot],
                numeric
            );
        }
    }

    #[test]
    fn h_statistics() {
        let net = diamond(true);
        let phi = Strategy::compute_at_dest_init(&net);
        let (_, m) = setup(&net, &phi);
        // data path 0 -> 1|2 -> 3: longest data path from 0 is 2 hops
        assert_eq!(m.h_minus[0][0], 2);
        assert_eq!(m.h_minus[0][3], 0);
        // no result flows: h_plus still reflects φ⁺ tree
        assert!(m.h_plus[0][0] >= 1);
    }

    #[test]
    fn residuals_nonnegative_and_zero_only_when_optimal_shape() {
        let net = diamond(false); // linear costs: SP is optimal
        let phi = Strategy::compute_at_dest_init(&net);
        let (fs, m) = setup(&net, &phi);
        let r1 = lemma1_residual(&net, &phi, &fs, &m);
        let rt = theorem1_residual(&net, &phi, &m);
        assert!(r1 >= 0.0 && rt >= 0.0);
    }

    #[test]
    fn delta_minus_slot0_formula() {
        let net = line3();
        let phi = Strategy::local_compute_init(&net);
        let (_, m) = setup(&net, &phi);
        for s in 0..net.s() {
            for i in 0..net.n() {
                let d = m.delta_minus(&net, s, i);
                let expect =
                    net.w_of(i, s) * m.c_node[i] + net.a_of(s) * m.dt_plus[s][i];
                assert!((d[0] - expect).abs() < 1e-12);
            }
        }
    }
}
