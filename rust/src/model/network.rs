//! The CEC network instance: graph + tasks + rates + cost functions (§II).

use crate::graph::algorithms::strongly_connected;
use crate::graph::DiGraph;

use super::cost::CostFn;

/// A computation task `(d, m)`: results must reach `dest`, computed with
/// type `ctype ∈ [M]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Task {
    pub dest: usize,
    pub ctype: usize,
}

/// A complete network instance. All vectors are indexed by dense ids:
/// tasks by `s`, nodes by `i`, directed edges by `e`, computation types by
/// `m`.
#[derive(Clone, Debug)]
pub struct Network {
    pub graph: DiGraph,
    pub tasks: Vec<Task>,
    /// Number of computation types `M`.
    pub num_types: usize,
    /// Exogenous data input rates `r_i(d,m)`, indexed `[task][node]`.
    pub input_rate: Vec<Vec<f64>>,
    /// Result-size ratios `a_m`, indexed by type.
    pub result_ratio: Vec<f64>,
    /// Computation weights `w_im`, indexed `[node][type]`.
    pub comp_weight: Vec<Vec<f64>>,
    /// Communication cost `D_ij`, indexed by edge id.
    pub link_cost: Vec<CostFn>,
    /// Computation cost `C_i`, indexed by node.
    pub comp_cost: Vec<CostFn>,
}

impl Network {
    /// Number of nodes `|V|`.
    pub fn n(&self) -> usize {
        self.graph.node_count()
    }

    /// Number of directed edges (2× the undirected link count).
    pub fn e(&self) -> usize {
        self.graph.edge_count()
    }

    /// Number of tasks `|S|`.
    pub fn s(&self) -> usize {
        self.tasks.len()
    }

    /// Result ratio `a_m` for a task.
    pub fn a_of(&self, task: usize) -> f64 {
        self.result_ratio[self.tasks[task].ctype]
    }

    /// Computation weight `w_im` for node `i` under a task's type.
    pub fn w_of(&self, node: usize, task: usize) -> f64 {
        self.comp_weight[node][self.tasks[task].ctype]
    }

    /// Total exogenous input rate of one task.
    pub fn task_input(&self, task: usize) -> f64 {
        self.input_rate[task].iter().sum()
    }

    /// Scale every exogenous input rate by `factor` (Fig. 5c sweeps).
    pub fn scale_rates(&mut self, factor: f64) {
        for per_node in &mut self.input_rate {
            for r in per_node {
                *r *= factor;
            }
        }
    }

    /// Structural validation; returns a list of human-readable problems
    /// (empty = valid instance).
    pub fn validate(&self) -> Vec<String> {
        let mut problems = Vec::new();
        let n = self.n();
        if !strongly_connected(&self.graph) {
            problems.push("graph is not strongly connected".into());
        }
        if self.tasks.is_empty() {
            problems.push("no tasks".into());
        }
        for (s, t) in self.tasks.iter().enumerate() {
            if t.dest >= n {
                problems.push(format!("task {s}: dest {} out of range", t.dest));
            }
            if t.ctype >= self.num_types {
                problems.push(format!("task {s}: ctype {} out of range", t.ctype));
            }
        }
        if self.input_rate.len() != self.s() {
            problems.push("input_rate task dimension mismatch".into());
        }
        for (s, per_node) in self.input_rate.iter().enumerate() {
            if per_node.len() != n {
                problems.push(format!("input_rate[{s}] node dimension mismatch"));
            }
            if per_node.iter().any(|&r| r < 0.0) {
                problems.push(format!("task {s}: negative input rate"));
            }
            if per_node.iter().all(|&r| r == 0.0) {
                problems.push(format!("task {s}: no data sources"));
            }
        }
        if self.result_ratio.len() != self.num_types {
            problems.push("result_ratio dimension mismatch".into());
        }
        if self.result_ratio.iter().any(|&a| a <= 0.0) {
            problems.push("a_m must be positive".into());
        }
        if self.comp_weight.len() != n {
            problems.push("comp_weight node dimension mismatch".into());
        } else if self
            .comp_weight
            .iter()
            .any(|ws| ws.len() != self.num_types || ws.iter().any(|&w| w <= 0.0))
        {
            problems.push("comp_weight entries must be positive, one per type".into());
        }
        if self.link_cost.len() != self.e() {
            problems.push("link_cost edge dimension mismatch".into());
        }
        if self.comp_cost.len() != n {
            problems.push("comp_cost node dimension mismatch".into());
        }
        problems
    }

    /// Panicking validation for construction sites.
    pub fn assert_valid(&self) {
        let problems = self.validate();
        assert!(problems.is_empty(), "invalid network: {problems:?}");
    }

    /// Can every node compute all of its local input within its own
    /// capacity? (The paper's LCOR baseline assumes this — §V.)
    pub fn local_computation_feasible(&self) -> bool {
        let n = self.n();
        for i in 0..n {
            let mut load = 0.0;
            for (s, task) in self.tasks.iter().enumerate() {
                load += self.comp_weight[i][task.ctype] * self.input_rate[s][i];
            }
            if !self.comp_cost[i].value(load).is_finite() {
                return false;
            }
        }
        true
    }

    /// Simulate a node failure (Fig. 5b): all incident links removed, the
    /// node stops being a data source; tasks destined there are retargeted
    /// to `fallback_dest`. Computation capability is disabled by making the
    /// local weight prohibitive through an infinite-cost curve.
    pub fn with_failed_node(&self, dead: usize, fallback_dest: usize) -> Network {
        assert_ne!(dead, fallback_dest);
        let mut net = self.clone();
        net.graph = self.graph.without_node(dead);
        for t in &mut net.tasks {
            if t.dest == dead {
                t.dest = fallback_dest;
            }
        }
        for per_node in &mut net.input_rate {
            per_node[dead] = 0.0;
        }
        // Rebuild link costs for the surviving edge set, preserving each
        // surviving (src,dst)'s original curve.
        let mut link_cost = Vec::with_capacity(net.graph.edge_count());
        for e in net.graph.edges() {
            let old_id = self
                .graph
                .edge_id(e.src, e.dst)
                .expect("surviving edge existed before");
            link_cost.push(self.link_cost[old_id]);
        }
        net.link_cost = link_cost;
        // Disable computation at the dead node: zero capacity.
        net.comp_cost[dead] = CostFn::Queue { cap: 1e-9 };
        net
    }
}

#[cfg(test)]
pub mod testnet {
    //! Small hand-built networks shared across the model/algo test suites.
    use super::*;
    use crate::graph::from_undirected;

    /// 4-node diamond: 0→{1,2}→3 (bidirectional links), one task ending at
    /// node 3, data entering at node 0.
    pub fn diamond(queue: bool) -> Network {
        let graph = from_undirected(4, &[(0, 1), (0, 2), (1, 3), (2, 3)]);
        let e = graph.edge_count();
        let link_cost = if queue {
            vec![CostFn::Queue { cap: 10.0 }; e]
        } else {
            vec![CostFn::Linear { unit: 1.0 }; e]
        };
        let comp_cost = if queue {
            vec![CostFn::Queue { cap: 12.0 }; 4]
        } else {
            vec![CostFn::Linear { unit: 1.0 }; 4]
        };
        Network {
            graph,
            tasks: vec![Task { dest: 3, ctype: 0 }],
            num_types: 1,
            input_rate: vec![vec![1.0, 0.0, 0.0, 0.0]],
            result_ratio: vec![0.5],
            comp_weight: vec![vec![1.0]; 4],
            link_cost,
            comp_cost,
        }
    }

    /// Line 0—1—2, two tasks with distinct destinations and types.
    pub fn line3() -> Network {
        let graph = from_undirected(3, &[(0, 1), (1, 2)]);
        let e = graph.edge_count();
        Network {
            graph,
            tasks: vec![Task { dest: 2, ctype: 0 }, Task { dest: 0, ctype: 1 }],
            num_types: 2,
            input_rate: vec![vec![1.0, 0.5, 0.0], vec![0.0, 0.0, 0.8]],
            result_ratio: vec![0.5, 2.0],
            comp_weight: vec![vec![1.0, 2.0], vec![1.5, 1.0], vec![2.0, 1.0]],
            link_cost: vec![CostFn::Queue { cap: 15.0 }; e],
            comp_cost: vec![CostFn::Queue { cap: 20.0 }; 3],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testnet::{diamond, line3};
    use super::*;

    #[test]
    fn valid_instances_pass() {
        assert!(diamond(true).validate().is_empty());
        assert!(diamond(false).validate().is_empty());
        assert!(line3().validate().is_empty());
    }

    #[test]
    fn accessors() {
        let net = line3();
        assert_eq!(net.n(), 3);
        assert_eq!(net.e(), 4);
        assert_eq!(net.s(), 2);
        assert_eq!(net.a_of(0), 0.5);
        assert_eq!(net.a_of(1), 2.0);
        assert_eq!(net.w_of(1, 0), 1.5);
        assert!((net.task_input(0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn validation_catches_bad_dest() {
        let mut net = diamond(true);
        net.tasks[0].dest = 99;
        assert!(!net.validate().is_empty());
    }

    #[test]
    fn validation_catches_negative_rate() {
        let mut net = diamond(true);
        net.input_rate[0][0] = -1.0;
        assert!(net.validate().iter().any(|p| p.contains("negative")));
    }

    #[test]
    fn validation_catches_sourceless_task() {
        let mut net = diamond(true);
        net.input_rate[0] = vec![0.0; 4];
        assert!(net.validate().iter().any(|p| p.contains("no data sources")));
    }

    #[test]
    fn scale_rates() {
        let mut net = diamond(true);
        net.scale_rates(2.0);
        assert_eq!(net.input_rate[0][0], 2.0);
    }

    #[test]
    fn local_feasibility() {
        let net = diamond(true); // rate 1.0, comp cap 12 — feasible
        assert!(net.local_computation_feasible());
        let mut tight = net.clone();
        tight.comp_cost[0] = CostFn::Queue { cap: 0.5 };
        assert!(!tight.local_computation_feasible());
    }

    #[test]
    fn failure_rewires() {
        let net = diamond(true);
        let failed = net.with_failed_node(1, 3);
        assert!(!failed.graph.has_edge(0, 1));
        assert!(!failed.graph.has_edge(1, 3));
        assert_eq!(failed.link_cost.len(), failed.graph.edge_count());
        // computation disabled at the dead node
        assert!(!failed.comp_cost[1].value(0.1).is_finite());
        // still a valid, strongly-connected instance on the survivors?
        // (0-2-3 path remains; node 1 is isolated so full-graph strong
        // connectivity fails — callers run on the surviving component.)
    }

    #[test]
    fn failure_retargets_dest() {
        let net = line3();
        let failed = net.with_failed_node(2, 0);
        assert_eq!(failed.tasks[0].dest, 0);
        assert_eq!(failed.input_rate[1][2], 0.0);
    }
}
