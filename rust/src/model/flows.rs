//! Exact flow computation for a feasible loop-free strategy (§II eqs 1–7).
//!
//! Given `φ`, the data traffic per task satisfies the linear fixed point
//! `t⁻_i = r_i + Σ_{j∈I(i)} t⁻_j φ⁻_{ji}`. Because the φ-active subgraph is
//! acyclic, one pass in topological order solves it exactly (no iteration,
//! no tolerance). Results follow the same pattern on the result plane with
//! source term `a_m · g_i`.

use crate::graph::algorithms::{topo_order_masked_into, TopoScratch};

use super::network::Network;
use super::strategy::Strategy;

/// All flow quantities of §II for one strategy.
#[derive(Clone, Debug)]
pub struct FlowState {
    /// Data traffic `t⁻_i(d,m)`, `[task][node]`.
    pub t_minus: Vec<Vec<f64>>,
    /// Result traffic `t⁺_i(d,m)`, `[task][node]`.
    pub t_plus: Vec<Vec<f64>>,
    /// Computational input `g_i(d,m)`, `[task][node]`.
    pub g: Vec<Vec<f64>>,
    /// Data flow per directed edge `f⁻_ij(d,m)`, `[task][edge]`.
    pub f_minus: Vec<Vec<f64>>,
    /// Result flow per directed edge `f⁺_ij(d,m)`, `[task][edge]`.
    pub f_plus: Vec<Vec<f64>>,
    /// Aggregate link flow `F_ij`, `[edge]`.
    pub link_flow: Vec<f64>,
    /// Computation workload `G_i = Σ_m w_im g_i^m`, `[node]`.
    pub workload: Vec<f64>,
    /// Total cost `T = Σ D_ij(F_ij) + Σ C_i(G_i)`; may be `+∞` when a
    /// capacitated cost is saturated.
    pub total_cost: f64,
}

/// Why flows could not be computed.
#[derive(Clone, Debug, PartialEq)]
pub enum FlowError {
    /// The data plane of `task` contains a routing loop.
    DataLoop { task: usize },
    /// The result plane of `task` contains a routing loop.
    ResultLoop { task: usize },
}

impl std::fmt::Display for FlowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FlowError::DataLoop { task } => write!(f, "data-plane loop in task {task}"),
            FlowError::ResultLoop { task } => write!(f, "result-plane loop in task {task}"),
        }
    }
}

impl std::error::Error for FlowError {}

impl FlowState {
    /// An all-zero flow state shaped for `net` — the scratch buffer
    /// [`compute_flows_into`] fills. Batched evaluation allocates one of
    /// these and reuses it across candidates.
    pub fn zeroed(net: &Network) -> FlowState {
        let n = net.n();
        let e = net.e();
        let s_count = net.s();
        FlowState {
            t_minus: vec![vec![0.0; n]; s_count],
            t_plus: vec![vec![0.0; n]; s_count],
            g: vec![vec![0.0; n]; s_count],
            f_minus: vec![vec![0.0; e]; s_count],
            f_plus: vec![vec![0.0; e]; s_count],
            link_flow: vec![0.0; e],
            workload: vec![0.0; n],
            total_cost: 0.0,
        }
    }
}

/// Compute all flows and the total cost for a feasible, loop-free strategy.
pub fn compute_flows(net: &Network, phi: &Strategy) -> Result<FlowState, FlowError> {
    let mut fs = FlowState::zeroed(net);
    compute_flows_into(net, phi, &mut fs)?;
    Ok(fs)
}

/// Reusable scratch (active-edge mask + topological-sort buffers) for the
/// allocation-free flow entry points [`compute_flows_with`] and
/// [`recompute_task_flows_with`]. One per worker thread; never shared.
#[derive(Clone, Debug, Default)]
pub struct FlowScratch {
    mask: Vec<bool>,
    topo: TopoScratch,
    order: Vec<usize>,
}

/// [`compute_flows`] into a caller-owned [`FlowState`] buffer (shaped by
/// [`FlowState::zeroed`] for the same network). The arithmetic — loop
/// order, accumulation order — is byte-for-byte the one `compute_flows`
/// performs on fresh buffers, so results are bitwise identical; only the
/// allocations are skipped. This is the single-pass core of
/// `NativeBackend::evaluate_batch`, which prices many candidate
/// strategies against one network without re-allocating the
/// `O(|S|·|E|)` per-task flow planes per candidate.
pub fn compute_flows_into(
    net: &Network,
    phi: &Strategy,
    fs: &mut FlowState,
) -> Result<(), FlowError> {
    let mut scratch = FlowScratch::default();
    compute_flows_with(net, phi, fs, &mut scratch)
}

/// [`compute_flows_into`] with caller-owned mask/topo scratch as well, so
/// the whole flow computation is allocation-free after warm-up. Arithmetic
/// (loop order, accumulation order) is identical to [`compute_flows`]:
/// the mask and topological order come out of the same algorithms, only
/// written into reused buffers.
pub fn compute_flows_with(
    net: &Network,
    phi: &Strategy,
    fs: &mut FlowState,
    scratch: &mut FlowScratch,
) -> Result<(), FlowError> {
    let n = net.n();
    let e = net.e();
    let s_count = net.s();
    let g_ref = &net.graph;

    // Reset the accumulators and the per-task planes that are *read*
    // before every entry is written (an inactive in-edge whose source
    // sits later in the topological order is read as 0 in compute_flows;
    // a stale value from the previous candidate must not leak in).
    // `t_minus` / `t_plus` are fully overwritten below (every node
    // appears in the topological order) and need no reset.
    for s in 0..s_count {
        fs.f_minus[s].fill(0.0);
        fs.f_plus[s].fill(0.0);
        fs.g[s].fill(0.0);
    }
    fs.link_flow.fill(0.0);
    fs.workload.fill(0.0);

    for s in 0..s_count {
        let a_m = net.a_of(s);

        // ---- data plane ----
        phi.data_active_mask_into(net, s, &mut scratch.mask);
        if !topo_order_masked_into(g_ref, &scratch.mask, &mut scratch.topo, &mut scratch.order)
        {
            return Err(FlowError::DataLoop { task: s });
        }
        for &i in &scratch.order {
            let t = net.input_rate[s][i]
                + g_ref
                    .in_edge_ids(i)
                    .iter()
                    .map(|&eid| fs.f_minus[s][eid])
                    .sum::<f64>();
            fs.t_minus[s][i] = t;
            // split to local computation + outgoing data flows (eqs 3,4)
            fs.g[s][i] = t * phi.data[s][i][0];
            for (k, &eid) in g_ref.out_edge_ids(i).iter().enumerate() {
                fs.f_minus[s][eid] = t * phi.data[s][i][k + 1];
            }
        }

        // ---- result plane ----
        phi.result_active_mask_into(net, s, &mut scratch.mask);
        if !topo_order_masked_into(g_ref, &scratch.mask, &mut scratch.topo, &mut scratch.order)
        {
            return Err(FlowError::ResultLoop { task: s });
        }
        for &i in &scratch.order {
            let t = a_m * fs.g[s][i]
                + g_ref
                    .in_edge_ids(i)
                    .iter()
                    .map(|&eid| fs.f_plus[s][eid])
                    .sum::<f64>();
            fs.t_plus[s][i] = t;
            for (k, &eid) in g_ref.out_edge_ids(i).iter().enumerate() {
                fs.f_plus[s][eid] = t * phi.result[s][i][k];
            }
        }

        // ---- aggregates ----
        for eid in 0..e {
            fs.link_flow[eid] += fs.f_minus[s][eid] + fs.f_plus[s][eid];
        }
        let ctype = net.tasks[s].ctype;
        for i in 0..n {
            fs.workload[i] += net.comp_weight[i][ctype] * fs.g[s][i];
        }
    }

    let mut total = 0.0;
    for eid in 0..e {
        total += net.link_cost[eid].value(fs.link_flow[eid]);
    }
    for i in 0..n {
        total += net.comp_cost[i].value(fs.workload[i]);
    }
    fs.total_cost = total;
    Ok(())
}

/// Total cost only (fast path used by line searches).
pub fn total_cost(net: &Network, phi: &Strategy) -> Result<f64, FlowError> {
    Ok(compute_flows(net, phi)?.total_cost)
}

/// Recompute the flows of a **single task** in place, updating the
/// aggregate `link_flow` / `workload` by subtract-old/add-new deltas.
/// `total_cost` is left stale — callers batch task updates and then call
/// [`refresh_total_cost`]. This is the incremental fast path of the
/// per-node Gauss–Seidel sweep (EXPERIMENTS.md §Perf): a single-node
/// strategy change touches only the tasks whose rows changed, so the
/// other `|S|−1` tasks need no recomputation.
pub fn recompute_task_flows(
    net: &Network,
    phi: &Strategy,
    fs: &mut FlowState,
    s: usize,
) -> Result<(), FlowError> {
    let mut scratch = FlowScratch::default();
    recompute_task_flows_with(net, phi, fs, s, &mut scratch)
}

/// [`recompute_task_flows`] with caller-owned mask/topo scratch — the
/// allocation-free form used by the SGP workspace inner loop.
pub fn recompute_task_flows_with(
    net: &Network,
    phi: &Strategy,
    fs: &mut FlowState,
    s: usize,
    scratch: &mut FlowScratch,
) -> Result<(), FlowError> {
    let g_ref = &net.graph;
    let n = net.n();
    let e = net.e();
    let a_m = net.a_of(s);
    let ctype = net.tasks[s].ctype;

    // subtract the task's old contribution from the aggregates
    for eid in 0..e {
        fs.link_flow[eid] -= fs.f_minus[s][eid] + fs.f_plus[s][eid];
    }
    for i in 0..n {
        fs.workload[i] -= net.comp_weight[i][ctype] * fs.g[s][i];
    }

    // Zero the task's per-edge flows before recomputation: the topological
    // order below only respects *active* edges, so a stale value on a
    // newly-inactive edge (src later in the order than dst) would
    // otherwise be read before being overwritten.
    fs.f_minus[s].fill(0.0);
    fs.f_plus[s].fill(0.0);
    fs.g[s].fill(0.0);

    // recompute the task exactly as in compute_flows
    phi.data_active_mask_into(net, s, &mut scratch.mask);
    if !topo_order_masked_into(g_ref, &scratch.mask, &mut scratch.topo, &mut scratch.order) {
        return Err(FlowError::DataLoop { task: s });
    }
    for &i in &scratch.order {
        let t = net.input_rate[s][i]
            + g_ref
                .in_edge_ids(i)
                .iter()
                .map(|&eid| fs.f_minus[s][eid])
                .sum::<f64>();
        fs.t_minus[s][i] = t;
        fs.g[s][i] = t * phi.data[s][i][0];
        for (k, &eid) in g_ref.out_edge_ids(i).iter().enumerate() {
            fs.f_minus[s][eid] = t * phi.data[s][i][k + 1];
        }
    }
    phi.result_active_mask_into(net, s, &mut scratch.mask);
    if !topo_order_masked_into(g_ref, &scratch.mask, &mut scratch.topo, &mut scratch.order) {
        return Err(FlowError::ResultLoop { task: s });
    }
    for &i in &scratch.order {
        let t = a_m * fs.g[s][i]
            + g_ref
                .in_edge_ids(i)
                .iter()
                .map(|&eid| fs.f_plus[s][eid])
                .sum::<f64>();
        fs.t_plus[s][i] = t;
        for (k, &eid) in g_ref.out_edge_ids(i).iter().enumerate() {
            fs.f_plus[s][eid] = t * phi.result[s][i][k];
        }
    }

    // add the new contribution back
    for eid in 0..e {
        fs.link_flow[eid] += fs.f_minus[s][eid] + fs.f_plus[s][eid];
    }
    for i in 0..n {
        fs.workload[i] += net.comp_weight[i][ctype] * fs.g[s][i];
    }
    Ok(())
}

/// Re-price the aggregates after a batch of [`recompute_task_flows`].
pub fn refresh_total_cost(net: &Network, fs: &mut FlowState) -> f64 {
    let mut total = 0.0;
    for eid in 0..net.e() {
        total += net.link_cost[eid].value(fs.link_flow[eid]);
    }
    for i in 0..net.n() {
        total += net.comp_cost[i].value(fs.workload[i]);
    }
    fs.total_cost = total;
    total
}

impl FlowState {
    /// Overwrite this state's per-task planes for task `s` from `other`
    /// (shapes must match). Snapshot/rollback primitive of the optimizer
    /// workspace's double-buffered flow pair — no allocation.
    pub fn copy_task_from(&mut self, other: &FlowState, s: usize) {
        self.t_minus[s].copy_from_slice(&other.t_minus[s]);
        self.t_plus[s].copy_from_slice(&other.t_plus[s]);
        self.g[s].copy_from_slice(&other.g[s]);
        self.f_minus[s].copy_from_slice(&other.f_minus[s]);
        self.f_plus[s].copy_from_slice(&other.f_plus[s]);
    }

    /// Overwrite the aggregates (`link_flow`, `workload`, `total_cost`)
    /// from `other` — the companion of [`FlowState::copy_task_from`].
    pub fn copy_aggregates_from(&mut self, other: &FlowState) {
        self.link_flow.copy_from_slice(&other.link_flow);
        self.workload.copy_from_slice(&other.workload);
        self.total_cost = other.total_cost;
    }

    /// Fast boolean form of [`FlowState::conservation_violations`]: same
    /// checks, same tolerances, but returns at the first violation and
    /// formats no `String`s. Hot-path callers that only test emptiness
    /// should use this.
    pub fn is_conserved(&self, net: &Network, phi: &Strategy) -> bool {
        let g_ref = &net.graph;
        let tol = 1e-8;
        for s in 0..net.s() {
            let a_m = net.a_of(s);
            let dest = net.tasks[s].dest;
            for i in 0..net.n() {
                let arr: f64 = g_ref
                    .in_edge_ids(i)
                    .iter()
                    .map(|&eid| self.f_minus[s][eid])
                    .sum::<f64>()
                    + net.input_rate[s][i];
                if (arr - self.t_minus[s][i]).abs() > tol {
                    return false;
                }
                if (self.g[s][i] - self.t_minus[s][i] * phi.data[s][i][0]).abs() > tol {
                    return false;
                }
                for (k, &eid) in g_ref.out_edge_ids(i).iter().enumerate() {
                    if (self.f_minus[s][eid] - self.t_minus[s][i] * phi.data[s][i][k + 1]).abs()
                        > tol
                    {
                        return false;
                    }
                    if (self.f_plus[s][eid] - self.t_plus[s][i] * phi.result[s][i][k]).abs() > tol
                    {
                        return false;
                    }
                }
                let arr_p: f64 = g_ref
                    .in_edge_ids(i)
                    .iter()
                    .map(|&eid| self.f_plus[s][eid])
                    .sum::<f64>()
                    + a_m * self.g[s][i];
                if (arr_p - self.t_plus[s][i]).abs() > tol {
                    return false;
                }
                if i == dest {
                    let fwd: f64 = g_ref
                        .out_edge_ids(i)
                        .iter()
                        .map(|&eid| self.f_plus[s][eid])
                        .sum();
                    if fwd.abs() > tol {
                        return false;
                    }
                }
            }
            let total_in: f64 = net.input_rate[s].iter().sum();
            let total_g: f64 = self.g[s].iter().sum();
            if (total_in - total_g).abs() > tol * (1.0 + total_in) {
                return false;
            }
            let total_res: f64 = a_m * total_g;
            let delivered = self.t_plus[s][dest];
            if (total_res - delivered).abs() > tol * (1.0 + total_res) {
                return false;
            }
        }
        true
    }

    /// Verify flow conservation (eqs 1–7) against the generating strategy;
    /// returns violations (used by property tests).
    pub fn conservation_violations(&self, net: &Network, phi: &Strategy) -> Vec<String> {
        let mut out = Vec::new();
        let g_ref = &net.graph;
        let tol = 1e-8;
        for s in 0..net.s() {
            let a_m = net.a_of(s);
            let dest = net.tasks[s].dest;
            for i in 0..net.n() {
                // (1): t⁻ = in-flows + exogenous
                let arr: f64 = g_ref
                    .in_edge_ids(i)
                    .iter()
                    .map(|&eid| self.f_minus[s][eid])
                    .sum::<f64>()
                    + net.input_rate[s][i];
                if (arr - self.t_minus[s][i]).abs() > tol {
                    out.push(format!("task {s} node {i}: (1) violated"));
                }
                // (3),(4): splits follow φ⁻
                if (self.g[s][i] - self.t_minus[s][i] * phi.data[s][i][0]).abs() > tol {
                    out.push(format!("task {s} node {i}: (4) violated"));
                }
                for (k, &eid) in g_ref.out_edge_ids(i).iter().enumerate() {
                    if (self.f_minus[s][eid] - self.t_minus[s][i] * phi.data[s][i][k + 1]).abs()
                        > tol
                    {
                        out.push(format!("task {s} edge {eid}: (3) violated"));
                    }
                    if (self.f_plus[s][eid] - self.t_plus[s][i] * phi.result[s][i][k]).abs() > tol
                    {
                        out.push(format!("task {s} edge {eid}: (6) violated"));
                    }
                }
                // (2): t⁺ = in result flows + a_m g
                let arr_p: f64 = g_ref
                    .in_edge_ids(i)
                    .iter()
                    .map(|&eid| self.f_plus[s][eid])
                    .sum::<f64>()
                    + a_m * self.g[s][i];
                if (arr_p - self.t_plus[s][i]).abs() > tol {
                    out.push(format!("task {s} node {i}: (2) violated"));
                }
                // destination absorbs results
                if i == dest {
                    let fwd: f64 = g_ref
                        .out_edge_ids(i)
                        .iter()
                        .map(|&eid| self.f_plus[s][eid])
                        .sum();
                    if fwd.abs() > tol {
                        out.push(format!("task {s}: destination forwards results"));
                    }
                }
            }
            // global balance: all data eventually computed
            let total_in: f64 = net.input_rate[s].iter().sum();
            let total_g: f64 = self.g[s].iter().sum();
            if (total_in - total_g).abs() > tol * (1.0 + total_in) {
                out.push(format!(
                    "task {s}: input {total_in} != computed {total_g}"
                ));
            }
            // global balance: all results delivered at dest
            let total_res: f64 = a_m * total_g;
            let delivered = self.t_plus[s][dest];
            if (total_res - delivered).abs() > tol * (1.0 + total_res) {
                out.push(format!(
                    "task {s}: results {total_res} != delivered {delivered}"
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::network::testnet::{diamond, line3};
    use crate::model::strategy::out_slot;

    #[test]
    fn local_compute_flows() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let fs = compute_flows(&net, &phi).unwrap();
        // all input computed at node 0
        assert!((fs.g[0][0] - 1.0).abs() < 1e-12);
        assert!((fs.workload[0] - 1.0).abs() < 1e-12);
        // results (a=0.5) delivered to dest 3
        assert!((fs.t_plus[0][3] - 0.5).abs() < 1e-12);
        assert!(fs.is_conserved(&net, &phi));
        assert!(fs.total_cost.is_finite());
    }

    #[test]
    fn compute_at_dest_flows() {
        let net = diamond(true);
        let phi = Strategy::compute_at_dest_init(&net);
        let fs = compute_flows(&net, &phi).unwrap();
        // all input computed at node 3
        assert!((fs.g[0][3] - 1.0).abs() < 1e-12);
        // no result flow on links (computed at dest)
        assert!(fs.f_plus[0].iter().all(|&f| f.abs() < 1e-12));
        // data flowed over 2 hops
        let used: usize = fs.f_minus[0].iter().filter(|&&f| f > 1e-12).count();
        assert_eq!(used, 2);
        assert!(fs.is_conserved(&net, &phi));
    }

    #[test]
    fn split_data_flows() {
        let net = diamond(false);
        let mut phi = Strategy::compute_at_dest_init(&net);
        // node 0 splits data 50/50 between neighbors 1 and 2
        let s1 = out_slot(&net.graph, 0, 1).unwrap();
        let s2 = out_slot(&net.graph, 0, 2).unwrap();
        phi.data[0][0] = vec![0.0; net.graph.out_degree(0) + 1];
        phi.data[0][0][s1 + 1] = 0.5;
        phi.data[0][0][s2 + 1] = 0.5;
        // nodes 1 and 2 forward everything to 3
        for i in [1usize, 2] {
            let s3 = out_slot(&net.graph, i, 3).unwrap();
            phi.data[0][i] = vec![0.0; net.graph.out_degree(i) + 1];
            phi.data[0][i][s3 + 1] = 1.0;
        }
        let fs = compute_flows(&net, &phi).unwrap();
        assert!((fs.t_minus[0][1] - 0.5).abs() < 1e-12);
        assert!((fs.t_minus[0][2] - 0.5).abs() < 1e-12);
        assert!((fs.t_minus[0][3] - 1.0).abs() < 1e-12);
        assert!((fs.g[0][3] - 1.0).abs() < 1e-12);
        assert!(fs.is_conserved(&net, &phi));
    }

    #[test]
    fn partial_offloading_mid_path() {
        let net = diamond(true);
        let mut phi = Strategy::compute_at_dest_init(&net);
        // node 0 sends everything to node 1; node 1 computes 40% locally,
        // forwards 60% to 3; results from 1 go to 3.
        let s1 = out_slot(&net.graph, 0, 1).unwrap();
        phi.data[0][0] = vec![0.0; net.graph.out_degree(0) + 1];
        phi.data[0][0][s1 + 1] = 1.0;
        let s13 = out_slot(&net.graph, 1, 3).unwrap();
        phi.data[0][1] = vec![0.0; net.graph.out_degree(1) + 1];
        phi.data[0][1][0] = 0.4;
        phi.data[0][1][s13 + 1] = 0.6;
        phi.result[0][1] = vec![0.0; net.graph.out_degree(1)];
        phi.result[0][1][s13] = 1.0;
        let fs = compute_flows(&net, &phi).unwrap();
        assert!((fs.g[0][1] - 0.4).abs() < 1e-12);
        assert!((fs.g[0][3] - 0.6).abs() < 1e-12);
        // result flow on (1,3): a_m * 0.4 = 0.2
        let e13 = net.graph.edge_id(1, 3).unwrap();
        assert!((fs.f_plus[0][e13] - 0.2).abs() < 1e-12);
        // total link flow on (1,3) = 0.6 data + 0.2 result
        assert!((fs.link_flow[e13] - 0.8).abs() < 1e-12);
        assert!(fs.is_conserved(&net, &phi));
    }

    #[test]
    fn detects_data_loop() {
        let net = diamond(true);
        let mut phi = Strategy::local_compute_init(&net);
        // create a data loop 0 -> 1 -> 0
        let s01 = out_slot(&net.graph, 0, 1).unwrap();
        let s10 = out_slot(&net.graph, 1, 0).unwrap();
        phi.data[0][0] = vec![0.0; net.graph.out_degree(0) + 1];
        phi.data[0][0][s01 + 1] = 1.0;
        phi.data[0][1] = vec![0.0; net.graph.out_degree(1) + 1];
        phi.data[0][1][s10 + 1] = 1.0;
        assert_eq!(
            compute_flows(&net, &phi).unwrap_err(),
            FlowError::DataLoop { task: 0 }
        );
    }

    #[test]
    fn saturated_queue_gives_infinite_cost() {
        let mut net = diamond(true);
        net.input_rate[0][0] = 100.0; // above comp capacity 12
        let phi = Strategy::local_compute_init(&net);
        let fs = compute_flows(&net, &phi).unwrap();
        assert!(fs.total_cost.is_infinite());
    }

    #[test]
    fn multi_task_aggregation() {
        let net = line3();
        let phi = Strategy::local_compute_init(&net);
        let fs = compute_flows(&net, &phi).unwrap();
        // workload at node 1: w(1,type0)*r + w(1,type1)*r = 1.5*0.5
        assert!((fs.workload[1] - 0.75).abs() < 1e-12);
        // node 2 computes task-1 input 0.8 with w=1 -> workload 0.8
        assert!((fs.workload[2] - 0.8).abs() < 1e-12);
        assert!(fs.is_conserved(&net, &phi));
        // task 1 has a=2.0: results delivered at node 0 = 1.6
        assert!((fs.t_plus[1][0] - 1.6).abs() < 1e-12);
    }

    #[test]
    fn compute_flows_into_reuse_is_bitwise_identical() {
        let net = diamond(true);
        let a = Strategy::local_compute_init(&net);
        let b = Strategy::compute_at_dest_init(&net);
        let mut scratch = FlowState::zeroed(&net);
        // dirty the scratch with a different candidate first, then check
        // re-filling it matches a fresh computation exactly
        compute_flows_into(&net, &a, &mut scratch).unwrap();
        compute_flows_into(&net, &b, &mut scratch).unwrap();
        let fresh = compute_flows(&net, &b).unwrap();
        assert_eq!(scratch.t_minus, fresh.t_minus);
        assert_eq!(scratch.t_plus, fresh.t_plus);
        assert_eq!(scratch.g, fresh.g);
        assert_eq!(scratch.f_minus, fresh.f_minus);
        assert_eq!(scratch.f_plus, fresh.f_plus);
        assert_eq!(scratch.link_flow, fresh.link_flow);
        assert_eq!(scratch.workload, fresh.workload);
        assert_eq!(scratch.total_cost.to_bits(), fresh.total_cost.to_bits());
    }

    #[test]
    fn compute_flows_into_recovers_after_loop_error() {
        let net = diamond(true);
        let mut bad = Strategy::local_compute_init(&net);
        let s01 = out_slot(&net.graph, 0, 1).unwrap();
        let s10 = out_slot(&net.graph, 1, 0).unwrap();
        bad.data[0][0] = vec![0.0; net.graph.out_degree(0) + 1];
        bad.data[0][0][s01 + 1] = 1.0;
        bad.data[0][1] = vec![0.0; net.graph.out_degree(1) + 1];
        bad.data[0][1][s10 + 1] = 1.0;
        let good = Strategy::local_compute_init(&net);
        let mut scratch = FlowState::zeroed(&net);
        assert!(compute_flows_into(&net, &bad, &mut scratch).is_err());
        // a failed fill must not poison the next candidate's evaluation
        compute_flows_into(&net, &good, &mut scratch).unwrap();
        let fresh = compute_flows(&net, &good).unwrap();
        assert_eq!(scratch.link_flow, fresh.link_flow);
        assert_eq!(scratch.total_cost.to_bits(), fresh.total_cost.to_bits());
    }

    #[test]
    fn is_conserved_agrees_with_violation_list() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let mut fs = compute_flows(&net, &phi).unwrap();
        assert!(fs.is_conserved(&net, &phi));
        assert!(fs.conservation_violations(&net, &phi).is_empty());
        // tamper with a flow entry: both forms must flag it
        fs.t_minus[0][1] += 1.0;
        assert!(!fs.is_conserved(&net, &phi));
        assert!(!fs.conservation_violations(&net, &phi).is_empty());
    }

    #[test]
    fn task_and_aggregate_copies_roundtrip() {
        let net = line3();
        let a = Strategy::local_compute_init(&net);
        let b = Strategy::compute_at_dest_init(&net);
        let fa = compute_flows(&net, &a).unwrap();
        let mut shadow = compute_flows(&net, &b).unwrap();
        for s in 0..net.s() {
            shadow.copy_task_from(&fa, s);
        }
        shadow.copy_aggregates_from(&fa);
        assert_eq!(shadow.t_minus, fa.t_minus);
        assert_eq!(shadow.t_plus, fa.t_plus);
        assert_eq!(shadow.g, fa.g);
        assert_eq!(shadow.f_minus, fa.f_minus);
        assert_eq!(shadow.f_plus, fa.f_plus);
        assert_eq!(shadow.link_flow, fa.link_flow);
        assert_eq!(shadow.workload, fa.workload);
        assert_eq!(shadow.total_cost.to_bits(), fa.total_cost.to_bits());
    }

    #[test]
    fn total_cost_helper_matches() {
        let net = line3();
        let phi = Strategy::local_compute_init(&net);
        let fs = compute_flows(&net, &phi).unwrap();
        assert_eq!(total_cost(&net, &phi).unwrap(), fs.total_cost);
    }
}
