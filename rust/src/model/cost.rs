//! Congestion-aware convex cost functions (§II).
//!
//! Both communication costs `D_ij(F_ij)` and computation costs `C_i(G_i)`
//! are increasing, continuously differentiable, convex functions; the paper
//! evaluates two families and mentions a third:
//!
//! * `Linear`  — `D(F) = c·F` (propagation-delay-like, no congestion);
//! * `Queue`   — `D(F) = F/(c−F)`, the M/M/1 expected number in system with
//!   service rate `c` (∝ average delay by Little's law), diverging at the
//!   capacity;
//! * `SmoothCap` — `D(F) = s·F − μ·ln(1 − F/c)`: a linear cost plus a log
//!   barrier that smoothly approximates a sharp capacity constraint
//!   `F ≤ c` (the paper's remark about approximating `F_ij ≤ C_ij`).
//!
//! The scaled-gradient-projection algorithm additionally needs
//! `A(T⁰) = sup { D''(F) : D(F) ≤ T⁰ }` (eq. 16): the supremum of the second
//! derivative over the sublevel set reachable while the total cost stays
//! below its initial value. For `Queue` this has the closed form
//! `2(1+T⁰)³/c²`; the other kinds use the same closed-form reasoning or a
//! bisection fallback, all behind [`CostFn::sup_second_deriv`].

/// One convex congestion cost curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CostFn {
    /// `D(F) = unit · F`.
    Linear { unit: f64 },
    /// `D(F) = F / (cap − F)` for `F < cap`, `+∞` otherwise.
    Queue { cap: f64 },
    /// `D(F) = slope·F − mu·ln(1 − F/cap)` for `F < cap`, `+∞` otherwise.
    SmoothCap { slope: f64, cap: f64, mu: f64 },
}

impl CostFn {
    /// Cost value. Returns `+∞` at or beyond capacity for capacitated kinds.
    pub fn value(&self, f: f64) -> f64 {
        debug_assert!(f >= -1e-9, "negative flow {f}");
        let f = f.max(0.0);
        match *self {
            CostFn::Linear { unit } => unit * f,
            CostFn::Queue { cap } => {
                if f >= cap {
                    f64::INFINITY
                } else {
                    f / (cap - f)
                }
            }
            CostFn::SmoothCap { slope, cap, mu } => {
                if f >= cap {
                    f64::INFINITY
                } else {
                    slope * f - mu * (1.0 - f / cap).ln()
                }
            }
        }
    }

    /// First derivative `D'(F)`. `+∞` at/beyond capacity.
    pub fn deriv(&self, f: f64) -> f64 {
        let f = f.max(0.0);
        match *self {
            CostFn::Linear { unit } => unit,
            CostFn::Queue { cap } => {
                if f >= cap {
                    f64::INFINITY
                } else {
                    cap / ((cap - f) * (cap - f))
                }
            }
            CostFn::SmoothCap { slope, cap, mu } => {
                if f >= cap {
                    f64::INFINITY
                } else {
                    slope + mu / (cap - f)
                }
            }
        }
    }

    /// Second derivative `D''(F)`. `+∞` at/beyond capacity.
    pub fn second_deriv(&self, f: f64) -> f64 {
        let f = f.max(0.0);
        match *self {
            CostFn::Linear { .. } => 0.0,
            CostFn::Queue { cap } => {
                if f >= cap {
                    f64::INFINITY
                } else {
                    2.0 * cap / (cap - f).powi(3)
                }
            }
            CostFn::SmoothCap { cap, mu, .. } => {
                if f >= cap {
                    f64::INFINITY
                } else {
                    mu / ((cap - f) * (cap - f))
                }
            }
        }
    }

    /// Marginal cost at zero flow — the SPOO/LPR linearization point.
    pub fn deriv_at_zero(&self) -> f64 {
        self.deriv(0.0)
    }

    /// Capacity (service rate) if the kind has one.
    pub fn capacity(&self) -> Option<f64> {
        match *self {
            CostFn::Linear { .. } => None,
            CostFn::Queue { cap } => Some(cap),
            CostFn::SmoothCap { cap, .. } => Some(cap),
        }
    }

    /// Largest flow with `value(F) ≤ t0` (the sublevel-set boundary).
    ///
    /// Closed form for `Linear` and `Queue`; bisection for `SmoothCap`.
    pub fn sublevel_flow(&self, t0: f64) -> f64 {
        assert!(t0 >= 0.0);
        match *self {
            CostFn::Linear { unit } => {
                if unit <= 0.0 {
                    f64::INFINITY
                } else {
                    t0 / unit
                }
            }
            CostFn::Queue { cap } => cap * t0 / (1.0 + t0),
            CostFn::SmoothCap { cap, .. } => {
                // value is increasing: bisect F in [0, cap)
                let mut lo = 0.0f64;
                let mut hi = cap * (1.0 - 1e-12);
                if self.value(hi) <= t0 {
                    return hi;
                }
                for _ in 0..200 {
                    let mid = 0.5 * (lo + hi);
                    if self.value(mid) <= t0 {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                lo
            }
        }
    }

    /// `A(T⁰) = sup_{D(F) ≤ T⁰} D''(F)` — the curvature bound used by the
    /// SGP scaling matrices (eq. 16). Since all our `D''` are non-decreasing
    /// in `F`, the sup is attained at the sublevel boundary.
    pub fn sup_second_deriv(&self, t0: f64) -> f64 {
        match *self {
            CostFn::Linear { .. } => 0.0,
            CostFn::Queue { cap } => {
                // F_max = cap·T0/(1+T0)  =>  cap − F_max = cap/(1+T0)
                // D'' = 2 cap/(cap−F)³  =>  2 (1+T0)³ / cap²
                2.0 * (1.0 + t0).powi(3) / (cap * cap)
            }
            CostFn::SmoothCap { .. } => self.second_deriv(self.sublevel_flow(t0)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finite_diff(c: &CostFn, f: f64, h: f64) -> (f64, f64) {
        let d1 = (c.value(f + h) - c.value(f - h)) / (2.0 * h);
        let d2 = (c.value(f + h) - 2.0 * c.value(f) + c.value(f - h)) / (h * h);
        (d1, d2)
    }

    #[test]
    fn linear_shapes() {
        let c = CostFn::Linear { unit: 2.5 };
        assert_eq!(c.value(4.0), 10.0);
        assert_eq!(c.deriv(100.0), 2.5);
        assert_eq!(c.second_deriv(1.0), 0.0);
        assert_eq!(c.capacity(), None);
    }

    #[test]
    fn queue_matches_mm1() {
        let c = CostFn::Queue { cap: 10.0 };
        assert!((c.value(5.0) - 1.0).abs() < 1e-12); // 5/(10-5)
        assert!(c.value(10.0).is_infinite());
        assert!(c.value(11.0).is_infinite());
        assert!(c.deriv(10.0).is_infinite());
    }

    #[test]
    fn derivatives_match_finite_differences() {
        let cases = [
            CostFn::Linear { unit: 3.0 },
            CostFn::Queue { cap: 8.0 },
            CostFn::SmoothCap {
                slope: 1.0,
                cap: 8.0,
                mu: 0.5,
            },
        ];
        for c in &cases {
            for &f in &[0.5, 1.0, 3.0, 6.0] {
                let (d1, d2) = finite_diff(c, f, 1e-5);
                assert!(
                    (c.deriv(f) - d1).abs() < 1e-5 * (1.0 + d1.abs()),
                    "{c:?} f={f}: deriv {} vs fd {d1}",
                    c.deriv(f)
                );
                assert!(
                    (c.second_deriv(f) - d2).abs() < 1e-3 * (1.0 + d2.abs()),
                    "{c:?} f={f}: d2 {} vs fd {d2}",
                    c.second_deriv(f)
                );
            }
        }
    }

    #[test]
    fn convexity_and_monotonicity_sampled() {
        let cases = [
            CostFn::Linear { unit: 1.0 },
            CostFn::Queue { cap: 5.0 },
            CostFn::SmoothCap {
                slope: 0.2,
                cap: 5.0,
                mu: 0.1,
            },
        ];
        for c in &cases {
            let mut prev_v = c.value(0.0);
            let mut prev_d = c.deriv(0.0);
            for k in 1..40 {
                let f = 4.9 * k as f64 / 40.0;
                let v = c.value(f);
                let d = c.deriv(f);
                assert!(v >= prev_v - 1e-12, "{c:?} not increasing at {f}");
                assert!(d >= prev_d - 1e-12, "{c:?} not convex at {f}");
                prev_v = v;
                prev_d = d;
            }
        }
    }

    #[test]
    fn queue_sublevel_closed_form() {
        let c = CostFn::Queue { cap: 12.0 };
        for &t0 in &[0.5, 1.0, 4.0] {
            let f = c.sublevel_flow(t0);
            assert!((c.value(f) - t0).abs() < 1e-9);
        }
    }

    #[test]
    fn sup_second_deriv_queue_closed_form() {
        let c = CostFn::Queue { cap: 12.0 };
        let t0 = 2.0;
        let f_max = c.sublevel_flow(t0);
        let expect = c.second_deriv(f_max);
        assert!((c.sup_second_deriv(t0) - expect).abs() < 1e-9 * expect);
    }

    #[test]
    fn sup_second_deriv_linear_zero() {
        assert_eq!(CostFn::Linear { unit: 7.0 }.sup_second_deriv(100.0), 0.0);
    }

    #[test]
    fn smoothcap_sublevel_bisection() {
        let c = CostFn::SmoothCap {
            slope: 1.0,
            cap: 10.0,
            mu: 0.5,
        };
        let f = c.sublevel_flow(3.0);
        assert!((c.value(f) - 3.0).abs() < 1e-6);
        // sup D'' attained at the boundary (D'' increasing)
        assert!(c.sup_second_deriv(3.0) >= c.second_deriv(f * 0.5));
    }

    #[test]
    fn deriv_at_zero() {
        assert_eq!(CostFn::Queue { cap: 4.0 }.deriv_at_zero(), 0.25);
        assert_eq!(CostFn::Linear { unit: 9.0 }.deriv_at_zero(), 9.0);
    }
}
