//! The flow model of §II–§III: network instances, convex congestion costs,
//! routing/offloading strategies, exact flow computation and marginal
//! costs (the `δ±` quantities of Theorem 1).

pub mod cost;
pub mod flows;
pub mod marginals;
pub mod network;
pub mod strategy;

pub use cost::CostFn;
pub use flows::{compute_flows, total_cost, FlowError, FlowState};
pub use marginals::{
    compute_marginals, lemma1_residual, theorem1_residual, Marginals,
};
pub use network::{Network, Task};
pub use strategy::{out_slot, Strategy};
