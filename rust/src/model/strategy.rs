//! The routing/offloading strategy `φ` (§II "Routing and offloading
//! strategy") and its invariants.
//!
//! For every task `s` and node `i`:
//!
//! * `data[s][i]` — the data-plane simplex `φ⁻_i(d,m)`: slot `0` is the
//!   local-computation fraction `φ⁻_i0`, slot `k+1` corresponds to the
//!   `k`-th outgoing edge `g.out_edge_ids(i)[k]`. Constraint (5): the slots
//!   sum to 1.
//! * `result[s][i]` — the result-plane simplex `φ⁺_i(d,m)`: slot `k` is the
//!   `k`-th outgoing edge. Constraint (7): sums to 1 unless `i` is the
//!   task's destination, where all entries are 0 (results exit there).
//!
//! *Loop-freedom* (§IV) is a property of the φ-induced *active subgraphs*:
//! the data plane and the result plane must each be acyclic per task
//! (a data path may legitimately concatenate with a result path into a
//! round trip — the paper's footnote 1 — which is why the two planes are
//! checked independently).

use anyhow::{Context, Result};

use crate::graph::algorithms::{dijkstra_to, has_cycle_masked};
use crate::graph::DiGraph;
use crate::util::json::Json;

use super::network::Network;

/// Fractions below this are treated as "no flow" when building active
/// masks; keeps floating-point dust from creating phantom routing loops.
pub const ACTIVE_EPS: f64 = 1e-12;

#[derive(Clone, Debug, PartialEq)]
pub struct Strategy {
    /// `[task][node][slot]`, slot 0 = local computation, slot k+1 = k-th out-edge.
    pub data: Vec<Vec<Vec<f64>>>,
    /// `[task][node][k]`, k-th out-edge.
    pub result: Vec<Vec<Vec<f64>>>,
}

impl Strategy {
    /// All-zero strategy with the right shape (infeasible until filled).
    pub fn zeroed(net: &Network) -> Strategy {
        let n = net.n();
        let s = net.s();
        let data = (0..s)
            .map(|_| {
                (0..n)
                    .map(|i| vec![0.0; net.graph.out_degree(i) + 1])
                    .collect()
            })
            .collect();
        let result = (0..s)
            .map(|_| {
                (0..n)
                    .map(|i| vec![0.0; net.graph.out_degree(i)])
                    .collect()
            })
            .collect();
        Strategy { data, result }
    }

    /// The paper's safe initial point (§V simulates settings where pure
    /// local computation is feasible): every node computes all arriving
    /// data locally (`φ⁻_i0 = 1`) and routes results along the
    /// shortest-path tree toward each destination under zero-flow marginal
    /// weights `D'(0)`. Loop-free by construction (SP trees are acyclic).
    pub fn local_compute_init(net: &Network) -> Strategy {
        let mut phi = Strategy::zeroed(net);
        let w0: Vec<f64> = net.link_cost.iter().map(|c| c.deriv_at_zero()).collect();
        for (s, task) in net.tasks.iter().enumerate() {
            let (_, next) = dijkstra_to(&net.graph, task.dest, &w0);
            for i in 0..net.n() {
                phi.data[s][i][0] = 1.0;
                if i == task.dest || net.graph.out_degree(i) == 0 {
                    continue; // sink, or isolated (e.g. a failed node)
                }
                let nxt = next[i];
                if nxt == usize::MAX {
                    // disconnected from this destination (can only happen
                    // on degraded graphs); the node carries no traffic for
                    // this task, so a zero result row is harmless.
                    continue;
                }
                let slot = out_slot(&net.graph, i, nxt)
                    .expect("next hop must be an out-neighbor");
                phi.result[s][i][slot] = 1.0;
            }
        }
        phi
    }

    /// Initial point that routes all data along the SP tree to the
    /// destination and computes there (used by tests as an alternative
    /// starting point; finite only when the destination's computation
    /// capacity covers the full task input).
    pub fn compute_at_dest_init(net: &Network) -> Strategy {
        let mut phi = Strategy::zeroed(net);
        let w0: Vec<f64> = net.link_cost.iter().map(|c| c.deriv_at_zero()).collect();
        for (s, task) in net.tasks.iter().enumerate() {
            let (_, next) = dijkstra_to(&net.graph, task.dest, &w0);
            for i in 0..net.n() {
                if i == task.dest {
                    phi.data[s][i][0] = 1.0; // compute here
                    continue;
                }
                let nxt = next[i];
                assert!(nxt != usize::MAX);
                let slot = out_slot(&net.graph, i, nxt).unwrap();
                phi.data[s][i][slot + 1] = 1.0;
                phi.result[s][i][slot] = 1.0; // (unused: no result traffic upstream)
            }
        }
        phi
    }

    /// Per-task edge mask of the **data** plane: `active[e]` iff
    /// `φ⁻_{src(e), dst(e)}(s) > ε`.
    pub fn data_active_mask(&self, net: &Network, s: usize) -> Vec<bool> {
        let mut mask = Vec::new();
        self.data_active_mask_into(net, s, &mut mask);
        mask
    }

    /// Allocation-free form of [`Strategy::data_active_mask`]: writes the
    /// mask into a caller-owned buffer (resized to `net.e()`).
    pub fn data_active_mask_into(&self, net: &Network, s: usize, mask: &mut Vec<bool>) {
        mask.clear();
        mask.resize(net.e(), false);
        for i in 0..net.n() {
            for (k, &eid) in net.graph.out_edge_ids(i).iter().enumerate() {
                if self.data[s][i][k + 1] > ACTIVE_EPS {
                    mask[eid] = true;
                }
            }
        }
    }

    /// Per-task edge mask of the **result** plane.
    pub fn result_active_mask(&self, net: &Network, s: usize) -> Vec<bool> {
        let mut mask = Vec::new();
        self.result_active_mask_into(net, s, &mut mask);
        mask
    }

    /// Allocation-free form of [`Strategy::result_active_mask`].
    pub fn result_active_mask_into(&self, net: &Network, s: usize, mask: &mut Vec<bool>) {
        mask.clear();
        mask.resize(net.e(), false);
        for i in 0..net.n() {
            for (k, &eid) in net.graph.out_edge_ids(i).iter().enumerate() {
                if self.result[s][i][k] > ACTIVE_EPS {
                    mask[eid] = true;
                }
            }
        }
    }

    /// Loop-freedom: no data loop and no result loop for any task (§IV).
    pub fn is_loop_free(&self, net: &Network) -> bool {
        for s in 0..net.s() {
            if has_cycle_masked(&net.graph, &self.data_active_mask(net, s)) {
                return false;
            }
            if has_cycle_masked(&net.graph, &self.result_active_mask(net, s)) {
                return false;
            }
        }
        true
    }

    /// Feasibility per constraints (5) and (7) plus non-negativity.
    /// Returns human-readable violations (empty = feasible).
    pub fn feasibility_violations(&self, net: &Network) -> Vec<String> {
        let mut out = Vec::new();
        let tol = 1e-9;
        for s in 0..net.s() {
            let dest = net.tasks[s].dest;
            for i in 0..net.n() {
                let dsum: f64 = self.data[s][i].iter().sum();
                if self.data[s][i].iter().any(|&x| x < -tol) {
                    out.push(format!("task {s} node {i}: negative data fraction"));
                }
                if (dsum - 1.0).abs() > 1e-6 {
                    out.push(format!("task {s} node {i}: data fractions sum to {dsum}"));
                }
                let rsum: f64 = self.result[s][i].iter().sum();
                if self.result[s][i].iter().any(|&x| x < -tol) {
                    out.push(format!("task {s} node {i}: negative result fraction"));
                }
                if i == dest {
                    if rsum.abs() > 1e-6 {
                        out.push(format!(
                            "task {s}: destination {i} must not forward results (sum={rsum})"
                        ));
                    }
                } else if net.graph.out_degree(i) > 0 && (rsum - 1.0).abs() > 1e-6 {
                    // isolated nodes (e.g. after a failure) are exempt: they
                    // carry no traffic and have no outgoing slots.
                    out.push(format!(
                        "task {s} node {i}: result fractions sum to {rsum}"
                    ));
                }
            }
        }
        out
    }

    pub fn is_feasible(&self, net: &Network) -> bool {
        self.feasibility_violations(net).is_empty()
    }

    /// Warm-start adaptation after a topology/task change (Fig. 5b): map
    /// surviving data-plane fractions onto the new graph by `(src,dst)`
    /// pair (mass on removed edges returns to the local-computation slot),
    /// and re-initialize the result plane along the new shortest-path
    /// trees (guaranteed loop-free). Nodes left with no out-edges fall
    /// back to pure local computation.
    pub fn adapt_to(&self, old_net: &Network, new_net: &Network) -> Strategy {
        use crate::graph::algorithms::dijkstra_to;
        let mut phi = Strategy::zeroed(new_net);
        let w0: Vec<f64> = new_net
            .link_cost
            .iter()
            .map(|c| c.deriv_at_zero())
            .collect();
        for (s, task) in new_net.tasks.iter().enumerate() {
            let (_, next) = dijkstra_to(&new_net.graph, task.dest, &w0);
            for i in 0..new_net.n() {
                // --- data plane: remap by (src,dst) ---
                let mut local = self.data[s][i][0];
                for (k_old, &eid_old) in old_net.graph.out_edge_ids(i).iter().enumerate() {
                    let j = old_net.graph.edge(eid_old).dst;
                    let frac = self.data[s][i][k_old + 1];
                    if frac == 0.0 {
                        continue;
                    }
                    match out_slot(&new_net.graph, i, j) {
                        Some(k_new) => phi.data[s][i][k_new + 1] = frac,
                        None => local += frac, // edge gone: compute locally
                    }
                }
                phi.data[s][i][0] = local;
                // renormalize tiny drift
                let sum: f64 = phi.data[s][i].iter().sum();
                if sum > 0.0 {
                    phi.data[s][i].iter_mut().for_each(|x| *x /= sum);
                } else {
                    phi.data[s][i][0] = 1.0;
                }
                // --- result plane: SP re-init (loop-free by construction) ---
                if i == task.dest || new_net.graph.out_degree(i) == 0 {
                    continue;
                }
                let nxt = next[i];
                if nxt == usize::MAX {
                    // disconnected from the destination: dead-end node;
                    // keep zero result strategy (it carries no traffic)
                    continue;
                }
                let slot = out_slot(&new_net.graph, i, nxt).unwrap();
                phi.result[s][i][slot] = 1.0;
            }
        }
        phi
    }

    /// Same-graph warm start across a *task-pattern* shift (the dynamic
    /// engine's epoch boundary, [`crate::coordinator::dynamics`]): both
    /// planes carry over untouched — rate changes never invalidate a
    /// feasible strategy — except the result plane of tasks whose
    /// destination moved, which is re-initialized along the new
    /// shortest-path tree (loop-free by construction, and the old
    /// destination's all-zero row becomes a forwarding row again). The
    /// networks must share the graph and the task count; for *topology*
    /// changes use [`Strategy::adapt_to`] instead.
    pub fn retarget(&self, old_net: &Network, new_net: &Network) -> Strategy {
        use crate::graph::algorithms::dijkstra_to;
        assert_eq!(old_net.n(), new_net.n(), "retarget requires the same node set");
        assert_eq!(old_net.e(), new_net.e(), "retarget requires the same edge set");
        assert_eq!(old_net.s(), new_net.s(), "retarget requires the same task count");
        let mut phi = self.clone();
        let w0: Vec<f64> = new_net
            .link_cost
            .iter()
            .map(|c| c.deriv_at_zero())
            .collect();
        for (s, task) in new_net.tasks.iter().enumerate() {
            if old_net.tasks[s].dest == task.dest {
                continue;
            }
            let (_, next) = dijkstra_to(&new_net.graph, task.dest, &w0);
            for i in 0..new_net.n() {
                phi.result[s][i] = vec![0.0; new_net.graph.out_degree(i)];
                if i == task.dest {
                    continue;
                }
                let nxt = next[i];
                if nxt == usize::MAX {
                    // disconnected from the destination: carries no traffic
                    continue;
                }
                let slot = out_slot(&new_net.graph, i, nxt)
                    .expect("shortest-path successor is a neighbor");
                phi.result[s][i][slot] = 1.0;
            }
        }
        phi
    }

    /// Shape compatibility with `net`: task count, node count and every
    /// per-node slot count line up with the graph's out-edge order. A
    /// strategy deserialized from a store keyed by the wrong network can
    /// never be *applied* to this one — callers treat a mismatch as a
    /// cache miss, never an index panic.
    pub fn matches(&self, net: &Network) -> bool {
        let (n, s) = (net.n(), net.s());
        if self.data.len() != s || self.result.len() != s {
            return false;
        }
        for t in 0..s {
            if self.data[t].len() != n || self.result[t].len() != n {
                return false;
            }
            for i in 0..n {
                let deg = net.graph.out_degree(i);
                if self.data[t][i].len() != deg + 1 || self.result[t][i].len() != deg {
                    return false;
                }
            }
        }
        true
    }

    /// FNV-1a digest over both planes' exact shape and f64 bits — the
    /// integrity seal embedded by [`Strategy::to_json`] and verified by
    /// [`Strategy::from_json`]. Row/plane lengths are folded in, so
    /// truncating a row collides only by forging the digest too.
    pub fn digest(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for plane in [&self.data, &self.result] {
            fnv_eat(&mut h, &(plane.len() as u64).to_le_bytes());
            for task in plane.iter() {
                fnv_eat(&mut h, &(task.len() as u64).to_le_bytes());
                for row in task.iter() {
                    fnv_eat(&mut h, &(row.len() as u64).to_le_bytes());
                    for &x in row.iter() {
                        fnv_eat(&mut h, &x.to_bits().to_le_bytes());
                    }
                }
            }
        }
        h
    }

    /// Exact-bits JSON form: every fraction as a 16-digit hex bit pattern
    /// (the shard protocol's convention — JSON numbers would round-trip
    /// through decimal and lose bits), plus the [`Strategy::digest`] seal.
    /// This is how a strategy leaves the process: store entries, shard
    /// artifacts and dynamic traces all carry this shape.
    pub fn to_json(&self) -> Json {
        let plane = |p: &Vec<Vec<Vec<f64>>>| {
            Json::Arr(
                p.iter()
                    .map(|task| {
                        Json::Arr(
                            task.iter()
                                .map(|row| {
                                    Json::Arr(
                                        row.iter()
                                            .map(|&x| Json::Str(f64_bits_hex(x)))
                                            .collect(),
                                    )
                                })
                                .collect(),
                        )
                    })
                    .collect(),
            )
        };
        let mut o = Json::obj();
        o.set("data", plane(&self.data))
            .set("result", plane(&self.result))
            .set("digest", Json::Str(format!("{:016x}", self.digest())));
        o
    }

    /// Parse the [`Strategy::to_json`] form, rejecting tampering: bad hex,
    /// a missing plane and a digest mismatch are all hard errors here —
    /// the *store* layer downgrades them to counted misses.
    pub fn from_json(doc: &Json) -> Result<Strategy> {
        let data = parse_plane(doc.get("data"), "data")?;
        let result = parse_plane(doc.get("result"), "result")?;
        let want = doc
            .get("digest")
            .as_str()
            .context("strategy JSON missing digest")?;
        let want = u64::from_str_radix(want, 16)
            .with_context(|| format!("bad strategy digest '{want}'"))?;
        let phi = Strategy { data, result };
        let got = phi.digest();
        anyhow::ensure!(
            got == want,
            "strategy digest mismatch: stored {want:016x}, recomputed {got:016x}"
        );
        Ok(phi)
    }

    /// Largest pairwise entry difference against another strategy —
    /// convergence metric for fixed-point comparisons.
    pub fn max_abs_diff(&self, other: &Strategy) -> f64 {
        let mut worst = 0.0f64;
        for (a_t, b_t) in self.data.iter().zip(&other.data) {
            for (a_n, b_n) in a_t.iter().zip(b_t) {
                for (a, b) in a_n.iter().zip(b_n) {
                    worst = worst.max((a - b).abs());
                }
            }
        }
        for (a_t, b_t) in self.result.iter().zip(&other.result) {
            for (a_n, b_n) in a_t.iter().zip(b_t) {
                for (a, b) in a_n.iter().zip(b_n) {
                    worst = worst.max((a - b).abs());
                }
            }
        }
        worst
    }
}

/// Slot index of out-neighbor `j` within node `i`'s out-edge order, if any.
pub fn out_slot(g: &DiGraph, i: usize, j: usize) -> Option<usize> {
    g.out_edge_ids(i)
        .iter()
        .position(|&eid| g.edge(eid).dst == j)
}

// --- exact-bits serde internals -------------------------------------------
//
// The bits-hex convention matches `coordinator::exec::artifact`, but the
// model layer must not depend on the coordinator, so the two tiny helpers
// are restated here rather than imported.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;

fn fnv_eat(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

fn f64_bits_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

fn parse_f64_bits_hex(s: &str) -> Result<f64> {
    anyhow::ensure!(s.len() == 16, "bits-hex must be 16 digits, got '{s}'");
    let bits =
        u64::from_str_radix(s, 16).with_context(|| format!("bad bits-hex '{s}'"))?;
    Ok(f64::from_bits(bits))
}

fn parse_plane(doc: &Json, name: &str) -> Result<Vec<Vec<Vec<f64>>>> {
    let tasks = doc
        .as_arr()
        .with_context(|| format!("strategy JSON missing '{name}' plane"))?;
    tasks
        .iter()
        .enumerate()
        .map(|(s, task)| {
            let rows = task
                .as_arr()
                .with_context(|| format!("{name} plane task {s} is not an array"))?;
            rows.iter()
                .enumerate()
                .map(|(i, row)| {
                    let slots = row.as_arr().with_context(|| {
                        format!("{name} plane task {s} node {i} is not an array")
                    })?;
                    slots
                        .iter()
                        .map(|x| {
                            let hex = x.as_str().with_context(|| {
                                format!("{name} plane task {s} node {i}: non-string slot")
                            })?;
                            parse_f64_bits_hex(hex).with_context(|| {
                                format!("{name} plane task {s} node {i}")
                            })
                        })
                        .collect()
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::network::testnet::{diamond, line3};

    #[test]
    fn zeroed_shape() {
        let net = diamond(true);
        let phi = Strategy::zeroed(&net);
        assert_eq!(phi.data.len(), 1);
        assert_eq!(phi.data[0].len(), 4);
        assert_eq!(phi.data[0][0].len(), net.graph.out_degree(0) + 1);
        assert_eq!(phi.result[0][0].len(), net.graph.out_degree(0));
    }

    #[test]
    fn local_init_feasible_loop_free() {
        for net in [diamond(true), diamond(false), line3()] {
            let phi = Strategy::local_compute_init(&net);
            assert!(phi.is_feasible(&net), "{:?}", phi.feasibility_violations(&net));
            assert!(phi.is_loop_free(&net));
            // all data computed locally
            for s in 0..net.s() {
                for i in 0..net.n() {
                    assert_eq!(phi.data[s][i][0], 1.0);
                }
            }
        }
    }

    #[test]
    fn dest_init_feasible_loop_free() {
        let net = diamond(true);
        let phi = Strategy::compute_at_dest_init(&net);
        assert!(phi.is_feasible(&net));
        assert!(phi.is_loop_free(&net));
        // destination computes
        assert_eq!(phi.data[0][3][0], 1.0);
        // source forwards
        assert_eq!(phi.data[0][0][0], 0.0);
    }

    #[test]
    fn feasibility_catches_bad_sum() {
        let net = diamond(true);
        let mut phi = Strategy::local_compute_init(&net);
        phi.data[0][0][0] = 0.7;
        assert!(!phi.is_feasible(&net));
    }

    #[test]
    fn feasibility_catches_dest_forwarding() {
        let net = diamond(true);
        let mut phi = Strategy::local_compute_init(&net);
        phi.result[0][3][0] = 0.2;
        assert!(phi
            .feasibility_violations(&net)
            .iter()
            .any(|v| v.contains("destination")));
    }

    #[test]
    fn loop_detection_on_result_plane() {
        let net = diamond(true);
        let mut phi = Strategy::local_compute_init(&net);
        // Make result traffic circulate 1 -> 0 -> 1 for the task at dest 3:
        let s01 = out_slot(&net.graph, 0, 1).unwrap();
        let s10 = out_slot(&net.graph, 1, 0).unwrap();
        phi.result[0][0] = vec![0.0; net.graph.out_degree(0)];
        phi.result[0][0][s01] = 1.0;
        phi.result[0][1] = vec![0.0; net.graph.out_degree(1)];
        phi.result[0][1][s10] = 1.0;
        assert!(!phi.is_loop_free(&net));
    }

    #[test]
    fn active_masks_follow_fractions() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        // local-compute init: no data flows at all
        assert!(phi.data_active_mask(&net, 0).iter().all(|&b| !b));
        // results flow along the SP tree: at least the dest's in-edges used
        let rmask = phi.result_active_mask(&net, 0);
        assert!(rmask.iter().any(|&b| b));
    }

    #[test]
    fn max_abs_diff_zero_for_clone() {
        let net = line3();
        let a = Strategy::local_compute_init(&net);
        let mut b = a.clone();
        assert_eq!(a.max_abs_diff(&b), 0.0);
        b.data[0][0][0] -= 0.25;
        assert!((a.max_abs_diff(&b) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn out_slot_lookup() {
        let net = diamond(true);
        let g = &net.graph;
        let slot = out_slot(g, 0, 2).unwrap();
        assert_eq!(g.edge(g.out_edge_ids(0)[slot]).dst, 2);
        assert_eq!(out_slot(g, 0, 3), None); // not adjacent
    }

    #[test]
    fn retarget_keeps_unchanged_tasks_bitwise() {
        let old = line3();
        let mut new = old.clone();
        new.scale_rates(1.7); // rate shift only — no dest change
        let phi = Strategy::local_compute_init(&old);
        let carried = phi.retarget(&old, &new);
        assert_eq!(carried.data, phi.data);
        assert_eq!(carried.result, phi.result);
        assert!(carried.is_feasible(&new));
        assert!(carried.is_loop_free(&new));
    }

    #[test]
    fn json_roundtrip_is_bitwise() {
        for net in [diamond(true), diamond(false), line3()] {
            let mut phi = Strategy::local_compute_init(&net);
            // plant awkward bit patterns: subnormal, negative zero, and a
            // long non-dyadic fraction — decimal JSON numbers would mangle
            // all three, bits-hex must not
            phi.data[0][0][0] = 0.1f64 + 0.2f64;
            phi.data[0][1][0] = -0.0;
            if !phi.result[0][0].is_empty() {
                phi.result[0][0][0] = f64::from_bits(1); // smallest subnormal
            }
            let back = Strategy::from_json(&phi.to_json()).unwrap();
            assert_eq!(bits_of(&phi), bits_of(&back), "round-trip lost bits");
            // and through the text form too
            let text = phi.to_json().dump();
            let back =
                Strategy::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
            assert_eq!(bits_of(&phi), bits_of(&back));
        }
    }

    fn bits_of(phi: &Strategy) -> Vec<u64> {
        phi.data
            .iter()
            .chain(phi.result.iter())
            .flatten()
            .flatten()
            .map(|x| x.to_bits())
            .collect()
    }

    #[test]
    fn json_shape_matches_network() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        assert!(phi.matches(&net));
        let other = line3();
        assert!(!phi.matches(&other));
        let mut truncated = phi.clone();
        truncated.data[0][0].pop();
        assert!(!truncated.matches(&net));
    }

    #[test]
    fn tampered_json_is_rejected() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        // flipped value without a matching digest
        let mut doc = phi.to_json();
        let mut evil = phi.clone();
        evil.data[0][0][0] = 0.5;
        doc.set("data", evil.to_json().get("data").clone());
        let err = Strategy::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("digest mismatch"), "{err}");
        // truncated row without a matching digest
        let mut doc = phi.to_json();
        let mut short = phi.clone();
        short.result[0][0].pop();
        doc.set("result", short.to_json().get("result").clone());
        assert!(Strategy::from_json(&doc).is_err());
        // garbage hex
        let mut doc = phi.to_json();
        doc.set(
            "data",
            Json::Arr(vec![Json::Arr(vec![Json::Arr(vec![Json::Str(
                "zz".to_string(),
            )])])]),
        );
        assert!(Strategy::from_json(&doc).is_err());
        // missing digest entirely
        let mut doc = phi.to_json();
        doc.set("digest", Json::Null);
        let err = Strategy::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("digest"), "{err}");
    }

    #[test]
    fn retarget_reroutes_moved_destinations() {
        let old = line3();
        let mut new = old.clone();
        new.tasks[0].dest = 0; // was 2
        let phi = Strategy::local_compute_init(&old);
        let carried = phi.retarget(&old, &new);
        // data plane untouched, result plane re-aimed at the new dest
        assert_eq!(carried.data, phi.data);
        assert!(carried.is_feasible(&new));
        assert!(carried.is_loop_free(&new));
        // the old destination forwards again; the new one terminates
        assert!(carried.result[0][2].iter().sum::<f64>() > 0.5);
        assert!(carried.result[0][0].iter().sum::<f64>() < 1e-12);
        // the untouched task's plane is bitwise intact
        assert_eq!(carried.result[1], phi.result[1]);
    }
}
