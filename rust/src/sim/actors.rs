//! Thread-based actor deployment of the broadcast protocol.
//!
//! `sim::protocol` models the two-stage broadcast on the virtual clock of
//! the [`super::core`] calendar queue; this module runs the same protocol
//! with *real* concurrency instead — one OS thread per network node,
//! mpsc channels as links — demonstrating that the protocol is genuinely
//! asynchronous: no barriers, nodes fire purely on message arrival, in
//! whatever order the scheduler produces. (tokio is unavailable offline;
//! std::thread + channels express the same thing for the network sizes in
//! the paper.)
//!
//! Each node thread knows only its local state (φ rows, measured `D'` on
//! out-links, `C'`, `w`, `a_m`) — mirroring what a physical device could
//! measure — and terminates once it has computed and broadcast both of its
//! marginals for every task.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;

use crate::model::flows::FlowState;
use crate::model::network::Network;
use crate::model::strategy::Strategy;

/// Message between node threads: (task, stage, from, value).
/// stage false = result marginal (stage 1), true = data marginal (stage 2).
#[derive(Clone, Copy, Debug)]
struct Wire {
    task: usize,
    stage2: bool,
    from: usize,
    value: f64,
}

/// Distributed marginals computed by the actor deployment.
#[derive(Clone, Debug)]
pub struct ActorResult {
    pub dt_plus: Vec<Vec<f64>>,
    pub dt_r: Vec<Vec<f64>>,
}

/// Run the two-stage broadcast with one thread per node.
pub fn run_actor_broadcast(net: &Network, phi: &Strategy, flows: &FlowState) -> ActorResult {
    let n = net.n();
    let s_count = net.s();
    let g = &net.graph;

    // Locally-measurable quantities, sliced per node.
    let d_link: Vec<f64> = (0..net.e())
        .map(|e| net.link_cost[e].deriv(flows.link_flow[e]))
        .collect();
    let c_node: Vec<f64> = (0..n)
        .map(|i| net.comp_cost[i].deriv(flows.workload[i]))
        .collect();

    // Channels: one inbox per node; senders cloned per in-neighbor.
    let mut inboxes: Vec<Option<Receiver<Wire>>> = Vec::with_capacity(n);
    let mut senders: Vec<Sender<Wire>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        senders.push(tx);
        inboxes.push(Some(rx));
    }
    // results flow back over a dedicated channel
    let (result_tx, result_rx) = channel::<(usize, Vec<f64>, Vec<f64>)>();

    let mut handles = Vec::with_capacity(n);
    for i in 0..n {
        let rx = inboxes[i].take().unwrap();
        let result_tx = result_tx.clone();
        // per-node local knowledge (cloned snapshots)
        let out_edges: Vec<(usize, usize)> = g
            .out_edge_ids(i)
            .iter()
            .map(|&eid| (eid, g.edge(eid).dst))
            .collect();
        let in_neighbors: Vec<usize> = g.in_neighbors(i).collect();
        let up_senders: Vec<Sender<Wire>> =
            in_neighbors.iter().map(|&j| senders[j].clone()).collect();
        let phi_data: Vec<Vec<f64>> = (0..s_count).map(|s| phi.data[s][i].clone()).collect();
        let phi_result: Vec<Vec<f64>> =
            (0..s_count).map(|s| phi.result[s][i].clone()).collect();
        let d_out: Vec<f64> = out_edges.iter().map(|&(eid, _)| d_link[eid]).collect();
        let c_i = c_node[i];
        let w_i: Vec<f64> = (0..s_count).map(|s| net.w_of(i, s)).collect();
        let a_s: Vec<f64> = (0..s_count).map(|s| net.a_of(s)).collect();
        let dests: Vec<usize> = net.tasks.iter().map(|t| t.dest).collect();

        handles.push(thread::spawn(move || {
            let deg = out_edges.len();
            let mut inbox1: Vec<Vec<Option<f64>>> = vec![vec![None; deg]; s_count];
            let mut inbox2: Vec<Vec<Option<f64>>> = vec![vec![None; deg]; s_count];
            let mut my_dt_plus: Vec<Option<f64>> = vec![None; s_count];
            let mut my_dt_r: Vec<Option<f64>> = vec![None; s_count];

            let broadcast = |task: usize, stage2: bool, value: f64| {
                for tx in &up_senders {
                    // a receiver hanging up just means that node finished
                    let _ = tx.send(Wire {
                        task,
                        stage2,
                        from: i,
                        value,
                    });
                }
            };

            let stage1_ready = |s: usize, inbox: &[Option<f64>]| -> bool {
                (0..deg).all(|k| phi_result[s][k] == 0.0 || inbox[k].is_some())
            };
            let stage2_ready = |s: usize, inbox: &[Option<f64>]| -> bool {
                (0..deg).all(|k| phi_data[s][k + 1] == 0.0 || inbox[k].is_some())
            };

            // try to fire stages for task s; returns whether progress happened
            macro_rules! try_fire {
                ($s:expr) => {{
                    let s = $s;
                    if my_dt_plus[s].is_none() && (dests[s] == i || stage1_ready(s, &inbox1[s])) {
                        let v = if dests[s] == i {
                            0.0
                        } else {
                            (0..deg)
                                .map(|k| {
                                    let f = phi_result[s][k];
                                    if f > 0.0 {
                                        f * (d_out[k] + inbox1[s][k].unwrap())
                                    } else {
                                        0.0
                                    }
                                })
                                .sum()
                        };
                        my_dt_plus[s] = Some(v);
                        broadcast(s, false, v);
                    }
                    if my_dt_r[s].is_none() {
                        if let Some(dtp) = my_dt_plus[s] {
                            if stage2_ready(s, &inbox2[s]) {
                                let mut v = phi_data[s][0] * (w_i[s] * c_i + a_s[s] * dtp);
                                for k in 0..deg {
                                    let f = phi_data[s][k + 1];
                                    if f > 0.0 {
                                        v += f * (d_out[k] + inbox2[s][k].unwrap());
                                    }
                                }
                                my_dt_r[s] = Some(v);
                                broadcast(s, true, v);
                            }
                        }
                    }
                }};
            }

            for s in 0..s_count {
                try_fire!(s);
            }
            while my_dt_plus.iter().any(Option::is_none) || my_dt_r.iter().any(Option::is_none)
            {
                let msg = rx.recv().expect("protocol deadlock: inbox closed early");
                if let Some(k) = out_edges.iter().position(|&(_, dst)| dst == msg.from) {
                    if msg.stage2 {
                        inbox2[msg.task][k] = Some(msg.value);
                    } else {
                        inbox1[msg.task][k] = Some(msg.value);
                    }
                }
                try_fire!(msg.task);
            }
            // drain-free exit; report results to the coordinator
            let dt_plus: Vec<f64> = my_dt_plus.into_iter().map(Option::unwrap).collect();
            let dt_r: Vec<f64> = my_dt_r.into_iter().map(Option::unwrap).collect();
            result_tx.send((i, dt_plus, dt_r)).unwrap();
        }));
    }
    drop(result_tx);
    drop(senders);

    let mut dt_plus = vec![vec![0.0; n]; s_count];
    let mut dt_r = vec![vec![0.0; n]; s_count];
    for _ in 0..n {
        let (i, p, r) = result_rx.recv().expect("node thread died");
        for s in 0..s_count {
            dt_plus[s][i] = p[s];
            dt_r[s][i] = r[s];
        }
    }
    for h in handles {
        h.join().expect("node thread panicked");
    }

    ActorResult { dt_plus, dt_r }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flows::compute_flows;
    use crate::model::marginals::compute_marginals;
    use crate::model::network::testnet::{diamond, line3};

    fn check(net: &Network, phi: &Strategy) {
        let flows = compute_flows(net, phi).unwrap();
        let marg = compute_marginals(net, phi, &flows).unwrap();
        let res = run_actor_broadcast(net, phi, &flows);
        for s in 0..net.s() {
            for i in 0..net.n() {
                assert!(
                    (res.dt_plus[s][i] - marg.dt_plus[s][i]).abs() < 1e-12,
                    "dt_plus[{s}][{i}]"
                );
                assert!(
                    (res.dt_r[s][i] - marg.dt_r[s][i]).abs() < 1e-12,
                    "dt_r[{s}][{i}]"
                );
            }
        }
    }

    #[test]
    fn actor_broadcast_matches_centralized_diamond() {
        let net = diamond(true);
        check(&net, &Strategy::local_compute_init(&net));
        check(&net, &Strategy::compute_at_dest_init(&net));
    }

    #[test]
    fn actor_broadcast_matches_centralized_line3() {
        let net = line3();
        check(&net, &Strategy::local_compute_init(&net));
    }

    #[test]
    fn repeated_runs_deterministic_values() {
        // thread interleavings vary; the computed fixed point must not
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let flows = compute_flows(&net, &phi).unwrap();
        let a = run_actor_broadcast(&net, &phi, &flows);
        for _ in 0..5 {
            let b = run_actor_broadcast(&net, &phi, &flows);
            assert_eq!(a.dt_plus, b.dt_plus);
            assert_eq!(a.dt_r, b.dt_r);
        }
    }
}
