//! Request arrival processes for the request-level engine.
//!
//! The optimizer sees demand as *rates* (`Network::input_rate`); the
//! simulator needs individual requests. This module turns the per-epoch
//! rate matrices — the same epochs the PR 4
//! [`PatternSchedule`](crate::coordinator::dynamics::PatternSchedule)
//! mutates and the optimizer re-converges on — into a single merged
//! arrival stream via thinning: candidates fire as a Poisson process at
//! the peak rate `λ_max` and are accepted with probability `λ(t)/λ_max`,
//! where `λ(t)` composes the epoch's total input rate with the arrival
//! kind's intra-epoch modulation:
//!
//! * **Poisson** — constant factor 1 (time-homogeneous within an epoch);
//! * **MMPP** — a two-state Markov-modulated factor alternating between
//!   `2b/(1+b)` (bursty) and `2/(1+b)` (quiet) with exponential holding
//!   times, normalized so the long-run mean factor is 1 and the
//!   burst-to-quiet ratio is exactly `b`;
//! * **Diurnal** — `1 + depth·sin(2πt/horizon)`: one smooth "day" over
//!   the run, mean 1.
//!
//! Accepted arrivals are attributed to a `(task, source)` pair by a draw
//! proportional to that epoch's individual input rates, so the simulated
//! demand matches the flow model the strategy was optimized for. All
//! randomness derives from a single seed through forked
//! [`Pcg`](crate::util::rng::Pcg) streams — the stream is a pure function
//! of `(spec, epoch rates, requests, seed)`.

use anyhow::{bail, Result};

use crate::model::network::Network;
use crate::util::rng::Pcg;

/// Arrival-process family plus its parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalKind {
    /// Homogeneous Poisson within each epoch.
    Poisson,
    /// Markov-modulated Poisson: `burst` ≥ 1 is the high/low rate ratio,
    /// `switch` > 0 the state-switch rate (expected switches per unit
    /// simulated time).
    Mmpp { burst: f64, switch: f64 },
    /// Sinusoidal day curve with relative amplitude `depth` ∈ [0, 1].
    Diurnal { depth: f64 },
}

/// Parsed arrival specification (CLI `--arrivals`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ArrivalSpec {
    pub kind: ArrivalKind,
}

impl Default for ArrivalSpec {
    /// Plain Poisson — the memoryless baseline every queueing formula in
    /// the paper's cost model assumes.
    fn default() -> Self {
        ArrivalSpec {
            kind: ArrivalKind::Poisson,
        }
    }
}

impl ArrivalSpec {
    /// Parse `poisson` | `mmpp[:burst[:switch]]` | `diurnal[:depth]`.
    pub fn parse(label: &str) -> Result<ArrivalSpec> {
        let mut parts = label.split(':');
        let head = parts.next().unwrap_or("").to_ascii_lowercase();
        let arg = |p: Option<&str>, default: f64| -> Result<f64> {
            match p {
                None => Ok(default),
                Some(s) => s
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("bad arrival parameter {s:?} in {label:?}")),
            }
        };
        let kind = match head.as_str() {
            "poisson" => ArrivalKind::Poisson,
            "mmpp" => {
                let burst = arg(parts.next(), 4.0)?;
                let switch = arg(parts.next(), 1.0)?;
                if burst.is_nan() || burst < 1.0 || burst.is_infinite() {
                    bail!("mmpp burst ratio must be finite and ≥ 1, got {burst}");
                }
                if switch.is_nan() || switch <= 0.0 || switch.is_infinite() {
                    bail!("mmpp switch rate must be finite and > 0, got {switch}");
                }
                ArrivalKind::Mmpp { burst, switch }
            }
            "diurnal" => {
                let depth = arg(parts.next(), 0.8)?;
                if !(0.0..=1.0).contains(&depth) {
                    bail!("diurnal depth must be in [0,1], got {depth}");
                }
                ArrivalKind::Diurnal { depth }
            }
            _ => bail!("unknown arrival kind {label:?} (poisson|mmpp|diurnal)"),
        };
        if parts.next().is_some() {
            bail!("too many parameters in arrival spec {label:?}");
        }
        Ok(ArrivalSpec { kind })
    }

    /// Canonical label; `parse(label())` round-trips.
    pub fn label(&self) -> String {
        match self.kind {
            ArrivalKind::Poisson => "poisson".to_string(),
            ArrivalKind::Mmpp { burst, switch } => format!("mmpp:{burst}:{switch}"),
            ArrivalKind::Diurnal { depth } => format!("diurnal:{depth}"),
        }
    }

    /// Maximum modulation factor (for the thinning envelope).
    fn peak_factor(&self) -> f64 {
        match self.kind {
            ArrivalKind::Poisson => 1.0,
            ArrivalKind::Mmpp { burst, .. } => 2.0 * burst / (1.0 + burst),
            ArrivalKind::Diurnal { depth } => 1.0 + depth,
        }
    }
}

/// One epoch's demand: total rate plus the cumulative per-(task, source)
/// rate table used to attribute accepted arrivals.
#[derive(Clone, Debug)]
pub struct EpochRates {
    pub total: f64,
    /// `(task, source, cumulative rate)`, ascending.
    cum: Vec<(u32, u32, f64)>,
}

impl EpochRates {
    /// Extract the positive input-rate entries of `net`.
    pub fn of(net: &Network) -> EpochRates {
        let mut cum = Vec::new();
        let mut acc = 0.0;
        for s in 0..net.s() {
            for i in 0..net.n() {
                let r = net.input_rate[s][i];
                if r > 0.0 {
                    acc += r;
                    cum.push((s as u32, i as u32, acc));
                }
            }
        }
        EpochRates { total: acc, cum }
    }

    /// Attribute a uniform draw `u ∈ [0, total)` to a `(task, source)`.
    fn pick(&self, u: f64) -> (usize, usize) {
        let k = self.cum.partition_point(|&(_, _, c)| c <= u);
        let (s, i, _) = self.cum[k.min(self.cum.len() - 1)];
        (s as usize, i as usize)
    }
}

/// One generated request arrival.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    pub time: f64,
    pub task: usize,
    pub source: usize,
}

/// Deterministic merged arrival stream over all `(task, source)` pairs.
pub struct ArrivalStream {
    spec: ArrivalSpec,
    epochs: Vec<EpochRates>,
    /// Expected-count horizon; epoch boundaries split it evenly.
    horizon: f64,
    epoch_len: f64,
    lambda_max: f64,
    remaining: u64,
    clock: f64,
    rng: Pcg,
    /// Dedicated stream for MMPP state switches, so modulation and
    /// thinning draws never interleave.
    rng_switch: Pcg,
    /// MMPP state: true = bursty phase.
    mmpp_high: bool,
    mmpp_next_switch: f64,
}

impl ArrivalStream {
    /// Stream generating exactly `requests` arrivals whose expected span
    /// is `horizon = requests / mean epoch rate`.
    pub fn new(
        spec: &ArrivalSpec,
        epochs: Vec<EpochRates>,
        requests: u64,
        seed: u64,
    ) -> Result<ArrivalStream> {
        if epochs.is_empty() {
            bail!("arrival stream needs at least one epoch");
        }
        if requests == 0 {
            bail!("arrival stream needs requests > 0");
        }
        let mean: f64 = epochs.iter().map(|e| e.total).sum::<f64>() / epochs.len() as f64;
        if mean <= 0.0 || mean.is_nan() {
            bail!("scenario has zero total input rate; nothing to simulate");
        }
        let peak = epochs.iter().fold(0.0f64, |m, e| m.max(e.total));
        let mut root = Pcg::with_stream(seed, 0x5e9_a11a);
        let rng = root.fork(1);
        let mut rng_switch = root.fork(2);
        let horizon = requests as f64 / mean;
        let first_switch = match spec.kind {
            ArrivalKind::Mmpp { switch, .. } => rng_switch.exponential(1.0 / switch),
            _ => f64::INFINITY,
        };
        Ok(ArrivalStream {
            spec: *spec,
            epoch_len: horizon / epochs.len() as f64,
            horizon,
            lambda_max: peak * spec.peak_factor(),
            epochs,
            remaining: requests,
            clock: 0.0,
            rng,
            rng_switch,
            mmpp_high: true,
            mmpp_next_switch: first_switch,
        })
    }

    pub fn horizon(&self) -> f64 {
        self.horizon
    }

    /// Epoch index at time `t` (clamped to the last epoch past the
    /// horizon, so overruns keep the final pattern).
    pub fn epoch_of(&self, t: f64) -> usize {
        ((t / self.epoch_len) as usize).min(self.epochs.len() - 1)
    }

    /// Instantaneous modulation factor of the arrival kind at time `t`,
    /// advancing the MMPP state chain up to `t` when applicable.
    fn factor_at(&mut self, t: f64) -> f64 {
        match self.spec.kind {
            ArrivalKind::Poisson => 1.0,
            ArrivalKind::Mmpp { burst, switch } => {
                while t >= self.mmpp_next_switch {
                    self.mmpp_high = !self.mmpp_high;
                    self.mmpp_next_switch += self.rng_switch.exponential(1.0 / switch);
                }
                if self.mmpp_high {
                    2.0 * burst / (1.0 + burst)
                } else {
                    2.0 / (1.0 + burst)
                }
            }
            ArrivalKind::Diurnal { depth } => {
                1.0 + depth * (2.0 * std::f64::consts::PI * t / self.horizon).sin()
            }
        }
    }

    /// Next arrival, or `None` once `requests` have been generated.
    pub fn next(&mut self) -> Option<Arrival> {
        if self.remaining == 0 {
            return None;
        }
        loop {
            self.clock += self.rng.exponential(1.0 / self.lambda_max);
            let t = self.clock;
            let e = self.epoch_of(t);
            let lambda = self.epochs[e].total * self.factor_at(t);
            debug_assert!(lambda <= self.lambda_max * (1.0 + 1e-12));
            if self.rng.f64() * self.lambda_max < lambda {
                let u = self.rng.f64() * self.epochs[e].total;
                let (task, source) = self.epochs[e].pick(u);
                self.remaining -= 1;
                return Some(Arrival {
                    time: t,
                    task,
                    source,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::network::testnet::diamond;

    fn stream(spec: &str, requests: u64, seed: u64) -> ArrivalStream {
        let net = diamond(true);
        let spec = ArrivalSpec::parse(spec).unwrap();
        ArrivalStream::new(&spec, vec![EpochRates::of(&net)], requests, seed).unwrap()
    }

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        for label in ["poisson", "mmpp:4:1", "mmpp:2.5:0.25", "diurnal:0.8"] {
            let spec = ArrivalSpec::parse(label).unwrap();
            assert_eq!(ArrivalSpec::parse(&spec.label()).unwrap(), spec);
        }
        assert!(ArrivalSpec::parse("weibull").is_err());
        assert!(ArrivalSpec::parse("mmpp:0.5").is_err());
        assert!(ArrivalSpec::parse("diurnal:2").is_err());
        assert!(ArrivalSpec::parse("poisson:1:2:3").is_err());
    }

    #[test]
    fn generates_exactly_n_increasing_arrivals() {
        let mut st = stream("poisson", 500, 42);
        let mut last = 0.0;
        let mut n = 0;
        while let Some(a) = st.next() {
            assert!(a.time >= last);
            last = a.time;
            n += 1;
        }
        assert_eq!(n, 500);
        assert!(st.next().is_none());
    }

    #[test]
    fn poisson_span_matches_rate() {
        let net = diamond(true);
        let rates = EpochRates::of(&net);
        let total = rates.total;
        let n = 20_000u64;
        let mut st =
            ArrivalStream::new(&ArrivalSpec::parse("poisson").unwrap(), vec![rates], n, 7)
                .unwrap();
        let mut last = 0.0;
        while let Some(a) = st.next() {
            last = a.time;
        }
        let expected = n as f64 / total;
        assert!(
            (last - expected).abs() / expected < 0.05,
            "span {last} vs expected {expected}"
        );
    }

    #[test]
    fn mmpp_preserves_mean_rate() {
        let mut st = stream("mmpp:4:5", 20_000, 11);
        let mut last = 0.0;
        while let Some(a) = st.next() {
            last = a.time;
        }
        // Mean factor is 1, so the span still matches requests / rate.
        let expected = st.horizon();
        assert!(
            (last - expected).abs() / expected < 0.10,
            "span {last} vs horizon {expected}"
        );
    }

    #[test]
    fn attribution_tracks_input_rates() {
        let net = diamond(true);
        let mut counts = vec![vec![0u64; net.n()]; net.s()];
        let mut st = stream("poisson", 50_000, 3);
        while let Some(a) = st.next() {
            counts[a.task][a.source] += 1;
        }
        let total_rate: f64 = net.input_rate.iter().flatten().sum();
        for s in 0..net.s() {
            for i in 0..net.n() {
                let expect = 50_000.0 * net.input_rate[s][i] / total_rate;
                let got = counts[s][i] as f64;
                assert!(
                    (got - expect).abs() <= 5.0 * expect.sqrt().max(3.0),
                    "task {s} node {i}: {got} vs {expect}"
                );
            }
        }
    }

    #[test]
    fn identical_seeds_identical_streams() {
        let mut a = stream("diurnal:0.5", 1000, 9);
        let mut b = stream("diurnal:0.5", 1000, 9);
        while let Some(x) = a.next() {
            let y = b.next().unwrap();
            assert_eq!(x.time.to_bits(), y.time.to_bits());
            assert_eq!((x.task, x.source), (y.task, y.source));
        }
    }
}
