//! Core discrete-event scheduler: an indexed calendar queue.
//!
//! Drop-in replacement for the original `BinaryHeap`-backed
//! [`super::event::EventQueue`] with O(1) *amortized* schedule/pop instead
//! of O(log n) (Brown 1988, "Calendar queues: a fast O(1) priority queue
//! implementation for the simulation event set problem"). The request-level
//! engine ([`super::tasks`]) keeps 10^5–10^6 events in flight, where the
//! heap's log factor and its pathological cache behaviour dominate; the
//! calendar spreads events over an array of time buckets ("days") so that
//! a pop only scans the handful of events sharing the current day.
//!
//! Semantics are *identical* to the legacy queue and pinned by a
//! randomized parity test (`rust/tests/sim_engine.rs`):
//!
//! * events fire in `(time, seq)` order — simultaneous events in
//!   deterministic FIFO order of scheduling (equal times always land in
//!   the same bucket, so the local scan sees every tie candidate);
//! * `pop` advances the clock to the fired event's time;
//! * `schedule` rejects non-finite delays — the legacy queue accepted
//!   `+∞` silently and `NaN` would have corrupted the heap order, since
//!   `Event::cmp` falls back to `Ordering::Equal` on incomparable times
//!   (the satellite bugfix, applied to both queues).
//!
//! The bucket count doubles when occupancy exceeds two events per bucket
//! and halves below one half, re-sampling the bucket width from observed
//! inter-event gaps, so both the dense protocol workload and sparse
//! long-horizon arrival streams stay O(1) per operation.

/// An event scheduled at `time` carrying `payload`.
///
/// Unlike the legacy [`super::event::Event`] this carries no `Ord`
/// machinery: ordering is the queue's job, not the element's.
#[derive(Clone, Debug)]
pub struct Event<P> {
    pub time: f64,
    pub seq: u64,
    pub payload: P,
}

const MIN_BUCKETS: usize = 4;
/// Resize samples at most this many event times to estimate bucket width.
const WIDTH_SAMPLE: usize = 64;

/// Calendar-queue event scheduler / simulation clock.
///
/// API-compatible with the legacy heap queue: `new`, `now`, `schedule`,
/// `pop`, `is_empty`, `len` and the public `processed` counter.
pub struct EventQueue<P> {
    buckets: Vec<Vec<Event<P>>>,
    /// Width of one bucket ("day length").
    width: f64,
    /// Bucket the next pop scans first.
    cursor: usize,
    /// Start time of the cursor bucket's current window ("today 00:00").
    window_start: f64,
    now: f64,
    seq: u64,
    len: usize,
    pub processed: u64,
}

impl<P> EventQueue<P> {
    pub fn new() -> Self {
        EventQueue {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            width: 1.0,
            cursor: 0,
            window_start: 0.0,
            now: 0.0,
            seq: 0,
            len: 0,
            processed: 0,
        }
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }

    fn bucket_of(&self, time: f64) -> usize {
        // `as` saturates for huge quotients; a misfiled far-future event is
        // still found by the direct-search fallback in `pop`.
        (time / self.width) as u64 as usize % self.buckets.len()
    }

    /// Schedule `payload` to fire `delay` from now.
    ///
    /// Panics on negative or non-finite delays: a NaN event time would make
    /// every ordering comparison incomparable and an infinite one would jam
    /// the clock at `+∞`, so both are programming errors worth failing fast
    /// on.
    pub fn schedule(&mut self, delay: f64, payload: P) {
        assert!(
            delay.is_finite() && delay >= 0.0,
            "event delay must be finite and non-negative, got {delay}"
        );
        let ev = Event {
            time: self.now + delay,
            seq: self.seq,
            payload,
        };
        self.seq += 1;
        let b = self.bucket_of(ev.time);
        self.buckets[b].push(ev);
        self.len += 1;
        if self.len > 2 * self.buckets.len() {
            self.resize(self.buckets.len() * 2);
        }
    }

    /// Pop the next event in `(time, seq)` order, advancing the clock.
    pub fn pop(&mut self) -> Option<Event<P>> {
        if self.len == 0 {
            return None;
        }
        // Walk day by day from the cursor; an event belongs to the current
        // day iff its time falls before the window end. Equal times share a
        // bucket, so scanning one day sees every FIFO tie candidate.
        for _ in 0..self.buckets.len() {
            let window_end = self.window_start + self.width;
            let bucket = &self.buckets[self.cursor];
            let mut best = usize::MAX;
            for (k, ev) in bucket.iter().enumerate() {
                if ev.time < window_end
                    && (best == usize::MAX
                        || (ev.time, ev.seq) < (bucket[best].time, bucket[best].seq))
                {
                    best = k;
                }
            }
            if best != usize::MAX {
                return Some(self.take(self.cursor, best));
            }
            self.cursor = (self.cursor + 1) % self.buckets.len();
            self.window_start = window_end;
        }
        // A full year passed with every bucket's events beyond its current
        // window (sparse queue): jump straight to the global minimum.
        let (mut bb, mut kk) = (usize::MAX, usize::MAX);
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (k, ev) in bucket.iter().enumerate() {
                if bb == usize::MAX
                    || (ev.time, ev.seq) < (self.buckets[bb][kk].time, self.buckets[bb][kk].seq)
                {
                    (bb, kk) = (b, k);
                }
            }
        }
        debug_assert!(bb != usize::MAX);
        // Re-anchor the calendar on the minimum's day.
        let t = self.buckets[bb][kk].time;
        self.cursor = bb;
        self.window_start = (t / self.width).floor() * self.width;
        Some(self.take(bb, kk))
    }

    /// Remove event `k` of bucket `b` and account for the fired event.
    fn take(&mut self, b: usize, k: usize) -> Event<P> {
        let ev = self.buckets[b].swap_remove(k);
        self.len -= 1;
        debug_assert!(ev.time >= self.now - 1e-12);
        self.now = ev.time;
        self.processed += 1;
        if self.buckets.len() > MIN_BUCKETS && self.len * 2 < self.buckets.len() {
            self.resize(self.buckets.len() / 2);
        }
        ev
    }

    /// Rebuild with `nb` buckets, re-estimating the width from a sample of
    /// inter-event gaps so roughly one event shares each day.
    fn resize(&mut self, nb: usize) {
        let mut events: Vec<Event<P>> = Vec::with_capacity(self.len);
        for bucket in &mut self.buckets {
            events.append(bucket);
        }
        // Sample event times (deterministic: bucket order) for the width.
        let mut sample: Vec<f64> = events.iter().take(WIDTH_SAMPLE).map(|e| e.time).collect();
        sample.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut gap_sum = 0.0;
        let mut gaps = 0u32;
        for w in sample.windows(2) {
            if w[1] > w[0] {
                gap_sum += w[1] - w[0];
                gaps += 1;
            }
        }
        if gaps > 0 {
            // Brown's rule of thumb: day ≈ 2 × average separation.
            self.width = (2.0 * gap_sum / f64::from(gaps)).max(1e-9);
        }
        self.buckets = (0..nb.max(MIN_BUCKETS)).map(|_| Vec::new()).collect();
        for ev in events {
            let b = self.bucket_of(ev.time);
            self.buckets[b].push(ev);
        }
        // Resume the walk on the day containing the clock.
        self.window_start = (self.now / self.width).floor() * self.width;
        self.cursor = self.bucket_of(self.now.max(0.0));
    }
}

impl<P> Default for EventQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The four legacy-queue unit tests, verbatim against the calendar.

    #[test]
    fn fires_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
        assert_eq!(q.processed, 3);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(1.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_with_nested_scheduling() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(1.0, 1);
        let e = q.pop().unwrap();
        assert_eq!(e.payload, 1);
        q.schedule(0.5, 2);
        let e2 = q.pop().unwrap();
        assert!((e2.time - 1.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn negative_delay_rejected() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.schedule(-1.0, ());
    }

    // Calendar-specific coverage.

    #[test]
    #[should_panic]
    fn nan_delay_rejected() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.schedule(f64::NAN, ());
    }

    #[test]
    #[should_panic]
    fn infinite_delay_rejected() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.schedule(f64::INFINITY, ());
    }

    #[test]
    fn survives_growth_and_shrink() {
        let mut q = EventQueue::new();
        for i in 0..1000u32 {
            q.schedule(f64::from(i % 97) * 0.25, i);
        }
        let mut last = (-1.0, 0u64);
        let mut n = 0;
        while let Some(e) = q.pop() {
            assert!((e.time, e.seq) > last, "order violated at {n}");
            last = (e.time, e.seq);
            n += 1;
            // interleave new arrivals to force mid-drain resizes
            if n % 50 == 0 {
                q.schedule(0.125, 10_000 + n);
            }
        }
        assert_eq!(q.len(), 0);
        assert!(q.processed >= 1000);
    }

    #[test]
    fn sparse_far_future_jump() {
        let mut q = EventQueue::new();
        q.schedule(0.5, "near");
        q.schedule(1.0e7, "far");
        assert_eq!(q.pop().unwrap().payload, "near");
        // The far event lives many "years" past the cursor; the fallback
        // search must find it rather than spinning through empty days.
        let e = q.pop().unwrap();
        assert_eq!(e.payload, "far");
        assert_eq!(q.now(), 0.5 + 1.0e7);
    }

    #[test]
    fn zero_delay_fires_immediately_in_fifo() {
        let mut q = EventQueue::new();
        q.schedule(0.0, 1);
        q.schedule(0.0, 2);
        assert_eq!(q.pop().unwrap().payload, 1);
        q.schedule(0.0, 3);
        assert_eq!(q.pop().unwrap().payload, 2);
        assert_eq!(q.pop().unwrap().payload, 3);
        assert_eq!(q.now(), 0.0);
    }
}
