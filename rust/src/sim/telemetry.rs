//! Streaming telemetry for the request-level engine: tail latency without
//! storing samples.
//!
//! A 10^6-request run must not keep 10^6 sojourn times around just to sort
//! them at the end — the whole point of the layered engine is bounded
//! memory. Sojourn times therefore stream into a
//! [`QuantileSketch`](crate::util::stats::QuantileSketch) (log-bucketed,
//! ≤ 1% relative error by default, memory independent of request count)
//! plus a Welford mean; per-node/per-link utilization is accumulated as
//! busy time and queue pressure as an in-system high-water mark. Everything
//! here is a pure fold over the event stream, so two runs that process the
//! same events produce bit-identical telemetry — the property the
//! determinism regression in `rust/tests/sim_engine.rs` pins.
//!
//! Empty-telemetry contract: a run with zero post-warm-up completions
//! reports **explicit zeros** for the mean and every quantile, with
//! `sojourn.count = 0` as the marker — never NaN, which the JSON layer
//! would serialize as `null` and break artifact consumers. The underlying
//! sketch keeps its NaN-on-empty contract; the gating happens here.

use crate::util::json::Json;
use crate::util::stats::{QuantileSketch, Welford};

/// Hex-encoded IEEE-754 bits, mirroring `coordinator::exec::artifact`'s
/// convention (`sim::` must not depend on `coordinator::`, so the one-line
/// encoder is repeated rather than imported).
pub(crate) fn bits_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

/// Streaming counters and sketches for one simulation run.
#[derive(Clone, Debug)]
pub struct Telemetry {
    /// Sojourn-time sketch over post-warm-up completions.
    pub sojourn: QuantileSketch,
    mean: Welford,
    /// Requests injected by the arrival process.
    pub arrived: u64,
    /// Requests that reached their task's destination.
    pub completed: u64,
    /// Completions excluded from the sketch as warm-up.
    pub warmup_skipped: u64,
    /// Requests abandoned because the strategy offered no outgoing slot —
    /// always 0 for a feasible, loop-free strategy (asserted in tests).
    pub stranded: u64,
    /// Arrivals dropped at the in-flight ceiling
    /// (`SimConfig::max_in_flight`) — nonzero means the strategy is
    /// overloaded and the closed-loop validator must alarm.
    pub overload_dropped: u64,
    /// Busy time per compute node (CPU utilization = busy / end_time).
    pub node_busy: Vec<f64>,
    /// Busy time per directed link.
    pub link_busy: Vec<f64>,
    /// High-water mark of requests in system per compute node.
    pub node_peak: Vec<u64>,
    /// High-water mark of requests in system per link.
    pub link_peak: Vec<u64>,
    /// Time-average number in system per compute node — the simulated
    /// counterpart of the analytic occupancy `CostFn::value(F)` that the
    /// closed-loop validator compares per server.
    pub node_occupancy: Vec<f64>,
    /// Time-average number in system per directed link.
    pub link_occupancy: Vec<f64>,
    /// Simulation clock when the last event fired.
    pub end_time: f64,
    /// Total events processed by the calendar queue.
    pub events: u64,
    /// Peak concurrent in-flight requests (arena high-water mark).
    pub max_in_flight: u64,
    /// In-loop re-optimization ticks that ran (0 without `ReoptConfig`).
    pub reopt_events: u64,
    /// Single-node SGP updates applied across all ticks.
    pub reopt_updates: u64,
    /// Single-node SGP updates skipped (unpriceable estimated state).
    pub reopt_skipped: u64,
}

impl Telemetry {
    pub fn new(nodes: usize, links: usize) -> Self {
        Telemetry {
            sojourn: QuantileSketch::with_default_error(),
            mean: Welford::default(),
            arrived: 0,
            completed: 0,
            warmup_skipped: 0,
            stranded: 0,
            overload_dropped: 0,
            node_busy: vec![0.0; nodes],
            link_busy: vec![0.0; links],
            node_peak: vec![0; nodes],
            link_peak: vec![0; links],
            node_occupancy: vec![0.0; nodes],
            link_occupancy: vec![0.0; links],
            end_time: 0.0,
            events: 0,
            max_in_flight: 0,
            reopt_events: 0,
            reopt_updates: 0,
            reopt_skipped: 0,
        }
    }

    /// Record one completed request's sojourn time; warm-up completions
    /// count but do not enter the sketch.
    pub fn record_completion(&mut self, sojourn: f64, warmed_up: bool) {
        self.completed += 1;
        if warmed_up {
            self.sojourn.record(sojourn);
            self.mean.push(sojourn);
        } else {
            self.warmup_skipped += 1;
        }
    }

    /// Mean post-warm-up sojourn; explicit 0.0 when no sample was recorded
    /// (`sojourn.count() == 0` is the empties marker).
    pub fn mean_sojourn(&self) -> f64 {
        if self.mean.count() == 0 {
            0.0
        } else {
            self.mean.mean()
        }
    }

    /// The three headline tail quantiles (p50, p99, p999); explicit zeros
    /// when the sketch is empty.
    pub fn tail(&self) -> (f64, f64, f64) {
        if self.sojourn.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        (
            self.sojourn.quantile(0.50),
            self.sojourn.quantile(0.99),
            self.sojourn.quantile(0.999),
        )
    }

    /// Utilization vectors busy/elapsed (empty horizon ⇒ zeros).
    fn utilization(busy: &[f64], elapsed: f64) -> Json {
        let xs: Vec<f64> = busy
            .iter()
            .map(|&b| if elapsed > 0.0 { b / elapsed } else { 0.0 })
            .collect();
        Json::from_f64_slice(&xs)
    }

    /// Full JSON report. Quantiles carry both a human-readable number and
    /// authoritative `_bits` hex so determinism checks compare exact bits.
    /// Empty runs emit zeros (with `sojourn.count = 0`), never `null`.
    pub fn to_json(&self) -> Json {
        let (p50, p99, p999) = self.tail();
        let mean = self.mean_sojourn();
        let max = if self.sojourn.is_empty() {
            0.0
        } else {
            self.sojourn.max()
        };
        let mut soj = Json::obj();
        soj.set("count", Json::Num(self.sojourn.count() as f64))
            .set("error_bound", Json::Num(self.sojourn.relative_error_bound()))
            .set("p50", Json::Num(p50))
            .set("p50_bits", Json::Str(bits_hex(p50)))
            .set("p99", Json::Num(p99))
            .set("p99_bits", Json::Str(bits_hex(p99)))
            .set("p999", Json::Num(p999))
            .set("p999_bits", Json::Str(bits_hex(p999)))
            .set("mean", Json::Num(mean))
            .set("mean_bits", Json::Str(bits_hex(mean)))
            .set("max", Json::Num(max));
        let mut j = Json::obj();
        j.set("arrived", Json::Num(self.arrived as f64))
            .set("completed", Json::Num(self.completed as f64))
            .set("warmup_skipped", Json::Num(self.warmup_skipped as f64))
            .set("stranded", Json::Num(self.stranded as f64))
            .set("overload_dropped", Json::Num(self.overload_dropped as f64))
            .set("events", Json::Num(self.events as f64))
            .set("end_time", Json::Num(self.end_time))
            .set("end_time_bits", Json::Str(bits_hex(self.end_time)))
            .set("max_in_flight", Json::Num(self.max_in_flight as f64))
            .set("reopt_events", Json::Num(self.reopt_events as f64))
            .set("reopt_updates", Json::Num(self.reopt_updates as f64))
            .set("reopt_skipped", Json::Num(self.reopt_skipped as f64))
            .set("sojourn", soj)
            .set(
                "node_utilization",
                Self::utilization(&self.node_busy, self.end_time),
            )
            .set(
                "link_utilization",
                Self::utilization(&self.link_busy, self.end_time),
            )
            .set(
                "node_occupancy",
                Json::from_f64_slice(&self.node_occupancy),
            )
            .set(
                "link_occupancy",
                Json::from_f64_slice(&self.link_occupancy),
            )
            .set(
                "node_queue_peak",
                Json::Arr(self.node_peak.iter().map(|&p| Json::Num(p as f64)).collect()),
            )
            .set(
                "link_queue_peak",
                Json::Arr(self.link_peak.iter().map(|&p| Json::Num(p as f64)).collect()),
            );
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_completions_do_not_enter_sketch() {
        let mut t = Telemetry::new(2, 3);
        t.record_completion(9.0, false);
        t.record_completion(1.0, true);
        t.record_completion(2.0, true);
        assert_eq!(t.completed, 3);
        assert_eq!(t.warmup_skipped, 1);
        assert_eq!(t.sojourn.count(), 2);
        assert!((t.mean_sojourn() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn quantiles_are_ordered() {
        let mut t = Telemetry::new(1, 1);
        for i in 1..=1000 {
            t.record_completion(f64::from(i) * 0.01, true);
        }
        let (p50, p99, p999) = t.tail();
        assert!(p50 <= p99 && p99 <= p999, "{p50} {p99} {p999}");
    }

    #[test]
    fn json_roundtrips_and_carries_bits() {
        let mut t = Telemetry::new(1, 2);
        t.arrived = 5;
        t.record_completion(0.5, true);
        t.end_time = 2.0;
        t.node_busy[0] = 1.0;
        let j = t.to_json();
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back.path("sojourn.count").as_usize(), Some(1));
        assert_eq!(
            back.path("node_utilization").as_arr().unwrap()[0].as_num(),
            Some(0.5)
        );
        assert_eq!(
            back.path("sojourn.p50_bits").as_str().unwrap().len(),
            16
        );
    }

    #[test]
    fn empty_telemetry_serializes_zeros_not_nulls() {
        let t = Telemetry::new(2, 1);
        assert_eq!(t.mean_sojourn(), 0.0);
        assert_eq!(t.tail(), (0.0, 0.0, 0.0));
        let dump = t.to_json().dump();
        assert!(!dump.contains("null"), "empty telemetry leaked null: {dump}");
        let back = Json::parse(&dump).unwrap();
        assert_eq!(back.path("sojourn.count").as_usize(), Some(0));
        assert_eq!(back.path("sojourn.p50").as_num(), Some(0.0));
        assert_eq!(back.path("sojourn.mean").as_num(), Some(0.0));
        assert_eq!(back.path("sojourn.max").as_num(), Some(0.0));
        assert_eq!(back.path("overload_dropped").as_num(), Some(0.0));
    }
}
