//! Streaming telemetry for the request-level engine: tail latency without
//! storing samples.
//!
//! A 10^6-request run must not keep 10^6 sojourn times around just to sort
//! them at the end — the whole point of the layered engine is bounded
//! memory. Sojourn times therefore stream into a
//! [`QuantileSketch`](crate::util::stats::QuantileSketch) (log-bucketed,
//! ≤ 1% relative error by default, memory independent of request count)
//! plus a Welford mean; per-node/per-link utilization is accumulated as
//! busy time and queue pressure as an in-system high-water mark. Everything
//! here is a pure fold over the event stream, so two runs that process the
//! same events produce bit-identical telemetry — the property the
//! determinism regression in `rust/tests/sim_engine.rs` pins.
//!
//! Empty-telemetry contract: a run with zero post-warm-up completions
//! reports **explicit zeros** for the mean and every quantile, with
//! `sojourn.count = 0` as the marker — never NaN, which the JSON layer
//! would serialize as `null` and break artifact consumers. The underlying
//! sketch keeps its NaN-on-empty contract; the gating happens here.

use crate::util::json::Json;
use crate::util::stats::{QuantileSketch, Welford};

/// Hex-encoded IEEE-754 bits, mirroring `coordinator::exec::artifact`'s
/// convention (`sim::` must not depend on `coordinator::`, so the one-line
/// encoder is repeated rather than imported).
pub(crate) fn bits_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

/// Largest `u64` a JSON number (an `f64`) can carry exactly: 2^53.
pub(crate) const JSON_EXACT_MAX: u64 = 1 << 53;

/// Counter → JSON number, exact up to [`JSON_EXACT_MAX`] and saturating
/// beyond it. A `x as f64` cast above 2^53 silently rounds to an even
/// neighbor — a counter that quietly loses its low bits is worse than one
/// pinned at a documented ceiling, and every consumer can detect the
/// ceiling exactly.
pub(crate) fn num_u64(x: u64) -> Json {
    Json::Num(x.min(JSON_EXACT_MAX) as f64)
}

/// Streaming counters and sketches for one simulation run.
#[derive(Clone, Debug)]
pub struct Telemetry {
    /// Sojourn-time sketch over post-warm-up completions.
    pub sojourn: QuantileSketch,
    mean: Welford,
    /// Requests injected by the arrival process.
    pub arrived: u64,
    /// Requests that reached their task's destination.
    pub completed: u64,
    /// Completions excluded from the sketch as warm-up.
    pub warmup_skipped: u64,
    /// Requests abandoned because the strategy offered no outgoing slot —
    /// always 0 for a feasible, loop-free strategy (asserted in tests).
    pub stranded: u64,
    /// Arrivals dropped at the in-flight ceiling
    /// (`SimConfig::max_in_flight`) — nonzero means the strategy is
    /// overloaded and the closed-loop validator must alarm.
    pub overload_dropped: u64,
    /// Requests dropped at a full per-server FIFO
    /// (`SimConfig::queue_cap`). Disjoint from `overload_dropped` — the
    /// global ceiling refuses an arrival before any queue is consulted —
    /// so the widened conservation invariant is exact:
    /// `completed + stranded + overload_dropped + queue_dropped == arrived`.
    pub queue_dropped: u64,
    /// Admissions refused per compute node because its FIFO was full.
    pub node_blocked: Vec<u64>,
    /// Admissions refused per directed link because its FIFO was full.
    pub link_blocked: Vec<u64>,
    /// Admission attempts per compute node (accepted + blocked) — the
    /// denominator of the simulated blocking rate the validator compares
    /// against the Erlang prediction.
    pub node_offered: Vec<u64>,
    /// Admission attempts per directed link.
    pub link_offered: Vec<u64>,
    /// Effective `(cpu, link)` FIFO capacities of the run (`u64::MAX`
    /// marks a kind left unbounded by a partial override); `None` for an
    /// uncapped run. Doubles as the serialization gate: uncapped runs
    /// emit none of the queue-cap telemetry keys and their JSON is
    /// bit-identical to the pre-admission-control engine.
    pub queue_caps: Option<(u64, u64)>,
    /// Busy time per compute node (CPU utilization = busy / end_time).
    pub node_busy: Vec<f64>,
    /// Busy time per directed link.
    pub link_busy: Vec<f64>,
    /// High-water mark of requests in system per compute node.
    pub node_peak: Vec<u64>,
    /// High-water mark of requests in system per link.
    pub link_peak: Vec<u64>,
    /// Time-average number in system per compute node — the simulated
    /// counterpart of the analytic occupancy `CostFn::value(F)` that the
    /// closed-loop validator compares per server.
    pub node_occupancy: Vec<f64>,
    /// Time-average number in system per directed link.
    pub link_occupancy: Vec<f64>,
    /// Simulation clock when the last event fired.
    pub end_time: f64,
    /// Total events processed by the calendar queue.
    pub events: u64,
    /// Peak concurrent in-flight requests (arena high-water mark).
    pub max_in_flight: u64,
    /// In-loop re-optimization ticks that ran (0 without `ReoptConfig`).
    pub reopt_events: u64,
    /// Single-node SGP updates applied across all ticks.
    pub reopt_updates: u64,
    /// Single-node SGP updates skipped (unpriceable estimated state).
    pub reopt_skipped: u64,
}

impl Telemetry {
    pub fn new(nodes: usize, links: usize) -> Self {
        Telemetry {
            sojourn: QuantileSketch::with_default_error(),
            mean: Welford::default(),
            arrived: 0,
            completed: 0,
            warmup_skipped: 0,
            stranded: 0,
            overload_dropped: 0,
            queue_dropped: 0,
            node_blocked: vec![0; nodes],
            link_blocked: vec![0; links],
            node_offered: vec![0; nodes],
            link_offered: vec![0; links],
            queue_caps: None,
            node_busy: vec![0.0; nodes],
            link_busy: vec![0.0; links],
            node_peak: vec![0; nodes],
            link_peak: vec![0; links],
            node_occupancy: vec![0.0; nodes],
            link_occupancy: vec![0.0; links],
            end_time: 0.0,
            events: 0,
            max_in_flight: 0,
            reopt_events: 0,
            reopt_updates: 0,
            reopt_skipped: 0,
        }
    }

    /// Record one completed request's sojourn time; warm-up completions
    /// count but do not enter the sketch.
    pub fn record_completion(&mut self, sojourn: f64, warmed_up: bool) {
        self.completed += 1;
        if warmed_up {
            self.sojourn.record(sojourn);
            self.mean.push(sojourn);
        } else {
            self.warmup_skipped += 1;
        }
    }

    /// Mean post-warm-up sojourn; explicit 0.0 when no sample was recorded
    /// (`sojourn.count() == 0` is the empties marker).
    pub fn mean_sojourn(&self) -> f64 {
        if self.mean.count() == 0 {
            0.0
        } else {
            self.mean.mean()
        }
    }

    /// The three headline tail quantiles (p50, p99, p999); explicit zeros
    /// when the sketch is empty.
    pub fn tail(&self) -> (f64, f64, f64) {
        if self.sojourn.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        (
            self.sojourn.quantile(0.50),
            self.sojourn.quantile(0.99),
            self.sojourn.quantile(0.999),
        )
    }

    /// Utilization vectors busy/elapsed (empty horizon ⇒ zeros).
    fn utilization(busy: &[f64], elapsed: f64) -> Json {
        let xs: Vec<f64> = busy
            .iter()
            .map(|&b| if elapsed > 0.0 { b / elapsed } else { 0.0 })
            .collect();
        Json::from_f64_slice(&xs)
    }

    /// Full JSON report. Quantiles carry both a human-readable number and
    /// authoritative `_bits` hex so determinism checks compare exact bits.
    /// Empty runs emit zeros (with `sojourn.count = 0`), never `null`.
    /// Counters serialize through [`num_u64`] (exact to 2^53, saturating
    /// beyond), and every queue-cap key is gated on `queue_caps` so an
    /// uncapped run's JSON is byte-identical to the pre-capacity engine.
    pub fn to_json(&self) -> Json {
        let (p50, p99, p999) = self.tail();
        let mean = self.mean_sojourn();
        let max = if self.sojourn.is_empty() {
            0.0
        } else {
            self.sojourn.max()
        };
        let counters = |xs: &[u64]| Json::Arr(xs.iter().map(|&x| num_u64(x)).collect());
        let mut soj = Json::obj();
        soj.set("count", num_u64(self.sojourn.count()))
            .set("error_bound", Json::Num(self.sojourn.relative_error_bound()))
            .set("p50", Json::Num(p50))
            .set("p50_bits", Json::Str(bits_hex(p50)))
            .set("p99", Json::Num(p99))
            .set("p99_bits", Json::Str(bits_hex(p99)))
            .set("p999", Json::Num(p999))
            .set("p999_bits", Json::Str(bits_hex(p999)))
            .set("mean", Json::Num(mean))
            .set("mean_bits", Json::Str(bits_hex(mean)))
            .set("max", Json::Num(max));
        let mut j = Json::obj();
        j.set("arrived", num_u64(self.arrived))
            .set("completed", num_u64(self.completed))
            .set("warmup_skipped", num_u64(self.warmup_skipped))
            .set("stranded", num_u64(self.stranded))
            .set("overload_dropped", num_u64(self.overload_dropped))
            .set("events", num_u64(self.events))
            .set("end_time", Json::Num(self.end_time))
            .set("end_time_bits", Json::Str(bits_hex(self.end_time)))
            .set("max_in_flight", num_u64(self.max_in_flight))
            .set("reopt_events", num_u64(self.reopt_events))
            .set("reopt_updates", num_u64(self.reopt_updates))
            .set("reopt_skipped", num_u64(self.reopt_skipped))
            .set("sojourn", soj)
            .set(
                "node_utilization",
                Self::utilization(&self.node_busy, self.end_time),
            )
            .set(
                "link_utilization",
                Self::utilization(&self.link_busy, self.end_time),
            )
            .set(
                "node_occupancy",
                Json::from_f64_slice(&self.node_occupancy),
            )
            .set(
                "link_occupancy",
                Json::from_f64_slice(&self.link_occupancy),
            )
            .set("node_queue_peak", counters(&self.node_peak))
            .set("link_queue_peak", counters(&self.link_peak));
        if let Some((cpu_cap, link_cap)) = self.queue_caps {
            let cap_json = |c: u64| {
                if c == u64::MAX {
                    Json::Str("unbounded".to_string())
                } else {
                    num_u64(c)
                }
            };
            let mut caps = Json::obj();
            caps.set("cpu", cap_json(cpu_cap))
                .set("link", cap_json(link_cap));
            j.set("queue_cap", caps)
                .set("queue_dropped", num_u64(self.queue_dropped))
                .set("node_blocked", counters(&self.node_blocked))
                .set("link_blocked", counters(&self.link_blocked))
                .set("node_offered", counters(&self.node_offered))
                .set("link_offered", counters(&self.link_offered));
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_completions_do_not_enter_sketch() {
        let mut t = Telemetry::new(2, 3);
        t.record_completion(9.0, false);
        t.record_completion(1.0, true);
        t.record_completion(2.0, true);
        assert_eq!(t.completed, 3);
        assert_eq!(t.warmup_skipped, 1);
        assert_eq!(t.sojourn.count(), 2);
        assert!((t.mean_sojourn() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn quantiles_are_ordered() {
        let mut t = Telemetry::new(1, 1);
        for i in 1..=1000 {
            t.record_completion(f64::from(i) * 0.01, true);
        }
        let (p50, p99, p999) = t.tail();
        assert!(p50 <= p99 && p99 <= p999, "{p50} {p99} {p999}");
    }

    #[test]
    fn json_roundtrips_and_carries_bits() {
        let mut t = Telemetry::new(1, 2);
        t.arrived = 5;
        t.record_completion(0.5, true);
        t.end_time = 2.0;
        t.node_busy[0] = 1.0;
        let j = t.to_json();
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back.path("sojourn.count").as_usize(), Some(1));
        assert_eq!(
            back.path("node_utilization").as_arr().unwrap()[0].as_num(),
            Some(0.5)
        );
        assert_eq!(
            back.path("sojourn.p50_bits").as_str().unwrap().len(),
            16
        );
    }

    #[test]
    fn counter_serialization_is_exact_to_2_pow_53_then_saturates() {
        // Below and at the boundary: the f64 carries the exact integer.
        for x in [0u64, 1, JSON_EXACT_MAX - 1, JSON_EXACT_MAX] {
            assert_eq!(num_u64(x).as_num(), Some(x as f64));
            assert_eq!(num_u64(x).as_num().map(|f| f as u64), Some(x));
        }
        // Above it: saturate to the documented ceiling instead of rounding
        // to an even neighbor the way `as f64` silently would.
        for x in [JSON_EXACT_MAX + 1, JSON_EXACT_MAX + 3, u64::MAX] {
            assert_eq!(num_u64(x).as_num(), Some(JSON_EXACT_MAX as f64));
        }
        // The boundary matters: 2^53 + 1 is the first unrepresentable u64.
        assert_eq!((JSON_EXACT_MAX + 1) as f64, JSON_EXACT_MAX as f64);
        // A saturating counter round-trips through dump/parse losslessly.
        let mut t = Telemetry::new(1, 1);
        t.events = u64::MAX;
        let back = Json::parse(&t.to_json().dump()).unwrap();
        assert_eq!(back.path("events").as_num(), Some(JSON_EXACT_MAX as f64));
    }

    #[test]
    fn queue_cap_keys_are_gated_on_capped_runs() {
        let mut t = Telemetry::new(2, 1);
        let uncapped = t.to_json().dump();
        for key in ["queue_cap", "queue_dropped", "node_blocked", "node_offered"] {
            assert!(!uncapped.contains(key), "uncapped dump leaked {key}");
        }
        t.queue_caps = Some((4, u64::MAX));
        t.queue_dropped = 7;
        t.node_blocked[1] = 7;
        t.node_offered[1] = 10;
        let j = t.to_json();
        let dump = j.dump();
        assert!(!dump.contains("null"), "capped telemetry leaked null: {dump}");
        assert_eq!(j.path("queue_cap.cpu").as_num(), Some(4.0));
        assert_eq!(
            j.path("queue_cap.link").as_str(),
            Some("unbounded"),
            "partial override must mark the unbounded kind"
        );
        assert_eq!(j.path("queue_dropped").as_num(), Some(7.0));
        assert_eq!(
            j.get("node_blocked").as_arr().unwrap()[1].as_num(),
            Some(7.0)
        );
        assert_eq!(
            j.get("node_offered").as_arr().unwrap()[1].as_num(),
            Some(10.0)
        );
    }

    #[test]
    fn empty_telemetry_serializes_zeros_not_nulls() {
        let t = Telemetry::new(2, 1);
        assert_eq!(t.mean_sojourn(), 0.0);
        assert_eq!(t.tail(), (0.0, 0.0, 0.0));
        let dump = t.to_json().dump();
        assert!(!dump.contains("null"), "empty telemetry leaked null: {dump}");
        let back = Json::parse(&dump).unwrap();
        assert_eq!(back.path("sojourn.count").as_usize(), Some(0));
        assert_eq!(back.path("sojourn.p50").as_num(), Some(0.0));
        assert_eq!(back.path("sojourn.mean").as_num(), Some(0.0));
        assert_eq!(back.path("sojourn.max").as_num(), Some(0.0));
        assert_eq!(back.path("overload_dropped").as_num(), Some(0.0));
    }
}
