//! Legacy binary-heap event queue, kept as the reference implementation.
//!
//! A minimal time-ordered event queue: events carry an opaque payload and
//! fire in (time, sequence) order, so simultaneous events are processed in
//! deterministic FIFO order. Production callers (`sim::protocol`, the
//! request-level `sim::tasks` engine) now run on the O(1)-amortized
//! calendar queue in [`super::core`]; this heap version stays because its
//! O(log n) semantics are trivially auditable, which makes it the oracle
//! for the randomized ordering-parity test in `rust/tests/sim_engine.rs`
//! that pins the calendar queue's behaviour.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at `time` carrying `payload`.
#[derive(Clone, Debug)]
pub struct Event<P> {
    pub time: f64,
    pub seq: u64,
    pub payload: P,
}

impl<P> PartialEq for Event<P> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<P> Eq for Event<P> {}

impl<P> PartialOrd for Event<P> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<P> Ord for Event<P> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event queue / simulation clock.
pub struct EventQueue<P> {
    heap: BinaryHeap<Event<P>>,
    now: f64,
    seq: u64,
    pub processed: u64,
}

impl<P> EventQueue<P> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: 0.0,
            seq: 0,
            processed: 0,
        }
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `payload` to fire `delay` from now.
    ///
    /// Non-finite delays are rejected: `Event::cmp` falls back to
    /// `Ordering::Equal` when times are incomparable, so a NaN time would
    /// silently corrupt the heap order rather than fail loudly, and an
    /// infinite time would pin the clock at `+∞` on pop.
    pub fn schedule(&mut self, delay: f64, payload: P) {
        assert!(
            delay.is_finite() && delay >= 0.0,
            "event delay must be finite and non-negative, got {delay}"
        );
        let ev = Event {
            time: self.now + delay,
            seq: self.seq,
            payload,
        };
        self.seq += 1;
        self.heap.push(ev);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<Event<P>> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now - 1e-12);
        self.now = ev.time;
        self.processed += 1;
        Some(ev)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<P> Default for EventQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
        assert_eq!(q.processed, 3);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(1.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_with_nested_scheduling() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(1.0, 1);
        let e = q.pop().unwrap();
        assert_eq!(e.payload, 1);
        // schedule relative to the new now
        q.schedule(0.5, 2);
        let e2 = q.pop().unwrap();
        assert!((e2.time - 1.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn negative_delay_rejected() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.schedule(-1.0, ());
    }

    #[test]
    #[should_panic]
    fn nan_delay_rejected() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.schedule(f64::NAN, ());
    }

    #[test]
    #[should_panic]
    fn infinite_delay_rejected() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.schedule(f64::INFINITY, ());
    }
}
