//! Closed-loop layer: analytic-vs-simulated validation and in-simulation
//! re-optimization.
//!
//! The paper's optimality theorem is a statement about the *analytic*
//! congestion cost `T = Σ D_ij(F_ij) + Σ C_i(G_i)`; the request-level
//! engine ([`super::tasks`]) measures *simulated* sojourn. This module
//! closes the loop between them in both directions:
//!
//! * **Validation** ([`validate`]): by Little's law the analytic expected
//!   sojourn of a steady-state run is `T / λ` (λ = total arrival rate),
//!   because every cost term `value(F)` is an expected number-in-system —
//!   `F/(cap−F)` for the M/M/1 `Queue` cost, `unit·F` for the
//!   infinite-server `Linear` delay. The validator derives `T` from the
//!   converged flows ([`compute_flows`]), compares against the simulated
//!   mean sojourn, and emits a per-server divergence report comparing each
//!   server's analytic occupancy `value(F)` with its simulated
//!   time-average number in system. A **hard alarm** fires when the
//!   aggregate relative error exceeds the configured bound, when any
//!   capacitated server is saturated (`F ≥ cap`), when arrivals were
//!   dropped at the in-flight ceiling, or when there are no post-warm-up
//!   samples to compare.
//!
//!   Tolerance semantics: the headline check is the *aggregate mean*
//!   (`rel_diff(T/λ, simulated mean)` ≤ tol). Per-server rows are
//!   diagnostic: a server fed by heterogeneous request sizes is M/G/1
//!   (hyperexponential service), not the M/M/1 the closed form assumes,
//!   so per-server error is reported and folded into
//!   `max_server_rel_error` but does not by itself trip the alarm.
//!
//! * **Re-optimization** ([`simulate_adaptive`] / [`ReoptConfig`]): instead
//!   of pre-converging every epoch offline (`AdaptiveRunner`), schedule
//!   SGP ticks on the calendar queue that re-run the paper's asynchronous
//!   single-node update against arrival rates estimated from accumulated
//!   telemetry — the strategy adapts *inside* the run, the asynchronous
//!   operation of Theorem 2 rather than an offline oracle.

use anyhow::{ensure, Result};

use crate::model::cost::CostFn;
use crate::model::flows::compute_flows;
use crate::model::network::Network;
use crate::model::strategy::Strategy;
use crate::util::json::Json;
use crate::util::stats::rel_diff;
use crate::util::table::{fnum, Table};

use super::tasks::{simulate_with, SimConfig, SimPlan};
use super::telemetry::{bits_hex, Telemetry};
use super::workload::ArrivalSpec;

/// Servers with analytic utilization below this floor are excluded from
/// the headline `max_server_rel_error`: a near-idle server's occupancy is
/// dominated by sampling noise, so its relative error is meaningless. The
/// rows still appear in the report.
pub const RHO_FLOOR: f64 = 0.05;

/// In-simulation re-optimization parameters.
#[derive(Clone, Copy, Debug)]
pub struct ReoptConfig {
    /// Simulated time between SGP ticks; each tick updates one node
    /// (round-robin) across every task and both planes.
    pub interval: f64,
    /// Minimum arrivals in the observation window before the rate
    /// estimate is refreshed from telemetry — below it, ticks keep
    /// pricing against the previous estimate.
    pub min_window: u64,
}

impl ReoptConfig {
    /// Tick every `interval` simulated time units with the default
    /// observation-window floor.
    pub fn every(interval: f64) -> Result<ReoptConfig> {
        ensure!(
            interval.is_finite() && interval > 0.0,
            "re-optimization interval must be finite and positive, got {interval}"
        );
        Ok(ReoptConfig {
            interval,
            min_window: 50,
        })
    }
}

/// Run the request-level simulation with in-loop asynchronous
/// re-optimization ([`ReoptConfig`]). Deterministic: the tick schedule
/// rides the same calendar queue as the workload, and the SGP update is
/// randomness-free, so repeated runs are bit-identical.
pub fn simulate_adaptive(
    plan: &SimPlan,
    arrivals: &ArrivalSpec,
    cfg: &SimConfig,
    reopt: &ReoptConfig,
) -> Result<Telemetry> {
    simulate_with(plan, arrivals, cfg, Some(reopt))
}

/// One server's analytic-vs-simulated occupancy comparison.
#[derive(Clone, Debug)]
pub struct ServerDivergence {
    /// `cpu:<node>` or `link:<edge>`.
    pub name: String,
    /// Analytic flow through the server (`G_i` or `F_ij`).
    pub flow: f64,
    /// Analytic utilization `flow / cap` (0 for uncapacitated servers).
    pub rho: f64,
    /// Analytic expected number in system, `CostFn::value(flow)`.
    pub analytic: f64,
    /// Simulated time-average number in system.
    pub simulated: f64,
    /// `rel_diff(analytic, simulated)`; +∞ when either is non-finite.
    pub rel_error: f64,
    /// Analytic flow at or beyond capacity — the queue is divergent.
    pub saturated: bool,
}

/// Outcome of [`validate`]: the aggregate comparison, per-server rows, and
/// the alarm verdict with human-readable reasons.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub tol: f64,
    /// Total arrival rate λ = Σ_m Σ_i r_i^m.
    pub lambda: f64,
    /// Analytic total cost `T` from the converged flows.
    pub analytic_cost: f64,
    /// Little's law: `T / λ`.
    pub analytic_mean_sojourn: f64,
    pub simulated_mean_sojourn: f64,
    /// `rel_diff` of the two means; +∞ when incomparable (saturation,
    /// zero samples).
    pub mean_rel_error: f64,
    /// Largest per-server `rel_error` among servers with ρ ≥ [`RHO_FLOOR`].
    pub max_server_rel_error: f64,
    /// Post-warm-up completions backing the simulated mean.
    pub samples: u64,
    pub overload_dropped: u64,
    pub servers: Vec<ServerDivergence>,
    pub alarm: bool,
    pub alarm_reasons: Vec<String>,
}

/// `rel_diff` that stays meaningful under saturation: non-finite inputs
/// compare as +∞ (maximally divergent), never NaN.
fn guarded_rel(a: f64, b: f64) -> f64 {
    if a.is_finite() && b.is_finite() {
        rel_diff(a, b)
    } else {
        f64::INFINITY
    }
}

/// Compare the analytic steady-state prediction of `(net, phi)` against
/// the simulated telemetry of the same pair. See the module docs for the
/// tolerance semantics and alarm conditions.
pub fn validate(
    net: &Network,
    phi: &Strategy,
    t: &Telemetry,
    tol: f64,
) -> Result<ValidationReport> {
    ensure!(
        tol.is_finite() && tol > 0.0,
        "validation tolerance must be finite and positive, got {tol}"
    );
    ensure!(
        t.node_occupancy.len() == net.n() && t.link_occupancy.len() == net.e(),
        "telemetry dimensions ({} nodes, {} links) do not match the network ({}, {})",
        t.node_occupancy.len(),
        t.link_occupancy.len(),
        net.n(),
        net.e()
    );
    let flows = compute_flows(net, phi).map_err(anyhow::Error::new)?;
    let lambda: f64 = net.input_rate.iter().flat_map(|r| r.iter()).sum();
    ensure!(lambda > 0.0, "network offers no traffic (λ = 0)");

    let mut servers = Vec::with_capacity(net.n() + net.e());
    let mut push = |name: String, cost: &CostFn, flow: f64, simulated: f64| {
        let (rho, saturated) = match cost.capacity() {
            Some(cap) => (flow / cap, flow >= cap),
            None => (0.0, false),
        };
        let analytic = cost.value(flow);
        servers.push(ServerDivergence {
            name,
            flow,
            rho,
            analytic,
            simulated,
            rel_error: guarded_rel(analytic, simulated),
            saturated,
        });
    };
    for i in 0..net.n() {
        push(
            format!("cpu:{i}"),
            &net.comp_cost[i],
            flows.workload[i],
            t.node_occupancy[i],
        );
    }
    for e in 0..net.e() {
        push(
            format!("link:{e}"),
            &net.link_cost[e],
            flows.link_flow[e],
            t.link_occupancy[e],
        );
    }

    let analytic_cost = flows.total_cost;
    let analytic_mean = analytic_cost / lambda;
    let simulated_mean = t.mean_sojourn();
    let samples = t.sojourn.count();
    let mean_rel_error = if samples == 0 {
        f64::INFINITY
    } else {
        guarded_rel(analytic_mean, simulated_mean)
    };
    let max_server_rel_error = servers
        .iter()
        .filter(|s| s.rho >= RHO_FLOOR)
        .map(|s| s.rel_error)
        .fold(0.0, f64::max);

    let mut reasons = Vec::new();
    for s in servers.iter().filter(|s| s.saturated) {
        reasons.push(format!(
            "{}: analytic flow {} ≥ capacity — queue divergent",
            s.name,
            fnum(s.flow)
        ));
    }
    if t.overload_dropped > 0 {
        reasons.push(format!(
            "{} arrival(s) dropped at the in-flight ceiling — strategy overloaded",
            t.overload_dropped
        ));
    }
    if samples == 0 {
        reasons.push("no post-warm-up completions to compare".to_string());
    } else if mean_rel_error > tol {
        reasons.push(format!(
            "mean sojourn diverges: analytic {} vs simulated {} (rel err {} > tol {})",
            fnum(analytic_mean),
            fnum(simulated_mean),
            fnum(mean_rel_error),
            fnum(tol)
        ));
    }
    let alarm = !reasons.is_empty();
    Ok(ValidationReport {
        tol,
        lambda,
        analytic_cost,
        analytic_mean_sojourn: analytic_mean,
        simulated_mean_sojourn: simulated_mean,
        mean_rel_error,
        max_server_rel_error,
        samples,
        overload_dropped: t.overload_dropped,
        servers,
        alarm,
        alarm_reasons: reasons,
    })
}

impl ValidationReport {
    /// Human-readable divergence report: aggregate line, per-server table,
    /// alarm verdict.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "closed-loop validation (tol {}):\n  λ = {}  analytic cost T = {}\n  \
             mean sojourn: analytic T/λ = {} vs simulated {}  (rel err {}, {} sample(s))\n",
            fnum(self.tol),
            fnum(self.lambda),
            fnum(self.analytic_cost),
            fnum(self.analytic_mean_sojourn),
            fnum(self.simulated_mean_sojourn),
            fnum(self.mean_rel_error),
            self.samples
        ));
        let mut tbl = Table::new(&[
            "server",
            "flow",
            "rho",
            "analytic L",
            "simulated L",
            "rel err",
            "status",
        ]);
        for s in &self.servers {
            let status = if s.saturated {
                "SATURATED".to_string()
            } else if s.rho >= RHO_FLOOR && s.rel_error > self.tol {
                "divergent".to_string()
            } else {
                "ok".to_string()
            };
            tbl.row(vec![
                s.name.clone(),
                fnum(s.flow),
                fnum(s.rho),
                fnum(s.analytic),
                fnum(s.simulated),
                fnum(s.rel_error),
                status,
            ]);
        }
        out.push_str(&tbl.render());
        if self.alarm {
            out.push_str("ALARM:\n");
            for r in &self.alarm_reasons {
                out.push_str(&format!("  - {r}\n"));
            }
        } else {
            out.push_str(
                "alarm quiet: simulated sojourn matches the analytic model within tolerance\n",
            );
        }
        out
    }

    /// JSON report; headline numbers carry `_bits` hex for exact-bits
    /// determinism checks.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("tol", Json::Num(self.tol))
            .set("lambda", Json::Num(self.lambda))
            .set("analytic_cost", Json::Num(self.analytic_cost))
            .set("analytic_mean_sojourn", Json::Num(self.analytic_mean_sojourn))
            .set(
                "analytic_mean_sojourn_bits",
                Json::Str(bits_hex(self.analytic_mean_sojourn)),
            )
            .set(
                "simulated_mean_sojourn",
                Json::Num(self.simulated_mean_sojourn),
            )
            .set(
                "simulated_mean_sojourn_bits",
                Json::Str(bits_hex(self.simulated_mean_sojourn)),
            )
            .set("mean_rel_error", Json::Num(self.mean_rel_error))
            .set("mean_rel_error_bits", Json::Str(bits_hex(self.mean_rel_error)))
            .set("max_server_rel_error", Json::Num(self.max_server_rel_error))
            .set(
                "max_server_rel_error_bits",
                Json::Str(bits_hex(self.max_server_rel_error)),
            )
            .set("samples", Json::Num(self.samples as f64))
            .set("overload_dropped", Json::Num(self.overload_dropped as f64))
            .set("alarm", Json::Bool(self.alarm))
            .set(
                "alarm_reasons",
                Json::Arr(
                    self.alarm_reasons
                        .iter()
                        .map(|r| Json::Str(r.clone()))
                        .collect(),
                ),
            )
            .set(
                "servers",
                Json::Arr(
                    self.servers
                        .iter()
                        .map(|s| {
                            let mut so = Json::obj();
                            so.set("name", Json::Str(s.name.clone()))
                                .set("flow", Json::Num(s.flow))
                                .set("rho", Json::Num(s.rho))
                                .set("analytic_occupancy", Json::Num(s.analytic))
                                .set("simulated_occupancy", Json::Num(s.simulated))
                                .set("rel_error", Json::Num(s.rel_error))
                                .set("saturated", Json::Bool(s.saturated));
                            so
                        })
                        .collect(),
                ),
            );
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::network::testnet::diamond;
    use crate::sim::tasks::{simulate, SimEpoch};

    fn poisson() -> ArrivalSpec {
        ArrivalSpec::parse("poisson").unwrap()
    }

    #[test]
    fn reopt_config_rejects_degenerate_intervals() {
        assert!(ReoptConfig::every(0.0).is_err());
        assert!(ReoptConfig::every(-1.0).is_err());
        assert!(ReoptConfig::every(f64::INFINITY).is_err());
        assert!(ReoptConfig::every(f64::NAN).is_err());
        assert!(ReoptConfig::every(2.5).is_ok());
    }

    #[test]
    fn validate_rejects_degenerate_tolerances() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let t = Telemetry::new(net.n(), net.e());
        assert!(validate(&net, &phi, &t, 0.0).is_err());
        assert!(validate(&net, &phi, &t, f64::NAN).is_err());
        let wrong = Telemetry::new(1, 1);
        assert!(validate(&net, &phi, &wrong, 0.1).is_err());
    }

    #[test]
    fn empty_telemetry_raises_the_alarm() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let t = Telemetry::new(net.n(), net.e());
        let report = validate(&net, &phi, &t, 0.1).unwrap();
        assert!(report.alarm);
        assert_eq!(report.samples, 0);
        assert!(report.mean_rel_error.is_infinite());
        assert!(report
            .alarm_reasons
            .iter()
            .any(|r| r.contains("no post-warm-up completions")));
    }

    #[test]
    fn lightly_loaded_diamond_validates_quietly() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let plan = SimPlan {
            epochs: vec![SimEpoch {
                net: net.clone(),
                phi: phi.clone(),
            }],
        };
        let cfg = SimConfig {
            requests: 20_000,
            warmup: 0.1,
            seed: 17,
            ..SimConfig::default()
        };
        let t = simulate(&plan, &poisson(), &cfg).unwrap();
        let report = validate(&net, &phi, &t, 0.25).unwrap();
        assert!(
            !report.alarm,
            "expected quiet alarm, got: {:?}",
            report.alarm_reasons
        );
        assert_eq!(report.servers.len(), net.n() + net.e());
        assert!(report.lambda > 0.0 && report.analytic_cost.is_finite());
        assert!(report.mean_rel_error <= 0.25, "{}", report.mean_rel_error);
        // The rendered report and JSON must both carry the verdict.
        assert!(report.render().contains("alarm quiet"));
        assert_eq!(report.to_json().get("alarm").as_bool(), Some(false));
    }

    #[test]
    fn adaptive_simulation_ticks_and_stays_deterministic() {
        let run = || {
            let net = diamond(true);
            let phi = Strategy::local_compute_init(&net);
            let plan = SimPlan {
                epochs: vec![SimEpoch { net, phi }],
            };
            let cfg = SimConfig {
                requests: 3_000,
                warmup: 0.1,
                seed: 23,
                ..SimConfig::default()
            };
            let reopt = ReoptConfig::every(25.0).unwrap();
            simulate_adaptive(&plan, &poisson(), &cfg, &reopt).unwrap()
        };
        let a = run();
        assert!(a.reopt_events > 0, "no re-optimization tick fired");
        assert_eq!(a.completed + a.stranded + a.overload_dropped, a.arrived);
        let b = run();
        assert_eq!(a.to_json().dump(), b.to_json().dump());
    }
}
