//! Closed-loop layer: analytic-vs-simulated validation and in-simulation
//! re-optimization.
//!
//! The paper's optimality theorem is a statement about the *analytic*
//! congestion cost `T = Σ D_ij(F_ij) + Σ C_i(G_i)`; the request-level
//! engine ([`super::tasks`]) measures *simulated* sojourn. This module
//! closes the loop between them in both directions:
//!
//! * **Validation** ([`validate`]): by Little's law the analytic expected
//!   sojourn of a steady-state run is `T / λ` (λ = total arrival rate),
//!   because every cost term is an expected number-in-system. The
//!   validator derives per-server request classes from the converged
//!   flows ([`compute_flows`]), prices each queued server with the
//!   Pollaczek–Khinchine M/G/1 mean — the service distribution is a
//!   hyperexponential mixture of one exponential class per task, so
//!   `L = ρ + λ²·E[S²] / (2(1−ρ))` with `λ·E[S²] = Σ_k λ_k·2s_k²` — and
//!   compares it against the simulated time-average number in system. A
//!   server whose classes share one service mean is plain M/M/1 and gets
//!   the `Queue` closed form `F/(cap−F)` **bit-for-bit**, so homogeneous
//!   validation artifacts keep their pre-M/G/1 exact bits. A **hard
//!   alarm** fires when the M/G/1 aggregate mean diverges beyond the
//!   configured bound, when a per-server row diverges (relative error
//!   above tol *and* absolute occupancy gap above
//!   [`SERVER_ABS_FLOOR`] — heterogeneous servers are hard checks now,
//!   not diagnostics), when any uncapped capacitated server is saturated
//!   (`F ≥ cap`), when arrivals were dropped at the in-flight ceiling,
//!   when simulated per-server blocking exceeds the Erlang prediction by
//!   more than tol (capped runs), or when there are no post-warm-up
//!   samples to compare.
//!
//!   Tolerance semantics: `mean_rel_error` keeps its historical
//!   definition (`rel_diff(T/λ, simulated)` over the optimizer's cost
//!   `T = Σ value(F)`) for artifact continuity, while the headline hard
//!   check rides `pk_mean_rel_error`, the M/G/1 aggregate. Per-server
//!   rows below [`RHO_FLOOR`] utilization or with an absolute gap under
//!   [`SERVER_ABS_FLOOR`] stay diagnostic — near-idle occupancy is
//!   sampling noise.
//!
//!   Finite-capacity runs (`SimConfig::queue_cap`): each capped server is
//!   an M/M/1/K loss queue, so its analytic occupancy row uses the
//!   truncated-geometric mean (finite even at ρ ≥ 1 — a full FIFO blocks
//!   instead of diverging) and gains an Erlang-style expected-blocking
//!   column `(1−ρ)ρ^K/(1−ρ^{K+1})` checked one-sidedly against the
//!   simulated per-server drop rate `blocked/offered`: service-time
//!   variance and arrival burstiness only push true blocking *above* the
//!   M/M/1/K baseline, so only an excess alarms. The aggregate mean is
//!   compared against Little's law at the *admitted* rate
//!   `λ·(1 − dropped/arrived)`, since blocked arrivals never contribute a
//!   sojourn sample.
//!
//! * **Re-optimization** ([`simulate_adaptive`] / [`ReoptConfig`]): instead
//!   of pre-converging every epoch offline (`AdaptiveRunner`), schedule
//!   SGP ticks on the calendar queue that re-run the paper's asynchronous
//!   single-node update against arrival rates estimated from accumulated
//!   telemetry — the strategy adapts *inside* the run, the asynchronous
//!   operation of Theorem 2 rather than an offline oracle.

use anyhow::{ensure, Result};

use crate::model::cost::CostFn;
use crate::model::flows::compute_flows;
use crate::model::network::Network;
use crate::model::strategy::Strategy;
use crate::util::json::Json;
use crate::util::stats::rel_diff;
use crate::util::table::{fnum, Table};

use super::tasks::{simulate_with, SimConfig, SimPlan};
use super::telemetry::{bits_hex, num_u64, Telemetry};
use super::workload::ArrivalSpec;

/// Servers with analytic utilization below this floor are excluded from
/// the headline `max_server_rel_error`: a near-idle server's occupancy is
/// dominated by sampling noise, so its relative error is meaningless. The
/// rows still appear in the report.
pub const RHO_FLOOR: f64 = 0.05;

/// Absolute occupancy gap (in requests) below which a per-server row stays
/// diagnostic even when its relative error exceeds the tolerance: a queue
/// holding fractions of a request has a relative error dominated by
/// sampling noise, and alarming on it would punish exactly the lightly
/// loaded scenarios that validate best.
pub const SERVER_ABS_FLOOR: f64 = 0.1;

/// One `(request rate, mean service time)` class feeding a server — the
/// ingredients of the Pollaczek–Khinchine second moment. Each task
/// contributes one exponential class per server it touches: its data hops,
/// its result hops (size `a_m`), and its compute requirement `w_im`.
struct SvcClass {
    rate: f64,
    mean: f64,
}

/// Analytic expected number in system for one server fed by `classes`.
///
/// * `Linear{unit}` — infinite-server delay: `unit·F`, unchanged.
/// * Capped FIFO (`fifo = Some(K)`) — M/M/1/K truncated-geometric mean at
///   offered load ρ = F/cap ([`mm1k_occupancy`]), finite for every ρ.
/// * Uncapped `Queue`/`SmoothCap` — the M/G/1 Pollaczek–Khinchine mean
///   over the hyperexponential mixture. When every class shares one
///   service mean the mixture degenerates to M/M/1 and the `Queue` closed
///   form `F/(cap−F)` is returned bit-for-bit, keeping homogeneous
///   validation artifacts byte-stable across the M/G/1 upgrade.
///
/// `SmoothCap` adds its deterministic propagation term `slope·F` to the
/// queue part (the simulator holds a request in system through that extra
/// delay); the optimizer's log-barrier surrogate never described the
/// simulated queue and is no longer used here.
fn analytic_occupancy(cost: &CostFn, flow: f64, classes: &[SvcClass], fifo: Option<u64>) -> f64 {
    let Some(cap) = cost.capacity() else {
        return cost.value(flow);
    };
    let extra = match *cost {
        CostFn::SmoothCap { slope, .. } => slope * flow,
        _ => 0.0,
    };
    if let Some(k) = fifo {
        return mm1k_occupancy((flow / cap).max(0.0), k) + extra;
    }
    if flow >= cap {
        return f64::INFINITY;
    }
    let homogeneous = classes
        .windows(2)
        .all(|w| w[0].mean.to_bits() == w[1].mean.to_bits());
    if homogeneous {
        if let CostFn::Queue { .. } = cost {
            return cost.value(flow);
        }
    }
    let lambda: f64 = classes.iter().map(|c| c.rate).sum();
    if lambda <= 0.0 {
        return extra;
    }
    let rho = flow / cap;
    // λ·E[S²] of the mixture: exponential classes have E[S_k²] = 2·s_k².
    let lam_es2: f64 = classes.iter().map(|c| c.rate * 2.0 * c.mean * c.mean).sum();
    rho + lambda * lam_es2 / (2.0 * (1.0 - rho)) + extra
}

/// Expected number in system of an M/M/1/K loss queue at offered load ρ:
/// the truncated-geometric mean `Σ_{n≤K} n·ρ^n / Σ_{n≤K} ρ^n`. Finite for
/// every ρ — a full FIFO blocks instead of diverging.
fn mm1k_occupancy(rho: f64, k: u64) -> f64 {
    let kf = k as f64;
    if rho <= 0.0 {
        return 0.0;
    }
    if (rho - 1.0).abs() < 1e-9 {
        return kf / 2.0;
    }
    let rk = rho.powf(kf);
    if !rk.is_finite() {
        // Deep overload: the distribution piles up at n = K, a geometric
        // tail of ratio 1/ρ hanging below it.
        return (kf - 1.0 / (rho - 1.0)).max(0.0);
    }
    let rk1 = rk * rho;
    let s0 = (1.0 - rk1) / (1.0 - rho);
    let s1 = rho * (1.0 - (kf + 1.0) * rk + kf * rk1) / ((1.0 - rho) * (1.0 - rho));
    s1 / s0
}

/// Erlang-style blocking probability of an M/M/1/K loss queue at offered
/// load ρ: `(1−ρ)ρ^K / (1−ρ^{K+1})`, `1/(K+1)` at ρ = 1. This is the
/// analytic prediction for per-server drop rates under `--queue-cap`; the
/// validator's check is one-sided because service-time variance (for
/// K > 1) and arrival burstiness only push true blocking above this
/// baseline.
fn erlang_blocking(rho: f64, k: u64) -> f64 {
    if !rho.is_finite() || rho < 0.0 {
        return 1.0;
    }
    if rho == 0.0 {
        return 0.0;
    }
    if (rho - 1.0).abs() < 1e-9 {
        return 1.0 / (k as f64 + 1.0);
    }
    let rk = rho.powf(k as f64);
    if !rk.is_finite() {
        // ρ > 1 with a deep FIFO: blocking tends to the fluid limit.
        return (rho - 1.0) / rho;
    }
    (1.0 - rho) * rk / (1.0 - rho * rk)
}

/// In-simulation re-optimization parameters.
#[derive(Clone, Copy, Debug)]
pub struct ReoptConfig {
    /// Simulated time between SGP ticks; each tick updates one node
    /// (round-robin) across every task and both planes.
    pub interval: f64,
    /// Minimum arrivals in the observation window before the rate
    /// estimate is refreshed from telemetry — below it, ticks keep
    /// pricing against the previous estimate.
    pub min_window: u64,
}

impl ReoptConfig {
    /// Tick every `interval` simulated time units with the default
    /// observation-window floor.
    pub fn every(interval: f64) -> Result<ReoptConfig> {
        ensure!(
            interval.is_finite() && interval > 0.0,
            "re-optimization interval must be finite and positive, got {interval}"
        );
        Ok(ReoptConfig {
            interval,
            min_window: 50,
        })
    }
}

/// Run the request-level simulation with in-loop asynchronous
/// re-optimization ([`ReoptConfig`]). Deterministic: the tick schedule
/// rides the same calendar queue as the workload, and the SGP update is
/// randomness-free, so repeated runs are bit-identical.
pub fn simulate_adaptive(
    plan: &SimPlan,
    arrivals: &ArrivalSpec,
    cfg: &SimConfig,
    reopt: &ReoptConfig,
) -> Result<Telemetry> {
    simulate_with(plan, arrivals, cfg, Some(reopt))
}

/// One server's analytic-vs-simulated occupancy comparison.
#[derive(Clone, Debug)]
pub struct ServerDivergence {
    /// `cpu:<node>` or `link:<edge>`.
    pub name: String,
    /// Analytic flow through the server (`G_i` or `F_ij`).
    pub flow: f64,
    /// Analytic utilization `flow / cap` (0 for uncapacitated servers).
    pub rho: f64,
    /// Analytic expected number in system: Pollaczek–Khinchine M/G/1 for
    /// uncapped queued servers (exactly `CostFn::value(flow)` when the
    /// service classes are homogeneous), the M/M/1/K truncated mean for
    /// capped servers, `unit·F` for `Linear`.
    pub analytic: f64,
    /// Simulated time-average number in system.
    pub simulated: f64,
    /// `rel_diff(analytic, simulated)`; +∞ when either is non-finite.
    pub rel_error: f64,
    /// Analytic flow at or beyond capacity on an *unbounded* FIFO — the
    /// queue is divergent. A capped server at ρ ≥ 1 is a stable loss
    /// queue (its excess is counted as blocking) and is not flagged.
    pub saturated: bool,
    /// Finite FIFO capacity applied to this server in the simulated run;
    /// `None` on uncapped runs or for a kind left unbounded.
    pub queue_cap: Option<u64>,
    /// Erlang-style analytic blocking probability at the offered load
    /// ([`erlang_blocking`]); populated exactly when `queue_cap` is.
    pub expected_blocking: Option<f64>,
    /// Simulated per-server blocking rate `blocked / offered`.
    pub simulated_blocking: Option<f64>,
}

/// Outcome of [`validate`]: the aggregate comparison, per-server rows, and
/// the alarm verdict with human-readable reasons.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub tol: f64,
    /// Total arrival rate λ = Σ_m Σ_i r_i^m.
    pub lambda: f64,
    /// Analytic total cost `T` from the converged flows.
    pub analytic_cost: f64,
    /// Little's law: `T / λ`.
    pub analytic_mean_sojourn: f64,
    pub simulated_mean_sojourn: f64,
    /// `rel_diff` of the two means; +∞ when incomparable (saturation,
    /// zero samples). Kept on the historical `T = Σ value(F)` definition
    /// for artifact continuity — the hard check is `pk_mean_rel_error`.
    pub mean_rel_error: f64,
    /// M/G/1 aggregate prediction: Σ per-server analytic occupancy,
    /// divided by the admitted arrival rate (Little's law; the admitted
    /// rate is λ scaled by the fraction of arrivals not dropped).
    pub pk_mean_sojourn: f64,
    /// `rel_diff` of the M/G/1 aggregate against the simulated mean — the
    /// headline hard check.
    pub pk_mean_rel_error: f64,
    /// Largest per-server `rel_error` among servers with ρ ≥ [`RHO_FLOOR`].
    pub max_server_rel_error: f64,
    /// Post-warm-up completions backing the simulated mean.
    pub samples: u64,
    pub overload_dropped: u64,
    /// Requests dropped at full per-queue FIFOs (0 on uncapped runs).
    pub queue_dropped: u64,
    /// Effective `(cpu, link)` FIFO caps of the validated run (`u64::MAX`
    /// = kind unbounded); `None` for an uncapped run.
    pub queue_caps: Option<(u64, u64)>,
    pub servers: Vec<ServerDivergence>,
    pub alarm: bool,
    pub alarm_reasons: Vec<String>,
}

/// `rel_diff` that stays meaningful under saturation: non-finite inputs
/// compare as +∞ (maximally divergent), never NaN.
fn guarded_rel(a: f64, b: f64) -> f64 {
    if a.is_finite() && b.is_finite() {
        rel_diff(a, b)
    } else {
        f64::INFINITY
    }
}

/// Compare the analytic steady-state prediction of `(net, phi)` against
/// the simulated telemetry of the same pair. See the module docs for the
/// tolerance semantics and alarm conditions.
pub fn validate(
    net: &Network,
    phi: &Strategy,
    t: &Telemetry,
    tol: f64,
) -> Result<ValidationReport> {
    ensure!(
        tol.is_finite() && tol > 0.0,
        "validation tolerance must be finite and positive, got {tol}"
    );
    ensure!(
        t.node_occupancy.len() == net.n() && t.link_occupancy.len() == net.e(),
        "telemetry dimensions ({} nodes, {} links) do not match the network ({}, {})",
        t.node_occupancy.len(),
        t.link_occupancy.len(),
        net.n(),
        net.e()
    );
    let flows = compute_flows(net, phi).map_err(anyhow::Error::new)?;
    let lambda: f64 = net.input_rate.iter().flat_map(|r| r.iter()).sum();
    ensure!(lambda > 0.0, "network offers no traffic (λ = 0)");

    // Per-server service classes from the converged flows: one exponential
    // class per task touching the server. CPU i serves task m at rate
    // g_m(i) with mean w_im/cap; link e serves data at rate f⁻ with unit
    // size and results at request rate f⁺/a_m with size a_m.
    let mut cpu_classes: Vec<Vec<SvcClass>> = (0..net.n()).map(|_| Vec::new()).collect();
    let mut link_classes: Vec<Vec<SvcClass>> = (0..net.e()).map(|_| Vec::new()).collect();
    for m in 0..net.s() {
        let a = net.a_of(m);
        for (i, classes) in cpu_classes.iter_mut().enumerate() {
            let g = flows.g[m][i];
            if g > 0.0 {
                if let Some(cap) = net.comp_cost[i].capacity() {
                    classes.push(SvcClass {
                        rate: g,
                        mean: net.w_of(i, m) / cap,
                    });
                }
            }
        }
        for (e, classes) in link_classes.iter_mut().enumerate() {
            let Some(cap) = net.link_cost[e].capacity() else {
                continue;
            };
            let fd = flows.f_minus[m][e];
            if fd > 0.0 {
                classes.push(SvcClass {
                    rate: fd,
                    mean: 1.0 / cap,
                });
            }
            let fr = flows.f_plus[m][e];
            if fr > 0.0 && a > 0.0 {
                classes.push(SvcClass {
                    rate: fr / a,
                    mean: a / cap,
                });
            }
        }
    }

    let queue_caps = t.queue_caps;
    let (cpu_fifo, link_fifo) = queue_caps.unwrap_or((u64::MAX, u64::MAX));
    let fifo_of = |kind_cap: u64, cost: &CostFn| {
        (kind_cap != u64::MAX && cost.capacity().is_some()).then_some(kind_cap)
    };
    let mut servers = Vec::with_capacity(net.n() + net.e());
    let mut push = |name: String,
                    cost: &CostFn,
                    flow: f64,
                    simulated: f64,
                    classes: &[SvcClass],
                    kind_cap: u64,
                    blocked: u64,
                    offered: u64| {
        let fifo = fifo_of(kind_cap, cost);
        let (rho, saturated) = match cost.capacity() {
            Some(cap) => (flow / cap, flow >= cap && fifo.is_none()),
            None => (0.0, false),
        };
        let analytic = analytic_occupancy(cost, flow, classes, fifo);
        let (expected_blocking, simulated_blocking) = match fifo {
            Some(k) => (
                Some(erlang_blocking(rho, k)),
                Some(if offered > 0 {
                    blocked as f64 / offered as f64
                } else {
                    0.0
                }),
            ),
            None => (None, None),
        };
        servers.push(ServerDivergence {
            name,
            flow,
            rho,
            analytic,
            simulated,
            rel_error: guarded_rel(analytic, simulated),
            saturated,
            queue_cap: fifo,
            expected_blocking,
            simulated_blocking,
        });
    };
    for i in 0..net.n() {
        push(
            format!("cpu:{i}"),
            &net.comp_cost[i],
            flows.workload[i],
            t.node_occupancy[i],
            &cpu_classes[i],
            cpu_fifo,
            t.node_blocked[i],
            t.node_offered[i],
        );
    }
    for e in 0..net.e() {
        push(
            format!("link:{e}"),
            &net.link_cost[e],
            flows.link_flow[e],
            t.link_occupancy[e],
            &link_classes[e],
            link_fifo,
            t.link_blocked[e],
            t.link_offered[e],
        );
    }

    let analytic_cost = flows.total_cost;
    let analytic_mean = analytic_cost / lambda;
    let simulated_mean = t.mean_sojourn();
    let samples = t.sojourn.count();
    let mean_rel_error = if samples == 0 {
        f64::INFINITY
    } else {
        guarded_rel(analytic_mean, simulated_mean)
    };
    // M/G/1 aggregate: Little's law over the per-server analytic
    // occupancies, at the *admitted* rate on capped runs — blocked
    // arrivals hold no queue slot and contribute no sojourn sample.
    let pk_cost: f64 = servers.iter().map(|s| s.analytic).sum();
    let admitted_frac = if queue_caps.is_some() && t.arrived > 0 {
        (t.arrived - t.overload_dropped - t.queue_dropped) as f64 / t.arrived as f64
    } else {
        1.0
    };
    let pk_mean = if admitted_frac > 0.0 {
        pk_cost / (lambda * admitted_frac)
    } else {
        f64::INFINITY
    };
    let pk_mean_rel_error = if samples == 0 {
        f64::INFINITY
    } else {
        guarded_rel(pk_mean, simulated_mean)
    };
    let max_server_rel_error = servers
        .iter()
        .filter(|s| s.rho >= RHO_FLOOR)
        .map(|s| s.rel_error)
        .fold(0.0, f64::max);

    let mut reasons = Vec::new();
    for s in servers.iter().filter(|s| s.saturated) {
        reasons.push(format!(
            "{}: analytic flow {} ≥ capacity — queue divergent",
            s.name,
            fnum(s.flow)
        ));
    }
    // Per-server M/G/1 hard check (graduated from the old diagnostic-only
    // rows): meaningful utilization, meaningful absolute gap, relative
    // error beyond tolerance. Saturated servers already alarmed above.
    for s in servers.iter().filter(|s| !s.saturated) {
        if s.rho >= RHO_FLOOR
            && s.rel_error > tol
            && (s.analytic - s.simulated).abs() > SERVER_ABS_FLOOR
        {
            reasons.push(format!(
                "{}: simulated occupancy {} diverges from the M/G/1 analytic {} \
                 (rel err {} > tol {})",
                s.name,
                fnum(s.simulated),
                fnum(s.analytic),
                fnum(s.rel_error),
                fnum(tol)
            ));
        }
    }
    // One-sided Erlang blocking check: simulated drop rates above the
    // analytic prediction mean the loss queue is worse than its model.
    for s in &servers {
        if let (Some(eb), Some(sb)) = (s.expected_blocking, s.simulated_blocking) {
            if sb > eb + tol {
                reasons.push(format!(
                    "{}: simulated blocking {} exceeds the Erlang prediction {} \
                     by more than tol {}",
                    s.name,
                    fnum(sb),
                    fnum(eb),
                    fnum(tol)
                ));
            }
        }
    }
    if t.overload_dropped > 0 {
        reasons.push(format!(
            "{} arrival(s) dropped at the in-flight ceiling — strategy overloaded",
            t.overload_dropped
        ));
    }
    if samples == 0 {
        reasons.push("no post-warm-up completions to compare".to_string());
    } else if pk_mean_rel_error > tol {
        reasons.push(format!(
            "mean sojourn diverges: analytic (M/G/1) {} vs simulated {} (rel err {} > tol {})",
            fnum(pk_mean),
            fnum(simulated_mean),
            fnum(pk_mean_rel_error),
            fnum(tol)
        ));
    }
    let alarm = !reasons.is_empty();
    Ok(ValidationReport {
        tol,
        lambda,
        analytic_cost,
        analytic_mean_sojourn: analytic_mean,
        simulated_mean_sojourn: simulated_mean,
        mean_rel_error,
        pk_mean_sojourn: pk_mean,
        pk_mean_rel_error,
        max_server_rel_error,
        samples,
        overload_dropped: t.overload_dropped,
        queue_dropped: t.queue_dropped,
        queue_caps,
        servers,
        alarm,
        alarm_reasons: reasons,
    })
}

impl ValidationReport {
    /// Human-readable divergence report: aggregate line, per-server table
    /// (blocking columns appear on capped runs), alarm verdict.
    pub fn render(&self) -> String {
        let capped = self.queue_caps.is_some();
        let mut out = String::new();
        out.push_str(&format!(
            "closed-loop validation (tol {}):\n  λ = {}  analytic cost T = {}\n  \
             mean sojourn: analytic T/λ = {} vs simulated {}  (rel err {}, {} sample(s))\n  \
             M/G/1 mean sojourn: analytic {} vs simulated {}  (rel err {})\n",
            fnum(self.tol),
            fnum(self.lambda),
            fnum(self.analytic_cost),
            fnum(self.analytic_mean_sojourn),
            fnum(self.simulated_mean_sojourn),
            fnum(self.mean_rel_error),
            self.samples,
            fnum(self.pk_mean_sojourn),
            fnum(self.simulated_mean_sojourn),
            fnum(self.pk_mean_rel_error),
        ));
        if capped {
            out.push_str(&format!(
                "  per-queue admission: {} request(s) dropped at full FIFOs\n",
                self.queue_dropped
            ));
        }
        let mut headers = vec!["server", "flow", "rho", "analytic L", "simulated L", "rel err"];
        if capped {
            headers.extend(["cap", "erlang B", "sim B"]);
        }
        headers.push("status");
        let mut tbl = Table::new(&headers);
        for s in &self.servers {
            let status = if s.saturated {
                "SATURATED".to_string()
            } else if s.rho >= RHO_FLOOR && s.rel_error > self.tol {
                "divergent".to_string()
            } else {
                "ok".to_string()
            };
            let mut row = vec![
                s.name.clone(),
                fnum(s.flow),
                fnum(s.rho),
                fnum(s.analytic),
                fnum(s.simulated),
                fnum(s.rel_error),
            ];
            if capped {
                row.push(s.queue_cap.map_or("-".to_string(), |k| k.to_string()));
                row.push(s.expected_blocking.map_or("-".to_string(), fnum));
                row.push(s.simulated_blocking.map_or("-".to_string(), fnum));
            }
            row.push(status);
            tbl.row(row);
        }
        out.push_str(&tbl.render());
        if self.alarm {
            out.push_str("ALARM:\n");
            for r in &self.alarm_reasons {
                out.push_str(&format!("  - {r}\n"));
            }
        } else {
            out.push_str(
                "alarm quiet: simulated sojourn matches the analytic model within tolerance\n",
            );
        }
        out
    }

    /// JSON report; headline numbers carry `_bits` hex for exact-bits
    /// determinism checks.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("tol", Json::Num(self.tol))
            .set("lambda", Json::Num(self.lambda))
            .set("analytic_cost", Json::Num(self.analytic_cost))
            .set("analytic_mean_sojourn", Json::Num(self.analytic_mean_sojourn))
            .set(
                "analytic_mean_sojourn_bits",
                Json::Str(bits_hex(self.analytic_mean_sojourn)),
            )
            .set(
                "simulated_mean_sojourn",
                Json::Num(self.simulated_mean_sojourn),
            )
            .set(
                "simulated_mean_sojourn_bits",
                Json::Str(bits_hex(self.simulated_mean_sojourn)),
            )
            .set("mean_rel_error", Json::Num(self.mean_rel_error))
            .set("mean_rel_error_bits", Json::Str(bits_hex(self.mean_rel_error)))
            .set("pk_mean_sojourn", Json::Num(self.pk_mean_sojourn))
            .set(
                "pk_mean_sojourn_bits",
                Json::Str(bits_hex(self.pk_mean_sojourn)),
            )
            .set("pk_mean_rel_error", Json::Num(self.pk_mean_rel_error))
            .set(
                "pk_mean_rel_error_bits",
                Json::Str(bits_hex(self.pk_mean_rel_error)),
            )
            .set("max_server_rel_error", Json::Num(self.max_server_rel_error))
            .set(
                "max_server_rel_error_bits",
                Json::Str(bits_hex(self.max_server_rel_error)),
            )
            .set("samples", num_u64(self.samples))
            .set("overload_dropped", num_u64(self.overload_dropped))
            .set("alarm", Json::Bool(self.alarm))
            .set(
                "alarm_reasons",
                Json::Arr(
                    self.alarm_reasons
                        .iter()
                        .map(|r| Json::Str(r.clone()))
                        .collect(),
                ),
            )
            .set(
                "servers",
                Json::Arr(
                    self.servers
                        .iter()
                        .map(|s| {
                            let mut so = Json::obj();
                            so.set("name", Json::Str(s.name.clone()))
                                .set("flow", Json::Num(s.flow))
                                .set("rho", Json::Num(s.rho))
                                .set("analytic_occupancy", Json::Num(s.analytic))
                                .set("simulated_occupancy", Json::Num(s.simulated))
                                .set("rel_error", Json::Num(s.rel_error))
                                .set("saturated", Json::Bool(s.saturated));
                            // Blocking columns exist exactly when this
                            // server ran under a finite FIFO cap, keeping
                            // uncapped reports byte-stable.
                            if let (Some(k), Some(eb), Some(sb)) = (
                                s.queue_cap,
                                s.expected_blocking,
                                s.simulated_blocking,
                            ) {
                                so.set("queue_cap", num_u64(k))
                                    .set("expected_blocking", Json::Num(eb))
                                    .set(
                                        "expected_blocking_bits",
                                        Json::Str(bits_hex(eb)),
                                    )
                                    .set("simulated_blocking", Json::Num(sb))
                                    .set(
                                        "simulated_blocking_bits",
                                        Json::Str(bits_hex(sb)),
                                    );
                            }
                            so
                        })
                        .collect(),
                ),
            );
        if let Some((cpu_cap, link_cap)) = self.queue_caps {
            let cap_json = |c: u64| {
                if c == u64::MAX {
                    Json::Str("unbounded".to_string())
                } else {
                    num_u64(c)
                }
            };
            let mut caps = Json::obj();
            caps.set("cpu", cap_json(cpu_cap)).set("link", cap_json(link_cap));
            o.set("queue_cap", caps)
                .set("queue_dropped", num_u64(self.queue_dropped));
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_undirected;
    use crate::model::network::testnet::diamond;
    use crate::model::network::Task;
    use crate::sim::tasks::{simulate, SimEpoch};

    fn poisson() -> ArrivalSpec {
        ArrivalSpec::parse("poisson").unwrap()
    }

    /// Single node pair where both tasks compute locally at node 0 with
    /// wildly different service sizes (0.05 vs 0.8): an M/M/1 fit is off by
    /// ~3x while the M/G/1 form is exact.
    fn hetero_net() -> Network {
        Network {
            graph: from_undirected(2, &[(0, 1)]),
            tasks: vec![Task { dest: 0, ctype: 0 }, Task { dest: 0, ctype: 1 }],
            num_types: 2,
            input_rate: vec![vec![4.0, 0.0], vec![0.5, 0.0]],
            result_ratio: vec![0.05, 0.05],
            comp_weight: vec![vec![0.05, 0.8]; 2],
            link_cost: vec![CostFn::Queue { cap: 50.0 }; 2],
            comp_cost: vec![CostFn::Queue { cap: 1.0 }; 2],
        }
    }

    #[test]
    fn mm1k_and_erlang_formulas_match_hand_computations() {
        // M/M/1/2 at ρ = 0.5: L = 4/7, B = 1/7.
        assert!((mm1k_occupancy(0.5, 2) - 4.0 / 7.0).abs() < 1e-12);
        assert!((erlang_blocking(0.5, 2) - 1.0 / 7.0).abs() < 1e-12);
        // ρ = 1 limits: L = K/2, B = 1/(K+1).
        assert!((mm1k_occupancy(1.0, 2) - 1.0).abs() < 1e-9);
        assert!((erlang_blocking(1.0, 2) - 1.0 / 3.0).abs() < 1e-9);
        // Overloaded loss queue stays finite: ρ = 1.5, K = 2 → L = 6/4.75.
        assert!((mm1k_occupancy(1.5, 2) - 6.0 / 4.75).abs() < 1e-12);
        assert!((erlang_blocking(1.5, 2) - 1.125 / 2.375).abs() < 1e-12);
        // Degenerate inputs are tame.
        assert_eq!(mm1k_occupancy(0.0, 4), 0.0);
        assert_eq!(erlang_blocking(0.0, 4), 0.0);
        assert!(erlang_blocking(f64::NAN, 4) == 1.0);
        // Huge ρ^K overflow guards: blocking → (ρ−1)/ρ, occupancy → K − 1/(ρ−1).
        assert!((erlang_blocking(2.0, 4096) - 0.5).abs() < 1e-12);
        assert!(mm1k_occupancy(2.0, 4096).is_finite());
    }

    #[test]
    fn heterogeneous_service_graduates_to_a_hard_check() {
        let net = hetero_net();
        let phi = Strategy::local_compute_init(&net);
        let plan = SimPlan {
            epochs: vec![SimEpoch {
                net: net.clone(),
                phi: phi.clone(),
            }],
        };
        let cfg = SimConfig {
            requests: 80_000,
            warmup: 0.1,
            seed: 11,
            ..SimConfig::default()
        };
        let t = simulate(&plan, &poisson(), &cfg).unwrap();
        let report = validate(&net, &phi, &t, 0.25).unwrap();
        let cpu0 = &report.servers[0];
        assert_eq!(cpu0.name, "cpu:0");
        // ρ = 4·0.05 + 0.5·0.8 = 0.6; P-K with λ·E[S²] = 0.66 gives
        // L = 0.6 + 4.5·0.66/0.8 = 4.3125, vs the M/M/1 fit of 1.5.
        assert!(
            (cpu0.analytic - 4.3125).abs() < 1e-6,
            "P-K occupancy {} != 4.3125",
            cpu0.analytic
        );
        assert!(
            cpu0.rel_error <= 0.25,
            "M/G/1 row diverged: {}",
            cpu0.rel_error
        );
        // The M/M/1 closed form the validator used to trust is ~3x off the
        // simulated occupancy — the scenario the hard check must catch.
        let mm1 = net.comp_cost[0].value(cpu0.flow);
        assert!(
            rel_diff(mm1, cpu0.simulated) > 0.25,
            "M/M/1 fit {mm1} unexpectedly matches simulated {}",
            cpu0.simulated
        );
        // Value-based aggregate (historical column) fails; the M/G/1
        // headline passes, so the report stays quiet.
        assert!(report.mean_rel_error > 0.25, "{}", report.mean_rel_error);
        assert!(
            report.pk_mean_rel_error <= 0.25,
            "{}",
            report.pk_mean_rel_error
        );
        assert!(
            !report.alarm,
            "expected quiet alarm, got: {:?}",
            report.alarm_reasons
        );
        // Uncapped run: no blocking columns, no capped report keys.
        assert!(report.queue_caps.is_none());
        assert!(cpu0.queue_cap.is_none() && cpu0.expected_blocking.is_none());
        let dump = report.to_json().dump();
        assert!(!dump.contains("\"queue_cap\"") && !dump.contains("queue_dropped"));
        assert!(dump.contains("pk_mean_rel_error_bits"));
    }

    #[test]
    fn reopt_config_rejects_degenerate_intervals() {
        assert!(ReoptConfig::every(0.0).is_err());
        assert!(ReoptConfig::every(-1.0).is_err());
        assert!(ReoptConfig::every(f64::INFINITY).is_err());
        assert!(ReoptConfig::every(f64::NAN).is_err());
        assert!(ReoptConfig::every(2.5).is_ok());
    }

    #[test]
    fn validate_rejects_degenerate_tolerances() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let t = Telemetry::new(net.n(), net.e());
        assert!(validate(&net, &phi, &t, 0.0).is_err());
        assert!(validate(&net, &phi, &t, f64::NAN).is_err());
        let wrong = Telemetry::new(1, 1);
        assert!(validate(&net, &phi, &wrong, 0.1).is_err());
    }

    #[test]
    fn empty_telemetry_raises_the_alarm() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let t = Telemetry::new(net.n(), net.e());
        let report = validate(&net, &phi, &t, 0.1).unwrap();
        assert!(report.alarm);
        assert_eq!(report.samples, 0);
        assert!(report.mean_rel_error.is_infinite());
        assert!(report
            .alarm_reasons
            .iter()
            .any(|r| r.contains("no post-warm-up completions")));
    }

    #[test]
    fn lightly_loaded_diamond_validates_quietly() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let plan = SimPlan {
            epochs: vec![SimEpoch {
                net: net.clone(),
                phi: phi.clone(),
            }],
        };
        let cfg = SimConfig {
            requests: 20_000,
            warmup: 0.1,
            seed: 17,
            ..SimConfig::default()
        };
        let t = simulate(&plan, &poisson(), &cfg).unwrap();
        let report = validate(&net, &phi, &t, 0.25).unwrap();
        assert!(
            !report.alarm,
            "expected quiet alarm, got: {:?}",
            report.alarm_reasons
        );
        assert_eq!(report.servers.len(), net.n() + net.e());
        assert!(report.lambda > 0.0 && report.analytic_cost.is_finite());
        assert!(report.mean_rel_error <= 0.25, "{}", report.mean_rel_error);
        // The rendered report and JSON must both carry the verdict.
        assert!(report.render().contains("alarm quiet"));
        assert_eq!(report.to_json().get("alarm").as_bool(), Some(false));
    }

    #[test]
    fn adaptive_simulation_ticks_and_stays_deterministic() {
        let run = || {
            let net = diamond(true);
            let phi = Strategy::local_compute_init(&net);
            let plan = SimPlan {
                epochs: vec![SimEpoch { net, phi }],
            };
            let cfg = SimConfig {
                requests: 3_000,
                warmup: 0.1,
                seed: 23,
                ..SimConfig::default()
            };
            let reopt = ReoptConfig::every(25.0).unwrap();
            simulate_adaptive(&plan, &poisson(), &cfg, &reopt).unwrap()
        };
        let a = run();
        assert!(a.reopt_events > 0, "no re-optimization tick fired");
        assert_eq!(a.completed + a.stranded + a.overload_dropped, a.arrived);
        let b = run();
        assert_eq!(a.to_json().dump(), b.to_json().dump());
    }
}
