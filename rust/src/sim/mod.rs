//! Discrete-event simulation, layered (PR 6):
//!
//! * [`core`] — the indexed calendar queue: O(1)-amortized event
//!   scheduling with the deterministic `(time, seq)` FIFO tie-break
//!   ([`event`] keeps the legacy binary-heap queue as the parity oracle);
//! * [`workload`] — request arrival processes (Poisson, MMPP, diurnal)
//!   over the per-epoch rates of a `PatternSchedule`;
//! * [`tasks`] — arena-allocated request state machines walking
//!   data-flow hops, computation service and result-flow hops through
//!   per-link/per-CPU FIFO queues, per a converged [`Strategy`];
//! * [`telemetry`] — streaming tail-latency sketches and utilization
//!   counters (bounded memory, bit-reproducible);
//! * [`closedloop`] — analytic-vs-simulated validation (per-server
//!   divergence report + hard alarm) and in-simulation asynchronous
//!   re-optimization (SGP ticks on the calendar queue).
//!
//! Plus the original protocol layer: the paper's two-stage marginal
//! broadcast (§IV) in [`protocol`], asynchronous update schedules
//! (Theorem 2) in [`async_run`], mid-run failure injection (Fig. 5b), and
//! a thread-per-node actor deployment ([`actors`]) demonstrating true
//! asynchrony.
//!
//! [`Strategy`]: crate::model::strategy::Strategy

pub mod actors;
pub mod async_run;
pub mod closedloop;
pub mod core;
pub mod event;
pub mod protocol;
pub mod tasks;
pub mod telemetry;
pub mod workload;

pub use async_run::{
    run_async, run_async_dynamic, run_async_round_robin, run_with_failure, DynamicAsyncTrace,
    FailureRun,
};
pub use closedloop::{
    simulate_adaptive, validate, ReoptConfig, ServerDivergence, ValidationReport,
};
pub use protocol::{run_broadcast, ProtocolResult};
pub use tasks::{simulate, SimConfig, SimEpoch, SimPlan};
pub use telemetry::Telemetry;
pub use workload::{ArrivalSpec, ArrivalStream, EpochRates};
