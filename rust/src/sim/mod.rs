//! Distributed-protocol simulation: the paper's two-stage marginal
//! broadcast (§IV) on a discrete-event engine, asynchronous update
//! schedules (Theorem 2), mid-run failure injection (Fig. 5b), and a
//! thread-per-node actor deployment demonstrating true asynchrony.

pub mod actors;
pub mod async_run;
pub mod event;
pub mod protocol;

pub use async_run::{
    run_async, run_async_dynamic, run_async_round_robin, run_with_failure, DynamicAsyncTrace,
    FailureRun,
};
pub use protocol::{run_broadcast, ProtocolResult};
