//! Asynchronous optimization schedules (Theorem 2) and the Fig. 5b
//! failure-adaptation experiment driver.
//!
//! Theorem 2 guarantees convergence when each `(node, task, plane)` block
//! is updated infinitely often, one at a time, in any order. This module
//! drives [`crate::algo::Sgp::update_single_node`] under randomized
//! schedules, and simulates the mid-run server failure of Fig. 5b: at a
//! given iteration the failed node's links and computation are disabled,
//! strategies are warm-start adapted ([`Strategy::adapt_to`]), and the
//! optimizer continues — the paper's point being that SGP re-converges in
//! few iterations.

use anyhow::Result;

use crate::algo::sgp::Sgp;
use crate::model::flows::compute_flows;
use crate::model::network::Network;
use crate::model::strategy::Strategy;
use crate::util::rng::Pcg;

/// Trajectory of total cost under an asynchronous schedule.
#[derive(Clone, Debug)]
pub struct AsyncTrace {
    /// Cost after every single-block update.
    pub costs: Vec<f64>,
    /// Final strategy.
    pub phi: Strategy,
}

/// Run `updates` single-block asynchronous updates under a uniformly
/// random (node, task, plane) schedule.
pub fn run_async(
    net: &Network,
    phi0: &Strategy,
    updates: usize,
    seed: u64,
) -> Result<AsyncTrace> {
    let mut phi = phi0.clone();
    let mut sgp = Sgp::new();
    let mut rng = Pcg::new(seed);
    let mut costs = Vec::with_capacity(updates);
    for _ in 0..updates {
        let node = rng.below(net.n());
        let task = rng.below(net.s());
        let plane_result = rng.chance(0.5);
        let t = sgp.update_single_node(net, &mut phi, node, task, plane_result)?;
        costs.push(t);
    }
    Ok(AsyncTrace { costs, phi })
}

/// Round-robin asynchronous schedule (deterministic coverage of all
/// blocks): sweeps nodes × tasks × planes.
pub fn run_async_round_robin(
    net: &Network,
    phi0: &Strategy,
    sweeps: usize,
) -> Result<AsyncTrace> {
    let mut phi = phi0.clone();
    let mut sgp = Sgp::new();
    let mut costs = Vec::new();
    for _ in 0..sweeps {
        for task in 0..net.s() {
            for node in 0..net.n() {
                for plane_result in [false, true] {
                    let t =
                        sgp.update_single_node(net, &mut phi, node, task, plane_result)?;
                    costs.push(t);
                }
            }
        }
    }
    Ok(AsyncTrace { costs, phi })
}

/// The Fig. 5b experiment: run an optimizer synchronously for
/// `fail_at` iterations, fail `dead_node` (retargeting its tasks to
/// `fallback_dest`), warm-start adapt, and continue for `total - fail_at`
/// iterations. Returns the cost trajectory (one entry per iteration) and
/// the post-failure re-convergence iteration count.
pub struct FailureRun {
    pub costs: Vec<f64>,
    /// Iterations after the failure until the cost is within `tol_frac` of
    /// its post-failure steady state.
    pub reconverge_iters: usize,
    /// Absolute iteration (1-based index into `costs`) at which the run
    /// had recovered: `fail_at + reconverge_iters`. Previously implicit —
    /// the adaptivity suite asserts recovery time directly against this.
    pub recovery_epoch: usize,
    /// Cost immediately after adaptation (before re-optimizing).
    pub cost_after_failure: f64,
    /// Final steady-state cost on the degraded network.
    pub final_cost: f64,
}

pub fn run_with_failure<O: crate::algo::Optimizer>(
    net: &Network,
    mut opt_factory: impl FnMut() -> O,
    phi0: &Strategy,
    fail_at: usize,
    total: usize,
    dead_node: usize,
    fallback_dest: usize,
    tol_frac: f64,
) -> Result<FailureRun> {
    assert!(fail_at < total);
    let mut costs = Vec::with_capacity(total);

    // Phase A: healthy network.
    let mut phi = phi0.clone();
    let mut opt = opt_factory();
    for _ in 0..fail_at {
        let st = opt.step(net, &mut phi)?;
        costs.push(st.total_cost);
    }

    // Failure: rebuild network, adapt strategy, fresh optimizer state.
    let failed = net.with_failed_node(dead_node, fallback_dest);
    let mut phi = phi.adapt_to(net, &failed);
    debug_assert!(phi.is_loop_free(&failed));
    let mut cost_after_failure = compute_flows(&failed, &phi)?.total_cost;
    if !cost_after_failure.is_finite() {
        // The warm-started point can saturate a queue after a capacity
        // loss; fall back to the always-safe all-local strategy on the
        // degraded network (if even that is infinite, the failure is not
        // survivable for this instance and we report the error).
        let cold = Strategy::local_compute_init(&failed);
        let cold_cost = compute_flows(&failed, &cold)?.total_cost;
        anyhow::ensure!(
            cold_cost.is_finite(),
            "network cannot absorb the failure of node {dead_node}"
        );
        phi = cold;
        cost_after_failure = cold_cost;
    }
    let mut opt = opt_factory();
    for _ in fail_at..total {
        let st = opt.step(&failed, &mut phi)?;
        costs.push(st.total_cost);
    }
    let final_cost = *costs.last().unwrap();

    // Re-convergence: first post-failure iteration within tol of final.
    let thresh = final_cost * (1.0 + tol_frac);
    let reconverge_iters = costs[fail_at..]
        .iter()
        .position(|&c| c <= thresh)
        .map(|p| p + 1)
        .unwrap_or(total - fail_at);

    Ok(FailureRun {
        costs,
        reconverge_iters,
        recovery_epoch: fail_at + reconverge_iters,
        cost_after_failure,
        final_cost,
    })
}

/// Cost trajectories of an asynchronous run spanning task-pattern epochs.
#[derive(Clone, Debug)]
pub struct DynamicAsyncTrace {
    /// One trajectory per epoch network (one entry per single-block
    /// update).
    pub epoch_costs: Vec<Vec<f64>>,
    /// Final strategy on the last epoch's network.
    pub phi: Strategy,
}

/// Asynchronous single-block updates across epoch boundaries: run
/// `updates_per_epoch` random (node, task, plane) updates on each network
/// of `nets` in turn, carrying the strategy over every boundary via
/// [`Strategy::retarget`] — the asynchronous form of the paper's
/// "adaptive to changes in task pattern" claim (Theorem 2 schedules keep
/// converging; the shift just moves the fixed point). The epoch networks
/// must share one graph (the dynamic engine's schedules only mutate task
/// patterns); a carried point that saturates a queue on the new pattern
/// falls back to the all-local strategy, mirroring [`run_with_failure`].
pub fn run_async_dynamic(
    nets: &[Network],
    phi0: &Strategy,
    updates_per_epoch: usize,
    seed: u64,
) -> Result<DynamicAsyncTrace> {
    anyhow::ensure!(!nets.is_empty(), "need at least one epoch network");
    let mut phi = phi0.clone();
    let mut sgp = Sgp::new();
    let mut epoch_costs = Vec::with_capacity(nets.len());
    for (e, net) in nets.iter().enumerate() {
        if e > 0 {
            phi = phi.retarget(&nets[e - 1], net);
            let carried = compute_flows(net, &phi)?.total_cost;
            if !carried.is_finite() {
                phi = Strategy::local_compute_init(net);
            }
        }
        let mut rng = Pcg::with_stream(seed, 0xa57c + e as u64);
        let mut costs = Vec::with_capacity(updates_per_epoch);
        for _ in 0..updates_per_epoch {
            let node = rng.below(net.n());
            let task = rng.below(net.s());
            let plane_result = rng.chance(0.5);
            let t = sgp.update_single_node(net, &mut phi, node, task, plane_result)?;
            costs.push(t);
        }
        epoch_costs.push(costs);
    }
    Ok(DynamicAsyncTrace { epoch_costs, phi })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Gp, Optimizer, Sgp};
    use crate::model::network::testnet::diamond;

    #[test]
    fn async_random_descends() {
        let net = diamond(true);
        let phi0 = Strategy::local_compute_init(&net);
        let trace = run_async(&net, &phi0, 200, 7).unwrap();
        for w in trace.costs.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "async cost increased");
        }
        assert!(trace.phi.is_loop_free(&net));
        assert!(trace.phi.is_feasible(&net));
    }

    #[test]
    fn async_matches_sync_fixed_point() {
        let net = diamond(true);
        let phi0 = Strategy::local_compute_init(&net);
        let trace = run_async_round_robin(&net, &phi0, 40).unwrap();
        let t_async = *trace.costs.last().unwrap();

        let mut phi = phi0.clone();
        let mut sgp = Sgp::new();
        let mut t_sync = f64::INFINITY;
        for _ in 0..120 {
            t_sync = sgp.step(&net, &mut phi).unwrap().total_cost;
        }
        assert!(
            (t_async - t_sync).abs() < 5e-3 * t_sync.max(1e-9),
            "async {t_async} vs sync {t_sync}"
        );
    }

    #[test]
    fn failure_run_recovers() {
        let net = diamond(true);
        let phi0 = Strategy::local_compute_init(&net);
        // fail node 1 (a relay), fall back dest to 3 (unchanged here since
        // dest is 3 already)
        let run = run_with_failure(
            &net,
            Sgp::new,
            &phi0,
            20,
            60,
            1,
            3,
            0.01,
        )
        .unwrap();
        assert_eq!(run.costs.len(), 60);
        assert!(run.final_cost.is_finite());
        // the recovery epoch is the absolute iteration of re-convergence
        assert_eq!(run.recovery_epoch, 20 + run.reconverge_iters);
        assert!(run.recovery_epoch <= 60);
        // degraded network must still be solvable and not cheaper than the
        // healthy optimum
        let healthy_opt = run.costs[19];
        assert!(run.final_cost >= healthy_opt - 1e-9);
        // post-failure descent is monotone
        for w in run.costs[20..].windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
    }

    #[test]
    fn sgp_reconverges_no_slower_than_gp() {
        let net = diamond(true);
        let phi0 = Strategy::local_compute_init(&net);
        let sgp_run =
            run_with_failure(&net, Sgp::new, &phi0, 15, 100, 1, 3, 0.01).unwrap();
        let gp_run =
            run_with_failure(&net, || Gp::new(1.0), &phi0, 15, 100, 1, 3, 0.01).unwrap();
        assert!(
            sgp_run.reconverge_iters <= gp_run.reconverge_iters + 1,
            "SGP {} vs GP {}",
            sgp_run.reconverge_iters,
            gp_run.reconverge_iters
        );
    }

    #[test]
    fn async_dynamic_descends_within_every_epoch() {
        // Two epochs on the same graph: base diamond, then a 1.5× rate
        // step (a hand-rolled Step schedule — sim must not depend on the
        // coordinator layer).
        let base = diamond(true);
        let mut shifted = base.clone();
        shifted.scale_rates(1.5);
        let phi0 = Strategy::local_compute_init(&base);
        let trace = run_async_dynamic(&[base.clone(), shifted.clone()], &phi0, 150, 11).unwrap();
        assert_eq!(trace.epoch_costs.len(), 2);
        for (e, costs) in trace.epoch_costs.iter().enumerate() {
            assert_eq!(costs.len(), 150);
            for w in costs.windows(2) {
                assert!(w[1] <= w[0] + 1e-9, "epoch {e}: async cost increased");
            }
        }
        // the carried point starts the shifted epoch below its all-local cost
        let cold = compute_flows(&shifted, &Strategy::local_compute_init(&shifted))
            .unwrap()
            .total_cost;
        assert!(
            trace.epoch_costs[1][0] <= cold + 1e-9,
            "warm-carried start {} worse than all-local {}",
            trace.epoch_costs[1][0],
            cold
        );
        assert!(trace.phi.is_loop_free(&shifted));
        assert!(trace.phi.is_feasible(&shifted));
    }

    #[test]
    fn generic_over_optimizer_trait() {
        // run_with_failure accepts any Optimizer factory
        let net = diamond(true);
        let phi0 = Strategy::local_compute_init(&net);
        let run = run_with_failure(&net, || Gp::new(0.5), &phi0, 5, 15, 2, 3, 0.05).unwrap();
        assert_eq!(run.costs.len(), 15);
        let _: &dyn Optimizer = &Gp::new(0.5);
    }
}
