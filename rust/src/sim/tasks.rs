//! Request-level task engine: individual requests walking a converged
//! strategy through FIFO queues.
//!
//! The optimizer prices *flows*; this engine releases *requests* and
//! measures what the paper's convex link/CPU costs only promise on
//! average: sojourn time, including its tail. Each request walks the
//! three-leg journey of §II — data-flow hops from its source toward a
//! computation site (strategy slot 0 = compute here, slot k+1 = forward on
//! the k-th out-edge), exponential computation service, then result-flow
//! hops (size `a_m ×` the data size) to the task's destination — with
//! every routing choice drawn from the converged `Strategy`'s probability
//! rows, so the simulated demand splits exactly like the optimized flows.
//!
//! Service model per [`CostFn`]: `Queue{cap}` is a single-server FIFO with
//! exponential service of mean `size/cap` — an M/M/1 queue whose expected
//! occupancy is the paper's cost term `F/(cap−F)`, so measured mean delay
//! and analytic cost agree when the strategy keeps every flow under
//! capacity. `Linear{unit}` is a pure propagation delay (infinite server),
//! and `SmoothCap{slope,cap,..}` is the rate-capped server plus its
//! deterministic `slope·size` propagation term.
//!
//! Admission control happens at two granularities. A **global** in-flight
//! ceiling (`SimConfig::max_in_flight`) refuses arrivals before they touch
//! any queue (`Telemetry::overload_dropped`). A **per-queue** finite
//! capacity (`SimConfig::queue_cap` plus per-kind overrides) turns each
//! queued server into an M/M/1/K loss queue: a request whose next FIFO is
//! full is dropped where it stands, counted once against that server's
//! `blocked` counter and once in the global `Telemetry::queue_dropped` —
//! never against the overload counter, which was settled earlier in
//! `admit`. Uncapped runs take the exact pre-capacity code path and stay
//! bit-identical.
//!
//! Engineering constraints (acceptance criteria of the PR 6 issue):
//!
//! * request state lives in a generation-indexed slab arena — after
//!   warm-up the engine performs **no per-request heap allocation**
//!   (slab and free list grow to peak concurrency, then recycle);
//! * the event set rides the O(1)-amortized calendar queue
//!   ([`super::core`]);
//! * telemetry streams into bounded-memory sketches
//!   ([`super::telemetry`]) — total memory is independent of the number
//!   of requests simulated.
//!
//! Time-varying runs pin each request to the epoch it arrived in: routing,
//! sizes and destinations come from that epoch's `(Network, Strategy)`
//! snapshot while the physical FIFO servers are shared across epochs
//! (capacities are epoch-invariant under every `PatternSchedule` kind —
//! the schedules mutate rates and endpoints, not hardware).
//!
//! Two closed-loop extensions ride the same event set
//! ([`super::closedloop`]): every server integrates its number-in-system
//! over time so the validator can compare time-average occupancy against
//! the analytic cost value, and an optional [`ReoptConfig`] schedules
//! `Ev::Reopt` ticks that re-run the paper's asynchronous single-node SGP
//! update against arrival rates estimated from accumulated telemetry —
//! strategies then adapt *inside* the run instead of only at offline
//! epoch boundaries.

use anyhow::{bail, Result};

use crate::algo::{OptWorkspace, Sgp};
use crate::model::cost::CostFn;
use crate::model::network::Network;
use crate::model::strategy::Strategy;
use crate::util::rng::Pcg;

use super::closedloop::ReoptConfig;
use super::core::EventQueue;
use super::telemetry::Telemetry;
use super::workload::{Arrival, ArrivalSpec, ArrivalStream, EpochRates};

/// One epoch's world: the mutated scenario and the strategy the optimizer
/// converged to on it.
pub struct SimEpoch {
    pub net: Network,
    pub phi: Strategy,
}

/// The full simulation input: at least one epoch; all epochs must share
/// the same node/edge sets (strategies are retargeted, not re-wired).
pub struct SimPlan {
    pub epochs: Vec<SimEpoch>,
}

/// Engine parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Total requests to release.
    pub requests: u64,
    /// Fraction of requests (by arrival order) excluded from the sojourn
    /// sketch as warm-up transient.
    pub warmup: f64,
    pub seed: u64,
    /// Ceiling on concurrently in-flight requests. Arrivals beyond it are
    /// *dropped and counted* (`Telemetry::overload_dropped`) instead of
    /// aborting the run, so an overloaded strategy produces a measured
    /// outcome the closed-loop validator can alarm on. The default is high
    /// enough that only a genuinely divergent queue ever reaches it.
    pub max_in_flight: usize,
    /// Finite per-server FIFO capacity (queue + in service — the `K` of an
    /// M/M/1/K loss queue), applied to every queued server. A request that
    /// finds its next FIFO full is dropped where it stands: the server's
    /// `blocked` counter and the global `Telemetry::queue_dropped` each
    /// move by exactly one. `None` (the default) keeps every FIFO
    /// unbounded — bit-identical to the engine before per-queue admission
    /// control existed. `Linear` servers are infinite-server delay
    /// elements with nothing to overflow and never block.
    pub queue_cap: Option<u64>,
    /// Per-kind override: FIFO capacity for compute servers only. Takes
    /// precedence over `queue_cap` for CPUs when set.
    pub cpu_queue_cap: Option<u64>,
    /// Per-kind override: FIFO capacity for link servers only. Takes
    /// precedence over `queue_cap` for links when set.
    pub link_queue_cap: Option<u64>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            requests: 100_000,
            warmup: 0.05,
            seed: 1,
            max_in_flight: MAX_IN_FLIGHT,
            queue_cap: None,
            cpu_queue_cap: None,
            link_queue_cap: None,
        }
    }
}

impl SimConfig {
    /// Effective `(cpu, link)` FIFO capacities after folding the per-kind
    /// overrides over the global default; `None` when no cap was set at
    /// all (the unbounded pre-admission-control engine). A kind left
    /// unbounded by a partial override is reported as `u64::MAX`.
    pub fn effective_queue_caps(&self) -> Option<(u64, u64)> {
        if self.queue_cap.is_none()
            && self.cpu_queue_cap.is_none()
            && self.link_queue_cap.is_none()
        {
            return None;
        }
        Some((
            self.cpu_queue_cap.or(self.queue_cap).unwrap_or(u64::MAX),
            self.link_queue_cap.or(self.queue_cap).unwrap_or(u64::MAX),
        ))
    }
}

/// Default ceiling on concurrently in-flight requests: an overloaded
/// (infeasible) strategy grows queues without bound; dropping beyond this
/// point bounds memory on a run whose tail latency is divergent anyway.
const MAX_IN_FLIGHT: usize = 4_000_000;

/// Sentinel for "no link hop in progress".
const NO_LINK: u32 = u32::MAX;

/// What a request is currently waiting for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// A data-flow link hop is in service; on completion, decide again at
    /// the new node.
    Data,
    /// Computation is in service; on completion, start the result flow.
    Compute,
    /// A result-flow link hop is in service.
    Result,
}

/// Slab slot: generation-checked request state.
struct Slot {
    gen: u32,
    live: bool,
    task: u32,
    node: u32,
    epoch: u32,
    /// Edge id of the link hop in service ([`NO_LINK`] when computing or
    /// making the first decision), so completion releases the right FIFO.
    hop_edge: u32,
    phase: Phase,
    arrival: f64,
    ordinal: u64,
    rng: Pcg,
}

/// Single-server FIFO state for one link or one CPU.
#[derive(Clone, Copy, Debug, Default)]
struct Server {
    next_free: f64,
    in_system: u64,
    peak: u64,
    busy: f64,
    /// Time-integral of `in_system` up to `last_change`, so
    /// `area / end_time` is the time-average number in system — the
    /// quantity the closed-loop validator compares against the analytic
    /// occupancy `CostFn::value(F)`.
    area: f64,
    last_change: f64,
    /// Admission attempts refused because the FIFO held its full
    /// `queue_cap` complement (0 on unbounded runs).
    blocked: u64,
    /// Admission attempts, accepted or blocked — the exact denominator of
    /// this server's simulated blocking rate `blocked / offered`.
    offered: u64,
}

impl Server {
    fn enter(&mut self, now: f64) {
        self.settle(now);
        self.in_system += 1;
        self.peak = self.peak.max(self.in_system);
    }

    fn exit(&mut self, now: f64) {
        self.settle(now);
        self.in_system -= 1;
    }

    fn settle(&mut self, now: f64) {
        self.area += self.in_system as f64 * (now - self.last_change);
        self.last_change = now;
    }

    /// Time-average number in system over `[0, end]`.
    fn occupancy(&self, end: f64) -> f64 {
        if end <= 0.0 {
            return 0.0;
        }
        (self.area + self.in_system as f64 * (end - self.last_change)) / end
    }
}

enum Ev {
    /// The next arrival from the workload stream fires.
    Arrive,
    /// A service (link hop or computation) finished for slab slot `slot`,
    /// valid only while the slot's generation still matches `gen`.
    HopDone { slot: u32, gen: u32 },
    /// In-simulation re-optimization tick ([`ReoptConfig`]).
    Reopt,
}

/// Live state of in-simulation re-optimization: the asynchronous SGP
/// optimizer plus the telemetry-estimated arrival rates it prices against.
struct ReoptState {
    cfg: ReoptConfig,
    sgp: Sgp,
    /// Persistent optimizer scratch arena: re-optimization ticks fire on
    /// the hot simulation path, so the single-node updates reuse one
    /// workspace instead of reallocating per tick.
    ws: OptWorkspace,
    /// Round-robin node cursor — each tick updates one node's data and
    /// result rows for every task, the paper's asynchronous schedule.
    cursor: usize,
    /// Current `[task][node]` arrival-rate estimate, seeded from the
    /// epoch-0 pattern and refreshed from the observation window.
    rates: Vec<Vec<f64>>,
    /// Arrivals observed per `[task][node]` since `window_start`.
    window: Vec<Vec<u64>>,
    window_total: u64,
    window_start: f64,
}

struct Engine<'a> {
    plan: &'a SimPlan,
    queue: EventQueue<Ev>,
    slots: Vec<Slot>,
    free: Vec<u32>,
    in_flight: usize,
    inflight_cap: usize,
    /// Effective `(cpu, link)` FIFO capacities (`SimConfig::
    /// effective_queue_caps`); `None` leaves every queue unbounded.
    queue_caps: Option<(u64, u64)>,
    cpu_cap: u64,
    link_cap: u64,
    links: Vec<Server>,
    cpus: Vec<Server>,
    telemetry: Telemetry,
    stream: ArrivalStream,
    /// The arrival whose `Ev::Arrive` event is currently scheduled.
    pending: Option<Arrival>,
    /// Per-epoch working copies of the plan's strategies. Routing reads
    /// these, not `plan.epochs[..].phi`, so re-optimization ticks can
    /// mutate the strategy mid-run without touching the caller's plan.
    phis: Vec<Strategy>,
    reopt: Option<ReoptState>,
    rng_requests: Pcg,
    ordinal: u64,
    warm_count: u64,
}

/// Run the request-level simulation and return its streaming telemetry.
pub fn simulate(plan: &SimPlan, arrivals: &ArrivalSpec, cfg: &SimConfig) -> Result<Telemetry> {
    simulate_with(plan, arrivals, cfg, None)
}

/// [`simulate`] with optional in-loop re-optimization — the public entry
/// point for the adaptive mode is [`super::closedloop::simulate_adaptive`].
pub(crate) fn simulate_with(
    plan: &SimPlan,
    arrivals: &ArrivalSpec,
    cfg: &SimConfig,
    reopt: Option<&ReoptConfig>,
) -> Result<Telemetry> {
    if plan.epochs.is_empty() {
        bail!("simulation plan has no epochs");
    }
    let (n, e) = (plan.epochs[0].net.n(), plan.epochs[0].net.e());
    for ep in &plan.epochs[1..] {
        if ep.net.n() != n || ep.net.e() != e {
            bail!("simulation epochs must share the node/edge sets");
        }
    }
    if !(0.0..1.0).contains(&cfg.warmup) {
        bail!("warmup fraction must be in [0,1), got {}", cfg.warmup);
    }
    for c in [cfg.queue_cap, cfg.cpu_queue_cap, cfg.link_queue_cap]
        .into_iter()
        .flatten()
    {
        if c == 0 {
            bail!("per-queue capacity must be ≥ 1 (a zero-slot FIFO would block every request)");
        }
    }
    let reopt_state = match reopt {
        Some(rc) => {
            if !(rc.interval.is_finite() && rc.interval > 0.0) {
                bail!(
                    "re-optimization interval must be finite and positive, got {}",
                    rc.interval
                );
            }
            let s = plan.epochs[0].net.s();
            if plan.epochs.iter().any(|ep| ep.net.s() != s) {
                bail!("re-optimization requires every epoch to share the task set");
            }
            Some(ReoptState {
                cfg: *rc,
                sgp: Sgp::new(),
                ws: OptWorkspace::new(),
                cursor: 0,
                rates: plan.epochs[0].net.input_rate.clone(),
                window: vec![vec![0; n]; s],
                window_total: 0,
                window_start: 0.0,
            })
        }
        None => None,
    };
    let rates: Vec<EpochRates> = plan
        .epochs
        .iter()
        .map(|ep| EpochRates::of(&ep.net))
        .collect();
    let stream = ArrivalStream::new(arrivals, rates, cfg.requests, cfg.seed)?;
    let queue_caps = cfg.effective_queue_caps();
    let (cpu_cap, link_cap) = queue_caps.unwrap_or((u64::MAX, u64::MAX));
    let mut engine = Engine {
        plan,
        queue: EventQueue::new(),
        slots: Vec::new(),
        free: Vec::new(),
        in_flight: 0,
        inflight_cap: cfg.max_in_flight,
        queue_caps,
        cpu_cap,
        link_cap,
        links: vec![Server::default(); e],
        cpus: vec![Server::default(); n],
        telemetry: Telemetry::new(n, e),
        stream,
        pending: None,
        phis: plan.epochs.iter().map(|ep| ep.phi.clone()).collect(),
        reopt: reopt_state,
        rng_requests: Pcg::with_stream(cfg.seed, 0x7a5c_0de),
        ordinal: 0,
        warm_count: (cfg.warmup * cfg.requests as f64).floor() as u64,
    };
    if let Some(r) = &engine.reopt {
        engine.queue.schedule(r.cfg.interval, Ev::Reopt);
    }
    engine.run()?;
    Ok(engine.into_telemetry())
}

impl Engine<'_> {
    fn run(&mut self) -> Result<()> {
        self.schedule_next_arrival();
        while let Some(ev) = self.queue.pop() {
            match ev.payload {
                Ev::Arrive => {
                    let a = self.pending.take().expect("Arrive event without arrival");
                    self.schedule_next_arrival();
                    self.admit(a)?;
                }
                Ev::HopDone { slot, gen } => {
                    let idx = slot as usize;
                    // A stale generation would mean the slot was freed
                    // while a service was still in flight — an engine
                    // bug, since each request has one pending service.
                    debug_assert!(
                        self.slots[idx].live && self.slots[idx].gen == gen,
                        "stale hop event"
                    );
                    self.advance(idx)?;
                }
                Ev::Reopt => self.reopt_tick()?,
            }
        }
        Ok(())
    }

    fn into_telemetry(mut self) -> Telemetry {
        self.telemetry.end_time = self.queue.now();
        self.telemetry.events = self.queue.processed;
        self.telemetry.queue_caps = self.queue_caps;
        let end = self.telemetry.end_time;
        for (i, srv) in self.cpus.iter().enumerate() {
            self.telemetry.node_busy[i] = srv.busy;
            self.telemetry.node_peak[i] = srv.peak;
            self.telemetry.node_occupancy[i] = srv.occupancy(end);
            self.telemetry.node_blocked[i] = srv.blocked;
            self.telemetry.node_offered[i] = srv.offered;
        }
        for (e, srv) in self.links.iter().enumerate() {
            self.telemetry.link_busy[e] = srv.busy;
            self.telemetry.link_peak[e] = srv.peak;
            self.telemetry.link_occupancy[e] = srv.occupancy(end);
            self.telemetry.link_blocked[e] = srv.blocked;
            self.telemetry.link_offered[e] = srv.offered;
        }
        self.telemetry
    }

    fn schedule_next_arrival(&mut self) {
        if let Some(a) = self.stream.next() {
            let delay = (a.time - self.queue.now()).max(0.0);
            self.pending = Some(a);
            self.queue.schedule(delay, Ev::Arrive);
        }
    }

    /// Inject one request: allocate a slab slot and make its first
    /// data-plane decision at the source node.
    fn admit(&mut self, a: Arrival) -> Result<()> {
        if let Some(r) = self.reopt.as_mut() {
            // Offered load, dropped or not, informs the rate estimate.
            r.window[a.task][a.source] += 1;
            r.window_total += 1;
        }
        if self.in_flight >= self.inflight_cap {
            // Structured overload: drop the arrival and keep running, so
            // the run ends with telemetry the validator can alarm on
            // ("strategy infeasible / queue divergent") instead of a
            // process error that discards everything measured so far.
            self.telemetry.arrived += 1;
            self.telemetry.overload_dropped += 1;
            return Ok(());
        }
        let now = self.queue.now();
        let epoch = self.stream.epoch_of(a.time) as u32;
        let ordinal = self.ordinal;
        self.ordinal += 1;
        let rng = self.rng_requests.fork(ordinal);
        let idx = match self.free.pop() {
            Some(i) => {
                let s = &mut self.slots[i as usize];
                s.live = true;
                s.task = a.task as u32;
                s.node = a.source as u32;
                s.epoch = epoch;
                s.hop_edge = NO_LINK;
                s.phase = Phase::Data;
                s.arrival = now;
                s.ordinal = ordinal;
                s.rng = rng;
                i as usize
            }
            None => {
                self.slots.push(Slot {
                    gen: 0,
                    live: true,
                    task: a.task as u32,
                    node: a.source as u32,
                    epoch,
                    hop_edge: NO_LINK,
                    phase: Phase::Data,
                    arrival: now,
                    ordinal,
                    rng,
                });
                self.slots.len() - 1
            }
        };
        self.in_flight += 1;
        self.telemetry.arrived += 1;
        self.telemetry.max_in_flight = self.telemetry.max_in_flight.max(self.in_flight as u64);
        self.decide_data(idx)
    }

    /// A service completed: release its server and take the next step.
    fn advance(&mut self, idx: usize) -> Result<()> {
        let now = self.queue.now();
        let hop = self.slots[idx].hop_edge;
        if hop != NO_LINK {
            self.links[hop as usize].exit(now);
            self.slots[idx].hop_edge = NO_LINK;
        }
        match self.slots[idx].phase {
            Phase::Data => self.decide_data(idx),
            Phase::Compute => {
                self.cpus[self.slots[idx].node as usize].exit(now);
                self.slots[idx].phase = Phase::Result;
                self.decide_result(idx)
            }
            Phase::Result => self.decide_result(idx),
        }
    }

    /// Data plane at the request's current node: compute here (slot 0) or
    /// forward along an out-edge, per the strategy row.
    fn decide_data(&mut self, idx: usize) -> Result<()> {
        let plan = self.plan;
        let (task, node, epoch) = {
            let s = &self.slots[idx];
            (s.task as usize, s.node as usize, s.epoch as usize)
        };
        let ep = &plan.epochs[epoch];
        let row = &self.phis[epoch].data[task][node];
        let Some(choice) = sample_row(row, &mut self.slots[idx].rng) else {
            return self.strand(idx);
        };
        let now = self.queue.now();
        if choice == 0 {
            // Compute here: CPU service of requirement w_im × unit size.
            let size = ep.net.w_of(node, task);
            if !self.try_enter(SrvRef::Cpu(node), &ep.net.comp_cost[node], now) {
                return self.block(idx);
            }
            self.slots[idx].phase = Phase::Compute;
            let done = self.serve(SrvRef::Cpu(node), &ep.net.comp_cost[node], size, idx);
            self.schedule_hop(idx, done);
        } else {
            let eid = ep.net.graph.out_edge_ids(node)[choice - 1];
            let dst = ep.net.graph.edge(eid).dst;
            if !self.try_enter(SrvRef::Link(eid), &ep.net.link_cost[eid], now) {
                return self.block(idx);
            }
            self.slots[idx].phase = Phase::Data;
            self.slots[idx].node = dst as u32;
            self.slots[idx].hop_edge = eid as u32;
            let done = self.serve(SrvRef::Link(eid), &ep.net.link_cost[eid], 1.0, idx);
            self.schedule_hop(idx, done);
        }
        Ok(())
    }

    /// Result plane: complete at the destination or forward the result
    /// (size `a_m`) along an out-edge per the result strategy row.
    fn decide_result(&mut self, idx: usize) -> Result<()> {
        let plan = self.plan;
        let (task, node, epoch) = {
            let s = &self.slots[idx];
            (s.task as usize, s.node as usize, s.epoch as usize)
        };
        let ep = &plan.epochs[epoch];
        if node == ep.net.tasks[task].dest {
            self.complete(idx);
            return Ok(());
        }
        let row = &self.phis[epoch].result[task][node];
        let Some(k) = sample_row(row, &mut self.slots[idx].rng) else {
            return self.strand(idx);
        };
        let eid = ep.net.graph.out_edge_ids(node)[k];
        let dst = ep.net.graph.edge(eid).dst;
        let size = ep.net.a_of(task);
        let now = self.queue.now();
        if !self.try_enter(SrvRef::Link(eid), &ep.net.link_cost[eid], now) {
            return self.block(idx);
        }
        self.slots[idx].node = dst as u32;
        self.slots[idx].hop_edge = eid as u32;
        let done = self.serve(SrvRef::Link(eid), &ep.net.link_cost[eid], size, idx);
        self.schedule_hop(idx, done);
        Ok(())
    }

    /// Admit one request into a server's FIFO unless finite capacity
    /// refuses it. Only queued kinds can block — `Linear` is an
    /// infinite-server delay element with nothing to overflow — and
    /// capacity counts queue plus in-service occupants
    /// (`Server::in_system`), the `K` of an M/M/1/K loss queue. Every
    /// attempt is recorded as offered so per-server blocking rates carry
    /// an exact denominator. With the default unbounded caps the
    /// admission test can never fire and the engine's event and RNG
    /// streams are bit-identical to the pre-capacity engine.
    fn try_enter(&mut self, srv: SrvRef, cost: &CostFn, now: f64) -> bool {
        let cap = match srv {
            SrvRef::Cpu(_) => self.cpu_cap,
            SrvRef::Link(_) => self.link_cap,
        };
        let queued = !matches!(cost, CostFn::Linear { .. });
        let state = match srv {
            SrvRef::Cpu(i) => &mut self.cpus[i],
            SrvRef::Link(e) => &mut self.links[e],
        };
        state.offered += 1;
        if queued && state.in_system >= cap {
            state.blocked += 1;
            return false;
        }
        state.enter(now);
        true
    }

    /// A full FIFO refused the next hop: count the drop under its own name
    /// and release the slot. Kept separate from `strand` (strategy
    /// dead-end) and from `overload_dropped` (global in-flight ceiling,
    /// counted in `admit` before any queue is consulted), so the three
    /// drop reasons can never double-count one arrival and the widened
    /// conservation invariant stays exact:
    /// `completed + stranded + overload_dropped + queue_dropped == arrived`.
    /// The reopt observation window saw this arrival exactly once, in
    /// `admit` — blocked offered load still informs the rate estimate.
    fn block(&mut self, idx: usize) -> Result<()> {
        self.telemetry.queue_dropped += 1;
        self.release(idx);
        Ok(())
    }

    /// One asynchronous re-optimization tick: refresh the arrival-rate
    /// estimate from the observation window, then run the paper's
    /// single-node SGP update (data + result planes, every task) for the
    /// next node in round-robin order against the *estimated* network.
    /// Unpriceable states (e.g. estimated rates that saturate a server)
    /// skip the update rather than kill the run — the next window
    /// re-estimates. Fully deterministic: no randomness, and the tick
    /// order is fixed by the calendar queue.
    fn reopt_tick(&mut self) -> Result<()> {
        let Some(mut r) = self.reopt.take() else {
            return Ok(());
        };
        // Drain tick after the workload is exhausted: nothing left to
        // adapt for, so don't reschedule and let the queue empty.
        if self.pending.is_none() && self.in_flight == 0 {
            self.reopt = Some(r);
            return Ok(());
        }
        let now = self.queue.now();
        let epoch = self.stream.epoch_of(now);
        self.telemetry.reopt_events += 1;
        let elapsed = now - r.window_start;
        if elapsed > 0.0 && r.window_total >= r.cfg.min_window {
            for (m, per_node) in r.window.iter_mut().enumerate() {
                for (i, c) in per_node.iter_mut().enumerate() {
                    r.rates[m][i] = *c as f64 / elapsed;
                    *c = 0;
                }
            }
            r.window_total = 0;
            r.window_start = now;
        }
        let mut est = self.plan.epochs[epoch].net.clone();
        est.input_rate = r.rates.clone();
        let node = r.cursor % est.n();
        r.cursor += 1;
        for task in 0..est.s() {
            for plane_result in [false, true] {
                match r.sgp.update_single_node_ws(
                    &est,
                    &mut self.phis[epoch],
                    node,
                    task,
                    plane_result,
                    &mut r.ws,
                ) {
                    Ok(_) => self.telemetry.reopt_updates += 1,
                    Err(_) => self.telemetry.reopt_skipped += 1,
                }
            }
        }
        if self.pending.is_some() {
            self.queue.schedule(r.cfg.interval, Ev::Reopt);
        }
        self.reopt = Some(r);
        Ok(())
    }

    /// Occupy a server and return the absolute completion time.
    fn serve(&mut self, srv: SrvRef, cost: &CostFn, size: f64, idx: usize) -> f64 {
        let now = self.queue.now();
        let rng = &mut self.slots[idx].rng;
        // (queued service draw, deterministic propagation term)
        let (svc, extra) = match cost {
            CostFn::Linear { unit } => (None, unit * size),
            CostFn::Queue { cap } => (Some(draw_service(rng, size / cap)), 0.0),
            CostFn::SmoothCap { slope, cap, .. } => {
                (Some(draw_service(rng, size / cap)), slope * size)
            }
        };
        let state = match srv {
            SrvRef::Cpu(i) => &mut self.cpus[i],
            SrvRef::Link(e) => &mut self.links[e],
        };
        match svc {
            // Infinite-server delay element: busy time still accrues so
            // "utilization" reports offered work.
            None => {
                state.busy += extra;
                now + extra
            }
            Some(svc) => {
                let start = now.max(state.next_free);
                state.next_free = start + svc;
                state.busy += svc;
                start + svc + extra
            }
        }
    }

    fn schedule_hop(&mut self, idx: usize, done: f64) {
        let gen = self.slots[idx].gen;
        let delay = (done - self.queue.now()).max(0.0);
        self.queue.schedule(
            delay,
            Ev::HopDone {
                slot: idx as u32,
                gen,
            },
        );
    }

    fn complete(&mut self, idx: usize) {
        let sojourn = self.queue.now() - self.slots[idx].arrival;
        let warmed = self.slots[idx].ordinal >= self.warm_count;
        self.telemetry.record_completion(sojourn, warmed);
        self.release(idx);
    }

    /// Dead-end in the strategy (no positive slot): count and drop. A
    /// feasible, loop-free strategy never strands a request — tests
    /// assert the counter stays 0.
    fn strand(&mut self, idx: usize) -> Result<()> {
        self.telemetry.stranded += 1;
        self.release(idx);
        Ok(())
    }

    fn release(&mut self, idx: usize) {
        let s = &mut self.slots[idx];
        s.live = false;
        s.gen = s.gen.wrapping_add(1);
        self.free.push(idx as u32);
        self.in_flight -= 1;
    }
}

/// Server identity (which FIFO a service occupies).
#[derive(Clone, Copy)]
enum SrvRef {
    Cpu(usize),
    Link(usize),
}

/// Exponential service draw with mean `mean`; zero-requirement services
/// (e.g. a task with zero compute weight) complete instantly.
fn draw_service(rng: &mut Pcg, mean: f64) -> f64 {
    if mean > 0.0 && mean.is_finite() {
        rng.exponential(mean)
    } else {
        0.0
    }
}

/// Sample an index from a probability row (sums to ≈1): slot 0 = local
/// compute for data rows, out-edge k for result rows. Returns `None` when
/// the row has no positive entry.
fn sample_row(row: &[f64], rng: &mut Pcg) -> Option<usize> {
    let u = rng.f64();
    let mut acc = 0.0;
    let mut last_pos = None;
    for (k, &p) in row.iter().enumerate() {
        if p > 0.0 {
            acc += p;
            last_pos = Some(k);
            if u < acc {
                return Some(k);
            }
        }
    }
    // Float drift: the row sums to 1 − ε and u landed in the gap.
    last_pos
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::network::testnet::{diamond, line3};

    fn plan_of(net: Network, phi: Strategy) -> SimPlan {
        SimPlan {
            epochs: vec![SimEpoch { net, phi }],
        }
    }

    fn poisson() -> ArrivalSpec {
        ArrivalSpec::parse("poisson").unwrap()
    }

    #[test]
    fn local_compute_diamond_completes_everything() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let plan = plan_of(net, phi);
        let cfg = SimConfig {
            requests: 5_000,
            warmup: 0.1,
            seed: 3,
            ..SimConfig::default()
        };
        let t = simulate(&plan, &poisson(), &cfg).unwrap();
        assert_eq!(t.arrived, 5_000);
        assert_eq!(t.completed, 5_000);
        assert_eq!(t.stranded, 0);
        assert_eq!(t.overload_dropped, 0);
        let (p50, p99, p999) = t.tail();
        assert!(p50 > 0.0 && p50 <= p99 && p99 <= p999, "{p50} {p99} {p999}");
        assert!(t.mean_sojourn() > 0.0);
        assert!(t.end_time > 0.0);
        // M/M/1 sanity: every Queue server must be stable.
        for (e, &b) in t.link_busy.iter().enumerate() {
            assert!(b / t.end_time < 1.0, "link {e} overloaded");
        }
        // Occupancy integrals are finite and non-negative everywhere, and
        // some CPU actually held requests.
        for &occ in t.node_occupancy.iter().chain(t.link_occupancy.iter()) {
            assert!(occ.is_finite() && occ >= 0.0, "bad occupancy {occ}");
        }
        assert!(t.node_occupancy.iter().any(|&occ| occ > 0.0));
    }

    #[test]
    fn line3_compute_at_dest_routes_over_links() {
        let net = line3();
        let phi = Strategy::compute_at_dest_init(&net);
        let plan = plan_of(net, phi);
        let cfg = SimConfig {
            requests: 4_000,
            warmup: 0.05,
            seed: 7,
            ..SimConfig::default()
        };
        let t = simulate(&plan, &poisson(), &cfg).unwrap();
        assert_eq!(t.completed + t.stranded, 4_000);
        assert_eq!(t.stranded, 0);
        // Forwarding to the destination must exercise at least one link.
        assert!(t.link_busy.iter().any(|&b| b > 0.0));
        assert!(t.link_peak.iter().any(|&p| p > 0));
    }

    #[test]
    fn bit_identical_across_runs() {
        let cfg = SimConfig {
            requests: 2_000,
            warmup: 0.05,
            seed: 11,
            ..SimConfig::default()
        };
        let run = || {
            let net = diamond(true);
            let phi = Strategy::local_compute_init(&net);
            simulate(&plan_of(net, phi), &poisson(), &cfg)
                .unwrap()
                .to_json()
                .dump()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn warmup_fraction_excluded() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let cfg = SimConfig {
            requests: 1_000,
            warmup: 0.25,
            seed: 5,
            ..SimConfig::default()
        };
        let t = simulate(&plan_of(net, phi), &poisson(), &cfg).unwrap();
        assert_eq!(t.warmup_skipped, 250);
        assert_eq!(t.sojourn.count(), 750);
    }

    #[test]
    fn overload_drops_are_counted_not_fatal() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let cfg = SimConfig {
            requests: 2_000,
            warmup: 0.0,
            seed: 9,
            max_in_flight: 1,
            ..SimConfig::default()
        };
        let t = simulate(&plan_of(net, phi), &poisson(), &cfg).unwrap();
        assert!(t.overload_dropped > 0, "ceiling of 1 must drop arrivals");
        // Conservation: every arrival either completed, stranded, or was
        // dropped at the ceiling — and the run still finished cleanly.
        assert_eq!(t.arrived, 2_000);
        assert_eq!(t.completed + t.stranded + t.overload_dropped, t.arrived);
        assert!(t.max_in_flight <= 1);
    }

    #[test]
    fn zero_capacity_run_completes_with_empty_telemetry() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let cfg = SimConfig {
            requests: 100,
            warmup: 0.0,
            seed: 2,
            max_in_flight: 0,
            ..SimConfig::default()
        };
        let t = simulate(&plan_of(net, phi), &poisson(), &cfg).unwrap();
        assert_eq!(t.overload_dropped, 100);
        assert_eq!(t.completed, 0);
        // Empty telemetry still serializes to parseable, finite JSON
        // (satellite: no NaN→null leaks from the empty sketch).
        let dump = t.to_json().dump();
        assert!(!dump.contains("null"), "empty telemetry leaked null: {dump}");
    }

    #[test]
    fn tight_queue_cap_blocks_and_conserves() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let cfg = SimConfig {
            requests: 4_000,
            warmup: 0.0,
            seed: 13,
            queue_cap: Some(1),
            ..SimConfig::default()
        };
        let t = simulate(&plan_of(net, phi), &poisson(), &cfg).unwrap();
        assert!(t.queue_dropped > 0, "cap of 1 must block some arrivals");
        assert_eq!(t.overload_dropped, 0, "global ceiling must stay out of it");
        // Widened conservation: every arrival is accounted for exactly once.
        assert_eq!(
            t.completed + t.stranded + t.overload_dropped + t.queue_dropped,
            t.arrived
        );
        // Per-server blocked counters decompose the global drop counter.
        let blocked: u64 =
            t.node_blocked.iter().sum::<u64>() + t.link_blocked.iter().sum::<u64>();
        assert_eq!(blocked, t.queue_dropped);
        // Capacity binds the in-system high-water marks.
        for &p in t.node_peak.iter().chain(t.link_peak.iter()) {
            assert!(p <= 1, "peak {p} escaped the FIFO capacity");
        }
        assert_eq!(t.queue_caps, Some((1, 1)));
    }

    #[test]
    fn per_kind_override_caps_only_that_kind() {
        let net = line3();
        let phi = Strategy::compute_at_dest_init(&net);
        let cfg = SimConfig {
            requests: 3_000,
            warmup: 0.0,
            seed: 19,
            cpu_queue_cap: Some(2),
            ..SimConfig::default()
        };
        let t = simulate(&plan_of(net, phi), &poisson(), &cfg).unwrap();
        assert_eq!(t.queue_caps, Some((2, u64::MAX)));
        // Links are unbounded: no link ever blocks.
        assert_eq!(t.link_blocked.iter().sum::<u64>(), 0);
        for &p in t.node_peak.iter() {
            assert!(p <= 2, "cpu peak {p} escaped the per-kind capacity");
        }
        assert_eq!(
            t.completed + t.stranded + t.overload_dropped + t.queue_dropped,
            t.arrived
        );
    }

    #[test]
    fn uncapped_runs_emit_no_queue_cap_telemetry() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let cfg = SimConfig {
            requests: 500,
            warmup: 0.0,
            seed: 4,
            ..SimConfig::default()
        };
        let t = simulate(&plan_of(net, phi), &poisson(), &cfg).unwrap();
        assert_eq!(t.queue_caps, None);
        let dump = t.to_json().dump();
        for key in ["queue_dropped", "queue_cap", "node_blocked", "link_blocked"] {
            assert!(!dump.contains(key), "uncapped dump leaked {key}: {dump}");
        }
    }

    #[test]
    fn zero_queue_cap_is_rejected() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let plan = plan_of(net, phi);
        for cfg in [
            SimConfig {
                queue_cap: Some(0),
                ..SimConfig::default()
            },
            SimConfig {
                link_queue_cap: Some(0),
                ..SimConfig::default()
            },
        ] {
            assert!(simulate(&plan, &poisson(), &cfg).is_err());
        }
    }

    #[test]
    fn rejects_empty_plan_and_bad_warmup() {
        let plan = SimPlan { epochs: vec![] };
        assert!(simulate(&plan, &poisson(), &SimConfig::default()).is_err());
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let cfg = SimConfig {
            requests: 10,
            warmup: 1.0,
            seed: 1,
            ..SimConfig::default()
        };
        assert!(simulate(&plan_of(net, phi), &poisson(), &cfg).is_err());
    }
}
