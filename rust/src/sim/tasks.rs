//! Request-level task engine: individual requests walking a converged
//! strategy through FIFO queues.
//!
//! The optimizer prices *flows*; this engine releases *requests* and
//! measures what the paper's convex link/CPU costs only promise on
//! average: sojourn time, including its tail. Each request walks the
//! three-leg journey of §II — data-flow hops from its source toward a
//! computation site (strategy slot 0 = compute here, slot k+1 = forward on
//! the k-th out-edge), exponential computation service, then result-flow
//! hops (size `a_m ×` the data size) to the task's destination — with
//! every routing choice drawn from the converged `Strategy`'s probability
//! rows, so the simulated demand splits exactly like the optimized flows.
//!
//! Service model per [`CostFn`]: `Queue{cap}` is a single-server FIFO with
//! exponential service of mean `size/cap` — an M/M/1 queue whose expected
//! occupancy is the paper's cost term `F/(cap−F)`, so measured mean delay
//! and analytic cost agree when the strategy keeps every flow under
//! capacity. `Linear{unit}` is a pure propagation delay (infinite server),
//! and `SmoothCap{slope,cap,..}` is the rate-capped server plus its
//! deterministic `slope·size` propagation term.
//!
//! Engineering constraints (acceptance criteria of the PR 6 issue):
//!
//! * request state lives in a generation-indexed slab arena — after
//!   warm-up the engine performs **no per-request heap allocation**
//!   (slab and free list grow to peak concurrency, then recycle);
//! * the event set rides the O(1)-amortized calendar queue
//!   ([`super::core`]);
//! * telemetry streams into bounded-memory sketches
//!   ([`super::telemetry`]) — total memory is independent of the number
//!   of requests simulated.
//!
//! Time-varying runs pin each request to the epoch it arrived in: routing,
//! sizes and destinations come from that epoch's `(Network, Strategy)`
//! snapshot while the physical FIFO servers are shared across epochs
//! (capacities are epoch-invariant under every `PatternSchedule` kind —
//! the schedules mutate rates and endpoints, not hardware).

use anyhow::{bail, Result};

use crate::model::cost::CostFn;
use crate::model::network::Network;
use crate::model::strategy::Strategy;
use crate::util::rng::Pcg;

use super::core::EventQueue;
use super::telemetry::Telemetry;
use super::workload::{Arrival, ArrivalSpec, ArrivalStream, EpochRates};

/// One epoch's world: the mutated scenario and the strategy the optimizer
/// converged to on it.
pub struct SimEpoch {
    pub net: Network,
    pub phi: Strategy,
}

/// The full simulation input: at least one epoch; all epochs must share
/// the same node/edge sets (strategies are retargeted, not re-wired).
pub struct SimPlan {
    pub epochs: Vec<SimEpoch>,
}

/// Engine parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Total requests to release.
    pub requests: u64,
    /// Fraction of requests (by arrival order) excluded from the sojourn
    /// sketch as warm-up transient.
    pub warmup: f64,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            requests: 100_000,
            warmup: 0.05,
            seed: 1,
        }
    }
}

/// Hard ceiling on concurrently in-flight requests: an overloaded
/// (infeasible) strategy grows queues without bound; failing fast beats
/// exhausting memory on a run whose tail latency is divergent anyway.
const MAX_IN_FLIGHT: usize = 4_000_000;

/// Sentinel for "no link hop in progress".
const NO_LINK: u32 = u32::MAX;

/// What a request is currently waiting for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// A data-flow link hop is in service; on completion, decide again at
    /// the new node.
    Data,
    /// Computation is in service; on completion, start the result flow.
    Compute,
    /// A result-flow link hop is in service.
    Result,
}

/// Slab slot: generation-checked request state.
struct Slot {
    gen: u32,
    live: bool,
    task: u32,
    node: u32,
    epoch: u32,
    /// Edge id of the link hop in service ([`NO_LINK`] when computing or
    /// making the first decision), so completion releases the right FIFO.
    hop_edge: u32,
    phase: Phase,
    arrival: f64,
    ordinal: u64,
    rng: Pcg,
}

/// Single-server FIFO state for one link or one CPU.
#[derive(Clone, Copy, Debug, Default)]
struct Server {
    next_free: f64,
    in_system: u64,
    peak: u64,
    busy: f64,
}

impl Server {
    fn enter(&mut self) {
        self.in_system += 1;
        self.peak = self.peak.max(self.in_system);
    }
}

enum Ev {
    /// The next arrival from the workload stream fires.
    Arrive,
    /// A service (link hop or computation) finished for slab slot `slot`,
    /// valid only while the slot's generation still matches `gen`.
    HopDone { slot: u32, gen: u32 },
}

struct Engine<'a> {
    plan: &'a SimPlan,
    queue: EventQueue<Ev>,
    slots: Vec<Slot>,
    free: Vec<u32>,
    in_flight: usize,
    links: Vec<Server>,
    cpus: Vec<Server>,
    telemetry: Telemetry,
    stream: ArrivalStream,
    /// The arrival whose `Ev::Arrive` event is currently scheduled.
    pending: Option<Arrival>,
    rng_requests: Pcg,
    ordinal: u64,
    warm_count: u64,
}

/// Run the request-level simulation and return its streaming telemetry.
pub fn simulate(plan: &SimPlan, arrivals: &ArrivalSpec, cfg: &SimConfig) -> Result<Telemetry> {
    if plan.epochs.is_empty() {
        bail!("simulation plan has no epochs");
    }
    let (n, e) = (plan.epochs[0].net.n(), plan.epochs[0].net.e());
    for ep in &plan.epochs[1..] {
        if ep.net.n() != n || ep.net.e() != e {
            bail!("simulation epochs must share the node/edge sets");
        }
    }
    if !(0.0..1.0).contains(&cfg.warmup) {
        bail!("warmup fraction must be in [0,1), got {}", cfg.warmup);
    }
    let rates: Vec<EpochRates> = plan
        .epochs
        .iter()
        .map(|ep| EpochRates::of(&ep.net))
        .collect();
    let stream = ArrivalStream::new(arrivals, rates, cfg.requests, cfg.seed)?;
    let mut engine = Engine {
        plan,
        queue: EventQueue::new(),
        slots: Vec::new(),
        free: Vec::new(),
        in_flight: 0,
        links: vec![Server::default(); e],
        cpus: vec![Server::default(); n],
        telemetry: Telemetry::new(n, e),
        stream,
        pending: None,
        rng_requests: Pcg::with_stream(cfg.seed, 0x7a5c_0de),
        ordinal: 0,
        warm_count: (cfg.warmup * cfg.requests as f64).floor() as u64,
    };
    engine.run()?;
    Ok(engine.into_telemetry())
}

impl Engine<'_> {
    fn run(&mut self) -> Result<()> {
        self.schedule_next_arrival();
        while let Some(ev) = self.queue.pop() {
            match ev.payload {
                Ev::Arrive => {
                    let a = self.pending.take().expect("Arrive event without arrival");
                    self.schedule_next_arrival();
                    self.admit(a)?;
                }
                Ev::HopDone { slot, gen } => {
                    let idx = slot as usize;
                    // A stale generation would mean the slot was freed
                    // while a service was still in flight — an engine
                    // bug, since each request has one pending service.
                    debug_assert!(
                        self.slots[idx].live && self.slots[idx].gen == gen,
                        "stale hop event"
                    );
                    self.advance(idx)?;
                }
            }
        }
        Ok(())
    }

    fn into_telemetry(mut self) -> Telemetry {
        self.telemetry.end_time = self.queue.now();
        self.telemetry.events = self.queue.processed;
        for (i, srv) in self.cpus.iter().enumerate() {
            self.telemetry.node_busy[i] = srv.busy;
            self.telemetry.node_peak[i] = srv.peak;
        }
        for (e, srv) in self.links.iter().enumerate() {
            self.telemetry.link_busy[e] = srv.busy;
            self.telemetry.link_peak[e] = srv.peak;
        }
        self.telemetry
    }

    fn schedule_next_arrival(&mut self) {
        if let Some(a) = self.stream.next() {
            let delay = (a.time - self.queue.now()).max(0.0);
            self.pending = Some(a);
            self.queue.schedule(delay, Ev::Arrive);
        }
    }

    /// Inject one request: allocate a slab slot and make its first
    /// data-plane decision at the source node.
    fn admit(&mut self, a: Arrival) -> Result<()> {
        if self.in_flight >= MAX_IN_FLIGHT {
            bail!(
                "over {MAX_IN_FLIGHT} requests in flight — the strategy is \
                 overloaded (some queue has utilization ≥ 1); aborting"
            );
        }
        let now = self.queue.now();
        let epoch = self.stream.epoch_of(a.time) as u32;
        let ordinal = self.ordinal;
        self.ordinal += 1;
        let rng = self.rng_requests.fork(ordinal);
        let idx = match self.free.pop() {
            Some(i) => {
                let s = &mut self.slots[i as usize];
                s.live = true;
                s.task = a.task as u32;
                s.node = a.source as u32;
                s.epoch = epoch;
                s.hop_edge = NO_LINK;
                s.phase = Phase::Data;
                s.arrival = now;
                s.ordinal = ordinal;
                s.rng = rng;
                i as usize
            }
            None => {
                self.slots.push(Slot {
                    gen: 0,
                    live: true,
                    task: a.task as u32,
                    node: a.source as u32,
                    epoch,
                    hop_edge: NO_LINK,
                    phase: Phase::Data,
                    arrival: now,
                    ordinal,
                    rng,
                });
                self.slots.len() - 1
            }
        };
        self.in_flight += 1;
        self.telemetry.arrived += 1;
        self.telemetry.max_in_flight = self.telemetry.max_in_flight.max(self.in_flight as u64);
        self.decide_data(idx)
    }

    /// A service completed: release its server and take the next step.
    fn advance(&mut self, idx: usize) -> Result<()> {
        let hop = self.slots[idx].hop_edge;
        if hop != NO_LINK {
            self.links[hop as usize].in_system -= 1;
            self.slots[idx].hop_edge = NO_LINK;
        }
        match self.slots[idx].phase {
            Phase::Data => self.decide_data(idx),
            Phase::Compute => {
                self.cpus[self.slots[idx].node as usize].in_system -= 1;
                self.slots[idx].phase = Phase::Result;
                self.decide_result(idx)
            }
            Phase::Result => self.decide_result(idx),
        }
    }

    /// Data plane at the request's current node: compute here (slot 0) or
    /// forward along an out-edge, per the strategy row.
    fn decide_data(&mut self, idx: usize) -> Result<()> {
        let plan = self.plan;
        let (task, node, epoch) = {
            let s = &self.slots[idx];
            (s.task as usize, s.node as usize, s.epoch as usize)
        };
        let ep = &plan.epochs[epoch];
        let row = &ep.phi.data[task][node];
        let Some(choice) = sample_row(row, &mut self.slots[idx].rng) else {
            return self.strand(idx);
        };
        if choice == 0 {
            // Compute here: CPU service of requirement w_im × unit size.
            let size = ep.net.w_of(node, task);
            self.slots[idx].phase = Phase::Compute;
            self.cpus[node].enter();
            let done = self.serve(SrvRef::Cpu(node), &ep.net.comp_cost[node], size, idx);
            self.schedule_hop(idx, done);
        } else {
            let eid = ep.net.graph.out_edge_ids(node)[choice - 1];
            let dst = ep.net.graph.edge(eid).dst;
            self.slots[idx].phase = Phase::Data;
            self.slots[idx].node = dst as u32;
            self.slots[idx].hop_edge = eid as u32;
            self.links[eid].enter();
            let done = self.serve(SrvRef::Link(eid), &ep.net.link_cost[eid], 1.0, idx);
            self.schedule_hop(idx, done);
        }
        Ok(())
    }

    /// Result plane: complete at the destination or forward the result
    /// (size `a_m`) along an out-edge per the result strategy row.
    fn decide_result(&mut self, idx: usize) -> Result<()> {
        let plan = self.plan;
        let (task, node, epoch) = {
            let s = &self.slots[idx];
            (s.task as usize, s.node as usize, s.epoch as usize)
        };
        let ep = &plan.epochs[epoch];
        if node == ep.net.tasks[task].dest {
            self.complete(idx);
            return Ok(());
        }
        let row = &ep.phi.result[task][node];
        let Some(k) = sample_row(row, &mut self.slots[idx].rng) else {
            return self.strand(idx);
        };
        let eid = ep.net.graph.out_edge_ids(node)[k];
        let dst = ep.net.graph.edge(eid).dst;
        let size = ep.net.a_of(task);
        self.slots[idx].node = dst as u32;
        self.slots[idx].hop_edge = eid as u32;
        self.links[eid].enter();
        let done = self.serve(SrvRef::Link(eid), &ep.net.link_cost[eid], size, idx);
        self.schedule_hop(idx, done);
        Ok(())
    }

    /// Occupy a server and return the absolute completion time.
    fn serve(&mut self, srv: SrvRef, cost: &CostFn, size: f64, idx: usize) -> f64 {
        let now = self.queue.now();
        let rng = &mut self.slots[idx].rng;
        // (queued service draw, deterministic propagation term)
        let (svc, extra) = match cost {
            CostFn::Linear { unit } => (None, unit * size),
            CostFn::Queue { cap } => (Some(draw_service(rng, size / cap)), 0.0),
            CostFn::SmoothCap { slope, cap, .. } => {
                (Some(draw_service(rng, size / cap)), slope * size)
            }
        };
        let state = match srv {
            SrvRef::Cpu(i) => &mut self.cpus[i],
            SrvRef::Link(e) => &mut self.links[e],
        };
        match svc {
            // Infinite-server delay element: busy time still accrues so
            // "utilization" reports offered work.
            None => {
                state.busy += extra;
                now + extra
            }
            Some(svc) => {
                let start = now.max(state.next_free);
                state.next_free = start + svc;
                state.busy += svc;
                start + svc + extra
            }
        }
    }

    fn schedule_hop(&mut self, idx: usize, done: f64) {
        let gen = self.slots[idx].gen;
        let delay = (done - self.queue.now()).max(0.0);
        self.queue.schedule(
            delay,
            Ev::HopDone {
                slot: idx as u32,
                gen,
            },
        );
    }

    fn complete(&mut self, idx: usize) {
        let sojourn = self.queue.now() - self.slots[idx].arrival;
        let warmed = self.slots[idx].ordinal >= self.warm_count;
        self.telemetry.record_completion(sojourn, warmed);
        self.release(idx);
    }

    /// Dead-end in the strategy (no positive slot): count and drop. A
    /// feasible, loop-free strategy never strands a request — tests
    /// assert the counter stays 0.
    fn strand(&mut self, idx: usize) -> Result<()> {
        self.telemetry.stranded += 1;
        self.release(idx);
        Ok(())
    }

    fn release(&mut self, idx: usize) {
        let s = &mut self.slots[idx];
        s.live = false;
        s.gen = s.gen.wrapping_add(1);
        self.free.push(idx as u32);
        self.in_flight -= 1;
    }
}

/// Server identity (which FIFO a service occupies).
#[derive(Clone, Copy)]
enum SrvRef {
    Cpu(usize),
    Link(usize),
}

/// Exponential service draw with mean `mean`; zero-requirement services
/// (e.g. a task with zero compute weight) complete instantly.
fn draw_service(rng: &mut Pcg, mean: f64) -> f64 {
    if mean > 0.0 && mean.is_finite() {
        rng.exponential(mean)
    } else {
        0.0
    }
}

/// Sample an index from a probability row (sums to ≈1): slot 0 = local
/// compute for data rows, out-edge k for result rows. Returns `None` when
/// the row has no positive entry.
fn sample_row(row: &[f64], rng: &mut Pcg) -> Option<usize> {
    let u = rng.f64();
    let mut acc = 0.0;
    let mut last_pos = None;
    for (k, &p) in row.iter().enumerate() {
        if p > 0.0 {
            acc += p;
            last_pos = Some(k);
            if u < acc {
                return Some(k);
            }
        }
    }
    // Float drift: the row sums to 1 − ε and u landed in the gap.
    last_pos
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::network::testnet::{diamond, line3};

    fn plan_of(net: Network, phi: Strategy) -> SimPlan {
        SimPlan {
            epochs: vec![SimEpoch { net, phi }],
        }
    }

    fn poisson() -> ArrivalSpec {
        ArrivalSpec::parse("poisson").unwrap()
    }

    #[test]
    fn local_compute_diamond_completes_everything() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let plan = plan_of(net, phi);
        let cfg = SimConfig {
            requests: 5_000,
            warmup: 0.1,
            seed: 3,
        };
        let t = simulate(&plan, &poisson(), &cfg).unwrap();
        assert_eq!(t.arrived, 5_000);
        assert_eq!(t.completed, 5_000);
        assert_eq!(t.stranded, 0);
        let (p50, p99, p999) = t.tail();
        assert!(p50 > 0.0 && p50 <= p99 && p99 <= p999, "{p50} {p99} {p999}");
        assert!(t.mean_sojourn() > 0.0);
        assert!(t.end_time > 0.0);
        // M/M/1 sanity: every Queue server must be stable.
        for (e, &b) in t.link_busy.iter().enumerate() {
            assert!(b / t.end_time < 1.0, "link {e} overloaded");
        }
    }

    #[test]
    fn line3_compute_at_dest_routes_over_links() {
        let net = line3();
        let phi = Strategy::compute_at_dest_init(&net);
        let plan = plan_of(net, phi);
        let cfg = SimConfig {
            requests: 4_000,
            warmup: 0.05,
            seed: 7,
        };
        let t = simulate(&plan, &poisson(), &cfg).unwrap();
        assert_eq!(t.completed + t.stranded, 4_000);
        assert_eq!(t.stranded, 0);
        // Forwarding to the destination must exercise at least one link.
        assert!(t.link_busy.iter().any(|&b| b > 0.0));
        assert!(t.link_peak.iter().any(|&p| p > 0));
    }

    #[test]
    fn bit_identical_across_runs() {
        let cfg = SimConfig {
            requests: 2_000,
            warmup: 0.05,
            seed: 11,
        };
        let run = || {
            let net = diamond(true);
            let phi = Strategy::local_compute_init(&net);
            simulate(&plan_of(net, phi), &poisson(), &cfg)
                .unwrap()
                .to_json()
                .dump()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn warmup_fraction_excluded() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let cfg = SimConfig {
            requests: 1_000,
            warmup: 0.25,
            seed: 5,
        };
        let t = simulate(&plan_of(net, phi), &poisson(), &cfg).unwrap();
        assert_eq!(t.warmup_skipped, 250);
        assert_eq!(t.sojourn.count(), 750);
    }

    #[test]
    fn rejects_empty_plan_and_bad_warmup() {
        let plan = SimPlan { epochs: vec![] };
        assert!(simulate(&plan, &poisson(), &SimConfig::default()).is_err());
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let cfg = SimConfig {
            requests: 10,
            warmup: 1.0,
            seed: 1,
        };
        assert!(simulate(&plan_of(net, phi), &poisson(), &cfg).is_err());
    }
}
