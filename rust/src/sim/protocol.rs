//! The paper's two-stage marginal-cost broadcast (§IV "Marginal cost
//! broadcast") as an explicit distributed message-passing protocol on the
//! discrete-event engine.
//!
//! Stage 1 computes `∂T/∂t⁺` upstream from each destination; stage 2
//! computes `∂T/∂r` upstream from the computation exits, and may start at a
//! node only after its own stage-1 value is known (eq. 11 references
//! `∂T/∂t⁺_i`). The max-path-length statistics `h±` ride piggyback, exactly
//! as the paper suggests.
//!
//! Each node runs on purely local knowledge: its `φ` rows, its measured
//! link marginals `D'_ij` on outgoing links, its local `C'_i`, `w_im`,
//! `a_m`. Messages carry `(value, h)` and take `t_c` time units on the
//! non-congestible control channel. A node *fires* once all of its active
//! downstream dependencies have reported; firing broadcasts to all
//! in-neighbors (upstream nodes need the value of every out-neighbor to
//! build the Theorem-1 vectors `δ±`, not just of active ones).
//!
//! The integration test `rust/tests/protocol_parity.rs` pins this protocol
//! bit-for-bit to the centralized `model::marginals` computation; the unit
//! tests here check timing/complexity claims (completion ≤ 2·h̄·t_c, message
//! count 2·|S|·|E| per iteration).

use crate::model::flows::FlowState;
use crate::model::marginals::Marginals;
use crate::model::network::Network;
use crate::model::strategy::Strategy;

// The broadcast runs on the O(1)-amortized calendar queue; the legacy heap
// queue in `super::event` remains only as the parity-test oracle.
use super::core::EventQueue;

/// A broadcast message for one task: either a stage-1 (`∂T/∂t⁺`) or
/// stage-2 (`∂T/∂r`) value, from `from`, delivered to `to`.
#[derive(Clone, Copy, Debug)]
pub struct Message {
    pub task: usize,
    pub stage: Stage,
    pub from: usize,
    pub to: usize,
    pub value: f64,
    pub hops: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    ResultMarginal, // stage 1: ∂T/∂t⁺ with h⁺
    DataMarginal,   // stage 2: ∂T/∂r with h⁻
}

/// Outcome of running the protocol for one iteration.
#[derive(Clone, Debug)]
pub struct ProtocolResult {
    /// `∂T/∂t⁺` per `[task][node]` as learned distributively.
    pub dt_plus: Vec<Vec<f64>>,
    /// `∂T/∂r` per `[task][node]`.
    pub dt_r: Vec<Vec<f64>>,
    /// piggybacked `h⁺` / `h⁻`.
    pub h_plus: Vec<Vec<usize>>,
    pub h_minus: Vec<Vec<usize>>,
    /// Total broadcast messages sent.
    pub messages: u64,
    /// Simulated completion time (all nodes informed), in `t_c` units when
    /// `t_c = 1`.
    pub completion_time: f64,
}

/// Per-(task,node) protocol state machine.
struct NodeState {
    // stage 1
    dt_plus: Option<f64>,
    h_plus: usize,
    pending_stage1: usize, // active result out-neighbors not yet reported
    stage1_inbox: Vec<Option<(f64, usize)>>, // per out-slot: (value, h)
    // stage 2
    dt_r: Option<f64>,
    h_minus: usize,
    pending_stage2: usize, // active data out-neighbors not yet reported
    stage2_inbox: Vec<Option<(f64, usize)>>,
}

/// Run the two-stage broadcast for every task. `t_c` is the per-message
/// latency; `flows` supplies the locally-measured quantities (each node
/// only reads its own rows).
pub fn run_broadcast(
    net: &Network,
    phi: &Strategy,
    flows: &FlowState,
    t_c: f64,
) -> ProtocolResult {
    let n = net.n();
    let s_count = net.s();
    let g = &net.graph;

    // Locally-measured marginals: node i measures D'_ij on its out-links
    // and C'_i at its computation unit.
    let d_link: Vec<f64> = (0..net.e())
        .map(|e| net.link_cost[e].deriv(flows.link_flow[e]))
        .collect();
    let c_node: Vec<f64> = (0..n)
        .map(|i| net.comp_cost[i].deriv(flows.workload[i]))
        .collect();

    let mut states: Vec<Vec<NodeState>> = (0..s_count)
        .map(|s| {
            (0..n)
                .map(|i| {
                    let deg = g.out_degree(i);
                    let active_res = (0..deg)
                        .filter(|&k| phi.result[s][i][k] > 0.0)
                        .count();
                    let active_data = (0..deg)
                        .filter(|&k| phi.data[s][i][k + 1] > 0.0)
                        .count();
                    NodeState {
                        dt_plus: None,
                        h_plus: 0,
                        pending_stage1: active_res,
                        stage1_inbox: vec![None; deg],
                        dt_r: None,
                        h_minus: 0,
                        pending_stage2: active_data,
                        stage2_inbox: vec![None; deg],
                    }
                })
                .collect()
        })
        .collect();

    let mut queue: EventQueue<Message> = EventQueue::new();
    let mut messages: u64 = 0;

    // A node "fires" stage 1 when its dt⁺ becomes known: broadcast to all
    // in-neighbors, then check whether stage 2 can fire too.
    fn fire_stage1(
        net: &Network,
        s: usize,
        i: usize,
        value: f64,
        hops: usize,
        queue: &mut EventQueue<Message>,
        messages: &mut u64,
        t_c: f64,
    ) {
        for j in net.graph.in_neighbors(i).collect::<Vec<_>>() {
            queue.schedule(
                t_c,
                Message {
                    task: s,
                    stage: Stage::ResultMarginal,
                    from: i,
                    to: j,
                    value,
                    hops,
                },
            );
            *messages += 1;
        }
    }

    fn fire_stage2(
        net: &Network,
        s: usize,
        i: usize,
        value: f64,
        hops: usize,
        queue: &mut EventQueue<Message>,
        messages: &mut u64,
        t_c: f64,
    ) {
        for j in net.graph.in_neighbors(i).collect::<Vec<_>>() {
            queue.schedule(
                t_c,
                Message {
                    task: s,
                    stage: Stage::DataMarginal,
                    from: i,
                    to: j,
                    value,
                    hops,
                },
            );
            *messages += 1;
        }
    }

    // Try to resolve stage 1 at (s,i); on success fire and cascade stage 2.
    fn try_stage1(
        net: &Network,
        phi: &Strategy,
        d_link: &[f64],
        states: &mut [Vec<NodeState>],
        s: usize,
        i: usize,
        queue: &mut EventQueue<Message>,
        messages: &mut u64,
        t_c: f64,
    ) {
        let st = &states[s][i];
        if st.dt_plus.is_some() || st.pending_stage1 > 0 {
            return;
        }
        let g = &net.graph;
        let dest = net.tasks[s].dest;
        let (value, hops) = if i == dest {
            (0.0, 0)
        } else {
            let mut acc = 0.0;
            let mut h = 0usize;
            for (k, &eid) in g.out_edge_ids(i).iter().enumerate() {
                let frac = phi.result[s][i][k];
                if frac > 0.0 {
                    let (v_j, h_j) = states[s][i].stage1_inbox[k]
                        .expect("pending_stage1 reached 0 but inbox incomplete");
                    acc += frac * (d_link[eid] + v_j);
                    h = h.max(1 + h_j);
                }
            }
            (acc, h)
        };
        states[s][i].dt_plus = Some(value);
        states[s][i].h_plus = hops;
        fire_stage1(net, s, i, value, hops, queue, messages, t_c);
    }

    fn try_stage2(
        net: &Network,
        phi: &Strategy,
        d_link: &[f64],
        c_node: &[f64],
        states: &mut [Vec<NodeState>],
        s: usize,
        i: usize,
        queue: &mut EventQueue<Message>,
        messages: &mut u64,
        t_c: f64,
    ) {
        let st = &states[s][i];
        if st.dt_r.is_some() || st.pending_stage2 > 0 || st.dt_plus.is_none() {
            return;
        }
        let g = &net.graph;
        let ctype = net.tasks[s].ctype;
        let a_m = net.a_of(s);
        let mut acc =
            phi.data[s][i][0] * (net.comp_weight[i][ctype] * c_node[i] + a_m * st.dt_plus.unwrap());
        let mut h = 0usize;
        for (k, &eid) in g.out_edge_ids(i).iter().enumerate() {
            let frac = phi.data[s][i][k + 1];
            if frac > 0.0 {
                let (v_j, h_j) = states[s][i].stage2_inbox[k]
                    .expect("pending_stage2 reached 0 but inbox incomplete");
                acc += frac * (d_link[eid] + v_j);
                h = h.max(1 + h_j);
            }
        }
        states[s][i].dt_r = Some(acc);
        states[s][i].h_minus = h;
        fire_stage2(net, s, i, acc, h, queue, messages, t_c);
    }

    // Bootstrap: destinations fire stage 1; stage-2 leaves cascade from
    // try_stage2 as soon as their stage-1 value lands.
    for s in 0..s_count {
        for i in 0..n {
            try_stage1(net, phi, &d_link, &mut states, s, i, &mut queue, &mut messages, t_c);
            try_stage2(
                net, phi, &d_link, &c_node, &mut states, s, i, &mut queue, &mut messages, t_c,
            );
        }
    }

    // Event loop.
    while let Some(ev) = queue.pop() {
        let m = ev.payload;
        let s = m.task;
        let i = m.to;
        let slot = crate::model::strategy::out_slot(&net.graph, i, m.from);
        match m.stage {
            Stage::ResultMarginal => {
                if let Some(k) = slot {
                    if states[s][i].stage1_inbox[k].is_none() {
                        states[s][i].stage1_inbox[k] = Some((m.value, m.hops));
                        if phi.result[s][i][k] > 0.0 {
                            states[s][i].pending_stage1 -= 1;
                        }
                    }
                }
                try_stage1(net, phi, &d_link, &mut states, s, i, &mut queue, &mut messages, t_c);
                try_stage2(
                    net, phi, &d_link, &c_node, &mut states, s, i, &mut queue, &mut messages,
                    t_c,
                );
            }
            Stage::DataMarginal => {
                if let Some(k) = slot {
                    if states[s][i].stage2_inbox[k].is_none() {
                        states[s][i].stage2_inbox[k] = Some((m.value, m.hops));
                        if phi.data[s][i][k + 1] > 0.0 {
                            states[s][i].pending_stage2 -= 1;
                        }
                    }
                }
                try_stage2(
                    net, phi, &d_link, &c_node, &mut states, s, i, &mut queue, &mut messages,
                    t_c,
                );
            }
        }
    }

    let completion_time = queue.now();
    let mut dt_plus = vec![vec![0.0; n]; s_count];
    let mut dt_r = vec![vec![0.0; n]; s_count];
    let mut h_plus = vec![vec![0usize; n]; s_count];
    let mut h_minus = vec![vec![0usize; n]; s_count];
    for s in 0..s_count {
        for i in 0..n {
            dt_plus[s][i] = states[s][i]
                .dt_plus
                .unwrap_or_else(|| panic!("stage 1 incomplete at task {s} node {i}"));
            dt_r[s][i] = states[s][i]
                .dt_r
                .unwrap_or_else(|| panic!("stage 2 incomplete at task {s} node {i}"));
            h_plus[s][i] = states[s][i].h_plus;
            h_minus[s][i] = states[s][i].h_minus;
        }
    }

    ProtocolResult {
        dt_plus,
        dt_r,
        h_plus,
        h_minus,
        messages,
        completion_time,
    }
}

impl ProtocolResult {
    /// Max absolute deviation from a centralized marginal computation.
    pub fn max_deviation(&self, marg: &Marginals) -> f64 {
        let mut worst = 0.0f64;
        for (a_t, b_t) in self.dt_plus.iter().zip(&marg.dt_plus) {
            for (a, b) in a_t.iter().zip(b_t) {
                worst = worst.max((a - b).abs());
            }
        }
        for (a_t, b_t) in self.dt_r.iter().zip(&marg.dt_r) {
            for (a, b) in a_t.iter().zip(b_t) {
                worst = worst.max((a - b).abs());
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flows::compute_flows;
    use crate::model::marginals::compute_marginals;
    use crate::model::network::testnet::{diamond, line3};

    #[test]
    fn matches_centralized_on_diamond() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let flows = compute_flows(&net, &phi).unwrap();
        let marg = compute_marginals(&net, &phi, &flows).unwrap();
        let res = run_broadcast(&net, &phi, &flows, 1.0);
        assert!(
            res.max_deviation(&marg) < 1e-12,
            "deviation {}",
            res.max_deviation(&marg)
        );
        assert_eq!(res.h_plus, marg.h_plus);
        assert_eq!(res.h_minus, marg.h_minus);
    }

    #[test]
    fn matches_centralized_on_line3() {
        let net = line3();
        let phi = Strategy::local_compute_init(&net);
        let flows = compute_flows(&net, &phi).unwrap();
        let marg = compute_marginals(&net, &phi, &flows).unwrap();
        let res = run_broadcast(&net, &phi, &flows, 0.5);
        assert!(res.max_deviation(&marg) < 1e-12);
    }

    #[test]
    fn message_count_bound() {
        // ≤ 2 messages per (edge, task): one per stage, each node fires
        // each stage exactly once over all its in-edges.
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let flows = compute_flows(&net, &phi).unwrap();
        let res = run_broadcast(&net, &phi, &flows, 1.0);
        let bound = 2 * net.s() as u64 * net.e() as u64;
        assert!(
            res.messages <= bound,
            "{} messages > bound {bound}",
            res.messages
        );
        assert!(res.messages > 0);
    }

    #[test]
    fn completion_time_bound() {
        // Completion ≤ 2·(h̄+1)·t_c with h̄ the max hop count (paper §IV:
        // 2·h̄·t_c for the waves; +2 for the final informational broadcasts
        // of sink nodes).
        let net = diamond(true);
        let phi = Strategy::compute_at_dest_init(&net);
        let flows = compute_flows(&net, &phi).unwrap();
        let t_c = 1.0;
        let res = run_broadcast(&net, &phi, &flows, t_c);
        let marg = compute_marginals(&net, &phi, &flows).unwrap();
        let h_bar = marg
            .h_plus
            .iter()
            .chain(marg.h_minus.iter())
            .flat_map(|v| v.iter())
            .cloned()
            .max()
            .unwrap_or(0) as f64;
        assert!(
            res.completion_time <= 2.0 * (h_bar + 1.0) * t_c + 1e-9,
            "completion {} vs bound {}",
            res.completion_time,
            2.0 * (h_bar + 1.0) * t_c
        );
    }

    #[test]
    fn scales_latency_with_tc() {
        let net = line3();
        let phi = Strategy::local_compute_init(&net);
        let flows = compute_flows(&net, &phi).unwrap();
        let r1 = run_broadcast(&net, &phi, &flows, 1.0);
        let r2 = run_broadcast(&net, &phi, &flows, 2.0);
        assert!((r2.completion_time - 2.0 * r1.completion_time).abs() < 1e-9);
        assert_eq!(r1.messages, r2.messages);
    }
}
