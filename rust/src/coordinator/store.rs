//! Content-addressed store of converged strategies — the warm-start layer
//! (ROADMAP item 4b, "Exploiting Storage for Computing", arxiv 2401.03620).
//!
//! A [`StoredRun`] is the reusable residue of one cold solve: the
//! converged strategy plus the exact cost trajectory that produced it,
//! everything serialized bits-exact ([`Strategy::to_json`]). Entries are
//! addressed by a caller-built FNV key over the *pre-solve* identity of
//! the work (the cell-identity prefix of the sweep fingerprint: scenario,
//! seed, algorithm, backend, schedule, stopping rule, rate scale — see
//! `sweep::cell_store_key` and `dynamics::epoch_store_key`), because the
//! consult happens before any solving.
//!
//! Two implementations of [`StrategyStore`]:
//!
//! * [`MemStore`] — in-process, the default carrier between dynamic
//!   epochs (`AdaptiveRunner::run_epochs` rides it instead of its old
//!   bespoke `runs.last()` warm path);
//! * [`FsStore`] — one file per key under `--cache-dir`, shared by sweep
//!   shard children and surviving across sessions.
//!
//! **Failure contract:** a corrupt, truncated, tampered or wrong-key
//! entry is a counted *miss* with a stderr warning — never a panic and
//! never an error. **Determinism contract:** a hit is only *adopted*
//! after verification: the stored strategy is re-priced on the freshly
//! built network and must reproduce the stored cost bits exactly
//! ([`StoredRun::price_bits`]); an entry that fails re-pricing is
//! discarded and the cell re-runs cold, counted as a verification miss
//! (`sweep::run_cell`). Artifacts therefore keep fingerprint equality
//! whether the cache is cold, warm, or hostile.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::model::flows::compute_flows;
use crate::model::network::Network;
use crate::model::strategy::Strategy;
use crate::util::json::Json;

use super::exec::artifact::{parse_u64_hex, u64_hex};
use super::exec::GridHasher;

/// Format salt folded into every store key (via [`key_hasher`]): bumping
/// it orphans all existing entries when the entry layout changes, turning
/// a format migration into plain misses instead of parse warnings.
const STORE_FORMAT: &[u8] = b"cecflow-strategy-store-v1";

/// A [`GridHasher`] pre-seeded with the store format salt — the starting
/// point for every store key.
pub fn key_hasher() -> GridHasher {
    let mut h = GridHasher::new();
    h.eat(STORE_FORMAT);
    h
}

/// The stored residue of one converged cold solve.
#[derive(Clone, Debug)]
pub struct StoredRun {
    /// Label of the optimizer that produced the run (`"sgp"`,
    /// `"sgp-native"`, …) — informational; the key already pins it.
    pub algorithm: String,
    /// Exact per-iteration cost bits; `last()` is the final cost the
    /// adopting cell reports, `len()` the iteration count a verified hit
    /// avoids re-running.
    pub cost_bits: Vec<u64>,
    /// First iteration (1-based) within 1% of the final cost.
    pub iters_to_1pct: usize,
    /// The verification seal: `compute_flows(net, phi).total_cost` bits
    /// at save time. A consult re-prices the stored strategy on the
    /// freshly built network and must reproduce these bits exactly —
    /// re-pricing is a pure function of (network, strategy) bits, so an
    /// honest entry always verifies, while a stale or colliding one
    /// (which internal digests cannot catch) fails and falls back to a
    /// cold solve. This is deliberately *not* `cost_bits.last()`: the
    /// optimizer's in-step cost accounting need not be bit-identical to
    /// a fresh flow evaluation.
    pub price_bits: u64,
    /// The converged strategy (digest-sealed through serde).
    pub phi: Strategy,
}

impl StoredRun {
    pub fn iterations(&self) -> usize {
        self.cost_bits.len()
    }

    pub fn final_cost_bits(&self) -> u64 {
        *self.cost_bits.last().expect("entry validated non-empty")
    }

    pub fn final_cost(&self) -> f64 {
        f64::from_bits(self.final_cost_bits())
    }

    pub fn costs(&self) -> Vec<f64> {
        self.cost_bits.iter().map(|&b| f64::from_bits(b)).collect()
    }

    /// Re-pricing verification against a freshly built network: the
    /// stored strategy must fit the network's shape and re-pricing it
    /// must reproduce [`StoredRun::price_bits`] exactly. Pure in
    /// (network, strategy) bits, so an honest entry always verifies; a
    /// stale or key-colliding one fails and the caller re-runs cold.
    pub fn verifies_on(&self, net: &Network) -> bool {
        self.phi.matches(net)
            && compute_flows(net, &self.phi)
                .map(|f| f.total_cost.to_bits() == self.price_bits)
                .unwrap_or(false)
    }

    /// Capture a finished run: the cost trajectory (exact bits), the
    /// 1%-convergence marker, the re-pricing seal and the converged
    /// strategy.
    pub fn capture(
        algorithm: &str,
        costs: &[f64],
        iters_to_1pct: usize,
        price: f64,
        phi: &Strategy,
    ) -> StoredRun {
        assert!(!costs.is_empty(), "cannot store an empty run");
        StoredRun {
            algorithm: algorithm.to_string(),
            cost_bits: costs.iter().map(|c| c.to_bits()).collect(),
            iters_to_1pct,
            price_bits: price.to_bits(),
            phi: phi.clone(),
        }
    }

    /// FNV-1a seal over every field (including the strategy's own
    /// digest), embedded in the JSON form: editing *any* field of an
    /// entry on disk without re-forging this is detected on load.
    pub fn entry_digest(&self) -> u64 {
        let mut h = key_hasher();
        h.eat(self.algorithm.as_bytes());
        h.eat(&[0]);
        h.eat(&(self.iters_to_1pct as u64).to_le_bytes());
        for &b in &self.cost_bits {
            h.eat(&b.to_le_bytes());
        }
        h.eat(&self.price_bits.to_le_bytes());
        h.eat(&self.phi.digest().to_le_bytes());
        h.finish()
    }

    /// Serialize with the key stamped in, so an entry copied under another
    /// key's address is detected as tampering on load.
    pub fn to_json(&self, key: u64) -> Json {
        let mut o = Json::obj();
        o.set("key", Json::Str(u64_hex(key)))
            .set("algorithm", Json::Str(self.algorithm.clone()))
            .set("iters_to_1pct", Json::Num(self.iters_to_1pct as f64))
            .set(
                "cost_bits",
                Json::Arr(
                    self.cost_bits
                        .iter()
                        .map(|&b| Json::Str(u64_hex(b)))
                        .collect(),
                ),
            )
            .set("price_bits", Json::Str(u64_hex(self.price_bits)))
            .set("strategy", self.phi.to_json())
            .set("entry_digest", Json::Str(u64_hex(self.entry_digest())));
        o
    }

    /// Strict parse + integrity checks (the store impls downgrade any
    /// error here to a counted miss): key must match the address, the
    /// trajectory must be non-empty with a consistent 1% marker, the
    /// strategy digest must verify, and the whole-entry digest must
    /// match.
    pub fn from_json(doc: &Json, key: u64) -> Result<StoredRun> {
        let stored_key = doc
            .get("key")
            .as_str()
            .context("store entry missing key")?;
        let stored_key = parse_u64_hex(stored_key)?;
        anyhow::ensure!(
            stored_key == key,
            "store entry key {stored_key:016x} does not match its address {key:016x}"
        );
        let algorithm = doc
            .get("algorithm")
            .as_str()
            .context("store entry missing algorithm")?
            .to_string();
        let cost_bits = doc
            .get("cost_bits")
            .as_arr()
            .context("store entry missing cost_bits")?
            .iter()
            .map(|b| parse_u64_hex(b.as_str().context("non-string cost bits")?))
            .collect::<Result<Vec<u64>>>()?;
        anyhow::ensure!(!cost_bits.is_empty(), "store entry has an empty trajectory");
        let iters_to_1pct = doc
            .get("iters_to_1pct")
            .as_usize()
            .context("store entry missing iters_to_1pct")?;
        anyhow::ensure!(
            (1..=cost_bits.len()).contains(&iters_to_1pct),
            "store entry iters_to_1pct {iters_to_1pct} outside 1..={}",
            cost_bits.len()
        );
        let price_bits = parse_u64_hex(
            doc.get("price_bits")
                .as_str()
                .context("store entry missing price_bits")?,
        )?;
        let phi = Strategy::from_json(doc.get("strategy")).context("store entry strategy")?;
        let run = StoredRun {
            algorithm,
            cost_bits,
            iters_to_1pct,
            price_bits,
            phi,
        };
        let want = parse_u64_hex(
            doc.get("entry_digest")
                .as_str()
                .context("store entry missing entry_digest")?,
        )?;
        let got = run.entry_digest();
        anyhow::ensure!(
            got == want,
            "store entry digest mismatch: stored {want:016x}, recomputed {got:016x}"
        );
        Ok(run)
    }
}

/// A content-addressed strategy store. `load` returning `None` means
/// *miss* — absent, unreadable, or failed integrity checks (with a
/// warning); `save` is best-effort and never fails the run.
pub trait StrategyStore: Send + Sync {
    fn load(&self, key: u64) -> Option<StoredRun>;
    fn save(&self, key: u64, run: &StoredRun);
    /// Human-readable identity for logs ("memory", "dir /tmp/cache").
    fn describe(&self) -> String;
}

/// In-process store. Entries are kept *serialized* so `load` exercises
/// the exact same parse-and-verify path as [`FsStore`] — MemStore and
/// FsStore are observably identical modulo persistence.
#[derive(Default)]
pub struct MemStore {
    map: Mutex<HashMap<u64, String>>,
}

impl MemStore {
    pub fn new() -> MemStore {
        MemStore::default()
    }

    pub fn len(&self) -> usize {
        self.map.lock().expect("store mutex").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl StrategyStore for MemStore {
    fn load(&self, key: u64) -> Option<StoredRun> {
        let text = self.map.lock().expect("store mutex").get(&key).cloned()?;
        match Json::parse(&text)
            .map_err(anyhow::Error::from)
            .and_then(|doc| StoredRun::from_json(&doc, key))
        {
            Ok(run) => Some(run),
            Err(err) => {
                eprintln!(
                    "warning: strategy store: discarding in-memory entry {:016x}: {err:#}",
                    key
                );
                None
            }
        }
    }

    fn save(&self, key: u64, run: &StoredRun) {
        self.map
            .lock()
            .expect("store mutex")
            .insert(key, run.to_json(key).dump());
    }

    fn describe(&self) -> String {
        "memory".to_string()
    }
}

/// Filesystem store: one `<key-hex>.json` per entry under a directory
/// (the `--cache-dir` flag), shared by concurrent sweep shard children —
/// writes go through a rename so a reader never sees a half-written
/// entry, and two children racing on one key write identical bytes
/// (entries are deterministic), so either winner is correct.
pub struct FsStore {
    dir: PathBuf,
}

impl FsStore {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: &Path) -> Result<FsStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {dir:?}"))?;
        Ok(FsStore {
            dir: dir.to_path_buf(),
        })
    }

    fn entry_path(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.json"))
    }
}

impl StrategyStore for FsStore {
    fn load(&self, key: u64) -> Option<StoredRun> {
        let path = self.entry_path(key);
        let text = match std::fs::read_to_string(&path) {
            Ok(text) => text,
            Err(err) if err.kind() == std::io::ErrorKind::NotFound => return None,
            Err(err) => {
                eprintln!("warning: strategy store: cannot read {path:?}: {err}");
                return None;
            }
        };
        match Json::parse(&text)
            .map_err(anyhow::Error::from)
            .and_then(|doc| StoredRun::from_json(&doc, key))
        {
            Ok(run) => Some(run),
            Err(err) => {
                eprintln!("warning: strategy store: discarding {path:?}: {err:#}");
                None
            }
        }
    }

    fn save(&self, key: u64, run: &StoredRun) {
        let path = self.entry_path(key);
        let tmp = self.dir.join(format!("{key:016x}.tmp.{}", std::process::id()));
        let text = run.to_json(key).pretty();
        let result = std::fs::write(&tmp, &text).and_then(|()| std::fs::rename(&tmp, &path));
        if let Err(err) = result {
            let _ = std::fs::remove_file(&tmp);
            eprintln!("warning: strategy store: cannot persist {path:?}: {err}");
        }
    }

    fn describe(&self) -> String {
        format!("dir {}", self.dir.display())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::network::testnet::diamond;

    fn sample_run() -> StoredRun {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        StoredRun::capture("sgp", &[12.5, 11.0 + 1e-13, 10.75], 2, 10.75 + 1e-13, &phi)
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "cecflow-store-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn entry_roundtrip_is_bitwise() {
        let run = sample_run();
        let back = StoredRun::from_json(&run.to_json(7), 7).unwrap();
        assert_eq!(back.cost_bits, run.cost_bits);
        assert_eq!(back.iters_to_1pct, run.iters_to_1pct);
        assert_eq!(back.algorithm, run.algorithm);
        assert_eq!(back.phi, run.phi);
        assert_eq!(back.price_bits, run.price_bits);
        assert_eq!(back.final_cost_bits(), 10.75f64.to_bits());
        assert_eq!(back.iterations(), 3);
    }

    #[test]
    fn entry_rejects_key_and_shape_tampering() {
        let run = sample_run();
        // copied under another address
        let err = StoredRun::from_json(&run.to_json(7), 8).unwrap_err().to_string();
        assert!(err.contains("does not match"), "{err}");
        // truncated trajectory
        let mut doc = run.to_json(7);
        doc.set("cost_bits", Json::Arr(Vec::new()));
        assert!(StoredRun::from_json(&doc, 7).is_err());
        // 1% marker outside the trajectory
        let mut doc = run.to_json(7);
        doc.set("iters_to_1pct", Json::Num(99.0));
        assert!(StoredRun::from_json(&doc, 7).is_err());
        // edited trajectory bits behind an unchanged entry digest
        let mut doc = run.to_json(7);
        let mut forged = run.clone();
        forged.cost_bits[0] ^= 1;
        doc.set(
            "cost_bits",
            forged.to_json(7).get("cost_bits").clone(),
        );
        let err = StoredRun::from_json(&doc, 7).unwrap_err().to_string();
        assert!(err.contains("entry digest mismatch"), "{err}");
    }

    #[test]
    fn mem_store_roundtrip_and_miss() {
        let store = MemStore::new();
        assert!(store.load(1).is_none());
        let run = sample_run();
        store.save(1, &run);
        assert_eq!(store.len(), 1);
        let back = store.load(1).expect("hit");
        assert_eq!(back.cost_bits, run.cost_bits);
        assert_eq!(back.phi, run.phi);
        assert!(store.load(2).is_none());
    }

    #[test]
    fn fs_store_roundtrip_and_corruption_misses() {
        let dir = tmp_dir("corrupt");
        let store = FsStore::open(&dir).unwrap();
        assert!(store.describe().contains("dir"));
        let run = sample_run();
        store.save(3, &run);
        assert_eq!(store.load(3).expect("hit").cost_bits, run.cost_bits);

        // truncated entry → miss, not a panic
        let path = dir.join(format!("{:016x}.json", 3u64));
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        assert!(store.load(3).is_none());

        // garbage entry → miss
        std::fs::write(&path, "not json at all").unwrap();
        assert!(store.load(3).is_none());

        // an entry renamed to another key's address → key-mismatch miss
        store.save(4, &run);
        std::fs::copy(dir.join(format!("{:016x}.json", 4u64)), &path).unwrap();
        assert!(store.load(3).is_none());
        assert!(store.load(4).is_some(), "the honest entry still hits");

        // flipped strategy bits behind an unchanged digest → miss
        let path4 = dir.join(format!("{:016x}.json", 4u64));
        let tampered = std::fs::read_to_string(&path4)
            .unwrap()
            .replacen("3ff0000000000000", "3ff0000000000001", 1);
        std::fs::write(&path4, tampered).unwrap();
        assert!(store.load(4).is_none());

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn verification_demands_the_exact_price_bits() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let price = compute_flows(&net, &phi).unwrap().total_cost;
        let good = StoredRun::capture("sgp", &[price], 1, price, &phi);
        assert!(good.verifies_on(&net));
        // one flipped price bit → the seal breaks
        let mut bad = good.clone();
        bad.price_bits ^= 1;
        assert!(!bad.verifies_on(&net));
        // same shape, different cost surface (linear vs queue) → the
        // re-priced bits differ and the stale entry is rejected
        let other = diamond(false);
        assert!(good.phi.matches(&other), "test needs a shape-compatible net");
        assert!(!good.verifies_on(&other));
    }

    #[test]
    fn key_hasher_is_salted_and_deterministic() {
        let k = |bytes: &[u8]| {
            let mut h = key_hasher();
            h.eat(bytes);
            h.finish()
        };
        assert_eq!(k(b"abc"), k(b"abc"));
        assert_ne!(k(b"abc"), k(b"abd"));
        // the salt moves keys away from a bare FNV of the same bytes
        let mut bare = GridHasher::new();
        bare.eat(b"abc");
        assert_ne!(k(b"abc"), bare.finish());
    }
}
