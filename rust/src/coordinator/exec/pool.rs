//! Pool layer of the execution engine: the panic-safe in-process worker
//! pool that runs a grid's indexed cells on `std::thread` workers.
//!
//! Workers pull cells from an atomic cursor (in-process work stealing),
//! which keeps long cells from serializing behind a static partition, and
//! write results into per-cell slots so the output order is the global
//! index order regardless of which worker ran what.
//!
//! Failure discipline: the first failing cell raises a flag that stops
//! workers from *claiming* further cells (a typo'd scenario name must not
//! make the user wait out the healthy cells), and the whole run returns
//! that cell's error with the cell named via [`GridCell::describe`]. A
//! **panicking** cell cannot deadlock or poison the pool: the panic is
//! caught at the cell boundary and surfaced as that cell's error (so
//! `std::thread::scope` joins normally), and slot mutexes are read
//! through `PoisonError::into_inner` so even a poisoned lock yields its
//! data. Pinned by this module's injected-panic test and the sweep's
//! determinism suites.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::grid::GridCell;

/// Render a panic payload as text for the cell's error message.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `cells` (global index + payload) on up to `workers` threads
/// (clamped to `[1, #cells]`), calling `runner` per cell and `on_result`
/// (from worker threads) as each cell finishes — the shard-worker
/// streaming hook. Results come back in global-index order.
pub fn run_cells<C, R, F>(
    cells: &[(usize, C)],
    workers: usize,
    runner: F,
    on_result: Option<&(dyn Fn(&R) + Sync)>,
) -> Result<Vec<R>>
where
    C: GridCell,
    R: Send,
    F: Fn(usize, &C) -> Result<R> + Sync,
{
    anyhow::ensure!(!cells.is_empty(), "empty grid: no cells to run");
    let workers = workers.clamp(1, cells.len());

    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    // one slot per cell; the Option<Result<R>> type is left to inference
    let slots: Vec<_> = (0..cells.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= cells.len() {
                    break;
                }
                let (index, cell) = &cells[k];
                let res = std::panic::catch_unwind(AssertUnwindSafe(|| runner(*index, cell)))
                    .unwrap_or_else(|payload| {
                        Err(anyhow::anyhow!(
                            "cell panicked: {}",
                            panic_message(payload.as_ref())
                        ))
                    });
                match &res {
                    Ok(r) => {
                        if let Some(cb) = on_result {
                            cb(r);
                        }
                    }
                    Err(_) => failed.store(true, Ordering::Relaxed),
                }
                *slots[k].lock().unwrap_or_else(|p| p.into_inner()) = Some(res);
            });
        }
    });

    // The cursor hands out cells in order, so unclaimed (None) slots can
    // only sit *after* every claimed one — the first error is always
    // reached before any cancellation gap.
    let mut out = Vec::with_capacity(cells.len());
    let mut skipped: Option<usize> = None;
    for (k, slot) in slots.into_iter().enumerate() {
        match slot.into_inner().unwrap_or_else(|p| p.into_inner()) {
            Some(res) => {
                out.push(res.with_context(|| cells[k].1.describe(cells[k].0))?);
            }
            None => skipped = skipped.or(Some(k)),
        }
    }
    if let Some(k) = skipped {
        bail!(
            "run aborted early ({} never ran) without a reported error",
            cells[k].1.describe(cells[k].0)
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::grid::{GridCell, GridHasher};
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[derive(Clone, Debug)]
    struct TestCell(usize);

    impl GridCell for TestCell {
        fn describe(&self, index: usize) -> String {
            format!("pool cell {index}")
        }
        fn write_identity(&self, h: &mut GridHasher) {
            h.eat(&self.0.to_le_bytes());
        }
    }

    fn cells(n: usize) -> Vec<(usize, TestCell)> {
        (0..n).map(|i| (i, TestCell(i))).collect()
    }

    #[test]
    fn results_come_back_in_index_order_on_any_worker_count() {
        for workers in [1usize, 2, 7] {
            let out = run_cells(&cells(9), workers, |i, c| Ok(i * 10 + c.0), None).unwrap();
            assert_eq!(out, (0..9).map(|i| i * 11).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_grid_is_rejected() {
        let none: Vec<(usize, TestCell)> = Vec::new();
        assert!(run_cells(&none, 2, |_, _| Ok(0usize), None).is_err());
    }

    #[test]
    fn first_failure_cancels_and_names_the_cell() {
        let ran = AtomicUsize::new(0);
        let err = run_cells(
            &cells(64),
            1,
            |i, _| {
                ran.fetch_add(1, Ordering::Relaxed);
                anyhow::ensure!(i != 3, "boom at {i}");
                Ok(i)
            },
            None,
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("boom at 3"), "{msg}");
        assert!(msg.contains("pool cell 3"), "{msg}");
        assert!(
            ran.load(Ordering::Relaxed) < 64,
            "failure did not cancel the remaining cells"
        );
    }

    #[test]
    fn panicking_cell_fails_cleanly_without_deadlock() {
        let err = run_cells(
            &cells(4),
            2,
            |i, _| {
                if i == 1 {
                    panic!("injected cell panic");
                }
                Ok(i)
            },
            None,
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected cell panic"), "{msg}");
        assert!(msg.contains("pool cell 1"), "{msg}");
    }

    #[test]
    fn on_result_streams_every_finished_cell() {
        let seen = Mutex::new(Vec::new());
        let hook = |r: &usize| seen.lock().unwrap().push(*r);
        run_cells(&cells(5), 2, |i, _| Ok(i), Some(&hook)).unwrap();
        let mut got = seen.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }
}
