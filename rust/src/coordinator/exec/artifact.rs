//! Artifact layer of the execution engine: shard reports as first-class
//! JSON files — serialization, loading, and index- and hash-verified
//! merge.
//!
//! A shard that ran with `--shard i/n --out f.json` leaves behind an
//! artifact: its items (each tagged with a global grid index), the worker
//! budget it used, and the [`super::grid::Grid::identity_hash`] of the grid it was
//! cut from. Merging artifacts back into the full-grid report enforces
//! two invariants:
//!
//! * **hash-verified** — every part with a known (nonzero) grid hash must
//!   carry the *same* hash; shards of different grids with same-sized
//!   index ranges would otherwise interleave silently.
//! * **index-verified** — the merged indices must form exactly
//!   `0..total`: a duplicate global index (an overlapping shard split) or
//!   a gap (a missing shard) is a contextful error naming the colliding
//!   or missing index. Duplicates are rejected at *load* time too — a
//!   single corrupt artifact must not survive to a merge that happens to
//!   cover the grid.
//!
//! Items are free to ship more than their fingerprint: a store-enabled
//! sweep cell ([`crate::coordinator::store`]) carries its converged
//! strategy and cache outcome through the artifact, exactly-bits like
//! everything else. The strategy-store configuration folds into the grid
//! hash as an enabled bit, so cached and uncached shard artifacts refuse
//! to merge via the hash check above.
//!
//! Exact-bits helpers ([`f64_bits_hex`] / [`parse_f64_bits_hex`]) live
//! here because every artifact and protocol writer needs them: JSON
//! numbers cannot carry `±∞` and decimal round-trips are not part of the
//! determinism contract, so costs travel as hex-encoded IEEE-754 bits.

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// An artifact item: one per-cell result that knows its global grid
/// index, can name itself in errors, and round-trips through JSON.
pub trait ArtifactItem: Sized {
    /// Global grid index of the cell this item came from.
    fn index(&self) -> usize;
    /// Human-readable identity for merge errors.
    fn describe(&self) -> String;
    /// Machine-readable record (must include enough to re-[`from_json`]).
    ///
    /// [`from_json`]: ArtifactItem::from_json
    fn to_json(&self) -> Json;
    /// Parse a record produced by [`ArtifactItem::to_json`].
    fn from_json(doc: &Json) -> Result<Self>;
}

/// A loaded shard artifact (or a full report): items sorted by global
/// index plus the worker/grid-identity metadata.
#[derive(Clone, Debug)]
pub struct Artifact<T> {
    pub items: Vec<T>,
    /// Worker threads used (metadata only, excluded from fingerprints).
    pub workers: usize,
    /// Identity of the generating grid; `0` when unknown (hand-built
    /// artifacts), in which case merge skips the hash check for this part.
    pub grid_hash: u64,
}

impl<T: ArtifactItem> Artifact<T> {
    /// Serialize: `{workers, grid_hash, cells: […]}` — the shape every
    /// report artifact shares (callers may add derived sections on top).
    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("workers", Json::Num(self.workers as f64))
            // hex string: u64 hashes exceed f64's exact-integer range
            .set("grid_hash", Json::Str(u64_hex(self.grid_hash)))
            .set(
                "cells",
                Json::Arr(self.items.iter().map(ArtifactItem::to_json).collect()),
            );
        doc
    }

    /// Parse an artifact written by [`Artifact::to_json`]. Items are
    /// re-sorted by global index; a duplicate index inside one artifact
    /// is rejected here, naming the colliding index — first-write-wins
    /// loading could otherwise mask an overlapping shard split.
    pub fn from_json(doc: &Json) -> Result<Artifact<T>> {
        let cells_json = doc
            .get("cells")
            .as_arr()
            .context("report artifact missing cells array")?;
        let mut items = cells_json
            .iter()
            .enumerate()
            .map(|(k, c)| T::from_json(c).with_context(|| format!("cell record {k}")))
            .collect::<Result<Vec<_>>>()?;
        items.sort_by_key(ArtifactItem::index);
        for pair in items.windows(2) {
            if pair[0].index() == pair[1].index() {
                bail!(
                    "artifact contains global cell index {} twice ({}) — overlapping or \
                     corrupt shard output",
                    pair[0].index(),
                    pair[0].describe()
                );
            }
        }
        let grid_hash = match doc.get("grid_hash").as_str() {
            Some(hex) => parse_u64_hex(hex).with_context(|| format!("bad grid_hash '{hex}'"))?,
            None => 0,
        };
        Ok(Artifact {
            items,
            workers: doc.get("workers").as_usize().unwrap_or(0),
            grid_hash,
        })
    }

    /// Merge shard artifacts back into one full-grid artifact: every part
    /// must carry the same nonzero grid hash (or none), and the combined
    /// indices must form exactly `0..total` — duplicates and gaps are
    /// contextful errors naming the index.
    pub fn merge(parts: Vec<Artifact<T>>) -> Result<Artifact<T>> {
        let mut grid_hash = 0u64;
        for p in &parts {
            if p.grid_hash == 0 {
                continue; // hand-built artifact: no identity to check
            }
            if grid_hash == 0 {
                grid_hash = p.grid_hash;
            } else if p.grid_hash != grid_hash {
                bail!(
                    "shard merge: reports come from different sweep specs \
                     (grid hash {} vs {})",
                    u64_hex(grid_hash),
                    u64_hex(p.grid_hash)
                );
            }
        }
        let workers = parts.iter().map(|p| p.workers).sum::<usize>().max(1);
        let mut items: Vec<T> = parts.into_iter().flat_map(|p| p.items).collect();
        anyhow::ensure!(!items.is_empty(), "merging empty shard reports");
        items.sort_by_key(ArtifactItem::index);
        for (k, item) in items.iter().enumerate() {
            if item.index() != k {
                if item.index() < k {
                    bail!(
                        "shard merge: duplicate result for global cell index {} ({})",
                        item.index(),
                        item.describe()
                    );
                }
                bail!(
                    "shard merge: missing cell index {k} — the shard reports do not cover \
                     the whole grid"
                );
            }
        }
        Ok(Artifact {
            items,
            workers,
            grid_hash,
        })
    }
}

/// Exact-bits hex encoding of an f64 (16 lowercase hex digits).
pub fn f64_bits_hex(x: f64) -> String {
    u64_hex(x.to_bits())
}

/// Decode [`f64_bits_hex`].
pub fn parse_f64_bits_hex(hex: &str) -> Result<f64> {
    Ok(f64::from_bits(parse_u64_hex(hex)?))
}

/// 16-digit lowercase hex encoding of a u64 (grid hashes, cost bits).
pub fn u64_hex(x: u64) -> String {
    format!("{x:016x}")
}

/// Decode [`u64_hex`].
pub fn parse_u64_hex(hex: &str) -> Result<u64> {
    u64::from_str_radix(hex, 16).with_context(|| format!("bad hex u64 '{hex}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Debug, PartialEq)]
    struct Item {
        index: usize,
        cost: f64,
    }

    impl ArtifactItem for Item {
        fn index(&self) -> usize {
            self.index
        }
        fn describe(&self) -> String {
            format!("item {}", self.index)
        }
        fn to_json(&self) -> Json {
            let mut o = Json::obj();
            o.set("index", Json::Num(self.index as f64))
                .set("cost_bits", Json::Str(f64_bits_hex(self.cost)));
            o
        }
        fn from_json(doc: &Json) -> Result<Item> {
            Ok(Item {
                index: doc.get("index").as_usize().context("missing index")?,
                cost: parse_f64_bits_hex(
                    doc.get("cost_bits").as_str().context("missing cost_bits")?,
                )?,
            })
        }
    }

    fn art(indices: &[usize]) -> Artifact<Item> {
        Artifact {
            items: indices
                .iter()
                .map(|&i| Item {
                    index: i,
                    cost: i as f64 + 0.5,
                })
                .collect(),
            workers: 1,
            grid_hash: 0xfeed,
        }
    }

    #[test]
    fn bits_roundtrip_including_infinity() {
        for x in [1.5, f64::INFINITY, f64::NEG_INFINITY, -0.0, 123.456_789] {
            assert_eq!(
                parse_f64_bits_hex(&f64_bits_hex(x)).unwrap().to_bits(),
                x.to_bits()
            );
        }
        assert!(parse_f64_bits_hex("zz").is_err());
    }

    #[test]
    fn artifact_json_roundtrips_and_sorts() {
        let a = art(&[2, 0, 1]);
        let back = Artifact::<Item>::from_json(&Json::parse(&a.to_json().pretty()).unwrap())
            .unwrap();
        assert_eq!(
            back.items.iter().map(|i| i.index).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert_eq!(back.grid_hash, 0xfeed);
        assert_eq!(back.workers, 1);
    }

    #[test]
    fn loading_rejects_duplicate_indices_naming_the_index() {
        let a = art(&[0, 1, 1]);
        let err = Artifact::<Item>::from_json(&Json::parse(&a.to_json().pretty()).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("index 1 twice"), "{err}");
    }

    #[test]
    fn merge_verifies_coverage_and_hashes() {
        // clean merge
        let merged = Artifact::merge(vec![art(&[0, 2]), art(&[1, 3])]).unwrap();
        assert_eq!(merged.items.len(), 4);
        assert_eq!(merged.workers, 2);
        // duplicate across parts names the colliding index
        let err = Artifact::merge(vec![art(&[0, 1]), art(&[1, 2])])
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate"), "{err}");
        assert!(err.contains("index 1"), "{err}");
        // gap
        let err = Artifact::merge(vec![art(&[0]), art(&[2])])
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing cell index 1"), "{err}");
        // different grids refuse
        let mut other = art(&[1]);
        other.grid_hash = 0xbeef;
        let err = Artifact::merge(vec![art(&[0]), other])
            .unwrap_err()
            .to_string();
        assert!(err.contains("different sweep specs"), "{err}");
        // unknown-hash parts merge with known-hash ones
        let mut unknown = art(&[1]);
        unknown.grid_hash = 0;
        let merged = Artifact::merge(vec![art(&[0]), unknown]).unwrap();
        assert_eq!(merged.grid_hash, 0xfeed);
    }
}
