//! The layered execution engine behind every grid runner (`cecflow
//! sweep`, `cecflow dynamic`, the benches): generic machinery for running
//! an indexed cell grid on worker threads and child processes and for
//! reassembling the results into verified artifacts.
//!
//! The layers, bottom to top — each generic over the cell payload, so
//! grid *definitions* ([`super::sweep`], [`super::dynamics`]) stay thin:
//!
//! * [`grid`] — the [`grid::Grid`]/[`grid::GridCell`] abstraction: index
//!   assignment, human naming, identity hashing, shard striding.
//! * [`pool`] — the panic-safe in-process worker pool
//!   ([`pool::run_cells`]): atomic-cursor work stealing across
//!   `std::thread` workers, first-failure cancellation.
//! * [`shard`] — child-process execution ([`shard::run_sharded`]): the
//!   JSON-lines stdout protocol, strided `--shard-worker i/n` children,
//!   timeouts, and bounded shard retry + work re-stealing
//!   (`--shard-retries`, `--steal-cells`).
//! * [`artifact`] — shard reports as files ([`artifact::Artifact`]):
//!   index- and hash-verified load and merge, exact-bits f64 transport.
//!
//! Determinism is the engine-wide contract: a cell is a pure function of
//! its grid identity, results carry their global index, and every
//! execution shape (worker counts, shard counts, mid-run kills with
//! re-stealing) reassembles the same fingerprint.

pub mod artifact;
pub mod grid;
pub mod pool;
pub mod shard;

pub use artifact::{Artifact, ArtifactItem};
pub use grid::{Grid, GridCell, GridHasher};
pub use shard::{ShardDriver, ShardLine, ShardOptions};
