//! Shard layer of the execution engine: child-process execution of a
//! grid, with bounded shard retry and work re-stealing.
//!
//! A sharded run splits the grid over `n` child processes of the same
//! binary. Shard `k` (1-based on the CLI) owns the strided index set of
//! [`super::grid::shard_indices`]; each child re-parses the same spec
//! flags ([`ShardDriver::child_args`]) and speaks a JSON-lines protocol
//! on stdout: one `{"type":"cell",…}` object per finished cell (global
//! index + exact result bits), a final `{"type":"done",…}`, or
//! `{"type":"error",…}` on failure. The parent reassembles results by
//! global index, so a sharded run is fingerprint-identical to the
//! in-process run of the same grid.
//!
//! ## Retry and work re-stealing
//!
//! Child failure is no longer fatal by default. When a child dies — it
//! reports an error cell, exits nonzero, gets killed mid-stream, or
//! speaks garbage — the parent computes its **orphans** (assigned cells
//! with no result yet; results streamed before the death are kept) and,
//! while the shard's [`ShardOptions::retries`] budget lasts, re-queues
//! them onto a fresh *steal-worker*: a respawned child running
//! `--steal-cells i,j,…` alongside the surviving shards. Only when the
//! budget is exhausted does the failure surface, naming the first
//! unfinished cell. Because every cell is a pure function of its grid
//! identity, a re-stolen run is bit-identical to the one that died —
//! the merged report's fingerprint matches the single-process run even
//! after a mid-sweep kill (pinned by `rust/tests/sweep_shard.rs` and the
//! `retry-smoke` CI job). `--shard-retries 0` restores fail-fast.

use std::io::BufRead;
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// How a grid plugs into the sharded orchestrator: the full cell list for
/// validation and error naming, the argv a child needs to rebuild the
/// same grid, and the payload-specific half of the stdout protocol.
pub trait ShardDriver: Sync {
    /// One finished cell's result as carried by the protocol.
    type Item: Send;

    /// Human noun for error messages (e.g. "sweep").
    fn label(&self) -> &str {
        "grid"
    }

    /// Total number of cells in the grid.
    fn total(&self) -> usize;

    /// Human-readable identity of cell `index` for error contexts.
    fn describe(&self, index: usize) -> String;

    /// Argv (subcommand + spec flags) a child of the same binary needs to
    /// rebuild an identical grid; the engine appends the shard-mode and
    /// worker flags.
    fn child_args(&self) -> Vec<String>;

    /// Parse a `"type":"cell"` protocol object into its global index and
    /// item, verifying the payload identity against the grid (a result
    /// for a cell not in this grid is an error).
    fn parse_cell(&self, doc: &Json) -> Result<(usize, Self::Item)>;
}

/// One parsed line of the shard-worker stdout protocol.
#[derive(Clone, Debug)]
pub enum ShardLine<T> {
    /// A finished cell, tagged with its global index.
    Cell { index: usize, item: T },
    /// Shard finished cleanly after reporting `cells` results.
    Done { shard: usize, cells: usize },
    /// Shard failed; the parent surfaces `message` (after retries).
    Error { message: String },
}

/// Serialize the shard-completed protocol line (`shard` 0-based).
pub fn done_line(shard: usize, cells: usize) -> String {
    let mut o = Json::obj();
    o.set("type", Json::Str("done".to_string()))
        .set("shard", Json::Num(shard as f64))
        .set("cells", Json::Num(cells as f64));
    o.dump()
}

/// Serialize the shard-failed protocol line.
pub fn error_line(message: &str) -> String {
    let mut o = Json::obj();
    o.set("type", Json::Str("error".to_string()))
        .set("message", Json::Str(message.to_string()));
    o.dump()
}

/// Parse one protocol line; `"cell"` payloads go through
/// [`ShardDriver::parse_cell`].
pub fn parse_line<D: ShardDriver + ?Sized>(driver: &D, line: &str) -> Result<ShardLine<D::Item>> {
    let doc = Json::parse(line).with_context(|| format!("bad shard protocol line: {line}"))?;
    match doc.get("type").as_str() {
        Some("cell") => {
            let (index, item) = driver.parse_cell(&doc)?;
            Ok(ShardLine::Cell { index, item })
        }
        Some("done") => Ok(ShardLine::Done {
            shard: doc.get("shard").as_usize().unwrap_or(0),
            cells: doc.get("cells").as_usize().unwrap_or(0),
        }),
        Some("error") => Ok(ShardLine::Error {
            message: doc
                .get("message")
                .as_str()
                .unwrap_or("unknown shard error")
                .to_string(),
        }),
        other => bail!("unknown shard protocol line type {other:?} in: {line}"),
    }
}

/// Parse a `--shard i/n` / `--shard-worker i/n` argument (`i` 1-based on
/// the CLI). Returns the 0-based shard index and the shard count.
pub fn parse_shard_arg(s: &str) -> Result<(usize, usize)> {
    let (i, n) = s
        .split_once('/')
        .with_context(|| format!("--shard expects i/n (e.g. 1/4), got '{s}'"))?;
    let i: usize = i
        .trim()
        .parse()
        .with_context(|| format!("bad shard index '{i}'"))?;
    let n: usize = n
        .trim()
        .parse()
        .with_context(|| format!("bad shard count '{n}'"))?;
    anyhow::ensure!(n >= 1, "shard count must be at least 1");
    anyhow::ensure!((1..=n).contains(&i), "shard index {i} out of range 1..={n}");
    Ok((i - 1, n))
}

/// Parse a `--steal-cells i,j,…` argument: the explicit global cell
/// indices a steal-worker re-runs.
pub fn parse_cell_list(s: &str) -> Result<Vec<usize>> {
    let out: Vec<usize> = s
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<usize>()
                .with_context(|| format!("bad cell index '{t}' in --steal-cells"))
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(!out.is_empty(), "--steal-cells needs at least one cell index");
    Ok(out)
}

/// Options for [`run_sharded`].
#[derive(Clone, Debug)]
pub struct ShardOptions {
    /// Number of child processes (clamped to `[1, #cells]`).
    pub shards: usize,
    /// Total worker-thread budget, divided evenly across children.
    pub workers: usize,
    /// Overall deadline for the whole sharded run; `None` waits forever.
    /// On expiry every child is killed and the error names the first cell
    /// still outstanding (no re-steal past the deadline).
    pub timeout: Option<Duration>,
    /// Re-steal budget **per shard**: how many times a failed child's
    /// unfinished cells may be re-queued onto a fresh steal-worker before
    /// the failure becomes the run's error. `0` restores fail-fast.
    pub retries: usize,
    /// Extra environment for spawned children — the failure-injection
    /// hooks of the retry tests (`CECFLOW_FAIL_SHARD`) ride here so test
    /// processes never mutate their own global environment.
    pub extra_env: Vec<(String, String)>,
}

impl Default for ShardOptions {
    fn default() -> ShardOptions {
        ShardOptions {
            shards: 1,
            workers: 1,
            timeout: None,
            retries: 1,
            extra_env: Vec::new(),
        }
    }
}

/// Book-keeping for one spawned child (original shard or steal-worker).
struct Worker {
    child: Child,
    /// Original 0-based shard whose retry budget this worker draws on.
    shard: usize,
    /// Global cell indices this worker was asked to run.
    assigned: Vec<usize>,
    /// Saw the `done` protocol line.
    done: bool,
    /// First failure observed on this worker; once set, its further
    /// output is ignored (a garbage-speaking child stays garbage).
    failure: Option<String>,
}

enum Event {
    Line(usize, String),
    ReadError(usize, String),
    Eof(usize),
}

fn kill_all(workers: &mut [Worker]) {
    for w in workers.iter_mut() {
        let _ = w.child.kill();
        let _ = w.child.wait();
    }
}

/// Wait for one child, bounded by the run's overall deadline: past the
/// deadline the child is killed and an error returned, so
/// [`ShardOptions::timeout`] holds even for a child that wedges *after*
/// closing its stdout (the protocol loop can no longer observe it).
fn wait_with_deadline(
    child: &mut Child,
    deadline: Option<Instant>,
) -> Result<std::process::ExitStatus> {
    loop {
        if let Some(status) = child.try_wait().context("polling child status")? {
            return Ok(status);
        }
        if deadline.is_some_and(|d| Instant::now() >= d) {
            let _ = child.kill();
            let _ = child.wait();
            bail!("child did not exit before the run deadline");
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Run `driver`'s grid sharded across `opts.shards` child processes of
/// the binary at `exe`, with bounded retry + work re-stealing (see the
/// module docs). Returns the per-cell items in global-index order.
pub fn run_sharded<D: ShardDriver>(
    driver: &D,
    exe: &Path,
    opts: &ShardOptions,
) -> Result<Vec<D::Item>> {
    let total = driver.total();
    anyhow::ensure!(total > 0, "empty grid: no cells to run");
    let shards = opts.shards.clamp(1, total);
    let child_workers = (opts.workers / shards).max(1);
    let label = driver.label().to_string();

    let (tx, rx) = mpsc::channel::<Event>();
    let mut workers: Vec<Worker> = Vec::with_capacity(shards);
    // per-shard re-steal budget already spent
    let mut attempts = vec![0usize; shards];
    let mut slots: Vec<Option<D::Item>> = std::iter::repeat_with(|| None).take(total).collect();

    let spawn = |id: usize,
                 shard: usize,
                 mode_args: &[String],
                 assigned: Vec<usize>,
                 tx: &mpsc::Sender<Event>|
     -> Result<Worker> {
        let mut cmd = Command::new(exe);
        cmd.args(driver.child_args())
            .args(mode_args)
            .arg("--workers")
            .arg(child_workers.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit());
        for (k, v) in &opts.extra_env {
            cmd.env(k, v);
        }
        let mut child = cmd.spawn().with_context(|| {
            format!(
                "spawning {label} shard {}/{shards} ({})",
                shard + 1,
                exe.display()
            )
        })?;
        let stdout = child.stdout.take().expect("stdout was piped");
        let tx = tx.clone();
        std::thread::spawn(move || {
            for line in std::io::BufReader::new(stdout).lines() {
                match line {
                    Ok(l) => {
                        if tx.send(Event::Line(id, l)).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        // report the failure, then fall through to the Eof
                        // send — the orchestrator's live-count and retry
                        // bookkeeping only run at Eof, so a reader that
                        // stopped without one would hang the whole run
                        let _ = tx.send(Event::ReadError(id, e.to_string()));
                        break;
                    }
                }
            }
            let _ = tx.send(Event::Eof(id));
        });
        Ok(Worker {
            child,
            shard,
            assigned,
            done: false,
            failure: None,
        })
    };

    for shard in 0..shards {
        let assigned = super::grid::shard_indices(total, shard, shards);
        let mode = vec![
            "--shard-worker".to_string(),
            format!("{}/{shards}", shard + 1),
        ];
        let w = spawn(shard, shard, &mode, assigned, &tx)?;
        workers.push(w);
    }

    let deadline = opts.timeout.map(|t| Instant::now() + t);
    let mut live = workers.len();
    while live > 0 {
        let timed_out = |slots: &[Option<D::Item>], workers: &mut [Worker]| {
            let missing = slots.iter().position(|s| s.is_none());
            kill_all(workers);
            let what = missing
                .map(|i| format!(" waiting for {}", driver.describe(i)))
                .unwrap_or_default();
            anyhow::anyhow!(
                "sharded {label} timed out after {:.1}s{what}",
                opts.timeout.unwrap_or_default().as_secs_f64()
            )
        };
        let ev = if let Some(d) = deadline {
            match d.checked_duration_since(Instant::now()) {
                None => return Err(timed_out(&slots, &mut workers)),
                Some(left) => match rx.recv_timeout(left) {
                    Ok(ev) => ev,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        return Err(timed_out(&slots, &mut workers))
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                },
            }
        } else {
            match rx.recv() {
                Ok(ev) => ev,
                Err(_) => break,
            }
        };
        // Mark a worker failed and kill it; the retry-or-surface decision
        // happens at its EOF, once every result it did stream is in.
        let fail_worker = |workers: &mut [Worker], id: usize, msg: String| {
            if workers[id].failure.is_none() {
                workers[id].failure = Some(msg);
            }
            let _ = workers[id].child.kill();
        };
        match ev {
            Event::Line(id, line) => {
                if workers[id].failure.is_some() || line.trim().is_empty() {
                    continue;
                }
                match parse_line(driver, &line) {
                    Err(e) => fail_worker(
                        &mut workers,
                        id,
                        format!("{:#}", e.context("spoke garbage on stdout")),
                    ),
                    Ok(ShardLine::Cell { index, item }) => {
                        if index >= slots.len() {
                            fail_worker(
                                &mut workers,
                                id,
                                format!(
                                    "reported cell index {index} outside the {total}-cell grid"
                                ),
                            );
                        } else if slots[index].is_some() {
                            fail_worker(
                                &mut workers,
                                id,
                                format!("reported {} twice", driver.describe(index)),
                            );
                        } else {
                            slots[index] = Some(item);
                        }
                    }
                    Ok(ShardLine::Error { message }) => fail_worker(&mut workers, id, message),
                    Ok(ShardLine::Done { .. }) => workers[id].done = true,
                }
            }
            Event::ReadError(id, msg) => {
                fail_worker(&mut workers, id, format!("reading its results: {msg}"));
            }
            Event::Eof(id) => {
                live -= 1;
                let status = match wait_with_deadline(&mut workers[id].child, deadline) {
                    Ok(status) => status,
                    Err(e) => {
                        let shard = workers[id].shard;
                        kill_all(&mut workers);
                        return Err(e.context(format!(
                            "waiting for {label} shard {}/{shards}",
                            shard + 1
                        )));
                    }
                };
                let orphans: Vec<usize> = workers[id]
                    .assigned
                    .iter()
                    .copied()
                    .filter(|&i| slots[i].is_none())
                    .collect();
                // A child counts as healthy only if it finished its
                // protocol cleanly.
                let healthy =
                    workers[id].done && workers[id].failure.is_none() && status.success();
                if healthy {
                    continue;
                }
                let shard = workers[id].shard;
                let failed = workers[id].failure.is_some() || !status.success();
                let msg = workers[id].failure.clone().unwrap_or_else(|| {
                    if !status.success() {
                        format!("exited with {status} before finishing its cells")
                    } else {
                        "closed stdout before finishing its cells".to_string()
                    }
                });
                // retries == 0 is the documented fail-fast mode: any
                // observed failure surfaces immediately, even one that
                // orphaned no cells.
                if failed && opts.retries == 0 {
                    kill_all(&mut workers);
                    bail!("{label} shard {}/{shards} failed: {msg}", shard + 1);
                }
                if orphans.is_empty() {
                    // a death that orphaned nothing: every assigned result
                    // already streamed and was index-verified, so there is
                    // nothing to re-steal — keep the results, note the loss
                    if failed {
                        eprintln!(
                            "{label} shard {}/{shards}: {msg}; all its cells were already \
                             reported, nothing to re-steal",
                            shard + 1
                        );
                    }
                    continue;
                }
                if attempts[shard] < opts.retries {
                    attempts[shard] += 1;
                    eprintln!(
                        "{label} shard {}/{shards}: {msg}; re-stealing {} unfinished cell(s) \
                         onto a fresh worker (attempt {}/{})",
                        shard + 1,
                        orphans.len(),
                        attempts[shard],
                        opts.retries
                    );
                    let mode = vec![
                        "--steal-cells".to_string(),
                        orphans
                            .iter()
                            .map(usize::to_string)
                            .collect::<Vec<_>>()
                            .join(","),
                    ];
                    let id = workers.len();
                    match spawn(id, shard, &mode, orphans, &tx) {
                        Ok(w) => {
                            workers.push(w);
                            live += 1;
                        }
                        Err(e) => {
                            kill_all(&mut workers);
                            return Err(e.context("respawning a steal-worker"));
                        }
                    }
                } else {
                    kill_all(&mut workers);
                    if opts.retries == 0 {
                        bail!("{label} shard {}/{shards} failed: {msg}", shard + 1);
                    }
                    bail!(
                        "{label} shard {}/{shards} failed after {} re-steal attempt(s): {msg} \
                         ({} cell(s) unfinished, first: {})",
                        shard + 1,
                        attempts[shard],
                        orphans.len(),
                        driver.describe(orphans[0])
                    );
                }
            }
        }
    }

    let mut out = Vec::with_capacity(total);
    for (i, slot) in slots.into_iter().enumerate() {
        out.push(slot.with_context(|| {
            format!(
                "sharded {label} finished without a result for {}",
                driver.describe(i)
            )
        })?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_arg_parses_one_based() {
        assert_eq!(parse_shard_arg("1/4").unwrap(), (0, 4));
        assert_eq!(parse_shard_arg("4/4").unwrap(), (3, 4));
        assert!(parse_shard_arg("0/4").is_err());
        assert!(parse_shard_arg("5/4").is_err());
        assert!(parse_shard_arg("x/4").is_err());
        assert!(parse_shard_arg("2").is_err());
    }

    #[test]
    fn cell_lists_parse_and_reject_garbage() {
        assert_eq!(parse_cell_list("3, 7,11").unwrap(), vec![3, 7, 11]);
        assert!(parse_cell_list("").is_err());
        assert!(parse_cell_list("1,x").is_err());
    }

    struct NoCells;
    impl ShardDriver for NoCells {
        type Item = ();
        fn total(&self) -> usize {
            3
        }
        fn describe(&self, index: usize) -> String {
            format!("cell {index}")
        }
        fn child_args(&self) -> Vec<String> {
            vec!["noop".to_string()]
        }
        fn parse_cell(&self, _doc: &Json) -> Result<(usize, ())> {
            bail!("no cell payloads in this test driver")
        }
    }

    #[test]
    fn control_lines_roundtrip() {
        let d = NoCells;
        match parse_line(&d, &done_line(1, 9)).unwrap() {
            ShardLine::Done { shard, cells } => assert_eq!((shard, cells), (1, 9)),
            other => panic!("wrong line kind: {other:?}"),
        }
        match parse_line(&d, &error_line("boom: cell 3")).unwrap() {
            ShardLine::Error { message } => assert!(message.contains("boom")),
            other => panic!("wrong line kind: {other:?}"),
        }
        assert!(parse_line(&d, "not json").is_err());
        assert!(parse_line(&d, "{\"type\":\"wat\"}").is_err());
    }
}
