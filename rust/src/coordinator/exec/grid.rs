//! Grid layer of the execution engine: a cell grid generic over the cell
//! payload, owning index assignment, identity hashing and shard striding.
//!
//! A [`Grid`] is an ordered list of cells; the *position* of a cell in
//! that list is its **global index**, the one identity that survives
//! worker pools, child processes and report artifacts. Everything above
//! this layer (the pool, the shard protocol, artifact merge) speaks in
//! global indices; everything below it (the cell payload) is opaque to
//! the engine except for the two hooks of [`GridCell`]:
//!
//! * [`GridCell::describe`] — the human-readable identity used in every
//!   error message ("which cell failed?");
//! * [`GridCell::write_identity`] — the byte-stream identity folded into
//!   the [`Grid::identity_hash`] that artifact merge uses to refuse shard reports
//!   from different grids ([`super::artifact`]).

use anyhow::Context;

/// A cell payload the execution engine can schedule, name and hash.
pub trait GridCell: Clone + Send + Sync {
    /// Human-readable identity of the cell at `index`, used in error
    /// contexts ("sweep cell 3 (abilene seed 2 algo sgp …)").
    fn describe(&self, index: usize) -> String;

    /// Feed the cell's result-relevant identity into the grid hash. Two
    /// cells that can produce different results must write different
    /// byte streams.
    fn write_identity(&self, h: &mut GridHasher);
}

/// Incremental FNV-1a over byte streams — the deterministic, dependency-
/// free identity hash behind [`Grid::identity_hash`] and the sweep's
/// `spec_grid_hash`.
#[derive(Clone, Debug)]
pub struct GridHasher {
    h: u64,
}

impl GridHasher {
    pub fn new() -> GridHasher {
        GridHasher {
            h: 0xcbf2_9ce4_8422_2325,
        }
    }

    /// Fold `bytes` into the running hash.
    pub fn eat(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.h ^= b as u64;
            self.h = self.h.wrapping_mul(0x100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.h
    }
}

impl Default for GridHasher {
    fn default() -> Self {
        GridHasher::new()
    }
}

/// An indexed cell grid: the canonical cell order plus the operations the
/// engine layers need (striding, subsetting, identity hashing).
#[derive(Clone, Debug)]
pub struct Grid<C: GridCell> {
    cells: Vec<C>,
}

impl<C: GridCell> Grid<C> {
    /// Wrap a cell list; the list order becomes the global index order.
    pub fn new(cells: Vec<C>) -> Grid<C> {
        Grid { cells }
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    pub fn cells(&self) -> &[C] {
        &self.cells
    }

    pub fn get(&self, index: usize) -> Option<&C> {
        self.cells.get(index)
    }

    /// Human-readable identity of cell `index` (see
    /// [`GridCell::describe`]); a placeholder for out-of-range indices so
    /// error paths never panic.
    pub fn describe(&self, index: usize) -> String {
        match self.cells.get(index) {
            Some(c) => c.describe(index),
            None => format!("cell {index} (outside this {}-cell grid)", self.len()),
        }
    }

    /// Every cell tagged with its global index — the work list the pool
    /// layer consumes.
    pub fn indexed(&self) -> Vec<(usize, C)> {
        self.cells.iter().cloned().enumerate().collect()
    }

    /// The indexed cells owned by shard `shard` (0-based) of `count`: the
    /// strided subset of [`shard_indices`].
    pub fn shard(&self, shard: usize, count: usize) -> Vec<(usize, C)> {
        shard_indices(self.len(), shard, count)
            .into_iter()
            .map(|i| (i, self.cells[i].clone()))
            .collect()
    }

    /// An explicit indexed subset — the work list of a steal-worker
    /// re-running another shard's unfinished cells. Out-of-range indices
    /// are an error (the caller's cell list came from a different grid).
    pub fn subset(&self, indices: &[usize]) -> anyhow::Result<Vec<(usize, C)>> {
        indices
            .iter()
            .map(|&i| {
                let cell = self.cells.get(i).cloned().with_context(|| {
                    format!("cell index {i} outside this {}-cell grid", self.len())
                })?;
                Ok((i, cell))
            })
            .collect()
    }

    /// Deterministic identity of the grid: FNV-1a over every cell's
    /// [`GridCell::write_identity`] stream, then over whatever extra
    /// result-relevant spec bytes `tail` appends (stopping rule, rate
    /// scale, …). Stamped into report artifacts so merge can refuse
    /// shards of different grids.
    pub fn identity_hash(&self, tail: impl FnOnce(&mut GridHasher)) -> u64 {
        let mut h = GridHasher::new();
        for cell in &self.cells {
            cell.write_identity(&mut h);
        }
        tail(&mut h);
        h.finish()
    }
}

/// Global cell indices owned by shard `shard` (0-based) of `count`: the
/// strided set `{shard, shard+count, shard+2·count, …}`. Striding
/// balances expensive scenarios (grid order keeps one scenario's cells
/// adjacent) across shards.
pub fn shard_indices(total: usize, shard: usize, count: usize) -> Vec<usize> {
    (shard..total).step_by(count.max(1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Debug, PartialEq)]
    struct TestCell(u64);

    impl GridCell for TestCell {
        fn describe(&self, index: usize) -> String {
            format!("test cell {index} (payload {})", self.0)
        }
        fn write_identity(&self, h: &mut GridHasher) {
            h.eat(&self.0.to_le_bytes());
        }
    }

    fn grid(n: u64) -> Grid<TestCell> {
        Grid::new((0..n).map(TestCell).collect())
    }

    #[test]
    fn shard_indices_partition_the_grid() {
        for count in [1usize, 2, 3, 4, 7] {
            let mut seen = vec![false; 10];
            for shard in 0..count {
                for i in shard_indices(10, shard, count) {
                    assert!(!seen[i], "index {i} assigned twice (count {count})");
                    seen[i] = true;
                    assert_eq!(i % count, shard, "striding violated");
                }
            }
            assert!(seen.iter().all(|&s| s), "indices dropped (count {count})");
        }
    }

    #[test]
    fn grid_shard_and_subset_agree_with_the_index_math() {
        let g = grid(10);
        let mine = g.shard(1, 3);
        assert_eq!(
            mine.iter().map(|(i, _)| *i).collect::<Vec<_>>(),
            vec![1, 4, 7]
        );
        for (i, c) in &mine {
            assert_eq!(c.0, *i as u64, "payload drifted from its index");
        }
        let sub = g.subset(&[7, 2]).unwrap();
        assert_eq!(sub[0], (7, TestCell(7)));
        assert_eq!(sub[1], (2, TestCell(2)));
        let err = g.subset(&[10]).unwrap_err().to_string();
        assert!(err.contains("10"), "{err}");
    }

    #[test]
    fn hash_separates_grids_and_tails() {
        let tail_a = |h: &mut GridHasher| h.eat(&1.0f64.to_bits().to_le_bytes());
        let tail_b = |h: &mut GridHasher| h.eat(&2.0f64.to_bits().to_le_bytes());
        assert_eq!(grid(4).identity_hash(tail_a), grid(4).identity_hash(tail_a));
        assert_ne!(grid(4).identity_hash(tail_a), grid(5).identity_hash(tail_a));
        assert_ne!(grid(4).identity_hash(tail_a), grid(4).identity_hash(tail_b));
    }

    #[test]
    fn describe_never_panics_out_of_range() {
        let g = grid(2);
        assert!(g.describe(0).contains("payload 0"));
        assert!(g.describe(9).contains("outside"));
    }
}
