//! Optimization loop driver: runs an optimizer to steady state, captures
//! the cost/residual trajectory, and detects convergence.

use std::time::Instant;

use anyhow::Result;

use crate::algo::{OptWorkspace, Optimizer, Sgp};
use crate::model::network::Network;
use crate::model::strategy::Strategy;
use crate::runtime::DenseBackend;

/// Stopping rule for optimization runs.
#[derive(Clone, Copy, Debug)]
pub struct RunConfig {
    pub max_iters: usize,
    /// Converged when the relative cost drop over `patience` iterations
    /// falls below `tol`.
    pub tol: f64,
    pub patience: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            max_iters: 300,
            tol: 1e-7,
            patience: 5,
        }
    }
}

impl RunConfig {
    pub fn quick() -> Self {
        RunConfig {
            max_iters: 80,
            tol: 1e-5,
            patience: 3,
        }
    }

    /// Fewest iterations a run can take while still attesting
    /// convergence: the trailing window must fill before the convergence
    /// check may fire. A dynamic epoch whose pattern did not change
    /// re-converges in exactly this many iterations (pinned by
    /// `rust/tests/adaptive_runner.rs`).
    pub fn min_iters_to_converge(&self) -> usize {
        self.patience + 1
    }
}

/// Result of one optimization run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub algorithm: String,
    /// Cost after each iteration (index 0 = after first step).
    pub costs: Vec<f64>,
    /// Theorem-1 residual after each iteration.
    pub residuals: Vec<f64>,
    /// First iteration (1-based) within 1% of the final cost.
    pub iters_to_1pct: usize,
    pub wall_seconds: f64,
    pub phi: Strategy,
}

impl RunResult {
    pub fn final_cost(&self) -> f64 {
        *self.costs.last().expect("empty run")
    }

    pub fn final_residual(&self) -> f64 {
        *self.residuals.last().expect("empty run")
    }

    fn finish(
        algorithm: &str,
        costs: Vec<f64>,
        residuals: Vec<f64>,
        wall: f64,
        phi: Strategy,
    ) -> RunResult {
        assert!(!costs.is_empty(), "empty run");
        let iters_to_1pct = super::metrics::iters_to_1pct(&costs);
        RunResult {
            algorithm: algorithm.to_string(),
            costs,
            residuals,
            iters_to_1pct,
            wall_seconds: wall,
            phi,
        }
    }
}

/// Converged when the relative cost drop over the trailing `patience`
/// window falls below `tol` — but only a fully *finite* window counts: a
/// saturated (`+∞`) or otherwise non-finite iteration inside the window
/// can never attest a steady state (`∞ − ∞ = NaN` compares false, but an
/// all-`∞` plateau would compare "stable" under a naive equality check).
fn converged(costs: &[f64], cfg: &RunConfig) -> bool {
    if costs.len() < cfg.patience + 1 {
        return false;
    }
    let window = &costs[costs.len() - 1 - cfg.patience..];
    if window.iter().any(|c| !c.is_finite()) {
        return false;
    }
    let now = window[window.len() - 1];
    let then = window[0];
    (then - now).abs() <= cfg.tol * then.abs().max(1e-12)
}

/// Record one iteration's stats: residuals of saturated iterations can
/// come out NaN (∞ marginals feeding the complementarity products); they
/// are stored as `+∞` so `final_residual` is never NaN.
fn record(costs: &mut Vec<f64>, residuals: &mut Vec<f64>, st: &crate::algo::IterationStats) {
    costs.push(st.total_cost);
    residuals.push(if st.residual.is_nan() {
        f64::INFINITY
    } else {
        st.residual
    });
}

/// Run any [`Optimizer`] to steady state (native evaluation).
/// Allocates a run-local workspace; use [`optimize_ws`] to reuse one
/// across runs (sweep cells, dynamic epochs).
pub fn optimize(
    net: &Network,
    opt: &mut dyn Optimizer,
    phi0: &Strategy,
    cfg: &RunConfig,
) -> Result<RunResult> {
    let mut ws = OptWorkspace::new();
    optimize_ws(net, opt, phi0, cfg, &mut ws)
}

/// [`optimize`] with a caller-owned [`OptWorkspace`], reused across every
/// iteration (and across calls) — the optimizer hot path allocates
/// nothing once the workspace is warm. Identical results to `optimize`.
pub fn optimize_ws(
    net: &Network,
    opt: &mut dyn Optimizer,
    phi0: &Strategy,
    cfg: &RunConfig,
    ws: &mut OptWorkspace,
) -> Result<RunResult> {
    let mut phi = phi0.clone();
    let mut costs = Vec::new();
    let mut residuals = Vec::new();
    let start = Instant::now();
    for _ in 0..cfg.max_iters {
        let st = opt.step_ws(net, &mut phi, ws)?;
        record(&mut costs, &mut residuals, &st);
        if converged(&costs, cfg) {
            break;
        }
    }
    Ok(RunResult::finish(
        opt.name(),
        costs,
        residuals,
        start.elapsed().as_secs_f64(),
        phi,
    ))
}

/// Run SGP with flows/marginals evaluated by a pluggable dense backend
/// (the native f64 evaluator by default; the PJRT/XLA engine behind the
/// `pjrt` feature). Sweep cells with `backend: native|pjrt` route here
/// via [`super::run_algorithm_with_backend`], so a sweep grid can price
/// the batched `Sgp::step_dense` ladder next to the sparse path —
/// `rust/tests/sweep_shard.rs` pins that a native-routed cell is bitwise
/// this function's result.
pub fn optimize_accelerated(
    net: &Network,
    sgp: &mut Sgp,
    phi0: &Strategy,
    cfg: &RunConfig,
    evaluator: &dyn DenseBackend,
) -> Result<RunResult> {
    let mut ws = OptWorkspace::new();
    optimize_accelerated_ws(net, sgp, phi0, cfg, evaluator, &mut ws)
}

/// [`optimize_accelerated`] with a caller-owned [`OptWorkspace`] (shared
/// QP buffers and pooled ladder candidates across iterations). Identical
/// results.
pub fn optimize_accelerated_ws(
    net: &Network,
    sgp: &mut Sgp,
    phi0: &Strategy,
    cfg: &RunConfig,
    evaluator: &dyn DenseBackend,
    ws: &mut OptWorkspace,
) -> Result<RunResult> {
    let mut phi = phi0.clone();
    let mut costs = Vec::new();
    let mut residuals = Vec::new();
    let start = Instant::now();
    for _ in 0..cfg.max_iters {
        let st = sgp.step_dense_ws(net, &mut phi, evaluator, ws)?;
        record(&mut costs, &mut residuals, &st);
        if converged(&costs, cfg) {
            break;
        }
    }
    let label = format!("sgp-{}", evaluator.name());
    Ok(RunResult::finish(
        &label,
        costs,
        residuals,
        start.elapsed().as_secs_f64(),
        phi,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Gp, Sgp};
    use crate::model::network::testnet::diamond;

    #[test]
    fn optimize_runs_to_convergence() {
        let net = diamond(true);
        let phi0 = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        let res = optimize(&net, &mut sgp, &phi0, &RunConfig::default()).unwrap();
        assert!(res.final_cost().is_finite());
        assert!(res.costs.len() >= 2);
        assert!(res.final_residual() < 1e-5, "residual {}", res.final_residual());
        // monotone
        for w in res.costs.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
    }

    #[test]
    fn convergence_detection_stops_early() {
        let net = diamond(true);
        let phi0 = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        let cfg = RunConfig {
            max_iters: 500,
            tol: 1e-6,
            patience: 4,
        };
        let res = optimize(&net, &mut sgp, &phi0, &cfg).unwrap();
        assert!(res.costs.len() < 500, "never detected convergence");
    }

    #[test]
    fn accelerated_with_native_backend_descends_and_labels() {
        use crate::runtime::NativeBackend;
        let net = diamond(true);
        let phi0 = Strategy::local_compute_init(&net);
        let mut sgp = Sgp::new();
        let res =
            optimize_accelerated(&net, &mut sgp, &phi0, &RunConfig::quick(), &NativeBackend)
                .unwrap();
        assert_eq!(res.algorithm, "sgp-native");
        assert!(res.final_cost().is_finite());
        for w in res.costs.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-5),
                "dense-backend cost increased: {} -> {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn converged_ignores_nonfinite_windows() {
        let cfg = RunConfig {
            max_iters: 100,
            tol: 1e-6,
            patience: 3,
        };
        let inf = f64::INFINITY;
        // a flat saturated plateau is NOT convergence
        assert!(!converged(&[inf, inf, inf, inf, inf], &cfg));
        // ∞ anywhere inside the trailing window blocks convergence
        assert!(!converged(&[10.0, 10.0, inf, 10.0, 10.0], &cfg));
        assert!(!converged(&[10.0, 10.0, f64::NAN, 10.0, 10.0], &cfg));
        // ∞ *before* the window is forgotten once a finite window stabilizes
        assert!(converged(&[inf, 10.0, 10.0, 10.0, 10.0], &cfg));
        // ordinary finite behaviour unchanged
        assert!(converged(&[12.0, 10.0, 10.0, 10.0, 10.0], &cfg));
        assert!(!converged(&[12.0, 11.0, 10.5, 10.2, 10.0], &cfg));
        assert!(!converged(&[10.0, 10.0], &cfg)); // shorter than window
    }

    /// Optimizer stub: saturated (∞ cost, NaN residual) for the first
    /// `sat` iterations, then a geometric descent to 10.
    struct Saturating {
        sat: usize,
        t: usize,
    }

    impl crate::algo::Optimizer for Saturating {
        fn name(&self) -> &'static str {
            "saturating-stub"
        }

        fn step(
            &mut self,
            _net: &crate::model::network::Network,
            _phi: &mut Strategy,
        ) -> anyhow::Result<crate::algo::IterationStats> {
            self.t += 1;
            if self.t <= self.sat {
                Ok(crate::algo::IterationStats {
                    total_cost: f64::INFINITY,
                    residual: f64::NAN,
                })
            } else {
                let k = (self.t - self.sat) as i32;
                Ok(crate::algo::IterationStats {
                    total_cost: 10.0 + 2.0f64.powi(-k),
                    residual: 2.0f64.powi(-k),
                })
            }
        }
    }

    #[test]
    fn saturated_iterations_never_fake_convergence_or_nan_residual() {
        let net = diamond(true);
        let phi0 = Strategy::local_compute_init(&net);
        let cfg = RunConfig {
            max_iters: 60,
            tol: 1e-9,
            patience: 3,
        };
        let mut opt = Saturating { sat: 8, t: 0 };
        let res = optimize(&net, &mut opt, &phi0, &cfg).unwrap();
        // must run past the 8 saturated iterations (patience is 3: a naive
        // window check would have "converged" on the ∞ plateau)
        assert!(res.costs.len() > 8, "stopped at {}", res.costs.len());
        assert!(res.final_cost().is_finite());
        assert!(!res.final_residual().is_nan());
        // no recorded residual is NaN (saturated ones are stored as +∞)
        assert!(res.residuals.iter().all(|r| !r.is_nan()));
        // iters-to-1% must not be iteration 1 via `x <= ∞`
        assert!(res.iters_to_1pct > 8, "iters_to_1pct {}", res.iters_to_1pct);
    }

    #[test]
    fn iters_to_1pct_sane() {
        let net = diamond(true);
        let phi0 = Strategy::local_compute_init(&net);
        let mut gp = Gp::new(1.0);
        let res = optimize(&net, &mut gp, &phi0, &RunConfig::quick()).unwrap();
        assert!(res.iters_to_1pct >= 1);
        assert!(res.iters_to_1pct <= res.costs.len());
    }
}
