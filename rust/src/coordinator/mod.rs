//! The coordination layer: scenario construction (Table II), optimization
//! loop driving, parallel scenario sweeps, metrics, reporting, and
//! experiment configuration — the pieces `main.rs`, the examples and
//! every bench build on.

pub mod config;
pub mod metrics;
pub mod report;
pub mod runner;
pub mod scenario;
pub mod sweep;

use anyhow::{Context, Result};

use crate::algo::{lcor_optimizer, spoo_optimizer, Gp, Lpr, Sgp};
use crate::model::flows::compute_flows;
use crate::model::network::Network;
use crate::model::strategy::Strategy;

pub use config::{Algorithm, ExperimentConfig, Schedule};
pub use runner::{optimize, optimize_accelerated, RunConfig, RunResult};
pub use scenario::{connected_er_servers, CostKind, Scenario, ScenarioSpec};
pub use sweep::{run_sweep, CellResult, GroupSummary, SweepCell, SweepReport, SweepSpec};

/// Unified outcome across iterative algorithms and the one-shot LPR.
#[derive(Clone, Debug)]
pub struct AlgoOutcome {
    pub algorithm: String,
    pub final_cost: f64,
    /// Iterations run (1 for LPR).
    pub iterations: usize,
    /// Cost trajectory (single entry for LPR).
    pub costs: Vec<f64>,
    pub l_data: f64,
    pub l_result: f64,
    pub wall_seconds: f64,
}

/// Run one algorithm on a network to steady state and collect the §V
/// metrics. This is the single entry point the Fig. 4 / 5c / 5d benches
/// loop over.
pub fn run_algorithm(net: &Network, algo: Algorithm, cfg: &RunConfig) -> Result<AlgoOutcome> {
    match algo {
        Algorithm::Lpr => {
            let start = std::time::Instant::now();
            let sol = Lpr::default().solve(net);
            Ok(AlgoOutcome {
                algorithm: "lpr".into(),
                final_cost: sol.total_cost,
                iterations: 1,
                costs: vec![sol.total_cost],
                l_data: sol.l_data,
                l_result: sol.l_result,
                wall_seconds: start.elapsed().as_secs_f64(),
            })
        }
        Algorithm::Sgp | Algorithm::Gp => {
            let phi0 = Strategy::local_compute_init(net);
            let res = match algo {
                Algorithm::Sgp => {
                    let mut opt = Sgp::new();
                    optimize(net, &mut opt, &phi0, cfg)?
                }
                _ => {
                    let mut opt = Gp::new(1.0);
                    optimize(net, &mut opt, &phi0, cfg)?
                }
            };
            finish_iterative(net, res)
        }
        Algorithm::Spoo => {
            let (mut opt, phi0) = spoo_optimizer(net);
            let res = optimize(net, &mut opt, &phi0, cfg)?;
            finish_iterative_named(net, res, "spoo")
        }
        Algorithm::Lcor => {
            let (mut opt, phi0) = lcor_optimizer(net);
            let res = optimize(net, &mut opt, &phi0, cfg)?;
            finish_iterative_named(net, res, "lcor")
        }
    }
}

fn finish_iterative(net: &Network, res: RunResult) -> Result<AlgoOutcome> {
    let name = res.algorithm.clone();
    finish_iterative_named(net, res, &name)
}

fn finish_iterative_named(net: &Network, res: RunResult, name: &str) -> Result<AlgoOutcome> {
    let flows = compute_flows(net, &res.phi)
        .context("evaluating final strategy")?;
    let td = metrics::travel_distance(net, &flows);
    Ok(AlgoOutcome {
        algorithm: name.to_string(),
        final_cost: res.final_cost(),
        iterations: res.costs.len(),
        costs: res.costs,
        l_data: td.l_data,
        l_result: td.l_result,
        wall_seconds: res.wall_seconds,
    })
}

/// Build the network for a named scenario, applying the rate scale.
pub fn build_scenario_network(name: &str, seed: u64, rate_scale: f64) -> Result<Network> {
    let spec = ScenarioSpec::by_name(name)
        .with_context(|| format!("unknown scenario '{name}'"))?;
    let mut sc = spec.build(seed);
    if (rate_scale - 1.0).abs() > 1e-12 {
        sc.net.scale_rates(rate_scale);
    }
    Ok(sc.net)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_algorithms_on_abilene() {
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let cfg = RunConfig::quick();
        let mut costs = std::collections::BTreeMap::new();
        for &algo in Algorithm::all() {
            let out = run_algorithm(&net, algo, &cfg).unwrap();
            assert!(
                out.final_cost.is_finite() || algo == Algorithm::Lpr,
                "{:?} infinite",
                algo
            );
            costs.insert(out.algorithm.clone(), out.final_cost);
        }
        // the headline claim of Fig. 4: SGP is the best of the bunch
        let sgp = costs["sgp"];
        for (name, &c) in &costs {
            assert!(
                sgp <= c + 1e-6,
                "SGP ({sgp}) beaten by {name} ({c})"
            );
        }
    }

    #[test]
    fn rate_scale_applied() {
        let a = build_scenario_network("abilene", 3, 1.0).unwrap();
        let b = build_scenario_network("abilene", 3, 2.0).unwrap();
        assert!((b.task_input(0) - 2.0 * a.task_input(0)).abs() < 1e-9);
    }

    #[test]
    fn unknown_scenario_rejected() {
        assert!(build_scenario_network("zzz", 1, 1.0).is_err());
    }
}
