//! The coordination layer: scenario construction (Table II), optimization
//! loop driving, the layered grid-execution engine ([`exec`]), parallel
//! scenario sweeps, metrics, reporting, and experiment configuration —
//! the pieces `main.rs`, the examples and every bench build on.

pub mod config;
pub mod dynamics;
pub mod exec;
pub mod metrics;
pub mod report;
pub mod runner;
pub mod scenario;
pub mod store;
pub mod sweep;
pub mod sweep_report;

use anyhow::{Context, Result};

use crate::algo::{lcor_optimizer, spoo_optimizer, Gp, Lpr, OptWorkspace, Sgp};
use crate::model::flows::compute_flows;
use crate::model::network::Network;
use crate::model::strategy::Strategy;

pub use config::{Algorithm, CellBackend, ExperimentConfig, Schedule};
pub use dynamics::{
    AdaptiveRunner, DynamicCell, DynamicSpec, DynamicTrace, EpochTrace, PatternSchedule,
    ScheduleKind,
};
pub use runner::{
    optimize, optimize_accelerated, optimize_accelerated_ws, optimize_ws, RunConfig, RunResult,
};
pub use scenario::{connected_er_servers, CostKind, Scenario, ScenarioSpec};
pub use store::{FsStore, MemStore, StoredRun, StrategyStore};
pub use sweep::{
    run_sweep, run_sweep_shard, run_sweep_sharded, CellCache, CellDivergence, CellResult,
    CellSim, GroupSummary, ShardOptions, SimSweepConfig, SweepCell, SweepReport, SweepSpec,
};

/// Unified outcome across iterative algorithms and the one-shot LPR.
#[derive(Clone, Debug)]
pub struct AlgoOutcome {
    pub algorithm: String,
    pub final_cost: f64,
    /// Iterations run (1 for LPR).
    pub iterations: usize,
    /// Cost trajectory (single entry for LPR).
    pub costs: Vec<f64>,
    pub l_data: f64,
    pub l_result: f64,
    pub wall_seconds: f64,
    /// Converged routing/offloading strategy, when the algorithm produces
    /// one (iterative optimizers do; the one-shot LPR bound does not).
    /// The request-level simulator ([`crate::sim::tasks`]) consumes this.
    pub phi: Option<Strategy>,
}

/// Run one algorithm on a network to steady state and collect the §V
/// metrics. This is the single entry point the Fig. 4 / 5c / 5d benches
/// loop over. Always the shortest-path cold start — the warm variant is
/// [`run_algorithm_warm`].
pub fn run_algorithm(net: &Network, algo: Algorithm, cfg: &RunConfig) -> Result<AlgoOutcome> {
    run_algorithm_warm(net, algo, cfg, None)
}

/// [`run_algorithm`] with an optional warm start: when `warm` is given,
/// the iterative optimizers (SGP, GP) start from it instead of the
/// shortest-path cold init [`Strategy::local_compute_init`] — the
/// adaptive engine ([`dynamics`]) and the strategy store ([`store`])
/// route through here. `warm = None` is bit-for-bit [`run_algorithm`].
///
/// Warm starts are only defined for the algorithms that accept an
/// arbitrary feasible initial point ([`Algorithm::supports_warm_start`]):
/// SPOO/LCOR construct their own restricted starting points and LPR is
/// one-shot, so passing `warm` with those is an error, as is a strategy
/// whose shape does not match `net`.
pub fn run_algorithm_warm(
    net: &Network,
    algo: Algorithm,
    cfg: &RunConfig,
    warm: Option<&Strategy>,
) -> Result<AlgoOutcome> {
    let mut ws = OptWorkspace::new();
    run_algorithm_warm_ws(net, algo, cfg, warm, &mut ws)
}

/// [`run_algorithm_warm`] with a caller-owned [`OptWorkspace`]: the sweep
/// engine keeps one per cell and the adaptive engine one per run, so
/// repeated invocations reuse the optimizer scratch instead of
/// reallocating it. Results are identical to [`run_algorithm_warm`].
/// Never share one workspace across threads.
pub fn run_algorithm_warm_ws(
    net: &Network,
    algo: Algorithm,
    cfg: &RunConfig,
    warm: Option<&Strategy>,
    ws: &mut OptWorkspace,
) -> Result<AlgoOutcome> {
    if let Some(w) = warm {
        anyhow::ensure!(
            algo.supports_warm_start(),
            "{} cannot be warm-started (only sgp and gp accept an arbitrary initial point)",
            algo.name()
        );
        anyhow::ensure!(
            w.matches(net),
            "warm-start strategy shape does not match the network"
        );
    }
    match algo {
        Algorithm::Lpr => {
            let start = std::time::Instant::now();
            let sol = Lpr::default().solve(net);
            Ok(AlgoOutcome {
                algorithm: "lpr".into(),
                final_cost: sol.total_cost,
                iterations: 1,
                costs: vec![sol.total_cost],
                l_data: sol.l_data,
                l_result: sol.l_result,
                wall_seconds: start.elapsed().as_secs_f64(),
                phi: None,
            })
        }
        Algorithm::Sgp | Algorithm::Gp => {
            let phi0 = warm_or_cold(net, warm);
            let res = match algo {
                Algorithm::Sgp => {
                    let mut opt = Sgp::new();
                    runner::optimize_ws(net, &mut opt, &phi0, cfg, ws)?
                }
                _ => {
                    let mut opt = Gp::new(1.0);
                    runner::optimize_ws(net, &mut opt, &phi0, cfg, ws)?
                }
            };
            finish_iterative(net, res)
        }
        Algorithm::Spoo => {
            let (mut opt, phi0) = spoo_optimizer(net);
            let res = runner::optimize_ws(net, &mut opt, &phi0, cfg, ws)?;
            finish_iterative_named(net, res, "spoo")
        }
        Algorithm::Lcor => {
            let (mut opt, phi0) = lcor_optimizer(net);
            let res = runner::optimize_ws(net, &mut opt, &phi0, cfg, ws)?;
            finish_iterative_named(net, res, "lcor")
        }
    }
}

/// The warm-start decision point shared by every route: an explicit
/// initial strategy when one is supplied (callers have already validated
/// shape), else the paper's shortest-path cold init.
fn warm_or_cold(net: &Network, warm: Option<&Strategy>) -> Strategy {
    match warm {
        Some(w) => w.clone(),
        None => Strategy::local_compute_init(net),
    }
}

fn finish_iterative(net: &Network, res: RunResult) -> Result<AlgoOutcome> {
    let name = res.algorithm.clone();
    finish_iterative_named(net, res, &name)
}

fn finish_iterative_named(net: &Network, res: RunResult, name: &str) -> Result<AlgoOutcome> {
    let flows = compute_flows(net, &res.phi)
        .context("evaluating final strategy")?;
    let td = metrics::travel_distance(net, &flows);
    let final_cost = res.final_cost();
    Ok(AlgoOutcome {
        algorithm: name.to_string(),
        final_cost,
        iterations: res.costs.len(),
        costs: res.costs,
        l_data: td.l_data,
        l_result: td.l_result,
        wall_seconds: res.wall_seconds,
        phi: Some(res.phi),
    })
}

/// [`run_algorithm`] with an explicit dense-evaluation route for the SGP
/// run — the per-cell backend selection of [`sweep::SweepSpec`].
///
/// * [`CellBackend::Sparse`] — the plain [`run_algorithm`] path (sparse
///   Gauss–Seidel `Sgp::step` for SGP); bit-for-bit the pre-routing sweep
///   behavior.
/// * [`CellBackend::Native`] — SGP through
///   [`optimize_accelerated`] → `Sgp::step_dense` on
///   [`crate::runtime::NativeBackend`], exercising the batched safeguard
///   ladder (`evaluate_batch`).
/// * [`CellBackend::Pjrt`] — same loop on the PJRT `DenseEvaluator`
///   (errors unless built with `--features pjrt` and artifacts exist).
///
/// Non-SGP algorithms only have the sparse path; asking for a dense route
/// on them is an error (the sweep grid builder never emits such cells).
pub fn run_algorithm_with_backend(
    net: &Network,
    algo: Algorithm,
    backend: CellBackend,
    cfg: &RunConfig,
) -> Result<AlgoOutcome> {
    run_algorithm_with_backend_warm(net, algo, backend, cfg, None)
}

/// [`run_algorithm_with_backend`] with an optional warm start, covering
/// all three routes (sparse / native / pjrt) — see [`run_algorithm_warm`]
/// for the warm-start rules. `warm = None` is bit-for-bit
/// [`run_algorithm_with_backend`].
pub fn run_algorithm_with_backend_warm(
    net: &Network,
    algo: Algorithm,
    backend: CellBackend,
    cfg: &RunConfig,
    warm: Option<&Strategy>,
) -> Result<AlgoOutcome> {
    let mut ws = OptWorkspace::new();
    run_algorithm_with_backend_warm_ws(net, algo, backend, cfg, warm, &mut ws)
}

/// [`run_algorithm_with_backend_warm`] with a caller-owned
/// [`OptWorkspace`] (see [`run_algorithm_warm_ws`]). Identical results.
pub fn run_algorithm_with_backend_warm_ws(
    net: &Network,
    algo: Algorithm,
    backend: CellBackend,
    cfg: &RunConfig,
    warm: Option<&Strategy>,
    ws: &mut OptWorkspace,
) -> Result<AlgoOutcome> {
    if backend == CellBackend::Sparse {
        return run_algorithm_warm_ws(net, algo, cfg, warm, ws);
    }
    anyhow::ensure!(
        algo == Algorithm::Sgp,
        "the {} backend routes through Sgp::step_dense and is only defined for sgp (got {})",
        backend.name(),
        algo.name()
    );
    if let Some(w) = warm {
        anyhow::ensure!(
            w.matches(net),
            "warm-start strategy shape does not match the network"
        );
    }
    match backend {
        CellBackend::Native => {
            let phi0 = warm_or_cold(net, warm);
            let mut sgp = Sgp::new();
            let res = runner::optimize_accelerated_ws(
                net,
                &mut sgp,
                &phi0,
                cfg,
                &crate::runtime::NativeBackend,
                ws,
            )?;
            finish_iterative(net, res)
        }
        CellBackend::Pjrt => run_sgp_pjrt(net, cfg, warm),
        CellBackend::Sparse => unreachable!("handled above"),
    }
}

#[cfg(feature = "pjrt")]
fn run_sgp_pjrt(net: &Network, cfg: &RunConfig, warm: Option<&Strategy>) -> Result<AlgoOutcome> {
    use crate::runtime::{resolve_artifacts_dir, DenseEvaluator, Engine};
    // Engine::load compiles every size class; loading per cell keeps the
    // sweep workers independent (no shared client across threads). Cache
    // at engine level once the real xla client's thread-safety is pinned.
    let engine = Engine::load(&resolve_artifacts_dir()?)?;
    let eval = DenseEvaluator::new(&engine);
    let phi0 = warm_or_cold(net, warm);
    let mut sgp = Sgp::new();
    let res = runner::optimize_accelerated(net, &mut sgp, &phi0, cfg, &eval)?;
    finish_iterative(net, res)
}

#[cfg(not(feature = "pjrt"))]
fn run_sgp_pjrt(
    _net: &Network,
    _cfg: &RunConfig,
    _warm: Option<&Strategy>,
) -> Result<AlgoOutcome> {
    anyhow::bail!(
        "this run requested the pjrt backend, but cecflow was built without the \
         `pjrt` cargo feature — rebuild with `--features pjrt` (and run `make \
         artifacts`), or select backend `native`"
    )
}

/// Build the network for a named scenario, applying the rate scale.
pub fn build_scenario_network(name: &str, seed: u64, rate_scale: f64) -> Result<Network> {
    let spec = ScenarioSpec::by_name(name)
        .with_context(|| format!("unknown scenario '{name}'"))?;
    let mut sc = spec.build(seed);
    if (rate_scale - 1.0).abs() > 1e-12 {
        sc.net.scale_rates(rate_scale);
    }
    Ok(sc.net)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_algorithms_on_abilene() {
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let cfg = RunConfig::quick();
        let mut costs = std::collections::BTreeMap::new();
        for &algo in Algorithm::all() {
            let out = run_algorithm(&net, algo, &cfg).unwrap();
            assert!(
                out.final_cost.is_finite() || algo == Algorithm::Lpr,
                "{:?} infinite",
                algo
            );
            costs.insert(out.algorithm.clone(), out.final_cost);
        }
        // the headline claim of Fig. 4: SGP is the best of the bunch
        let sgp = costs["sgp"];
        for (name, &c) in &costs {
            assert!(
                sgp <= c + 1e-6,
                "SGP ({sgp}) beaten by {name} ({c})"
            );
        }
    }

    #[test]
    fn rate_scale_applied() {
        let a = build_scenario_network("abilene", 3, 1.0).unwrap();
        let b = build_scenario_network("abilene", 3, 2.0).unwrap();
        assert!((b.task_input(0) - 2.0 * a.task_input(0)).abs() < 1e-9);
    }

    #[test]
    fn unknown_scenario_rejected() {
        assert!(build_scenario_network("zzz", 1, 1.0).is_err());
    }

    #[test]
    fn sparse_backend_routing_is_the_plain_path() {
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let cfg = RunConfig::quick();
        let plain = run_algorithm(&net, Algorithm::Sgp, &cfg).unwrap();
        let routed =
            run_algorithm_with_backend(&net, Algorithm::Sgp, CellBackend::Sparse, &cfg).unwrap();
        assert_eq!(plain.final_cost.to_bits(), routed.final_cost.to_bits());
        assert_eq!(plain.iterations, routed.iterations);
        assert_eq!(plain.algorithm, routed.algorithm);
    }

    #[test]
    fn native_backend_routing_runs_the_dense_loop() {
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let cfg = RunConfig::quick();
        let out =
            run_algorithm_with_backend(&net, Algorithm::Sgp, CellBackend::Native, &cfg).unwrap();
        assert_eq!(out.algorithm, "sgp-native");
        assert!(out.final_cost.is_finite());
        assert!(out.iterations >= 1);
    }

    #[test]
    fn dense_backends_rejected_for_non_sgp() {
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let cfg = RunConfig::quick();
        let err = run_algorithm_with_backend(&net, Algorithm::Lpr, CellBackend::Native, &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("sgp"), "{err}");
    }

    #[test]
    fn warm_none_is_bitwise_the_cold_path() {
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let cfg = RunConfig::quick();
        for &algo in Algorithm::all() {
            let cold = run_algorithm(&net, algo, &cfg).unwrap();
            let warm = run_algorithm_warm(&net, algo, &cfg, None).unwrap();
            assert_eq!(cold.final_cost.to_bits(), warm.final_cost.to_bits());
            assert_eq!(cold.iterations, warm.iterations);
        }
    }

    #[test]
    fn warm_start_from_converged_point_reconverges_fast() {
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let cfg = RunConfig::quick();
        for algo in [Algorithm::Sgp, Algorithm::Gp] {
            let cold = run_algorithm(&net, algo, &cfg).unwrap();
            let warm =
                run_algorithm_warm(&net, algo, &cfg, cold.phi.as_ref()).unwrap();
            assert!(
                warm.iterations < cold.iterations,
                "{}: warm {} !< cold {}",
                algo.name(),
                warm.iterations,
                cold.iterations
            );
            // re-convergence stays at the cold optimum (costs are within
            // tolerance; exact-bits equality is the *store's* contract and
            // is enforced by re-pricing, not by re-running)
            let rel = (warm.final_cost - cold.final_cost).abs() / cold.final_cost.abs();
            assert!(rel < 1e-4, "{}: drifted {rel}", algo.name());
        }
    }

    #[test]
    fn warm_start_rejected_for_fixed_init_algorithms() {
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let cfg = RunConfig::quick();
        let phi = Strategy::local_compute_init(&net);
        for algo in [Algorithm::Lpr, Algorithm::Spoo, Algorithm::Lcor] {
            let err = run_algorithm_warm(&net, algo, &cfg, Some(&phi))
                .unwrap_err()
                .to_string();
            assert!(err.contains("warm"), "{err}");
        }
    }

    #[test]
    fn warm_start_rejects_shape_mismatch() {
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let other = build_scenario_network("geant", 3, 1.0).unwrap();
        let phi = Strategy::local_compute_init(&other);
        let cfg = RunConfig::quick();
        for backend in [CellBackend::Sparse, CellBackend::Native] {
            let err = run_algorithm_with_backend_warm(
                &net,
                Algorithm::Sgp,
                backend,
                &cfg,
                Some(&phi),
            )
            .unwrap_err()
            .to_string();
            assert!(err.contains("shape"), "{err}");
        }
    }

    #[test]
    fn warm_native_route_runs_the_dense_loop() {
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let cfg = RunConfig::quick();
        let cold =
            run_algorithm_with_backend(&net, Algorithm::Sgp, CellBackend::Native, &cfg).unwrap();
        let warm = run_algorithm_with_backend_warm(
            &net,
            Algorithm::Sgp,
            CellBackend::Native,
            &cfg,
            cold.phi.as_ref(),
        )
        .unwrap();
        assert_eq!(warm.algorithm, "sgp-native");
        assert!(warm.iterations < cold.iterations);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_errors_without_the_feature() {
        let net = build_scenario_network("abilene", 3, 1.0).unwrap();
        let cfg = RunConfig::quick();
        let err = run_algorithm_with_backend(&net, Algorithm::Sgp, CellBackend::Pjrt, &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
